"""End-to-end engine tests: completion, greedy correctness vs. the
non-pipelined reference, metadata reuse, SAT/TSEM toggles."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import EngineConfig, NaivePPEngine, SiPipeEngine
from repro.core.sampling_params import SamplingParams
from repro.models import ModelOptions, ShardCtx, build_model


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single())
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _reference_generate(cfg, model, params, prompts, n_new):
    """Non-pipelined greedy reference: prefill + decode loop per batch."""
    outs = []
    for prompt in prompts:
        toks = jnp.asarray([prompt], jnp.int32)
        logits, cache = jax.jit(model.prefill)(params, {"tokens": toks})
        dcache = model.init_cache(1, len(prompt) + n_new + 4)

        def pad_into(dst, src):
            if dst.shape == src.shape:
                return src
            return dst.at[tuple(slice(0, d) for d in src.shape)].set(src)

        cache = jax.tree.map(pad_into, dcache, cache)
        seq = []
        tok = int(np.asarray(logits).argmax(-1)[0])
        seq.append(tok)
        pos = len(prompt)
        for _ in range(n_new - 1):
            logits, cache = jax.jit(model.decode)(params, cache, {
                "token": jnp.asarray([tok], jnp.int32),
                "positions": jnp.asarray([pos], jnp.int32)})
            tok = int(np.asarray(logits).argmax(-1)[0])
            seq.append(tok)
            pos += 1
        outs.append(seq)
    return outs


def test_sipipe_greedy_matches_reference(model_and_params):
    """The pipelined engine with stage splitting + CPU sampling must emit
    exactly the reference greedy continuation (cache/stage correctness)."""
    cfg, model, params = model_and_params
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(2, cfg.vocab_size, size=n)) for n in (5, 9)]
    n_new = 5
    want = _reference_generate(cfg, model, params, prompts, n_new)

    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=2, max_batch=2, max_seq_len=64, n_samplers=2))
    for p in prompts:
        eng.add_request(p, SamplingParams(greedy=True, max_new_tokens=n_new))
    done = sorted(eng.run(), key=lambda s: s.seq_id)
    assert [s.output_ids for s in done] == want


def test_naive_engine_greedy_matches_reference(model_and_params):
    cfg, model, params = model_and_params
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(2, cfg.vocab_size, size=n)) for n in (4, 7)]
    want = _reference_generate(cfg, model, params, prompts, 4)
    eng = NaivePPEngine(model, params, EngineConfig(
        pp_degree=2, max_batch=2, max_seq_len=64))
    for p in prompts:
        eng.add_request(p, SamplingParams(greedy=True, max_new_tokens=4))
    done = sorted(eng.run(), key=lambda s: s.seq_id)
    assert [s.output_ids for s in done] == want


def test_engines_agree_with_each_other(model_and_params):
    cfg, model, params = model_and_params
    rng = np.random.default_rng(2)
    prompts = [list(rng.integers(2, cfg.vocab_size, size=6)) for _ in range(4)]
    results = {}
    for name, Eng in (("sipipe", SiPipeEngine), ("naive", NaivePPEngine)):
        eng = Eng(model, params, EngineConfig(pp_degree=2, max_batch=2,
                                              max_seq_len=64))
        for p in prompts:
            eng.add_request(p, SamplingParams(greedy=True, max_new_tokens=4))
        done = sorted(eng.run(), key=lambda s: s.seq_id)
        results[name] = [s.output_ids for s in done]
    assert results["sipipe"] == results["naive"]


def test_continuous_batching_backfill(model_and_params):
    """More requests than slots: finished sequences free rows for waiters."""
    cfg, model, params = model_and_params
    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=2, max_batch=2, max_seq_len=64))
    rng = np.random.default_rng(3)
    for i in range(7):
        eng.add_request(list(rng.integers(2, cfg.vocab_size, size=4)),
                        SamplingParams(greedy=True, max_new_tokens=2 + i % 3))
    done = eng.run()
    assert len(done) == 7
    for s in done:
        assert len(s.output_ids) == s.params.max_new_tokens


def test_metadata_reuse_counts(model_and_params):
    cfg, model, params = model_and_params
    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=1, max_batch=2, max_seq_len=64))
    rng = np.random.default_rng(4)
    for _ in range(2):
        eng.add_request(list(rng.integers(2, cfg.vocab_size, size=4)),
                        SamplingParams(greedy=True, max_new_tokens=6))
    eng.run()
    m = eng.metrics()
    assert m["incremental_hits"] > m["meta_rebuilds"]


def test_pp4_deeper_pipeline(model_and_params):
    cfg, model, params = model_and_params
    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=4, max_batch=1, max_seq_len=64, n_samplers=1))
    rng = np.random.default_rng(5)
    want = _reference_generate(
        cfg, model, params,
        [list(rng.integers(2, cfg.vocab_size, size=5))], 4)
    eng.add_request(list(rng.integers(2, cfg.vocab_size, size=5)),
                    SamplingParams(greedy=True, max_new_tokens=4))
    # note: different rng draw -> regenerate the same prompt
    eng2 = SiPipeEngine(model, params, EngineConfig(
        pp_degree=4, max_batch=1, max_seq_len=64, n_samplers=1))
    rng = np.random.default_rng(5)
    prompt = list(rng.integers(2, cfg.vocab_size, size=5))
    eng2.add_request(prompt, SamplingParams(greedy=True, max_new_tokens=4))
    done = eng2.run()
    assert [s.output_ids for s in done] == want
