"""Paged KV cache as the engine memory substrate (docs/memory.md):
block-budget admission, block-table execution parity vs contiguous rows,
and preemption-by-recompute under memory pressure."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import EngineConfig, SiPipeEngine
from repro.core.request import RequestState, TokenStream
from repro.core.sampling_params import SamplingParams
from repro.core.scheduler import Scheduler
from repro.core.sequence import SeqStatus, Sequence
from repro.models import ModelOptions, ShardCtx, build_model
from repro.runtime.paged_kv import BlockSpaceManager


def _model(arch="stablelm-1.6b-smoke", kv_quant=False, key=0):
    cfg = get_config(arch)
    model = build_model(cfg, ShardCtx.single(), ModelOptions(kv_quant=kv_quant))
    return cfg, model, model.init(jax.random.key(key))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
            for n in lens]


def _run(model, params, prompts, n_new, *, policy="chunked", chunk=6,
         layout="paged", pp=2, max_batch=2, max_seq_len=64, block_size=8,
         kv_blocks=None, tpot_slo_s=None):
    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=pp, max_batch=max_batch, max_seq_len=max_seq_len,
        n_samplers=2, prefill_chunk_tokens=chunk, scheduling_policy=policy,
        tpot_slo_s=tpot_slo_s, kv_layout=layout, kv_block_size=block_size,
        kv_blocks=kv_blocks))
    for p in prompts:
        eng.add_request(p, SamplingParams(greedy=True, max_new_tokens=n_new))
    done = sorted(eng.run(), key=lambda s: s.seq_id)
    assert len(done) == len(prompts)
    return [s.output_ids for s in done], eng.metrics()


# ---------------------------------------------------------------------------
# Config validation
# ---------------------------------------------------------------------------

def test_kv_layout_validation():
    cfg, model, params = _model()
    with pytest.raises(ValueError, match="kv_layout"):
        SiPipeEngine(model, params, EngineConfig(kv_layout="virtual"))
    # the pool must hold at least one max-length sequence, else
    # preemption could never free enough to make progress
    with pytest.raises(ValueError, match="max_seq_len"):
        SiPipeEngine(model, params, EngineConfig(
            kv_layout="paged", kv_block_size=8, kv_blocks=2,
            max_seq_len=64))


def test_default_pool_rounds_per_sequence_up():
    """The equal-budget default sizes the pool by CEIL per sequence: a
    max_seq_len that is not a block multiple must still construct and
    hold one worst-case sequence per contiguous-row equivalent."""
    cfg, model, params = _model()
    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=1, max_batch=1, max_seq_len=60, kv_layout="paged",
        kv_block_size=16))
    assert eng.kv_manager.n_blocks == eng.kv_manager.blocks_for(60) == 4
    eng.shutdown()


def test_window_must_be_block_multiple():
    cfg, model, params = _model("mixtral-8x7b-smoke")   # window 32
    with pytest.raises(ValueError, match="divide the sliding window"):
        SiPipeEngine(model, params, EngineConfig(
            kv_layout="paged", kv_block_size=7, max_seq_len=64))


def test_paged_rejects_families_without_slot_cache():
    cfg = get_config("xlstm-1.3b-smoke")
    model = build_model(cfg, ShardCtx.single())
    params = model.init(jax.random.key(0))
    with pytest.raises(NotImplementedError, match="paged"):
        SiPipeEngine(model, params, EngineConfig(kv_layout="paged"))


# ---------------------------------------------------------------------------
# Fast parity pin: paged == contiguous, greedy-token-identical
# ---------------------------------------------------------------------------

def test_paged_token_identical_fast_pin():
    cfg, model, params = _model()
    prompts = _prompts(cfg, (13, 5))
    ref, _ = _run(model, params, prompts, 5, policy="monolithic",
                  chunk=None, layout="contiguous")
    mono, m1 = _run(model, params, prompts, 5, policy="monolithic",
                    chunk=None, layout="paged")
    chk, m2 = _run(model, params, prompts, 5, policy="chunked", chunk=6,
                   layout="paged")
    assert mono == ref and chk == ref
    assert m1["kv_layout"] == "paged" and m1["kv_preemptions"] == 0
    # everything released at the end of the run
    assert m1["kv_blocks_free"] == m1["kv_blocks_total"]
    assert m2["kv_blocks_free"] == m2["kv_blocks_total"]


# ---------------------------------------------------------------------------
# Policy x config parity matrix (acceptance criterion; slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch,kv_quant,key,lens", [
    ("stablelm-1.6b-smoke", False, 0, (13, 5, 9)),   # dense, full cache
    ("mixtral-8x7b-smoke", False, 3, (13, 13)),      # moe, sliding window
    ("stablelm-1.6b-smoke", True, 4, (11, 5)),       # int8 KV cache
])
def test_paged_parity_matrix(arch, kv_quant, key, lens):
    """Across every scheduling policy and cache config, the paged layout
    must be greedy-token-identical to contiguous rows."""
    cfg, model, params = _model(arch, kv_quant, key)
    prompts = _prompts(cfg, lens, seed=key)
    ref, _ = _run(model, params, prompts, 4, policy="monolithic",
                  chunk=None, layout="contiguous")
    for policy, chunk in (("monolithic", None), ("chunked", 6),
                          ("disaggregated", 6), ("adaptive", 6)):
        got, m = _run(model, params, prompts, 4, policy=policy, chunk=chunk,
                      layout="paged")
        assert got == ref, (arch, policy)
        assert m["kv_blocks_free"] == m["kv_blocks_total"]


# ---------------------------------------------------------------------------
# Preemption-by-recompute under memory pressure (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,chunk", [("chunked", 8), ("monolithic", None)])
def test_preempt_resume_bit_exact(policy, chunk):
    """A block pool too small for every sequence's decode growth forces
    preemption; survivors AND preempted sequences must finish with outputs
    bit-exact vs an unpressured contiguous run."""
    cfg, model, params = _model()
    prompts = _prompts(cfg, (20, 16, 12, 9), seed=7)
    ref, _ = _run(model, params, prompts, 12, policy=policy, chunk=chunk,
                  layout="contiguous", max_seq_len=48)
    got, m = _run(model, params, prompts, 12, policy=policy, chunk=chunk,
                  layout="paged", block_size=4, kv_blocks=14, max_seq_len=48)
    assert m["kv_preemptions"] > 0
    assert got == ref
    assert m["kv_blocks_free"] == m["kv_blocks_total"]


def test_preempted_request_state_and_stream_continuity():
    """The step-level view: a preempted request passes through the
    PREEMPTED state, keeps its already-streamed tokens, and its resumed
    stream extends them (prefix chain) to the same final output."""
    cfg, model, params = _model()
    prompts = _prompts(cfg, (20, 16, 12, 9), seed=7)
    ref, _ = _run(model, params, prompts, 12, layout="contiguous",
                  max_seq_len=48, chunk=8)
    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=2, max_batch=2, max_seq_len=48, n_samplers=2,
        prefill_chunk_tokens=8, scheduling_policy="chunked",
        kv_layout="paged", kv_block_size=4, kv_blocks=14))
    rids = [eng.add_request(p, SamplingParams(greedy=True,
                                              max_new_tokens=12))
            for p in prompts]
    streamed = {r: [] for r in rids}
    saw_preempted = False
    for _ in range(10_000):
        for out in eng.step():
            assert isinstance(out.token_ids, TokenStream)
            prev = streamed[out.request_id]
            assert out.token_ids == prev + out.new_token_ids  # prefix chain
            streamed[out.request_id] = out.token_ids.to_list()
        saw_preempted = saw_preempted or any(
            q.status == SeqStatus.PREEMPTED
            for q in eng.scheduler.seqs.values())
        if not eng.has_work:
            break
    eng.shutdown()
    assert eng.scheduler.n_preemptions > 0
    assert [streamed[r] for r in rids] == ref


def test_preempted_in_queue_abort_releases_everything():
    """Aborting a request while it sits preempted in the waiting queue
    must free its blocks and never resurrect it."""
    # pool of 6 blocks x 4 slots; a finished sequence peaks at 18 tokens
    # (5 blocks), so any single sequence always fits — the engine-level
    # invariant the EngineConfig validation enforces
    s = Scheduler(max_batch=2, pp_degree=1, max_seq_len=24, token_budget=8,
                  kv_manager=BlockSpaceManager(6, 4))
    for i, pl in enumerate((8, 8, 8)):
        s.add_request(Sequence(i, list(range(1, pl + 1)), SamplingParams(
            greedy=True, max_new_tokens=10)))
    for it in range(200):
        o = s.schedule(it)
        if s.n_preemptions:
            break
        if o is None:
            continue
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, np.full(len(ids), 7, np.int32))
    assert s.n_preemptions > 0
    victim = s.waiting[0]
    assert victim.status == SeqStatus.PREEMPTED
    assert s.abort(victim.seq_id) is victim
    assert victim not in s.waiting
    assert not s.kv.has(victim.seq_id)
    # drive the rest to completion: the abort must not wedge the queue
    for it in range(200, 600):
        o = s.schedule(it)
        if o is None:
            if not s.has_work:
                break
            continue
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, np.full(len(ids), 7, np.int32))
    assert not s.has_work
    assert s.kv.free_blocks == 6


# ---------------------------------------------------------------------------
# Scheduler-level block accounting
# ---------------------------------------------------------------------------

def test_admission_is_block_budget_not_seats():
    """With seats to spare, admission still waits for blocks: the third
    prompt only enters once a finished sequence frees its blocks."""
    kv = BlockSpaceManager(5, 4)
    s = Scheduler(max_batch=4, pp_degree=1, max_seq_len=16, token_budget=8,
                  kv_manager=kv)
    for i, pl in enumerate((8, 7, 6)):
        s.add_request(Sequence(i, list(range(1, pl + 1)), SamplingParams(
            greedy=True, max_new_tokens=2)))
    blocked_admission = False
    for it in range(400):
        o = s.schedule(it)
        n_running = sum(1 for q in s.seqs.values()
                        if q.status == SeqStatus.RUNNING)
        if (s.waiting and n_running and n_running < s.max_batch
                and not kv.can_admit(s.waiting[0].length)):
            # a SEAT is free but the BLOCKS are not: under the paged
            # layout this (and only this) is what holds admission back
            blocked_admission = True
        if o is None:
            if not s.has_work:
                break
            continue
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, np.full(len(ids), 7, np.int32))
    assert blocked_admission
    assert not s.has_work and len(s.finished) == 3
    assert kv.free_blocks == 5


def test_preemption_evicts_lowest_priority_and_resumes_history():
    """The victim is the latest-arrived RUNNING sequence; it re-enters at
    the queue head with prefill_target covering its full token history."""
    kv = BlockSpaceManager(5, 4)
    s = Scheduler(max_batch=2, pp_degree=1, max_seq_len=20, token_budget=12,
                  kv_manager=kv)
    for i, pl in enumerate((8, 8)):
        s.add_request(Sequence(i, list(range(1, pl + 1)), SamplingParams(
            greedy=True, max_new_tokens=10)))
    preempted_at = None
    for it in range(400):
        o = s.schedule(it)
        if preempted_at is None and s.n_preemptions:
            head = s.waiting[0]
            assert head.seq_id == 1            # lowest priority = latest
            assert head.prefill_target == head.length
            assert head.prefilled == 0
            preempted_at = it
        if o is None:
            if not s.has_work:
                break
            continue
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, np.full(len(ids), 7, np.int32))
    assert preempted_at is not None
    assert not s.has_work and len(s.finished) == 2
    assert kv.free_blocks == 5


# ---------------------------------------------------------------------------
# TSEM staging: block tables ride the incremental n/n+p fast path
# ---------------------------------------------------------------------------

def test_paged_decode_keeps_incremental_fast_path():
    """Steady-state paged decode must still hit the TSEM incremental
    metadata update (same seq set, width 1, same table width)."""
    cfg, model, params = _model()
    prompts = _prompts(cfg, (6, 5), seed=1)
    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=2, max_batch=2, max_seq_len=64, n_samplers=2,
        kv_layout="paged", kv_block_size=32))   # 1 block covers the run
    for p in prompts:
        eng.add_request(p, SamplingParams(greedy=True, max_new_tokens=10))
    eng.run()
    m = eng.metrics()
    assert m["incremental_hits"] > 0
    assert m["kv_layout"] == "paged"


# ---------------------------------------------------------------------------
# Streaming RequestOutput: delta-only emission (satellite regression)
# ---------------------------------------------------------------------------

def test_request_output_emits_deltas_not_copies():
    """Emit cost shape: across a request's lifetime the copied elements
    are exactly its tokens (sum of deltas == total, not quadratic), and
    every cumulative view shares one backing list."""
    cfg, model, params = _model()
    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=2, max_batch=2, max_seq_len=64, n_samplers=2))
    [rid] = [eng.add_request(_prompts(cfg, (6,), seed=2)[0],
                             SamplingParams(greedy=True, max_new_tokens=12))]
    outs = []
    while eng.has_work:
        outs.extend(o for o in eng.step() if o.request_id == rid)
    eng.shutdown()
    assert outs and outs[-1].finished
    total = outs[-1].token_ids.to_list()
    assert len(total) == 12
    # delta-only: copied-token count across all emits == total tokens
    assert sum(len(o.new_token_ids) for o in outs) == len(total)
    backing = outs[0].token_ids.backing
    for o in outs:
        assert isinstance(o.token_ids, TokenStream)
        assert o.token_ids.backing is backing      # zero-copy shared view
    # views are stable snapshots: an early view must not see later tokens
    assert outs[0].token_ids.to_list() == total[:len(outs[0].token_ids)]


def test_token_stream_semantics():
    backing = [1, 2, 3]
    v = TokenStream(backing, 2)
    assert list(v) == [1, 2] and len(v) == 2 and v[-1] == 2
    assert v == [1, 2] and v != [1, 2, 3] and v == (1, 2)
    assert v + [9] == [1, 2, 9] and [0] + v == [0, 1, 2]
    assert v[0:1] == [1]
    backing.append(4)           # growth never leaks into the bounded view
    assert v.to_list() == [1, 2]
    with pytest.raises(IndexError):
        v[2]
