"""Hybrid online/offline serving (docs/hybrid.md).

The load-bearing property: the ONLINE tier's schedule is bit-identical
with and without a saturating offline backlog — offline traffic rides
only in slack (leftover seats, leftover token budget, strictly
non-evicting block admission) and is reclaimed before any online
decision would change.  Verified here as a trace property on the real
scheduler across policies, KV pressure and enlargement factors, plus
an engine-level token-stream check; the victim-ordering units live in
tests/test_priority.py and the HTTP-tier units in tests/test_admission.py.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.engine import EngineConfig, SiPipeEngine
from repro.core.policies import make_policy
from repro.core.sampling_params import SamplingParams
from repro.core.scheduler import Scheduler, SlackAccount
from repro.core.sequence import SeqStatus, Sequence
from repro.models import ModelOptions, ShardCtx, build_model
from repro.runtime.paged_kv import BlockSpaceManager

OFFLINE_BASE = 1000          # offline seq ids: disjoint from online ids


def _params(n_new, tier="online", priority=0):
    return SamplingParams(greedy=True, max_new_tokens=n_new, tier=tier,
                          priority=priority)


def _mk_sched(policy, *, max_batch, budget, kv_blocks=None, block_size=4,
              factor=1, max_seq_len=128):
    kv = (BlockSpaceManager(kv_blocks, block_size, max_slots=max_seq_len)
          if kv_blocks else None)
    return Scheduler(max_batch=max_batch, pp_degree=2,
                     max_seq_len=max_seq_len,
                     token_budget=budget if policy != "monolithic" else None,
                     policy=policy, kv_manager=kv,
                     decode_enlarge_factor=factor)


def _add_online(s, plens, n_new):
    for i, pl in enumerate(plens):
        # online token alphabet: [1, 100)
        s.add_request(Sequence(i, [1 + (j % 99) for j in range(pl)],
                               _params(n_new)))


def _add_offline(s, plens, n_new):
    for j, pl in enumerate(plens):
        # offline token alphabet: [200, 300) — disjoint, so any leak of
        # offline tokens into the online stream is visible
        s.add_request(Sequence(OFFLINE_BASE + j,
                               [200 + (k % 99) for k in range(pl)],
                               _params(n_new, tier="offline")))


def _drive_online_trace(s, max_iters=20_000):
    """Run to completion; per-iteration ONLINE sub-records keyed by
    iteration number: (online seq ids in batch order, their spans,
    sampled online ids)."""
    trace = {}
    for it in range(max_iters):
        o = s.schedule(it)
        if o is None:
            if not s.has_work:
                break
            continue
        on = [(i, sid) for i, sid in enumerate(o.seq_ids)
              if sid < OFFLINE_BASE]
        cols = o.sample_indices()
        if on:
            spans = (tuple(o.spans[i] for i, _ in on)
                     if o.spans is not None else None)
            trace[it] = (tuple(sid for _, sid in on), spans,
                         tuple(o.seq_ids[i] for i in cols
                               if o.seq_ids[i] < OFFLINE_BASE))
        ids = [o.seq_ids[i] for i in cols]
        toks = np.array([7 if sid < OFFLINE_BASE else 207 for sid in ids],
                        np.int32)
        s.complete(it, ids, toks)
    else:
        pytest.fail("scheduler did not drain")
    return trace


# ---------------------------------------------------------------------------
# THE property: online sub-trace invariance under a saturating offline queue
# ---------------------------------------------------------------------------

@settings(max_examples=12)
@given(
    policy=st.sampled_from(["monolithic", "chunked", "disaggregated"]),
    plens=st.lists(st.integers(1, 12), min_size=1, max_size=5),
    off_plens=st.lists(st.integers(1, 12), min_size=1, max_size=8),
    n_new=st.integers(1, 6),
    max_batch=st.integers(1, 3),
    budget=st.integers(4, 16),
    kv_blocks=st.sampled_from([None, 10, 16, 24]),
    factor=st.sampled_from([1, 2, 4]),
)
def test_offline_backlog_never_perturbs_online_trace(
        policy, plens, off_plens, n_new, max_batch, budget, kv_blocks,
        factor):
    """The online-only trace (batch membership, spans, sampled ids per
    iteration) is bit-identical whether or not a saturating offline
    backlog is enqueued — across policies, seat pressure, block
    pressure, and decode enlargement."""
    if policy != "disaggregated":
        factor = 1
    if kv_blocks is not None:
        # every sequence must fit (same invariant the engine enforces)
        need = -(-(max(plens + off_plens) + n_new) // 4)
        if kv_blocks < 2 * need:
            kv_blocks = 2 * need
    base = _mk_sched(policy, max_batch=max_batch, budget=budget,
                     kv_blocks=kv_blocks, factor=factor)
    _add_online(base, plens, n_new)
    ref = _drive_online_trace(base)

    hyb = _mk_sched(policy, max_batch=max_batch, budget=budget,
                    kv_blocks=kv_blocks, factor=factor)
    _add_online(hyb, plens, n_new)
    _add_offline(hyb, off_plens, n_new)
    got = _drive_online_trace(hyb)
    assert got == ref
    # and the offline work actually completed (no starvation)
    assert not hyb.waiting_offline and not hyb.has_work
    assert hyb.slack.tokens_sold > 0


def test_offline_only_workload_completes_with_enlargement():
    """With no online traffic at all, the disaggregated phase machine
    runs on the offline tier: prefill accumulates members beyond
    max_batch, decode batches sit on pow2 rungs only, and rotation
    drains every sequence (no starvation between rungs)."""
    # 12 prompts over p=2 slots -> ~6 members per slot: enough to clear
    # the first rung (2*mb = 4) with headroom below the cap (4*mb = 8)
    s = _mk_sched("disaggregated", max_batch=2, budget=8, factor=4)
    _add_offline(s, [6, 5, 7, 4, 6, 5, 4, 6, 5, 7, 4, 5], n_new=5)
    widths = set()
    for it in range(10_000):
        o = s.schedule(it)
        if o is None:
            if not s.has_work:
                break
            continue
        if o.spans is not None and all(c == 1 for _, c in o.spans):
            widths.add(len(o.seq_ids))
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, np.full(len(ids), 207, np.int32))
    assert not s.has_work
    # decode widths only at ladder rungs: <= max_batch, or 2x/4x exactly
    assert all(w <= 2 or w in (4, 8) for w in widths), widths
    assert any(w > 2 for w in widths), "enlargement never engaged"
    assert s.policy.enlarged_decode_iters > 0
    assert s.policy.metrics()["decode_enlarge_factor"] == 4


def test_slack_account_counts_offers_and_sales():
    a = SlackAccount()
    a.see(0)            # empty offer: not an offer at all
    a.see(3)
    a.see(2)
    a.sell(0)
    a.sell(4)
    assert a.offers == 2
    assert a.seats_seen == 5
    assert a.tokens_sold == 4


def test_enlarge_factor_validation():
    with pytest.raises(ValueError, match="decode_enlarge_factor"):
        make_policy("chunked", token_budget=8, decode_enlarge_factor=2)
    with pytest.raises(ValueError, match="decode_enlarge_factor"):
        make_policy("disaggregated", token_budget=8, decode_enlarge_factor=0)
    p = make_policy("disaggregated", token_budget=8, decode_enlarge_factor=4)
    assert p.decode_enlarge_factor == 4


def test_sampling_params_tier_validation():
    with pytest.raises(ValueError, match="tier"):
        SamplingParams(tier="batch")
    assert SamplingParams(tier="offline").tier == "offline"


def test_offline_queue_is_separate_and_priority_ordered():
    s = _mk_sched("chunked", max_batch=2, budget=8)
    _add_online(s, [4], 2)
    s.add_request(Sequence(OFFLINE_BASE, [201, 202],
                           _params(2, tier="offline", priority=0)))
    s.add_request(Sequence(OFFLINE_BASE + 1, [203, 204],
                           _params(2, tier="offline", priority=5)))
    assert [q.seq_id for q in s.waiting] == [0]
    assert [q.seq_id for q in s.waiting_offline] == [OFFLINE_BASE + 1,
                                                     OFFLINE_BASE]


# ---------------------------------------------------------------------------
# Engine e2e: online token streams identical with/without offline traffic
# ---------------------------------------------------------------------------

def _model():
    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single(), ModelOptions())
    return cfg, model, model.init(jax.random.key(0))


@pytest.mark.slow
def test_engine_online_streams_bit_exact_under_offline_load():
    cfg, model, params = _model()
    rng = np.random.default_rng(11)
    online = [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
              for n in (14, 9, 6)]
    offline = [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
               for n in (10, 8, 12, 7)]

    def run(with_offline):
        eng = SiPipeEngine(model, params, EngineConfig(
            pp_degree=2, max_batch=2, max_seq_len=48, n_samplers=2,
            prefill_chunk_tokens=8, scheduling_policy="chunked",
            kv_layout="paged", kv_block_size=4, kv_blocks=20))
        rids = [eng.add_request(p, _params(6)) for p in online]
        if with_offline:
            for p in offline:
                eng.add_request(p, _params(5, tier="offline"))
        while eng.has_work:
            eng.step()
        eng.shutdown()
        outs = {q.seq_id: list(q.output_ids) for q in eng.scheduler.finished}
        return [outs[r] for r in rids], eng.metrics()

    ref, m0 = run(False)
    got, m1 = run(True)
    assert got == ref
    assert m0["slack_tokens_sold"] == 0          # nothing to sell solo
    assert m1["slack_tokens_sold"] > 0
    assert m1["offline_requests_seen"] == len(offline)
    assert m1["kv_blocks_free"] == m1["kv_blocks_total"]


def test_engine_rejects_offline_tier_on_contiguous_layout():
    cfg, model, params = _model()
    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=2, max_batch=2, max_seq_len=48,
        kv_layout="contiguous"))
    with pytest.raises(ValueError, match="offline"):
        eng.add_request([3, 4, 5], _params(2, tier="offline"))
    eng.shutdown()


def test_engine_rejects_enlargement_on_contiguous_layout():
    cfg, model, params = _model()
    with pytest.raises(ValueError, match="decode_enlarge_factor"):
        SiPipeEngine(model, params, EngineConfig(
            pp_degree=2, max_batch=2, max_seq_len=48,
            kv_layout="contiguous", prefill_chunk_tokens=8,
            scheduling_policy="disaggregated", decode_enlarge_factor=2))
