"""Shared-prefix KV reuse and CoW parallel sampling (docs/memory.md
"Prefix caching & CoW forks"): n > 1 fork streams bit-equal to solo
runs, warm prefix admissions bit-equal to cold, abort isolation,
bit-exactness under CoW/fork memory pressure, and on-ladder table
widths."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import EngineConfig, SiPipeEngine
from repro.core.sampling_params import SamplingParams
from repro.core.sequence import SeqStatus
from repro.models import ModelOptions, ShardCtx, build_model


def _model(arch="stablelm-1.6b-smoke", key=0):
    cfg = get_config(arch)
    model = build_model(cfg, ShardCtx.single(), ModelOptions())
    return cfg, model, model.init(jax.random.key(key))


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
            for n in lens]


def _engine(model, params, *, policy="chunked", chunk=6, max_batch=2,
            max_seq_len=64, block_size=8, kv_blocks=None,
            prefix_caching=True):
    return SiPipeEngine(model, params, EngineConfig(
        pp_degree=2, max_batch=max_batch, max_seq_len=max_seq_len,
        n_samplers=2, prefill_chunk_tokens=chunk, scheduling_policy=policy,
        kv_layout="paged", kv_block_size=block_size, kv_blocks=kv_blocks,
        enable_prefix_caching=prefix_caching))


def _drive(eng, max_iterations=10_000):
    """Step until idle; returns {request_id: final RequestOutput}."""
    finals = {}
    for _ in range(max_iterations):
        for out in eng.step():
            if out.finished:
                finals[out.request_id] = out
        if not eng.has_work:
            break
    return finals


def _solo_ref(model, params, prompts, n_new, *, policy, chunk):
    eng = _engine(model, params, policy=policy, chunk=chunk)
    rids = [eng.add_request(p, SamplingParams(greedy=True,
                                              max_new_tokens=n_new))
            for p in prompts]
    finals = _drive(eng)
    eng.shutdown()
    return [finals[r].token_ids.to_list() for r in rids]


# ---------------------------------------------------------------------------
# Parallel sampling: n > 1 forks, greedy-bit-equal to solo runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,chunk", [("chunked", 6),
                                          ("monolithic", None)])
def test_parallel_sampling_forks_bit_equal_solo(policy, chunk):
    """Every fork of a greedy n=3 request must emit exactly the solo
    (n=1) output — the forks share the prompt K/V, so any divergence
    means a shared block was written through."""
    cfg, model, params = _model()
    prompts = _prompts(cfg, (13, 7), seed=3)
    ref = _solo_ref(model, params, prompts, 8, policy=policy, chunk=chunk)
    eng = _engine(model, params, policy=policy, chunk=chunk)
    rids = [eng.add_request(p, SamplingParams(greedy=True, max_new_tokens=8,
                                              n=3))
            for p in prompts]
    finals = _drive(eng)
    m = eng.metrics()
    eng.shutdown()
    for rid, r in zip(rids, ref):
        out = finals[rid]
        assert out.token_ids.to_list() == r
        assert out.forks is not None and len(out.forks) == 2
        for f in out.forks:
            assert f.finished and f.token_ids.to_list() == r
    assert m["kv_fork_children"] == 4
    # everything (incl. CoW'd fork tails) released at the end
    assert m["kv_blocks_free"] == m["kv_blocks_total"]
    eng.kv_manager.alloc.check_invariants()


def test_n_requires_paged_layout():
    cfg, model, params = _model()
    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=1, max_batch=1, max_seq_len=32, kv_layout="contiguous"))
    with pytest.raises(ValueError, match="paged"):
        eng.add_request([1, 2, 3], SamplingParams(greedy=True, n=2))
    eng.shutdown()


# ---------------------------------------------------------------------------
# Prefix caching: warm admissions bit-equal to cold, hits counted
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,chunk", [("chunked", 6),
                                          ("monolithic", None)])
def test_prefix_cache_hit_bit_equal_cold(policy, chunk):
    """A warm request whose prompt shares a long prefix with a finished
    one maps the cached blocks instead of recomputing them — and its
    output must be bit-equal to a cold run of the same prompt."""
    cfg, model, params = _model()
    base = _prompts(cfg, (24,), seed=5)[0]      # 3 full blocks of 8
    t1, t2 = _prompts(cfg, (4, 4), seed=6)
    p1, p2 = base + t1, base + t2
    ref = _solo_ref(model, params, [p1, p2], 6, policy=policy, chunk=chunk)

    eng = _engine(model, params, policy=policy, chunk=chunk)
    r1 = eng.add_request(p1, SamplingParams(greedy=True, max_new_tokens=6))
    f1 = _drive(eng)
    r2 = eng.add_request(p2, SamplingParams(greedy=True, max_new_tokens=6))
    f2 = _drive(eng)
    m = eng.metrics()
    eng.shutdown()
    assert f1[r1].token_ids.to_list() == ref[0]
    assert f2[r2].token_ids.to_list() == ref[1]      # warm == cold
    assert m["kv_prefix_hits"] >= 1
    assert m["kv_prefix_tokens_served"] >= 24
    # the pinned cache still counts as reclaimable capacity: no leak
    assert m["kv_blocks_free"] == m["kv_blocks_total"]
    assert m["kv_blocks_cached"] > 0
    eng.kv_manager.alloc.check_invariants()


def test_prefix_caching_can_be_disabled():
    cfg, model, params = _model()
    p = _prompts(cfg, (20,), seed=5)[0]
    eng = _engine(model, params, prefix_caching=False)
    r1 = eng.add_request(p, SamplingParams(greedy=True, max_new_tokens=4))
    _drive(eng)
    r2 = eng.add_request(p, SamplingParams(greedy=True, max_new_tokens=4))
    _drive(eng)
    m = eng.metrics()
    eng.shutdown()
    assert "kv_prefix_hits" not in m
    assert m["kv_blocks_cached"] == 0
    assert m["kv_blocks_free"] == m["kv_blocks_total"]


# ---------------------------------------------------------------------------
# Abort isolation: killing one fork leaves its siblings bit-exact
# ---------------------------------------------------------------------------

def test_fork_abort_leaves_siblings_intact():
    cfg, model, params = _model()
    [prompt] = _prompts(cfg, (13,), seed=3)
    [ref] = _solo_ref(model, params, [prompt], 10, policy="chunked", chunk=6)

    eng = _engine(model, params)
    rid = eng.add_request(prompt, SamplingParams(greedy=True,
                                                 max_new_tokens=10, n=3))
    aborted = False
    final = None
    for _ in range(10_000):
        for out in eng.step():
            if (not aborted and out.request_id == rid and out.forks
                    and len(out.forks) == 2):
                assert eng.abort(rid, fork=1)    # kill the first fork only
                aborted = True
            if out.finished and out.request_id == rid:
                final = out
        if not eng.has_work:
            break
    m = eng.metrics()
    eng.shutdown()
    assert aborted and final is not None
    assert final.token_ids.to_list() == ref          # primary unharmed
    k1, k2 = final.forks
    assert k1.seq.status == SeqStatus.ABORTED
    assert k2.seq.status == SeqStatus.FINISHED
    assert k2.token_ids.to_list() == ref             # sibling unharmed
    # the aborted fork's blocks came back; shared blocks survived it
    assert m["kv_blocks_free"] == m["kv_blocks_total"]
    eng.kv_manager.alloc.check_invariants()


# ---------------------------------------------------------------------------
# CoW exhaustion under pressure: demote/preempt, resume bit-exact
# ---------------------------------------------------------------------------

def test_fork_pressure_bit_exact_with_demotion_or_preemption():
    """A pool too small for every fork's CoW growth forces fork demotion
    (resume-by-recompute) and/or preemption; all streams must still
    finish bit-exact vs an unpressured run."""
    cfg, model, params = _model()
    prompts = _prompts(cfg, (20, 16, 12), seed=7)
    ref = _solo_ref(model, params, prompts, 12, policy="chunked", chunk=8)

    eng = _engine(model, params, chunk=8, max_seq_len=48, block_size=4,
                  kv_blocks=14)
    rids = [eng.add_request(p, SamplingParams(greedy=True,
                                              max_new_tokens=12, n=2))
            for p in prompts]
    finals = _drive(eng, max_iterations=20_000)
    m = eng.metrics()
    eng.shutdown()
    assert m["kv_preemptions"] + m["kv_fork_demotions"] > 0
    for rid, r in zip(rids, ref):
        out = finals[rid]
        assert out.token_ids.to_list() == r, "primary diverged"
        assert len(out.forks) == 1
        assert out.forks[0].token_ids.to_list() == r, "fork diverged"
    assert m["kv_blocks_free"] == m["kv_blocks_total"]
    eng.kv_manager.alloc.check_invariants()


# ---------------------------------------------------------------------------
# Compile-shape discipline: realized table widths stay on the ladder
# ---------------------------------------------------------------------------

def test_realized_table_widths_stay_on_ladder():
    """Every padded block-table width the engine realizes — across
    prefix-cached admissions, forks and decode growth — must be a rung
    of the (possibly extended) width ladder, never an off-ladder one-off
    (each distinct width is one XLA compile)."""
    cfg, model, params = _model()
    prompts = _prompts(cfg, (24, 13), seed=1)
    eng = _engine(model, params)
    widths = []
    orig = eng.kv_manager.padded_tables

    def recording(seq_ids, *a, **kw):
        t = orig(seq_ids, *a, **kw)
        widths.append(t.shape[1])
        return t

    eng.kv_manager.padded_tables = recording
    for p in prompts:
        eng.add_request(p, SamplingParams(greedy=True, max_new_tokens=6,
                                          n=2))
    _drive(eng)
    m = eng.metrics()
    eng.shutdown()
    assert widths
    assert set(widths) <= set(m["kv_table_widths"]), \
        f"off-ladder widths: {sorted(set(widths))} vs {m['kv_table_widths']}"
