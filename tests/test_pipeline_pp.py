"""shard_map pipeline: numerical equivalence with the plain decode path.

Runs in a subprocess with 8 fake host devices (the main test process must
keep the single-device view), building a (2, 2, 2) pipe x data x model
mesh and comparing one pp_decode_round against p sequential model.decode
calls.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
sys_path = os.environ["REPRO_SRC"]
import sys; sys.path.insert(0, sys_path)
from repro.configs import get_config
from repro.models import build_model, ShardCtx, ModelOptions
from repro.core import pipeline as pl

cfg = get_config("stablelm-1.6b-smoke")
# (2,2,1): pipe + data live; model=1 sidesteps an XLA SPMD partitioner
# check-failure specific to tiny partial-manual meshes (the 256/512-chip
# dry-run meshes compile fine with model=16).
mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2, 1),
            ("pipe", "data", "model"))
shard = ShardCtx.from_mesh(mesh, "pp")
model = build_model(cfg, shard, ModelOptions())
params = model.init(jax.random.key(0))

p = 2
B_m = 2
S_max = 32
plan = pl.plan_pp(model, mesh, p * B_m)
step = pl.pp_decode_round(model, plan)

# re-stack blocks [n] -> [p, n/p]
params_pp = {**params, "stacks": {"blocks": pl._restack(
    params["stacks"]["blocks"], p, plan.groups_per_stage)}}

rng = np.random.default_rng(0)
tokens = jnp.asarray(rng.integers(2, cfg.vocab_size, (p, B_m)), jnp.int32)
positions = jnp.zeros((p, B_m), jnp.int32)

# pp cache [p_stage, p_micro, gps, B_m, ...]
base = model.abstract_cache(B_m, S_max)["blocks"]
cache = jax.tree.map(
    lambda sd: jnp.zeros((plan.p, plan.p, plan.groups_per_stage) + sd.shape[1:],
                         sd.dtype), base)
inflight = jnp.zeros((p, B_m, cfg.d_model), jnp.bfloat16)

# two rounds: round 0 is pipeline fill for microbatch flow; to sidestep
# warmup semantics, compare *per-stage math* instead — run the round with
# p identical microbatches and check microbatch 0's logits after the
# pipeline is full.  Simpler exact check: p=2, run 2 rounds feeding the
# same token/position; the second round's emitted logits for microbatch m
# correspond to tokens[m] processed through ALL stages with cache state
# from (already-written) slots — so instead we directly verify against
# a fresh reference decode on a fresh cache for round 1, microbatch 1.
#
# Exact equivalence harness: make every stage's weights IDENTITY-safe by
# comparing against the serial composition explicitly:
logits_r1, cache, inflight = jax.jit(step)(params_pp, cache, inflight,
                                           tokens, positions)
# after round 1: microbatch whose activation passed stage0 in tick t and
# stage1 in tick t+1 has complete logits: with p=2, microbatch 0 entered
# stage0 at tick0 and stage1 at tick1 => logits_r1[m=0] is fully processed.
ref_cache = model.init_cache(B_m, S_max)
ref_logits, _ = jax.jit(model.decode)(params, ref_cache, {
    "token": tokens[0], "positions": positions[0]})

got = np.asarray(logits_r1[0], np.float32)
want = np.asarray(ref_logits, np.float32)
err = np.abs(got - want).max()
print("PP max err:", err)
assert err < 0.05, err
print("PP_EQUIVALENCE_OK")
"""


@pytest.mark.slow
def test_pp_round_matches_reference(tmp_path):
    env = dict(os.environ)
    env["REPRO_SRC"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "PP_EQUIVALENCE_OK" in out.stdout, out.stdout + out.stderr
