"""Continuous-batching scheduler: slot stability + completion semantics."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sampling_params import SamplingParams
from repro.core.scheduler import Scheduler
from repro.core.sequence import Sequence


def _mk(max_batch=4, p=2, n=6, max_new=4):
    s = Scheduler(max_batch=max_batch, pp_degree=p, max_seq_len=512)
    for i in range(n):
        s.add_request(Sequence(i, [1, 2, 3], SamplingParams(
            greedy=True, max_new_tokens=max_new)))
    return s


def test_slots_partition_sequences():
    s = _mk(max_batch=4, p=2, n=6)
    o0 = s.schedule(0)
    o1 = s.schedule(1)
    assert len(o0.seq_ids) == 4 and len(o1.seq_ids) == 2
    assert set(o0.seq_ids).isdisjoint(o1.seq_ids)


def test_slot_stability_across_rounds():
    """Batches n and n+p contain the same sequences (§5.1 assumption)."""
    s = _mk(max_batch=4, p=2, n=6, max_new=8)
    o0 = s.schedule(0)
    s.complete(0, o0.seq_ids, np.zeros(len(o0.seq_ids), np.int32))
    o2 = s.schedule(2)
    assert o2.seq_ids == o0.seq_ids
    assert not o2.is_prefill


def test_positions_advance_with_tokens():
    s = _mk(n=2, max_batch=4, p=1)
    o = s.schedule(0)
    p0 = o.positions.copy()
    s.complete(0, o.seq_ids, np.array([7, 8], np.int32))
    o1 = s.schedule(1)
    np.testing.assert_array_equal(o1.positions, p0 + 1)
    np.testing.assert_array_equal(o1.tokens, [7, 8])


def test_completion_and_backfill():
    s = _mk(max_batch=2, p=1, n=4, max_new=1)
    o = s.schedule(0)
    done = s.complete(0, o.seq_ids, np.array([5, 5], np.int32))
    assert done == o.seq_ids                 # max_new=1 -> finish at once
    o1 = s.schedule(1)
    assert set(o1.seq_ids).isdisjoint(done)  # backfilled from waiting
    assert o1.is_prefill


def test_eos_stops_sequence():
    s = Scheduler(max_batch=1, pp_degree=1, max_seq_len=64)
    s.add_request(Sequence(0, [1], SamplingParams(max_new_tokens=10,
                                                  eos_token_id=2)))
    o = s.schedule(0)
    done = s.complete(0, o.seq_ids, np.array([2], np.int32))
    assert done == [0]


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 12),
    max_batch=st.integers(1, 5),
    p=st.integers(1, 3),
    rounds=st.integers(1, 30),
    seed=st.integers(0, 99),
)
def test_property_no_seq_in_two_slots_and_all_finish(n, max_batch, p, rounds, seed):
    rng = np.random.default_rng(seed)
    s = Scheduler(max_batch=max_batch, pp_degree=p, max_seq_len=128)
    for i in range(n):
        s.add_request(Sequence(i, [1, 2], SamplingParams(
            greedy=True, max_new_tokens=int(rng.integers(1, 4)))))
    for it in range(rounds * p):
        o = s.schedule(it)
        if o is None:
            continue
        # invariant: no sequence scheduled in two different slots
        others = set()
        for sl in range(p):
            if sl != o.slot:
                others |= set(s.slot_members[sl])
        assert not (set(o.seq_ids) & others)
        s.complete(it, o.seq_ids, rng.integers(3, 50, len(o.seq_ids)).astype(np.int32))
        if not s.has_work:
            break
    if rounds * p >= n * 5:
        assert len(s.finished) == n
        for seq in s.finished:
            assert len(seq.output_ids) == seq.params.max_new_tokens
