"""Continuous-batching scheduler: slot stability + completion semantics."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sampling_params import SamplingParams
from repro.core.scheduler import Scheduler
from repro.core.sequence import Sequence


def _mk(max_batch=4, p=2, n=6, max_new=4):
    s = Scheduler(max_batch=max_batch, pp_degree=p, max_seq_len=512)
    for i in range(n):
        s.add_request(Sequence(i, [1, 2, 3], SamplingParams(
            greedy=True, max_new_tokens=max_new)))
    return s


def test_slots_partition_sequences():
    s = _mk(max_batch=4, p=2, n=6)
    o0 = s.schedule(0)
    o1 = s.schedule(1)
    assert len(o0.seq_ids) == 4 and len(o1.seq_ids) == 2
    assert set(o0.seq_ids).isdisjoint(o1.seq_ids)


def test_slot_stability_across_rounds():
    """Batches n and n+p contain the same sequences (§5.1 assumption)."""
    s = _mk(max_batch=4, p=2, n=6, max_new=8)
    o0 = s.schedule(0)
    s.complete(0, o0.seq_ids, np.zeros(len(o0.seq_ids), np.int32))
    o2 = s.schedule(2)
    assert o2.seq_ids == o0.seq_ids
    assert not o2.is_prefill


def test_positions_advance_with_tokens():
    s = _mk(n=2, max_batch=4, p=1)
    o = s.schedule(0)
    p0 = o.positions.copy()
    s.complete(0, o.seq_ids, np.array([7, 8], np.int32))
    o1 = s.schedule(1)
    np.testing.assert_array_equal(o1.positions, p0 + 1)
    np.testing.assert_array_equal(o1.tokens, [7, 8])


def test_completion_and_backfill():
    s = _mk(max_batch=2, p=1, n=4, max_new=1)
    o = s.schedule(0)
    done = s.complete(0, o.seq_ids, np.array([5, 5], np.int32))
    assert done == o.seq_ids                 # max_new=1 -> finish at once
    o1 = s.schedule(1)
    assert set(o1.seq_ids).isdisjoint(done)  # backfilled from waiting
    assert o1.is_prefill


def test_eos_stops_sequence():
    s = Scheduler(max_batch=1, pp_degree=1, max_seq_len=64)
    s.add_request(Sequence(0, [1], SamplingParams(max_new_tokens=10,
                                                  eos_token_id=2)))
    o = s.schedule(0)
    done = s.complete(0, o.seq_ids, np.array([2], np.int32))
    assert done == [0]


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 12),
    max_batch=st.integers(1, 5),
    p=st.integers(1, 3),
    rounds=st.integers(1, 30),
    seed=st.integers(0, 99),
)
def test_property_no_seq_in_two_slots_and_all_finish(n, max_batch, p, rounds, seed):
    rng = np.random.default_rng(seed)
    s = Scheduler(max_batch=max_batch, pp_degree=p, max_seq_len=128)
    for i in range(n):
        s.add_request(Sequence(i, [1, 2], SamplingParams(
            greedy=True, max_new_tokens=int(rng.integers(1, 4)))))
    for it in range(rounds * p):
        o = s.schedule(it)
        if o is None:
            continue
        # invariant: no sequence scheduled in two different slots
        others = set()
        for sl in range(p):
            if sl != o.slot:
                others |= set(s.slot_members[sl])
        assert not (set(o.seq_ids) & others)
        s.complete(it, o.seq_ids, rng.integers(3, 50, len(o.seq_ids)).astype(np.int32))
        if not s.has_work:
            break
    if rounds * p >= n * 5:
        assert len(s.finished) == n
        for seq in s.finished:
            assert len(seq.output_ids) == seq.params.max_new_tokens


# ---------------------------------------------------------------------------
# Chunked-prefill invariants (property-based)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 10),
    max_batch=st.integers(1, 4),
    p=st.integers(1, 3),
    budget=st.integers(2, 24),
    seed=st.integers(0, 99),
)
def test_property_chunk_accounting_and_budget(n, max_batch, p, budget, seed):
    """Under random prompt lengths / finish times: (a) total tokens per
    iteration never exceed the (clamped) budget; (b) the prefill chunks of
    every sequence tile [0, prompt_len) exactly, in order."""
    rng = np.random.default_rng(seed)
    s = Scheduler(max_batch=max_batch, pp_degree=p, max_seq_len=512,
                  token_budget=budget)
    plens = {}
    for i in range(n):
        plens[i] = int(rng.integers(1, 60))
        s.add_request(Sequence(i, list(range(1, plens[i] + 1)), SamplingParams(
            greedy=True, max_new_tokens=int(rng.integers(1, 4)))))
    chunks = {i: [] for i in range(n)}
    for it in range(3000):
        o = s.schedule(it)
        if o is None:
            if not s.has_work:
                break
            continue
        assert o.total_tokens <= s.token_budget
        assert len(o.seq_ids) <= max_batch
        for sid, (off, c) in zip(o.seq_ids, o.spans):
            assert c >= 1
            if off + c <= plens[sid]:          # prefill chunk
                chunks[sid].append((off, c))
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, rng.integers(3, 50, len(ids)).astype(np.int32))
    assert not s.has_work
    for i in range(n):
        # chunks tile the prompt: contiguous, in-order, summing to len
        off = 0
        for o_, c_ in chunks[i]:
            assert o_ == off
            off += c_
        assert off == plens[i]


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 8),
    max_batch=st.integers(2, 4),
    p=st.integers(1, 3),
    budget=st.integers(4, 24),
    seed=st.integers(0, 99),
)
def test_property_slot_stability_in_steady_state(n, max_batch, p, budget, seed):
    """Once admission and prefill settle (no admits/finishes), iterations
    n and n+p of a slot carry the same sequence set — the §5.1 batch
    stability the TSEM replicas and column-wise sampler rely on."""
    rng = np.random.default_rng(seed)
    s = Scheduler(max_batch=max_batch, pp_degree=p, max_seq_len=4096,
                  token_budget=budget)
    for i in range(n):
        s.add_request(Sequence(i, list(range(1, int(rng.integers(1, 40)) + 1)),
                               SamplingParams(greedy=True,
                                              max_new_tokens=10 ** 6)))
    it = 0
    # settle: run until admission stalls (slots full or queue empty) and
    # every running sequence has completed its prefill
    while it < 500:
        o = s.schedule(it)
        if o is not None:
            ids = [o.seq_ids[i] for i in o.sample_indices()]
            s.complete(it, ids, np.full(len(ids), 7, np.int32))
        it += 1
        admission_stalled = (not s.waiting or
                             all(len(m) >= max_batch for m in s.slot_members))
        if admission_stalled and all(s.seqs[sid].prefill_done
                                     for m in s.slot_members for sid in m):
            break
    # steady state: two consecutive rounds of each slot must match
    # (slots may be empty when there are fewer sequences than slots)
    first = {}
    for k in range(2 * p):
        o = s.schedule(it + k)
        if o is None:
            assert not s.slot_members[(it + k) % p]
            continue
        if o.slot in first:
            assert o.seq_ids == first[o.slot]
            assert o.max_span == 1
        else:
            first[o.slot] = list(o.seq_ids)
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it + k, ids, np.full(len(ids), 7, np.int32))


# ---------------------------------------------------------------------------
# Packed ragged layout + bucket policy (property-based)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(t=st.integers(1, 4096))
def test_property_bucket_is_minimal_power_of_two(t):
    from repro.core.scheduler import BUCKET_FLOOR, bucket_width

    b = bucket_width(t)
    assert b >= max(t, BUCKET_FLOOR)
    assert b & (b - 1) == 0                     # power of two
    assert b == BUCKET_FLOOR or b // 2 < t      # minimal such bucket


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 8),
    max_batch=st.integers(1, 4),
    p=st.integers(1, 3),
    budget=st.integers(2, 24),
    seed=st.integers(0, 99),
)
def test_property_packed_layout_invariants(n, max_batch, p, budget, seed):
    """Across a whole scheduled run: every valid (seq, position) token
    appears exactly once in the packed layouts; positions are monotone
    per row within one layout; last_index points at each row's final
    token; the bucket covers the valid count."""
    rng = np.random.default_rng(seed)
    s = Scheduler(max_batch=max_batch, pp_degree=p, max_seq_len=256,
                  token_budget=budget)
    plens = {}
    for i in range(n):
        plens[i] = int(rng.integers(1, 40))
        s.add_request(Sequence(i, list(range(1, plens[i] + 1)), SamplingParams(
            greedy=True, max_new_tokens=int(rng.integers(1, 4)))))
    seen = {i: set() for i in range(n)}
    for it in range(2000):
        o = s.schedule(it)
        if o is None:
            if not s.has_work:
                break
            continue
        tok, pos, seq, last = o.packed_layout()
        t = o.total_tokens
        assert len(tok) == len(pos) == len(seq) == t
        assert o.packed_width == 1 or (o.packed_width >= max(t, 8)
                                       and o.packed_width & (o.packed_width - 1) == 0)
        for col in range(len(o.seq_ids)):
            idx = np.flatnonzero(seq == col)
            assert idx.size == o.spans[col][1]
            assert (np.diff(pos[idx]) == 1).all()     # monotone positions
            assert last[col] == idx[-1]               # final token of the row
            sid = o.seq_ids[col]
            # prefill chunks: record coverage of [0, prompt_len)
            for q in pos[idx]:
                if q < plens[sid]:
                    assert q not in seen[sid]         # exactly once
                    seen[sid].add(int(q))
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, rng.integers(3, 50, len(ids)).astype(np.int32))
    assert not s.has_work
    for i in range(n):
        assert seen[i] == set(range(plens[i]))        # full prompt coverage


def test_budget_is_clamped_above_max_batch():
    s = Scheduler(max_batch=4, pp_degree=1, max_seq_len=64, token_budget=2)
    assert s.token_budget == 5          # max_batch + 1: prefill can progress
    assert s.chunked
    assert Scheduler(max_batch=4, pp_degree=1, max_seq_len=64).token_budget is None


def test_overlong_prompt_rejected_up_front():
    s = Scheduler(max_batch=2, pp_degree=1, max_seq_len=16, token_budget=8)
    with pytest.raises(ValueError, match="does not fit"):
        s.add_request(Sequence(0, list(range(1, 17)),
                               SamplingParams(greedy=True, max_new_tokens=2)))
    # one below the limit is admissible
    s.add_request(Sequence(1, list(range(1, 16)),
                           SamplingParams(greedy=True, max_new_tokens=1)))
