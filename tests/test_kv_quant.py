"""int8 KV cache (§Perf C1): accuracy vs the bf16 cache + invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import ModelOptions, ShardCtx, build_model
from repro.models.attention import (
    decode_attention,
    decode_attention_quant,
    quantize_kv,
)


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, 64)), jnp.bfloat16)
    q8, s = quantize_kv(x)
    deq = q8.astype(jnp.float32) * s.astype(jnp.float32)[..., None]
    err = np.abs(np.asarray(deq) - np.asarray(x, np.float32))
    # half an int8 quantum + bf16 rounding of the scale (|q8|<=127, eps~2^-8)
    bound = np.asarray(s, np.float32)[..., None] * (0.5 + 127 / 256.0) + 1e-6
    assert (err <= bound).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500), s=st.sampled_from([16, 64]),
       kv=st.sampled_from([1, 2, 4]))
def test_property_quant_decode_close_to_fp(seed, s, kv):
    rng = np.random.default_rng(seed)
    b, g, hd = 2, 4, 32
    h = kv * g
    q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)), jnp.bfloat16)
    lengths = jnp.asarray(rng.integers(1, s + 1, b), jnp.int32)
    o_fp = decode_attention(q, k, v, lengths - 1)
    k8, ks = quantize_kv(k)
    v8, vs = quantize_kv(v)
    o_q = decode_attention_quant(q, k8, ks, v8, vs, lengths - 1)
    a, bq = np.asarray(o_fp, np.float32), np.asarray(o_q, np.float32)
    denom = np.abs(a).max() + 1e-6
    assert np.abs(a - bq).max() / denom < 0.06


def test_end_to_end_quant_decode_argmax_agreement():
    cfg = get_config("glm4-9b-smoke")
    rng = np.random.default_rng(0)
    b, s = 2, 12
    toks = rng.integers(2, cfg.vocab_size, (b, s + 1))
    outs = {}
    for quant in (False, True):
        model = build_model(cfg, ShardCtx.single(), ModelOptions(kv_quant=quant))
        params = model.init(jax.random.key(2))
        _, cache = jax.jit(model.prefill)(
            params, {"tokens": jnp.asarray(toks[:, :s], jnp.int32)})
        dcache = model.init_cache(b, s + 4)

        def pad(dst, src):
            if dst.shape == src.shape:
                return src
            return dst.at[tuple(slice(0, d) for d in src.shape)].set(src)

        dcache = jax.tree.map(pad, dcache, cache)
        got, _ = jax.jit(model.decode)(params, dcache, {
            "token": jnp.asarray(toks[:, s], jnp.int32),
            "positions": jnp.full((b,), s, jnp.int32)})
        outs[quant] = np.asarray(got, np.float32)
    rel = np.abs(outs[True] - outs[False]).max() / (np.abs(outs[False]).max())
    assert rel < 0.05
    assert (outs[True].argmax(-1) == outs[False].argmax(-1)).all()


def test_quant_cache_is_int8():
    cfg = get_config("glm4-9b-smoke")
    model = build_model(cfg, ShardCtx.single(), ModelOptions(kv_quant=True))
    cache = model.init_cache(2, 16)
    leaves = jax.tree_util.tree_flatten_with_path(cache)[0]
    kinds = {str(p[-1]): l.dtype for p, l in leaves}
    assert any(v == jnp.int8 for v in kinds.values())
