"""Token-safe execution model (§5.2): FSM gating, version alternation,
incremental BatchMetadata reuse, overlap without hazards."""
import threading
import time

import numpy as np
import pytest

from repro.core.scheduler import SchedulingOutput
from repro.core.tsem import (
    BatchMetadataCache,
    SynchronousExecutor,
    TokenSafeExecutor,
)


def _sched(it, p=2, b=3, seq_ids=None):
    return SchedulingOutput(
        iteration=it, slot=it % p,
        seq_ids=seq_ids or [10, 11, 12][:b],
        positions=np.full(b, it + 5, np.int32),
        tokens=np.full(b, it, np.int32),
        is_prefill=False)


def test_batch_metadata_incremental_vs_rebuild():
    c = BatchMetadataCache(pp_degree=2)
    rows = np.arange(3, dtype=np.int32)
    c.update(_sched(0), rows)
    c.update(_sched(1), rows)
    c.update(_sched(2), rows)      # slot 0 again, same seqs -> incremental
    c.update(_sched(3), rows)
    assert c.rebuilds == 2 and c.incremental_hits == 2
    c.update(_sched(4, seq_ids=[10, 11, 99]), rows)  # recomposition
    assert c.rebuilds == 3


def test_batch_metadata_inplace_advance():
    c = BatchMetadataCache(1)
    rows = np.arange(3, dtype=np.int32)
    m0 = c.update(_sched(0, p=1), rows)
    tok_buf = m0.tokens
    m1 = c.update(_sched(1, p=1), rows)
    assert m1 is m0 and m1.tokens is tok_buf          # no reallocation
    assert (m1.tokens == 1).all() and m1.iteration == 1


def test_executor_results_in_order_and_versions_alternate():
    log = []

    def prepare(sched, bufs):
        np.copyto(bufs["tokens"], sched.tokens)
        time.sleep(0.01)

    def execute(desc, bufs):
        log.append((desc.iteration, desc.version, int(bufs["tokens"][0])))
        time.sleep(0.01)
        return desc.iteration * 10

    ex = TokenSafeExecutor(prepare, execute, name="t")
    ex.start()
    try:
        for it in range(6):
            ex.submit(_sched(it))
        for it in range(6):
            assert ex.result(it, timeout=10) == it * 10
    finally:
        ex.stop()
    iters = [l[0] for l in log]
    assert iters == sorted(iters)
    versions = [l[1] for l in log]
    assert versions == [i & 1 for i in range(6)]       # strict alternation
    # the executed buffer content matches each iteration (no WAR clobber)
    assert [l[2] for l in log] == list(range(6))


def test_executor_overlaps_prepare_with_execute():
    """With TSEM, total wall < serial sum of prep+exec; with the
    synchronous baseline it is >= the serial sum."""
    PREP, EXEC, N = 0.02, 0.02, 8

    def prepare(sched, bufs):
        time.sleep(PREP)

    def execute(desc, bufs):
        time.sleep(EXEC)
        return True

    ex = TokenSafeExecutor(prepare, execute)
    ex.start()
    t0 = time.monotonic()
    for it in range(N):
        ex.submit(_sched(it))
    for it in range(N):
        ex.result(it, timeout=10)
    overlapped = time.monotonic() - t0
    ex.stop()

    sync = SynchronousExecutor(prepare, execute)
    t0 = time.monotonic()
    for it in range(N):
        sync.run(_sched(it))
    serial = time.monotonic() - t0

    assert serial >= N * (PREP + EXEC) * 0.9
    assert overlapped < serial * 0.85, (overlapped, serial)


def test_cpu_runs_exactly_one_ahead():
    """CI may exceed GI by at most max_ahead (the double-buffer bound)."""
    gaps = []

    def prepare(sched, bufs):
        time.sleep(0.001)

    def execute(desc, bufs):
        gaps.append(ex.ci - ex.gi)
        time.sleep(0.01)
        return True

    ex = TokenSafeExecutor(prepare, execute)
    ex.start()
    for it in range(6):
        ex.submit(_sched(it))
    for it in range(6):
        ex.result(it, timeout=10)
    ex.stop()
    assert max(gaps) <= 1, gaps
