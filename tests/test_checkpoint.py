"""Checkpointing: atomicity, integrity, restart, elastic re-mesh planning."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import checkpoint as ck
from repro.runtime.elastic import plan_new_mesh


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(16,)), jnp.float32)},
        "opt": {"step": jnp.asarray(7, jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ck.save(tmp_path, 10, t)
    step, got = ck.restore(tmp_path, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    assert step == 10
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                            np.asarray(b)),
                 t, got)


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ck.save(tmp_path, s, t, keep=2)
    assert ck.latest_step(tmp_path) == 5
    kept = sorted(p.name for p in tmp_path.iterdir())
    assert kept == ["step_00000004", "step_00000005"]


def test_uncommitted_checkpoint_ignored(tmp_path):
    t = _tree()
    ck.save(tmp_path, 3, t)
    # simulate crash mid-save of step 4: directory without COMMIT
    d = tmp_path / "step_00000004"
    d.mkdir()
    (d / "MANIFEST.json").write_text("{}")
    assert ck.latest_step(tmp_path) == 3


def test_corruption_detected(tmp_path):
    t = _tree()
    path = ck.save(tmp_path, 1, t)
    f = path / "params__w.npy"
    arr = np.load(f)
    arr[0, 0] += 1000.0
    np.save(f, arr)
    with pytest.raises(IOError):
        ck.restore(tmp_path, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))


def test_manager_interval(tmp_path):
    m = ck.CheckpointManager(str(tmp_path), interval_steps=5)
    t = _tree()
    saved = [s for s in range(1, 21) if m.maybe_save(s, t)]
    assert saved == [5, 10, 15, 20]


def test_restore_into_different_structure_fails(tmp_path):
    ck.save(tmp_path, 1, _tree())
    bad = {"params": {"nope": jax.ShapeDtypeStruct((2,), jnp.float32)}}
    with pytest.raises(KeyError):
        ck.restore(tmp_path, bad)


def test_elastic_mesh_planning():
    assert plan_new_mesh(256) == ((16, 16), ("data", "model"))
    assert plan_new_mesh(512) == ((2, 16, 16), ("pod", "data", "model"))
    # losing a node: 240 chips -> keep model=16, shrink data
    assert plan_new_mesh(240) == ((15, 16), ("data", "model"))
    # heavily degraded: model degree degrades by powers of two
    shape, axes = plan_new_mesh(24)
    assert np.prod(shape) <= 24 and axes[-1] == "model"


def test_train_restart_resumes(tmp_path):
    """End-to-end crash-restart through the train driver."""
    from repro.launch import train as T

    with pytest.raises(RuntimeError):
        T.run("stablelm-1.6b", steps=8, batch=2, seq=16,
              ckpt_dir=str(tmp_path), ckpt_every=2, simulate_crash_at=5,
              log_every=100)
    assert ck.latest_step(tmp_path) == 4
    out = T.run("stablelm-1.6b", steps=8, batch=2, seq=16,
                ckpt_dir=str(tmp_path), ckpt_every=2, log_every=100)
    assert len(out["losses"]) == 4  # resumed from step 4, ran 4..7


def test_async_save_roundtrip(tmp_path):
    m = ck.CheckpointManager(str(tmp_path), interval_steps=1, async_save=True)
    t = _tree(3)
    assert m.maybe_save(1, t)
    m.wait()
    step, got = ck.restore(tmp_path, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    assert step == 1
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, got)
