"""Pallas span-attention kernels vs. the pure-jnp packed oracles
(interpret mode): GQA ratios, ragged positions, sliding windows, int8
caches, and the rolling-cache two-source variant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.span_attention import (
    span_attention,
    span_attention_quant,
    span_attention_rolling,
    span_attention_rolling_quant,
)
from repro.models import attention as A

TOL = dict(rtol=2e-2, atol=2e-2)


def _rand(rng, shape, dtype=jnp.bfloat16):
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


def _packed_batch(rng, b, s, t):
    """Random ragged packed layout: sorted rows, positions < s."""
    seq = np.sort(rng.integers(0, b, t)).astype(np.int32)
    pos = rng.integers(0, s, t).astype(np.int32)
    return jnp.asarray(pos), jnp.asarray(seq)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("b,s,h,kv,hd,t", [
    (2, 64, 4, 4, 64, 8),      # MHA
    (3, 128, 8, 2, 64, 12),    # GQA 4:1
    (1, 96, 6, 1, 128, 5),     # MQA, non-pow2 cache len
])
def test_span_attention_sweep(b, s, h, kv, hd, t, dtype):
    rng = np.random.default_rng(0)
    q = _rand(rng, (t, h, hd), dtype)
    kc = _rand(rng, (b, s, kv, hd), dtype)
    vc = _rand(rng, (b, s, kv, hd), dtype)
    pos, seq = _packed_batch(rng, b, s, t)
    o = span_attention(q, kc, vc, pos, seq, kv_block=32, interpret=True)
    o_ref = A.packed_span_attention(q, kc, vc, pos, seq, kv_block=32)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **TOL)


@pytest.mark.parametrize("window", [16, 48])
def test_span_attention_window_lower_bound(window):
    """Sliding window on a full-length cache: the kernel skips kv blocks
    entirely below the window and masks the boundary block."""
    b, s, h, kv, hd, t = 2, 128, 4, 2, 64, 10
    rng = np.random.default_rng(1)
    q = _rand(rng, (t, h, hd))
    kc = _rand(rng, (b, s, kv, hd))
    vc = _rand(rng, (b, s, kv, hd))
    pos, seq = _packed_batch(rng, b, s, t)
    o = span_attention(q, kc, vc, pos, seq, window=window, kv_block=32,
                       interpret=True)
    o_ref = A.packed_span_attention(q, kc, vc, pos, seq, window=window,
                                    kv_block=32)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **TOL)


def test_span_attention_matches_padded_reference():
    """Kernel output at each packed token equals the padded [B, C]
    span_attention reference at the corresponding (row, span) entry."""
    b, s, kv, g, hd = 3, 64, 2, 2, 32
    h = kv * g
    spans = [(5, 4), (20, 1), (0, 3)]
    rng = np.random.default_rng(2)
    kc = _rand(rng, (b, s, kv, hd))
    vc = _rand(rng, (b, s, kv, hd))
    c = max(n for _, n in spans)
    qpad = _rand(rng, (b, c, h, hd))
    pos_pad = np.zeros((b, c), np.int32)
    for i, (off, n) in enumerate(spans):
        pos_pad[i] = off + np.minimum(np.arange(c), n - 1)
    o_pad = A.span_attention(qpad, kc, vc, jnp.asarray(pos_pad))

    qp, pos, seq = [], [], []
    for i, (off, n) in enumerate(spans):
        for j in range(n):
            qp.append(np.asarray(qpad[i, j], np.float32))
            pos.append(off + j)
            seq.append(i)
    q = jnp.asarray(np.stack(qp)).astype(jnp.bfloat16)
    o = span_attention(q, kc, vc, jnp.asarray(pos, jnp.int32),
                       jnp.asarray(seq, jnp.int32), kv_block=16,
                       interpret=True)
    k = 0
    for i, (off, n) in enumerate(spans):
        for j in range(n):
            np.testing.assert_allclose(
                np.asarray(o[k], np.float32),
                np.asarray(o_pad[i, j], np.float32).reshape(-1), **TOL)
            k += 1


@pytest.mark.parametrize("b,s,h,kv,hd,t", [
    (2, 64, 8, 2, 64, 9),
    (3, 128, 4, 4, 32, 6),
])
def test_span_attention_quant(b, s, h, kv, hd, t):
    rng = np.random.default_rng(3)
    q = _rand(rng, (t, h, hd))
    kc = _rand(rng, (b, s, kv, hd))
    vc = _rand(rng, (b, s, kv, hd))
    pos, seq = _packed_batch(rng, b, s, t)
    k8, ks = A.quantize_kv(kc)
    v8, vs = A.quantize_kv(vc)
    o = span_attention_quant(q, k8, ks, v8, vs, pos, seq, kv_block=32,
                             interpret=True)
    o_ref = A.packed_span_attention_quant(q, k8, ks, v8, vs, pos, seq,
                                          kv_block=32)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=5e-2, atol=5e-2)
    # s8 x s8 path stays close to the full-precision oracle
    o_fp = A.packed_span_attention(q, kc, vc, pos, seq, kv_block=32)
    a, bq = np.asarray(o_fp, np.float32), np.asarray(o, np.float32)
    assert np.abs(a - bq).max() / (np.abs(a).max() + 1e-6) < 0.08


def test_span_attention_rolling_two_sources():
    """Rolling-cache variant vs the jnp oracle AND a from-scratch
    full-history oracle with window masking."""
    b, w, kv, g, hd, t = 2, 16, 2, 2, 32, 7
    h = kv * g
    rng = np.random.default_rng(4)
    s_full = 48
    kfull = rng.normal(size=(b, s_full, kv, hd)).astype(np.float32)
    vfull = rng.normal(size=(b, s_full, kv, hd)).astype(np.float32)
    offs_row = [20, 3]
    lens_row = [4, 3]
    kroll = np.zeros((b, w, kv, hd), np.float32)
    vroll = np.zeros((b, w, kv, hd), np.float32)
    for i in range(b):
        for m in range(offs_row[i]):
            kroll[i, m % w] = kfull[i, m]
            vroll[i, m % w] = vfull[i, m]
    pos, seq, ksp, vsp, offs = [], [], [], [], []
    for i in range(b):
        for j in range(lens_row[i]):
            p = offs_row[i] + j
            pos.append(p)
            seq.append(i)
            offs.append(offs_row[i])
            ksp.append(kfull[i, p])
            vsp.append(vfull[i, p])
    q = _rand(rng, (t, h, hd), jnp.float32)
    args = (q, jnp.asarray(kroll), jnp.asarray(vroll),
            jnp.asarray(np.stack(ksp)), jnp.asarray(np.stack(vsp)),
            jnp.asarray(pos, jnp.int32), jnp.asarray(seq, jnp.int32),
            jnp.asarray(offs, jnp.int32))
    nv = jnp.asarray([t], jnp.int32)
    o = span_attention_rolling(*args, nv, window=w, kv_block=8,
                               interpret=True)
    o_ref = A.packed_span_attention_rolling(*args, nv[0], window=w,
                                            kv_block=8)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **TOL)
    # full-history oracle
    for k in range(t):
        i, p = seq[k], pos[k]
        qg = np.asarray(q[k], np.float32).reshape(kv, g, hd)
        sc = np.einsum("ngd,snd->ngs", qg, kfull[i]) * hd ** -0.5
        valid = (np.arange(s_full) <= p) & (np.arange(s_full) > p - w)
        sc = np.where(valid[None, None], sc, -1e30)
        pr = np.exp(sc - sc.max(-1, keepdims=True))
        pr /= pr.sum(-1, keepdims=True)
        ref = np.einsum("ngs,snd->ngd", pr, vfull[i]).reshape(-1)
        np.testing.assert_allclose(np.asarray(o[k], np.float32), ref,
                                   rtol=5e-3, atol=5e-3)


def test_span_attention_rolling_quant_two_sources():
    """int8 + sliding-window kernel twin vs the jnp oracle
    packed_span_attention_rolling_quant (previously the only
    implementation), plus a drift bound against the fp rolling oracle."""
    b, w, kv, g, hd, t = 2, 16, 2, 2, 32, 7
    h = kv * g
    rng = np.random.default_rng(6)
    s_full = 48
    kfull = rng.normal(size=(b, s_full, kv, hd)).astype(np.float32)
    vfull = rng.normal(size=(b, s_full, kv, hd)).astype(np.float32)
    offs_row = [20, 3]
    lens_row = [4, 3]
    kroll = np.zeros((b, w, kv, hd), np.float32)
    vroll = np.zeros((b, w, kv, hd), np.float32)
    for i in range(b):
        for m in range(offs_row[i]):
            kroll[i, m % w] = kfull[i, m]
            vroll[i, m % w] = vfull[i, m]
    pos, seq, ksp, vsp, offs = [], [], [], [], []
    for i in range(b):
        for j in range(lens_row[i]):
            p = offs_row[i] + j
            pos.append(p)
            seq.append(i)
            offs.append(offs_row[i])
            ksp.append(kfull[i, p])
            vsp.append(vfull[i, p])
    q = _rand(rng, (t, h, hd), jnp.float32)
    k8, ks = A.quantize_kv(jnp.asarray(kroll))
    v8, vs = A.quantize_kv(jnp.asarray(vroll))
    args = (q, k8, ks, v8, vs,
            jnp.asarray(np.stack(ksp)), jnp.asarray(np.stack(vsp)),
            jnp.asarray(pos, jnp.int32), jnp.asarray(seq, jnp.int32),
            jnp.asarray(offs, jnp.int32))
    nv = jnp.asarray([t], jnp.int32)
    o = span_attention_rolling_quant(*args, nv, window=w, kv_block=8,
                                     interpret=True)
    o_ref = A.packed_span_attention_rolling_quant(*args, nv[0], window=w,
                                                  kv_block=8)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=5e-2, atol=5e-2)
    # the s8 x s8 cache source stays close to the fp rolling oracle
    o_fp = A.packed_span_attention_rolling(
        q, jnp.asarray(kroll), jnp.asarray(vroll), args[5], args[6],
        args[7], args[8], args[9], nv[0], window=w, kv_block=8)
    a, bq = np.asarray(o_fp, np.float32), np.asarray(o, np.float32)
    assert np.abs(a - bq).max() / (np.abs(a).max() + 1e-6) < 0.08


def test_span_attention_rolling_quant_masks_bucket_padding():
    """Bucket-padded entries must be dropped by the n_valid mask in the
    quantized rolling kernel too (mirrors the fp test below)."""
    b, w, kv, g, hd = 1, 8, 1, 2, 16
    h = kv * g
    rng = np.random.default_rng(7)
    t_valid, t_pad = 3, 6
    pos_v = np.array([4, 5, 6], np.int32)
    kroll = _rand(rng, (b, w, kv, hd), jnp.float32)
    vroll = _rand(rng, (b, w, kv, hd), jnp.float32)
    k8, ks = A.quantize_kv(kroll)
    v8, vs = A.quantize_kv(vroll)
    ksp_v = rng.normal(size=(t_valid, kv, hd)).astype(np.float32)
    vsp_v = rng.normal(size=(t_valid, kv, hd)).astype(np.float32)

    def run(t_total):
        pos = np.concatenate([pos_v, np.full(t_total - t_valid, pos_v[-1])])
        seq = np.zeros(t_total, np.int32)
        offs = np.full(t_total, 4, np.int32)
        ksp = np.concatenate([ksp_v, np.repeat(ksp_v[-1:], t_total - t_valid, 0)])
        vsp = np.concatenate([vsp_v, np.repeat(vsp_v[-1:], t_total - t_valid, 0)])
        q = np.ones((t_total, h, hd), np.float32)
        o = span_attention_rolling_quant(
            jnp.asarray(q), k8, ks, v8, vs,
            jnp.asarray(ksp), jnp.asarray(vsp),
            jnp.asarray(pos.astype(np.int32)), jnp.asarray(seq),
            jnp.asarray(offs), jnp.asarray([t_valid], jnp.int32),
            window=w, kv_block=8, interpret=True)
        return np.asarray(o[:t_valid], np.float32)

    np.testing.assert_allclose(run(t_valid), run(t_pad), rtol=1e-5, atol=1e-5)


def test_span_attention_rolling_masks_bucket_padding():
    """Bucket-padded span entries duplicate the last valid token; without
    the n_valid mask they would be double-counted in the intra-span
    source.  The kernel and oracle must both drop them."""
    b, w, kv, g, hd = 1, 8, 1, 2, 16
    h = kv * g
    rng = np.random.default_rng(5)
    t_valid, t_pad = 3, 6
    pos_v = np.array([4, 5, 6], np.int32)
    kroll = _rand(rng, (b, w, kv, hd), jnp.float32)
    vroll = _rand(rng, (b, w, kv, hd), jnp.float32)
    ksp_v = rng.normal(size=(t_valid, kv, hd)).astype(np.float32)
    vsp_v = rng.normal(size=(t_valid, kv, hd)).astype(np.float32)

    def run(t_total):
        pos = np.concatenate([pos_v, np.full(t_total - t_valid, pos_v[-1])])
        seq = np.zeros(t_total, np.int32)
        offs = np.full(t_total, 4, np.int32)
        ksp = np.concatenate([ksp_v, np.repeat(ksp_v[-1:], t_total - t_valid, 0)])
        vsp = np.concatenate([vsp_v, np.repeat(vsp_v[-1:], t_total - t_valid, 0)])
        q = np.concatenate([np.ones((t_valid, h, hd), np.float32),
                            np.ones((t_total - t_valid, h, hd), np.float32)])
        o = span_attention_rolling(
            jnp.asarray(q), kroll, vroll, jnp.asarray(ksp), jnp.asarray(vsp),
            jnp.asarray(pos.astype(np.int32)), jnp.asarray(seq),
            jnp.asarray(offs), jnp.asarray([t_valid], jnp.int32),
            window=w, kv_block=8, interpret=True)
        return np.asarray(o[:t_valid], np.float32)

    np.testing.assert_allclose(run(t_valid), run(t_pad), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Paged twins: block-table scalar prefetch (docs/memory.md)
# ---------------------------------------------------------------------------

def _paged_layout(rng, b, s, bs, n_extra=3):
    """Random paged placement for b sequences of s logical slots each:
    shuffled physical blocks + n_extra unused (garbage) blocks, tables
    [B, nb] mapping logical block i -> physical block."""
    nb = -(-s // bs)
    n_phys = b * nb + n_extra
    perm = rng.permutation(n_phys)[:b * nb].reshape(b, nb).astype(np.int32)
    return perm, n_phys, nb


def _scatter_blocks(contig, tables, bs, n_phys, rng):
    """Build the physical [n_phys, bs, ...] cache whose gather under
    ``tables`` reproduces ``contig`` [B, S, ...]; unused blocks hold
    garbage that masking must never let through."""
    b, s = contig.shape[:2]
    nb = tables.shape[1]
    pad = nb * bs - s
    if pad:
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (contig.ndim - 2)
        contig = np.pad(np.asarray(contig, np.float32), widths)
    phys = rng.normal(size=(n_phys, bs) + contig.shape[2:]).astype(np.float32)
    blocks = np.asarray(contig, np.float32).reshape(b, nb, bs, *contig.shape[2:])
    for i in range(b):
        for j in range(nb):
            phys[tables[i, j]] = blocks[i, j]
    return phys


@pytest.mark.parametrize("window", [0, 24])
def test_paged_span_attention_matches_oracle_and_contiguous(window):
    """The paged kernel (block-table scalar prefetch) must match both the
    paged jnp oracle and the contiguous kernel run on the gathered view —
    with physical blocks shuffled and garbage in unused blocks."""
    from repro.kernels.span_attention import paged_span_attention
    b, s, h, kv, hd, t, bs = 3, 64, 4, 2, 32, 10, 16
    rng = np.random.default_rng(11)
    kc = np.asarray(_rand(rng, (b, s, kv, hd), jnp.float32))
    vc = np.asarray(_rand(rng, (b, s, kv, hd), jnp.float32))
    q = _rand(rng, (t, h, hd))
    pos, seq = _packed_batch(rng, b, s, t)
    tables, n_phys, nb = _paged_layout(rng, b, s, bs)
    kp = jnp.asarray(_scatter_blocks(kc, tables, bs, n_phys, rng),
                     jnp.bfloat16)
    vp = jnp.asarray(_scatter_blocks(vc, tables, bs, n_phys, rng),
                     jnp.bfloat16)
    tb = jnp.asarray(tables)
    o = paged_span_attention(q, kp, vp, pos, seq, tb, window=window,
                             interpret=True)
    o_oracle = A.paged_span_attention(q, kp, vp, tb, pos, seq,
                                      window=window, kv_block=bs)
    o_contig = span_attention(q, jnp.asarray(kc, jnp.bfloat16),
                              jnp.asarray(vc, jnp.bfloat16), pos, seq,
                              window=window, kv_block=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_oracle, np.float32), **TOL)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_contig, np.float32), **TOL)


def test_paged_span_attention_quant_matches_oracle():
    from repro.kernels.span_attention import paged_span_attention_quant
    b, s, h, kv, hd, t, bs = 2, 64, 4, 2, 32, 8, 16
    rng = np.random.default_rng(12)
    kc = _rand(rng, (b, s, kv, hd), jnp.float32)
    vc = _rand(rng, (b, s, kv, hd), jnp.float32)
    k8c, ksc = A.quantize_kv(kc)
    v8c, vsc = A.quantize_kv(vc)
    q = _rand(rng, (t, h, hd))
    pos, seq = _packed_batch(rng, b, s, t)
    tables, n_phys, nb = _paged_layout(rng, b, s, bs)
    tb = jnp.asarray(tables)
    k8 = jnp.asarray(_scatter_blocks(np.asarray(k8c, np.float32), tables,
                                     bs, n_phys, rng), jnp.int8)
    v8 = jnp.asarray(_scatter_blocks(np.asarray(v8c, np.float32), tables,
                                     bs, n_phys, rng), jnp.int8)
    ks = jnp.asarray(_scatter_blocks(np.asarray(ksc, np.float32), tables,
                                     bs, n_phys, rng), jnp.bfloat16)
    vs = jnp.asarray(_scatter_blocks(np.asarray(vsc, np.float32), tables,
                                     bs, n_phys, rng), jnp.bfloat16)
    o = paged_span_attention_quant(q, k8, ks, v8, vs, pos, seq, tb,
                                   interpret=True)
    o_oracle = A.paged_span_attention_quant(q, k8, ks, v8, vs, tb, pos,
                                            seq, kv_block=bs)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_oracle, np.float32), **TOL)
    # and against the contiguous quant kernel on the gathered view
    o_contig = span_attention_quant(q, jnp.asarray(k8c), jnp.asarray(ksc),
                                    jnp.asarray(v8c), jnp.asarray(vsc),
                                    pos, seq, kv_block=bs, interpret=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_contig, np.float32), **TOL)


def test_paged_span_attention_rolling_matches_oracle():
    """Rolling (sliding-window) paged twin: full-window tables, wrapped
    offsets — view width nb*bs == W so the stored-position modulus
    matches the contiguous rolling kernel exactly."""
    from repro.kernels.span_attention import paged_span_attention_rolling
    b, w, kv, g, hd, t, bs = 2, 32, 2, 2, 32, 6, 8
    h = kv * g
    rng = np.random.default_rng(13)
    kroll = np.asarray(_rand(rng, (b, w, kv, hd), jnp.float32))
    vroll = np.asarray(_rand(rng, (b, w, kv, hd), jnp.float32))
    q = _rand(rng, (t, h, hd))
    ksp = _rand(rng, (t, kv, hd))
    vsp = _rand(rng, (t, kv, hd))
    offs = np.array([40, 40, 40, 7, 7, 7], np.int32)   # row0 wrapped, row1 not
    pos = np.array([40, 41, 42, 7, 8, 9], np.int32)
    seq = np.array([0, 0, 0, 1, 1, 1], np.int32)
    tables, n_phys, nb = _paged_layout(rng, b, w, bs)
    tb = jnp.asarray(tables)
    kp = jnp.asarray(_scatter_blocks(kroll, tables, bs, n_phys, rng),
                     jnp.bfloat16)
    vp = jnp.asarray(_scatter_blocks(vroll, tables, bs, n_phys, rng),
                     jnp.bfloat16)
    args = (q, jnp.asarray(pos), jnp.asarray(seq), jnp.asarray(offs),
            jnp.asarray([t], jnp.int32))
    o = paged_span_attention_rolling(q, kp, vp, ksp, vsp, *args[1:], tb,
                                     window=w, interpret=True)
    o_oracle = A.paged_span_attention_rolling(
        q, kp, vp, ksp, vsp, tb, args[1], args[2], args[3], args[4][0],
        window=w, kv_block=bs)
    o_contig = span_attention_rolling(
        q, jnp.asarray(kroll, jnp.bfloat16), jnp.asarray(vroll, jnp.bfloat16),
        ksp, vsp, args[1], args[2], args[3], args[4], window=w, kv_block=bs,
        interpret=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_oracle, np.float32), **TOL)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_contig, np.float32), **TOL)


def test_paged_span_attention_rolling_quant_matches_oracle():
    from repro.kernels.span_attention import (
        paged_span_attention_rolling_quant,
    )
    b, w, kv, g, hd, t, bs = 2, 16, 1, 2, 16, 4, 8
    h = kv * g
    rng = np.random.default_rng(14)
    kroll = _rand(rng, (b, w, kv, hd), jnp.float32)
    vroll = _rand(rng, (b, w, kv, hd), jnp.float32)
    k8c, ksc = A.quantize_kv(kroll)
    v8c, vsc = A.quantize_kv(vroll)
    q = _rand(rng, (t, h, hd))
    ksp = _rand(rng, (t, kv, hd))
    vsp = _rand(rng, (t, kv, hd))
    offs = np.array([20, 20, 5, 5], np.int32)
    pos = np.array([20, 21, 5, 6], np.int32)
    seq = np.array([0, 0, 1, 1], np.int32)
    tables, n_phys, nb = _paged_layout(rng, b, w, bs)
    tb = jnp.asarray(tables)
    k8 = jnp.asarray(_scatter_blocks(np.asarray(k8c, np.float32), tables,
                                     bs, n_phys, rng), jnp.int8)
    v8 = jnp.asarray(_scatter_blocks(np.asarray(v8c, np.float32), tables,
                                     bs, n_phys, rng), jnp.int8)
    ks = jnp.asarray(_scatter_blocks(np.asarray(ksc, np.float32), tables,
                                     bs, n_phys, rng), jnp.bfloat16)
    vs = jnp.asarray(_scatter_blocks(np.asarray(vsc, np.float32), tables,
                                     bs, n_phys, rng), jnp.bfloat16)
    nv = jnp.asarray([t], jnp.int32)
    o = paged_span_attention_rolling_quant(
        q, k8, ks, v8, vs, ksp, vsp, jnp.asarray(pos), jnp.asarray(seq),
        jnp.asarray(offs), nv, tb, window=w, interpret=True)
    o_oracle = A.paged_span_attention_rolling_quant(
        q, k8, ks, v8, vs, ksp, vsp, tb, jnp.asarray(pos),
        jnp.asarray(seq), jnp.asarray(offs), nv[0], window=w, kv_block=bs)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_oracle, np.float32),
                               rtol=5e-2, atol=5e-2)
