import os
import sys

# Tests run on the single real CPU device (the 512-device override belongs
# ONLY to launch/dryrun.py).  Force a small test-friendly config.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
