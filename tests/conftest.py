import os
import sys

# Tests run on the single real CPU device (the 512-device override belongs
# ONLY to launch/dryrun.py).  Force a small test-friendly config.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The container may lack `hypothesis`; fall back to the minimal vendored
# shim so property-style tests still collect and run (deterministic
# pseudo-random examples instead of real shrinking search).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_shim

    _hypothesis_shim.install()

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
