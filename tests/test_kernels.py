"""Pallas kernels vs. pure-jnp oracles (interpret mode), sweeping shapes,
dtypes, GQA ratios, windows and ragged lengths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.swiglu import swiglu

TOL = dict(rtol=2e-2, atol=2e-2)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("b,s,h,kv,hd", [
    (1, 64, 4, 4, 64),     # MHA
    (2, 128, 8, 2, 64),    # GQA 4:1
    (1, 96, 6, 1, 128),    # MQA, non-pow2 seq
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, s, h, kv, hd, dtype, causal):
    ks = jax.random.split(jax.random.key(0), 3)
    q = _rand(ks[0], (b, h, s, hd), dtype)
    k = _rand(ks[1], (b, kv, s, hd), dtype)
    v = _rand(ks[2], (b, kv, s, hd), dtype)
    o = flash_attention(q, k, v, causal=causal, q_block=32, kv_block=32,
                        interpret=True)
    o_ref = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal)
    np.testing.assert_allclose(
        np.asarray(o.transpose(0, 2, 1, 3).reshape(b, s, h * hd), np.float32),
        np.asarray(o_ref, np.float32), **TOL)


@pytest.mark.parametrize("window", [16, 48])
def test_flash_attention_sliding_window(window):
    b, s, h, kv, hd = 1, 128, 4, 2, 64
    ks = jax.random.split(jax.random.key(1), 3)
    q = _rand(ks[0], (b, h, s, hd), jnp.bfloat16)
    k = _rand(ks[1], (b, kv, s, hd), jnp.bfloat16)
    v = _rand(ks[2], (b, kv, s, hd), jnp.bfloat16)
    o = flash_attention(q, k, v, causal=True, window=window,
                        q_block=32, kv_block=32, interpret=True)
    o_ref = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(o.transpose(0, 2, 1, 3).reshape(b, s, h * hd), np.float32),
        np.asarray(o_ref, np.float32), **TOL)


def test_flash_matches_model_attention_path():
    """Kernel agrees with the distribution-path chunked jnp attention."""
    from repro.models.attention import chunked_attention

    b, s, h, kv, hd = 2, 64, 4, 2, 64
    ks = jax.random.split(jax.random.key(2), 3)
    q = _rand(ks[0], (b, s, h, hd), jnp.bfloat16)
    k = _rand(ks[1], (b, s, kv, hd), jnp.bfloat16)
    v = _rand(ks[2], (b, s, kv, hd), jnp.bfloat16)
    o_jnp = chunked_attention(q, k, v, causal=True, kv_block=32)
    o_krn = ops.flash_attention_bshd(q, k, v, causal=True,
                                     q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(o_jnp, np.float32),
                               np.asarray(o_krn, np.float32), **TOL)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("b,s,h,kv,hd", [
    (2, 128, 8, 2, 64),
    (3, 64, 4, 4, 128),
    (1, 256, 16, 1, 64),
])
def test_decode_attention_ragged_lengths(b, s, h, kv, hd, dtype):
    ks = jax.random.split(jax.random.key(3), 4)
    q = _rand(ks[0], (b, h, hd), dtype)
    kc = _rand(ks[1], (b, s, kv, hd), dtype)
    vc = _rand(ks[2], (b, s, kv, hd), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, s + 1, b), jnp.int32)
    o = decode_attention(q, kc, vc, lengths, kv_block=32, interpret=True)
    o_ref = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **TOL)


@pytest.mark.parametrize("t,d,f", [(64, 128, 256), (32, 64, 96), (128, 256, 512)])
def test_swiglu_sweep(t, d, f):
    ks = jax.random.split(jax.random.key(4), 4)
    x = _rand(ks[0], (t, d), jnp.bfloat16)
    w1 = _rand(ks[1], (d, f), jnp.bfloat16) * 0.1
    w3 = _rand(ks[2], (d, f), jnp.bfloat16) * 0.1
    w2 = _rand(ks[3], (f, d), jnp.bfloat16) * 0.1
    y = swiglu(x, w1, w3, w2, t_block=16, f_block=32, interpret=True)
    y_ref = ref.swiglu_ref(x, w1, w3, w2)
    # bf16: kernel keeps the gate in fp32 where the oracle rounds, so the
    # comparison is absolute-tolerance dominated; scale by output magnitude
    yr = np.asarray(y_ref, np.float32)
    atol = 0.03 * max(float(np.abs(yr).max()), 1.0)
    np.testing.assert_allclose(np.asarray(y, np.float32), yr,
                               rtol=5e-2, atol=atol)


def test_swiglu_accumulation_over_many_f_blocks():
    """Numerical check that partial-ff accumulation is exact in fp32."""
    t, d, f = 16, 32, 512
    x = jnp.ones((t, d), jnp.float32) * 0.01
    w1 = jnp.ones((d, f), jnp.float32) * 0.02
    w3 = jnp.ones((d, f), jnp.float32) * 0.03
    w2 = jnp.ones((f, d), jnp.float32) * 0.04
    y = swiglu(x, w1, w3, w2, t_block=16, f_block=32, interpret=True)
    y_ref = ref.swiglu_ref(x, w1, w3, w2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5)


@pytest.mark.parametrize("t,d,f", [(64, 128, 256), (32, 256, 128)])
def test_rmsnorm_matmul_fused(t, d, f):
    from repro.kernels.rmsnorm_matmul import rmsnorm_matmul

    ks = jax.random.split(jax.random.key(7), 3)
    x = _rand(ks[0], (t, d), jnp.bfloat16)
    wn = jnp.abs(_rand(ks[1], (d,), jnp.bfloat16)) + 0.5
    wp = _rand(ks[2], (d, f), jnp.bfloat16) * 0.1
    y = rmsnorm_matmul(x, wn, wp, t_block=16, f_block=64, interpret=True)
    y_ref = ref.rmsnorm_matmul_ref(x, wn, wp)
    yr = np.asarray(y_ref, np.float32)
    np.testing.assert_allclose(np.asarray(y, np.float32), yr,
                               rtol=5e-2, atol=0.03 * max(float(np.abs(yr).max()), 1.0))
