"""Overlapped CPU sampling (sampling iteration n on a host worker while
the device runs n+1): the FIFO worker must preserve sampler-call order —
token streams are IDENTICAL with the overlap on or off — and must surface
sampler crashes to the serving thread instead of hanging the gate."""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import EngineConfig, NaivePPEngine, SiPipeEngine
from repro.core.sampler import SamplingWorker
from repro.core.sampling_params import SamplingParams
from repro.models import ShardCtx, build_model


# ---------------------------------------------------------------------------
# SamplingWorker unit behavior
# ---------------------------------------------------------------------------

def test_worker_preserves_submission_order():
    seen = []
    w = SamplingWorker(lambda sched, logits: seen.append(sched))
    for i in range(64):
        w.submit(i, None)
    w.stop()
    assert seen == list(range(64))


def test_worker_surfaces_crashes():
    def boom(sched, logits):
        raise ValueError("bad sampler")

    w = SamplingWorker(boom)
    w.submit(0, None)
    deadline = time.monotonic() + 5
    while time.monotonic() < deadline:
        try:
            w.check()
            time.sleep(0.005)
        except RuntimeError as e:
            assert isinstance(e.__cause__, ValueError)
            break
    else:
        pytest.fail("worker crash never surfaced")
    # later submissions drain without re-raising inside the thread
    w.submit(1, None)
    w.stop()


# ---------------------------------------------------------------------------
# Engine: token-identical streams with the overlap on/off
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single())
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _run(model, params, vocab, *, overlap, engine_cls=SiPipeEngine,
         chunk=None):
    rng = np.random.default_rng(5)
    prompts = [list(rng.integers(2, vocab, size=n)) for n in (11, 6, 9)]
    eng = engine_cls(model, params, EngineConfig(
        pp_degree=2, max_batch=2, max_seq_len=64, n_samplers=2,
        prefill_chunk_tokens=chunk, overlap_sampling=overlap, seed=7))
    sp = SamplingParams(temperature=0.8, top_k=12, top_p=0.9,
                        frequency_penalty=0.2, max_new_tokens=6)
    for p in prompts:
        eng.add_request(p, sp)
    done = sorted(eng.run(), key=lambda s: s.seq_id)
    return [s.output_ids for s in done], eng


@pytest.mark.parametrize("chunk", [None, 8])
def test_overlap_token_identical(model_and_params, chunk):
    cfg, model, params = model_and_params
    on, eng_on = _run(model, params, cfg.vocab_size, overlap=True,
                      chunk=chunk)
    off, eng_off = _run(model, params, cfg.vocab_size, overlap=False,
                        chunk=chunk)
    assert eng_on.sampling_worker is not None
    assert eng_off.sampling_worker is None
    assert on == off
    assert all(o for o in on)


def test_naive_engine_forces_overlap_off(model_and_params):
    cfg, model, params = model_and_params
    _, eng = _run(model, params, cfg.vocab_size, overlap=True,
                  engine_cls=NaivePPEngine)
    assert eng.cfg.overlap_sampling is False
    assert eng.sampling_worker is None
