"""Server-side admission control (docs/http.md §Admission): queue caps,
the dispatch window, and the priority -> per-tenant-fair-share -> FIFO
dispatch order.  Pure threading-level tests, no engine anywhere."""
import pytest

from repro.serving.admission import AdmissionController, Closed, QueueFull


def _drain(ac, *tickets):
    for t in tickets:
        ac.release(t)


def test_rejects_when_queue_full_without_touching_dispatched():
    ac = AdmissionController(max_queue=2, max_active=1)
    a = ac.submit()                      # dispatched (window of 1)
    b = ac.submit()                      # pending 1/2
    c = ac.submit()                      # pending 2/2
    with pytest.raises(QueueFull) as ei:
        ac.submit()
    assert ei.value.retry_after == 1
    # the running ticket and the queue are unperturbed by the reject
    assert a.dispatched.is_set()
    assert not b.dispatched.is_set() and not c.dispatched.is_set()
    s = ac.snapshot()
    assert s["admission_rejected_total"] == 1
    assert s["admission_pending"] == 2 and s["admission_active"] == 1


def test_dispatch_window_caps_active_and_release_refills():
    ac = AdmissionController(max_queue=8, max_active=2)
    t = [ac.submit() for _ in range(4)]
    assert [x.dispatched.is_set() for x in t] == [True, True, False, False]
    ac.release(t[0])
    assert t[2].dispatched.is_set() and not t[3].dispatched.is_set()
    assert ac.wait(t[2], timeout=0)


def test_priority_beats_arrival_order():
    ac = AdmissionController(max_queue=8, max_active=1)
    hold = ac.submit()
    low = ac.submit(priority=0)
    high = ac.submit(priority=5)
    ac.release(hold)
    assert high.dispatched.is_set() and not low.dispatched.is_set()


def test_tenant_fair_share_at_equal_priority():
    """Window of 2 filled by tenant A; at release time B's request wins
    over A's earlier-arrived third request (fewest in-flight first)."""
    ac = AdmissionController(max_queue=8, max_active=2)
    a1 = ac.submit(tenant="A")
    a2 = ac.submit(tenant="A")
    a3 = ac.submit(tenant="A")           # arrived before b1
    b1 = ac.submit(tenant="B")
    assert not a3.dispatched.is_set() and not b1.dispatched.is_set()
    ac.release(a1)
    assert b1.dispatched.is_set() and not a3.dispatched.is_set()
    ac.release(a2)
    assert a3.dispatched.is_set()
    _drain(ac, a3, b1)
    assert ac.snapshot()["admission_active"] == 0


def test_priority_overrides_fair_share():
    ac = AdmissionController(max_queue=8, max_active=1)
    a1 = ac.submit(tenant="A")
    a2 = ac.submit(tenant="A", priority=9)
    b1 = ac.submit(tenant="B", priority=0)
    ac.release(a1)
    # B has fewer in-flight, but A's ticket outranks on priority
    assert a2.dispatched.is_set() and not b1.dispatched.is_set()


def test_fifo_breaks_full_ties():
    ac = AdmissionController(max_queue=8, max_active=1)
    hold = ac.submit(tenant="A")
    x = ac.submit(tenant="B")
    y = ac.submit(tenant="C")
    ac.release(hold)
    assert x.dispatched.is_set() and not y.dispatched.is_set()


def test_release_is_idempotent_and_cancels_undispatched():
    ac = AdmissionController(max_queue=8, max_active=1)
    a = ac.submit()
    b = ac.submit()
    ac.release(b)                        # undispatched -> cancelled
    assert b.cancelled and not b.dispatched.is_set()
    ac.release(b)                        # no double-decrement
    ac.release(a)
    ac.release(a)
    s = ac.snapshot()
    assert s["admission_active"] == 0 and s["admission_pending"] == 0


def test_close_cancels_pending_and_rejects_new():
    ac = AdmissionController(max_queue=8, max_active=1)
    a = ac.submit()
    b = ac.submit()
    ac.close()
    # waiter wakes and must check .cancelled
    assert ac.wait(b, timeout=1.0) and b.cancelled
    assert not a.cancelled               # dispatched work keeps running
    with pytest.raises(Closed):
        ac.submit()


def test_unbounded_window_dispatches_immediately():
    ac = AdmissionController(max_queue=4, max_active=None)
    t = [ac.submit() for _ in range(4)]
    assert all(x.dispatched.is_set() for x in t)
    # pending stays empty, so the queue cap never triggers
    u = ac.submit()
    assert u.dispatched.is_set()


def test_snapshot_counters():
    ac = AdmissionController(max_queue=1, max_active=1)
    a = ac.submit()
    b = ac.submit()
    with pytest.raises(QueueFull):
        ac.submit()
    ac.release(a)
    s = ac.snapshot()
    assert s["admission_admitted_total"] == 2
    assert s["admission_rejected_total"] == 1
    assert s["admission_dispatched_total"] == 2
    assert s["admission_active"] == 1 and s["admission_pending"] == 0
    ac.release(b)


# ---------------------------------------------------------------------------
# Hybrid tiers (docs/hybrid.md): offline bypasses the online window
# ---------------------------------------------------------------------------

def test_offline_tickets_bypass_the_online_window():
    """Offline tickets dispatch immediately even with the online window
    full — pacing happens in the engine's slack scheduler, not here —
    and never consume online queue/window capacity."""
    ac = AdmissionController(max_queue=1, max_active=1)
    hold = ac.submit()                   # fills the online window
    off = [ac.submit(tier="offline") for _ in range(3)]
    assert all(t.dispatched.is_set() for t in off)
    assert all(t.tier == "offline" for t in off)
    # online capacity untouched by the offline traffic
    on = ac.submit()                     # pending 1/1 — not rejected
    assert not on.dispatched.is_set()
    s = ac.snapshot()
    assert s["admission_offline_live"] == 3
    assert s["admission_offline_admitted_total"] == 3
    assert s["admission_active"] == 1 and s["admission_pending"] == 1
    # offline release never pumps the online window
    _drain(ac, *off)
    assert not on.dispatched.is_set()
    assert ac.snapshot()["admission_offline_live"] == 0
    ac.release(hold)
    assert on.dispatched.is_set()


def test_offline_cap_rejects_with_offline_tier_tag():
    ac = AdmissionController(max_queue=1, max_active=1, max_queue_offline=2)
    t = [ac.submit(tier="offline") for _ in range(2)]
    with pytest.raises(QueueFull) as ei:
        ac.submit(tier="offline")
    assert ei.value.tier == "offline"
    assert ei.value.retry_after >= 1
    # the ONLINE queue is still wide open (distinct pools)
    on = ac.submit()
    assert on.dispatched.is_set() and on.tier == "online"
    assert ac.snapshot()["admission_offline_rejected_total"] == 1
    _drain(ac, *t)


def test_online_queue_full_reports_online_tier():
    ac = AdmissionController(max_queue=1, max_active=1)
    ac.submit()
    ac.submit()
    with pytest.raises(QueueFull) as ei:
        ac.submit()
    assert ei.value.tier == "online"


# ---------------------------------------------------------------------------
# Drain-rate Retry-After (satellite: no more constant 1)
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_retry_after_reflects_measured_drain_rate():
    """After observed releases, the 429 hint is (depth+1)/rate rounded
    up — a queue draining one request per 4s with one waiter ahead of
    you says 'come back in 8s', not '1s'."""
    clk = _Clock()
    ac = AdmissionController(max_queue=1, max_active=1, clock=clk)
    a = ac.submit()
    b = ac.submit()
    ac.release(a)                        # release at t=0
    clk.t = 4.0
    ac.release(b)                        # second release: rate = 0.25/s
    c = ac.submit()                      # window free again
    ac.submit()                          # pending 1/1
    with pytest.raises(QueueFull) as ei:
        ac.submit()
    assert ei.value.retry_after == 8     # ceil((1 + 1) / 0.25)
    ac.release(c)


def test_retry_after_clamps_to_sane_bounds():
    clk = _Clock()
    ac = AdmissionController(max_queue=1, max_active=1, clock=clk)
    a = ac.submit()
    b = ac.submit()
    ac.release(a)
    clk.t = 0.001                        # blistering drain -> clamp low
    ac.release(b)
    c = ac.submit()
    ac.submit()
    with pytest.raises(QueueFull) as ei:
        ac.submit()
    assert ei.value.retry_after == 1
    ac.release(c)

    clk2 = _Clock()
    ac2 = AdmissionController(max_queue=1, max_active=1, clock=clk2)
    a = ac2.submit()
    b = ac2.submit()
    ac2.release(a)
    clk2.t = 500.0                       # glacial drain -> clamp at 60
    ac2.release(b)
    c = ac2.submit()
    ac2.submit()
    with pytest.raises(QueueFull) as ei:
        ac2.submit()
    assert ei.value.retry_after == 60
    ac2.release(c)


def test_retry_after_falls_back_without_history():
    # fewer than two observed releases: keep the configured constant
    ac = AdmissionController(max_queue=1, max_active=1, retry_after_s=3)
    ac.submit()
    ac.submit()
    with pytest.raises(QueueFull) as ei:
        ac.submit()
    assert ei.value.retry_after == 3
