"""Corrected HLO cost analysis: loop trip multiplication + byte model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _compile(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_multiplied_by_trip_count():
    L, M = 7, 64

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c

    comp = _compile(f, (32, M), (L, M, M))
    s = H.analyze(comp.as_text())
    expect = L * 2 * 32 * M * M
    assert s.flops == pytest.approx(expect, rel=0.05), (s.flops, expect)


def test_flops_without_loop():
    def f(a, b):
        return a @ b

    comp = _compile(f, (64, 128), (128, 32))
    s = H.analyze(comp.as_text())
    assert s.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_nested_scan_multiplies_both_trips():
    Lo, Li, M = 3, 5, 32

    def f(x, w):
        def outer(c, wo):
            def inner(ci, _):
                return jnp.tanh(ci @ wo), None
            ci, _ = jax.lax.scan(inner, c, None, length=Li)
            return ci, None
        c, _ = jax.lax.scan(outer, x, w)
        return c

    comp = _compile(f, (16, M), (Lo, M, M))
    s = H.analyze(comp.as_text())
    expect = Lo * Li * 2 * 16 * M * M
    assert s.flops == pytest.approx(expect, rel=0.05)


def test_bytes_scale_with_loop():
    """Per-iteration weight reads must be multiplied by the trip count."""
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0]

    c3 = _compile(f, (8, 64), (3, 64, 64))
    c12 = _compile(f, (8, 64), (12, 64, 64))
    b3 = H.analyze(c3.as_text()).bytes_accessed
    b12 = H.analyze(c12.as_text()).bytes_accessed
    assert b12 > 2.5 * b3


def test_dynamic_slice_charged_by_slice():
    """Reading one row of a big table must not charge the whole table."""
    def f(t, i):
        return jax.lax.dynamic_slice_in_dim(t, 0, 4, 0) * 1.0

    comp = _compile(f, (4096, 256), (1,))
    s = H.analyze(comp.as_text())
    table_bytes = 4096 * 256 * 4
    assert s.bytes_accessed < table_bytes / 10


def test_parse_shapes():
    assert H._parse_shapes("bf16[2,3]{1,0}") == [("bf16", (2, 3))]
    assert H._parse_shapes("(f32[4], s32[])") == [("f32", (4,)), ("s32", ())]
    assert H._nbytes("bf16[10,10]") == 200
    assert H._nbytes("f32[10]", normalize_f32=True) == 20


def test_collective_detection_on_psum():
    import os
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (dry-run covers multi-device)")
