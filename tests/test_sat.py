"""Structure-aware transmission (§5.3): correctness + round elimination."""
import numpy as np
import pytest

from repro.core.sat import (
    StructureAwareChannel,
    StructureSignature,
    StructureUnawareChannel,
)


def _tensors(b, d=16, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "hidden": rng.normal(size=(b, d)).astype(np.float32),
        "residual": rng.normal(size=(b, d)).astype(np.float32),
    }


def test_unaware_roundtrip():
    ch = StructureUnawareChannel()
    t = _tensors(4)
    ch.send(t)
    out = ch.recv()
    for k in t:
        np.testing.assert_array_equal(out[k], t[k])
    # 2 metadata rounds + one per tensor
    assert ch.wire.rounds == 2 + len(t)


def test_aware_roundtrip_and_round_elimination():
    ch = StructureAwareChannel()
    for it in range(5):
        t = _tensors(4, seed=it)
        ch.send(t)
        out = ch.recv()
        for k in t:
            np.testing.assert_array_equal(out[k], t[k])
    # first iteration: full protocol (4 rounds); then 1 round each
    assert ch.captures == 1
    assert ch.wire.rounds == (2 + 2) + 4 * 1


def test_aware_handles_batch_size_change():
    """Batch size is the only dynamic factor — no recapture needed."""
    ch = StructureAwareChannel()
    for b in (4, 4, 2, 6, 2):
        t = _tensors(b, seed=b)
        ch.send(t)
        out = ch.recv()
        for k in t:
            np.testing.assert_array_equal(out[k], t[k])
    assert ch.captures == 1  # trailing dims unchanged -> structure stable


def test_aware_recaptures_on_structure_change():
    ch = StructureAwareChannel()
    ch.send(_tensors(4))
    ch.recv()
    t2 = {**_tensors(4), "extra": np.zeros((4, 3), np.int32)}
    ch.send(t2)
    out = ch.recv()
    assert set(out) == set(t2)
    assert ch.captures == 2


def test_signature_ignores_batch_dim():
    a = StructureSignature.of(_tensors(4))
    b = StructureSignature.of(_tensors(9, seed=5))
    assert a == b
    c = StructureSignature.of({"hidden": np.zeros((4, 17), np.float32),
                               "residual": np.zeros((4, 16), np.float32)})
    assert a != c


def test_prealloc_buffers_are_reused():
    ch = StructureAwareChannel()
    ch.send(_tensors(4))
    ch.recv()
    ch.send(_tensors(4, seed=1))
    o1 = ch.recv()
    ch.send(_tensors(4, seed=2))
    o2 = ch.recv()
    # steady state writes into the same pre-posted buffer (zero-alloc)
    assert o1["hidden"] is o2["hidden"]
