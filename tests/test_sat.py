"""Structure-aware transmission (§5.3): correctness + round elimination."""
import numpy as np
import pytest

from repro.core.sat import (
    StructureAwareChannel,
    StructureSignature,
    StructureUnawareChannel,
)


def _tensors(b, d=16, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "hidden": rng.normal(size=(b, d)).astype(np.float32),
        "residual": rng.normal(size=(b, d)).astype(np.float32),
    }


def test_unaware_roundtrip():
    ch = StructureUnawareChannel()
    t = _tensors(4)
    ch.send(t)
    out = ch.recv()
    for k in t:
        np.testing.assert_array_equal(out[k], t[k])
    # 2 metadata rounds + one per tensor
    assert ch.wire.rounds == 2 + len(t)


def test_aware_roundtrip_and_round_elimination():
    ch = StructureAwareChannel()
    for it in range(5):
        t = _tensors(4, seed=it)
        ch.send(t)
        out = ch.recv()
        for k in t:
            np.testing.assert_array_equal(out[k], t[k])
    # first iteration: full protocol (4 rounds); then 1 round each
    assert ch.captures == 1
    assert ch.wire.rounds == (2 + 2) + 4 * 1


def test_aware_handles_batch_size_change():
    """Batch size is the only dynamic factor — no recapture needed."""
    ch = StructureAwareChannel()
    for b in (4, 4, 2, 6, 2):
        t = _tensors(b, seed=b)
        ch.send(t)
        out = ch.recv()
        for k in t:
            np.testing.assert_array_equal(out[k], t[k])
    assert ch.captures == 1  # trailing dims unchanged -> structure stable


def test_aware_recaptures_on_structure_change():
    ch = StructureAwareChannel()
    ch.send(_tensors(4))
    ch.recv()
    t2 = {**_tensors(4), "extra": np.zeros((4, 3), np.int32)}
    ch.send(t2)
    out = ch.recv()
    assert set(out) == set(t2)
    assert ch.captures == 2


def test_signature_ignores_batch_dim():
    a = StructureSignature.of(_tensors(4))
    b = StructureSignature.of(_tensors(9, seed=5))
    assert a == b
    c = StructureSignature.of({"hidden": np.zeros((4, 17), np.float32),
                               "residual": np.zeros((4, 16), np.float32)})
    assert a != c


def test_prealloc_buffers_are_reused():
    ch = StructureAwareChannel()
    ch.send(_tensors(4))
    ch.recv()
    ch.send(_tensors(4, seed=1))
    o1 = ch.recv()
    ch.send(_tensors(4, seed=2))
    o2 = ch.recv()
    # steady state writes into the same pre-posted buffer (zero-alloc)
    assert o1["hidden"] is o2["hidden"]


# ---------------------------------------------------------------------------
# Steady-state equivalence with the structure-unaware baseline
# ---------------------------------------------------------------------------

def _assert_same_payload(a, b):
    assert set(a) == set(b)
    for k in a:
        assert a[k].dtype == b[k].dtype
        assert a[k].shape == b[k].shape
        assert a[k].tobytes() == b[k].tobytes()


def test_aware_matches_unaware_across_batch_changes():
    """SAT must be a pure transport optimization: across batch-size
    changes its steady-state output matches the unaware channel
    byte-for-byte."""
    aware, unaware = StructureAwareChannel(), StructureUnawareChannel()
    for i, b in enumerate((4, 4, 2, 6, 2, 8)):
        t = _tensors(b, seed=100 + i)
        aware.send(t)
        unaware.send(t)
        _assert_same_payload(aware.recv(), unaware.recv())
    assert aware.captures == 1          # batch dim alone never recaptures


def test_aware_matches_unaware_across_structure_recaptures():
    """Structure changes (new keys, dtype flips) force a recapture round;
    payloads must still match the baseline byte-for-byte through it."""
    aware, unaware = StructureAwareChannel(), StructureUnawareChannel()
    payloads = [
        _tensors(4, seed=0),
        _tensors(3, seed=1),
        {**_tensors(3, seed=2), "extra": np.arange(6, dtype=np.int32).reshape(3, 2)},
        {**_tensors(5, seed=3), "extra": np.arange(10, dtype=np.int32).reshape(5, 2)},
        _tensors(4, seed=4),            # key removed -> recapture again
        _tensors(2, seed=5),
    ]
    for t in payloads:
        aware.send(t)
        unaware.send(t)
        _assert_same_payload(aware.recv(), unaware.recv())
    assert aware.captures == 3


def test_aware_single_round_in_steady_state_after_recapture():
    ch = StructureAwareChannel()
    ch.send(_tensors(4))
    ch.recv()
    before = ch.wire.rounds
    for i in range(3):
        ch.send(_tensors(4, seed=10 + i))
        ch.recv()
    assert ch.wire.rounds - before == 3  # one round per steady iteration


def test_prealloc_invalidated_on_recapture():
    """Same batch size, different trailing dims across a recapture: the
    receiver must not reuse buffers preallocated under the old structure
    (chunked-prefill phase boundaries hit exactly this)."""
    ch = StructureAwareChannel()
    wide = {"hidden": np.ones((1, 6, 64), np.float32)}
    flat = {"hidden": np.full((1, 64), 2.0, np.float32)}
    for payload in (wide, wide, flat, flat, wide, flat):
        ch.send(payload)
        out = ch.recv()
        assert out["hidden"].shape == payload["hidden"].shape
        np.testing.assert_array_equal(out["hidden"], payload["hidden"])
    assert ch.captures == 4


def test_producer_running_ahead_of_consumer():
    """A pipeline producer can send iteration n+1 (even a recapture)
    before the consumer reads iteration n; the single-wire FIFO must
    keep parsing aligned."""
    ch = StructureAwareChannel()
    payloads = [_tensors(4, seed=0), _tensors(4, seed=1),
                {"other": np.arange(8, dtype=np.float32)},   # recapture
                _tensors(2, seed=2)]                          # recapture back
    for t in payloads:
        ch.send(t)          # all sends queued before any recv
    for t in payloads:
        _assert_same_payload(ch.recv(), t)


def test_single_round_steady_state_across_span_width_changes():
    """Packed chunk layout: inter-stage hiddens are [T, d] with T the
    bucket width, so a span-width change is a *leading-dim* change — the
    captured structure (trailing dims, dtypes) is untouched and steady
    state must stay single-round with per-(batch, bucket) pre-posted
    buffers, never paying a recapture round (ROADMAP item)."""
    d = 32
    ch = StructureAwareChannel()
    widths = [4, 8, 16, 8, 4, 32, 4, 16]   # decode [B,d] <-> chunk [T,d]
    ch.send({"hidden": np.zeros((widths[0], d), np.float32)})
    ch.recv()                               # capture iteration
    assert ch.captures == 1
    before = ch.wire.rounds
    for i, w in enumerate(widths):
        t = {"hidden": np.full((w, d), float(i), np.float32)}
        ch.post_recv(w)                     # pre-posted async receive
        ch.send(t)
        out = ch.recv()
        np.testing.assert_array_equal(out["hidden"], t["hidden"])
    assert ch.captures == 1                 # no recapture, ever
    assert ch.wire.rounds - before == len(widths)   # one round per iter
    # buffers are keyed per width and reused across revisits
    ch.send({"hidden": np.ones((8, d), np.float32)})
    o1 = ch.recv()
    ch.send({"hidden": np.full((8, d), 2.0, np.float32)})
    o2 = ch.recv()
    assert o1["hidden"] is o2["hidden"]
