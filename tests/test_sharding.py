"""Logical-axis resolver: first-fit-divisible mapping + graceful fallback."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro import sharding as shlib


class FakeMesh:
    """Duck-typed mesh: resolve_pspec only needs axis_names + devices.shape."""

    def __init__(self, shape, axes):
        self.axis_names = axes
        self.devices = np.empty(shape)


MESH = FakeMesh((16, 16), ("data", "model"))
MESH3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_heads_shard_when_divisible():
    spec = shlib.resolve_pspec(("embed", "heads"), (4096, 32 * 128),
                               shlib.SERVE_RULES, MESH)
    assert spec == P(None, "model")


def test_kv_heads_fall_back_to_replication_then_seq_claims_model():
    # glm4 decode cache: [B, S, kv=2, hd] -> kv can't shard over 16, the
    # sequence dim claims "model" instead (sequence-parallel cache)
    spec = shlib.resolve_pspec(("batch", "kv_seq", None, None),
                               (128, 32768, 2, 128), shlib.SERVE_RULES, MESH)
    assert spec == P("data", "model")


def test_batch_joint_pod_data():
    spec = shlib.resolve_pspec(("batch", None), (256, 4096),
                               shlib.TRAIN_RULES, MESH3)
    assert spec == P(("pod", "data"))


def test_batch_indivisible_falls_back():
    spec = shlib.resolve_pspec(("batch", None, None), (1, 1, 2048),
                               shlib.SERVE_RULES, MESH)
    assert spec == P()


def test_train_rules_fsdp_embed():
    spec = shlib.resolve_pspec(("embed", "ff"), (4096, 13696),
                               shlib.TRAIN_RULES, MESH)
    assert spec == P("data", "model")


def test_axis_used_once_per_tensor():
    # vocab and heads both want "model": only the first gets it
    spec = shlib.resolve_pspec(("vocab", "heads"), (32000, 32),
                               shlib.SERVE_RULES, MESH)
    assert spec == P("model")  # trailing None trimmed


def test_pp_rules_stage_axis():
    mesh = FakeMesh((8, 2, 16), ("pipe", "data", "model"))
    spec = shlib.resolve_pspec(("stage", "embed", "ff"), (8, 4096, 14336),
                               shlib.PP_RULES, mesh)
    assert spec == P("pipe", None, "model")


@settings(max_examples=50, deadline=None)
@given(
    dims=st.lists(st.sampled_from([1, 2, 7, 16, 32, 64, 100, 256]),
                  min_size=1, max_size=4),
    axes=st.lists(st.sampled_from(["batch", "embed", "ff", "heads",
                                   "kv_heads", "vocab", None]),
                  min_size=1, max_size=4),
)
def test_property_resolver_always_divisible(dims, axes):
    n = min(len(dims), len(axes))
    dims, axes = dims[:n], axes[:n]
    spec = shlib.resolve_pspec(axes, dims, shlib.SERVE_RULES, MESH)
    sizes = {"data": 16, "model": 16}
    used = []
    for dim, assigned in zip(dims, tuple(spec) + (None,) * (n - len(spec))):
        if assigned is None:
            continue
        names = assigned if isinstance(assigned, tuple) else (assigned,)
        total = int(np.prod([sizes[a] for a in names]))
        assert dim % total == 0, (dim, assigned)
        used.extend(names)
    assert len(used) == len(set(used))  # each mesh axis at most once
