"""Column-wise incremental CPU sampler: equivalence with the naive
recompute-from-scratch baseline + hypothesis properties (§5.1)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sampler import ColumnWiseSampler, NaiveSampler
from repro.core.sampling_params import SamplingParams

V, B = 97, 5


def _logits(rng, b=B, v=V):
    return rng.normal(size=(b, v)).astype(np.float32)


def test_greedy_equivalence_with_penalties():
    """Greedy decoding with penalties: incremental column-wise state must
    produce exactly the same tokens as full recompute."""
    rng = np.random.default_rng(0)
    cw = ColumnWiseSampler(V, B, max_len=64)
    nv = NaiveSampler(V)
    p = SamplingParams(greedy=True, frequency_penalty=0.7,
                       presence_penalty=0.3, repetition_penalty=1.2)
    for step in range(24):
        z = _logits(rng)
        a = cw.sample(z, p)
        b = nv.sample(z, p)
        np.testing.assert_array_equal(a, b, err_msg=f"step {step}")


def test_greedy_equivalence_multiplicative_only():
    rng = np.random.default_rng(1)
    cw = ColumnWiseSampler(V, B)
    nv = NaiveSampler(V)
    p = SamplingParams(greedy=True, repetition_penalty=1.5)
    for _ in range(16):
        z = _logits(rng)
        np.testing.assert_array_equal(cw.sample(z, p), nv.sample(z, p))


def test_incremental_state_matches_recompute():
    """The f buffers after k steps equal a from-scratch histogram."""
    rng = np.random.default_rng(2)
    cw = ColumnWiseSampler(V, B)
    p = SamplingParams(greedy=True, frequency_penalty=0.1)
    hist = [[] for _ in range(B)]
    for _ in range(20):
        ids = cw.sample(_logits(rng), p)
        for i, t in enumerate(ids):
            hist[i].append(int(t))
    rep = cw._replicas[0]
    expect = np.zeros((B, V), np.float32)   # row-major incremental buffers
    for col, h in enumerate(hist):
        for t in h:
            expect[col, t] += 1
    np.testing.assert_array_equal(rep.freq, expect)
    np.testing.assert_array_equal(rep.pres, (expect > 0).astype(np.float32))


def test_pp_replicas_are_independent():
    """Slot n and slot n+1 (different microbatches) keep separate state."""
    rng = np.random.default_rng(3)
    cw = ColumnWiseSampler(V, B, pp_degree=2)
    p = SamplingParams(greedy=True, frequency_penalty=1.0)
    z = _logits(rng)
    a0 = cw.sample(z.copy(), p, slot=0)
    a1 = cw.sample(z.copy(), p, slot=1)
    np.testing.assert_array_equal(a0, a1)  # fresh state in both slots
    # slot 0 advanced: repeated logits now get penalized there only
    b0 = cw.sample(z.copy(), p, slot=0, )
    assert not np.array_equal(a0, b0) or True  # penalty may or may not flip argmax
    assert cw._replicas[0].freq.sum() == 2 * B
    assert cw._replicas[1].freq.sum() == B


def test_transposed_input_path():
    rng = np.random.default_rng(4)
    z = _logits(rng)
    cw1 = ColumnWiseSampler(V, B)
    cw2 = ColumnWiseSampler(V, B)
    p = SamplingParams(greedy=True)
    a = cw1.sample(z, p)
    b = cw2.sample(np.ascontiguousarray(z.T), p, transposed=True)
    np.testing.assert_array_equal(a, b)


def test_top_k_restricts_support():
    rng = np.random.default_rng(5)
    cw = ColumnWiseSampler(V, B, seed=7)
    z = _logits(rng)
    top3 = np.argsort(-z, axis=1)[:, :3]
    p = SamplingParams(temperature=1.0, top_k=3)
    for _ in range(50):
        ids = cw.sample(z.copy(), p)
        for i, t in enumerate(ids):
            assert t in top3[i]


def test_top_p_mass():
    """top-p keeps the smallest prefix with mass > p (plus boundary token)."""
    cw = ColumnWiseSampler(10, 1, seed=3)
    z = np.log(np.array([[0.5, 0.3, 0.1, 0.05, 0.03, 0.02, 0, 0, 0, 0]],
                        np.float64) + 1e-12).astype(np.float32)
    p = SamplingParams(temperature=1.0, top_p=0.7)
    seen = {int(cw.sample(z.copy(), p)[0]) for _ in range(200)}
    assert seen <= {0, 1}, seen


def test_min_p_filter():
    cw = ColumnWiseSampler(8, 1, seed=9)
    z = np.log(np.array([[0.9, 0.05, 0.03, 0.02, 0, 0, 0, 0]], np.float64)
               + 1e-12).astype(np.float32)
    p = SamplingParams(temperature=1.0, min_p=0.2)  # cap = 0.9*0.2 = 0.18
    seen = {int(cw.sample(z.copy(), p)[0]) for _ in range(100)}
    assert seen == {0}


@settings(max_examples=30, deadline=None)
@given(
    steps=st.integers(1, 12),
    b=st.integers(1, 7),
    fp=st.floats(0.0, 2.0),
    pp=st.floats(0.0, 2.0),
    seed=st.integers(0, 1000),
)
def test_property_greedy_incremental_equals_naive(steps, b, fp, pp, seed):
    rng = np.random.default_rng(seed)
    cw = ColumnWiseSampler(V, b)
    nv = NaiveSampler(V)
    p = SamplingParams(greedy=True, frequency_penalty=fp, presence_penalty=pp)
    for _ in range(steps):
        z = rng.normal(size=(b, V)).astype(np.float32)
        np.testing.assert_array_equal(cw.sample(z, p), nv.sample(z, p))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), temp=st.floats(0.2, 2.0))
def test_property_sampled_ids_in_range(seed, temp):
    rng = np.random.default_rng(seed)
    cw = ColumnWiseSampler(V, B, seed=seed)
    p = SamplingParams(temperature=temp, top_k=10, top_p=0.9,
                       frequency_penalty=0.2)
    ids = cw.sample(rng.normal(size=(B, V)).astype(np.float32), p)
    assert ids.dtype == np.int32 and (0 <= ids).all() and (ids < V).all()


def test_prompt_seeding_affects_penalties():
    cw = ColumnWiseSampler(V, 2)
    cw.seed_prompt(0, 2, [0, 1], [np.array([5, 5, 5]), np.array([7])])
    rep = cw._replicas[0]
    assert rep.freq[0, 5] == 3 and rep.pres[1, 7] == 1
    cw2 = ColumnWiseSampler(V, 2)
    cw2.seed_prompt(0, 2, [0, 1], [np.array([5, 5, 5]), np.array([7])],
                    layout="cw")
    rep2 = cw2._replicas[0]
    assert rep2.freq[5, 0] == 3 and rep2.pres[7, 1] == 1


def test_transposed_sample_does_not_mutate_input():
    """Regression: np.asarray(float32) is a no-copy view, and the penalty
    ops run in place — the caller's (shipped) logits must survive."""
    rng = np.random.default_rng(11)
    zt = np.ascontiguousarray(rng.normal(size=(V, B)).astype(np.float32))
    before = zt.copy()
    cw = ColumnWiseSampler(V, B)
    p = SamplingParams(greedy=True, frequency_penalty=0.5,
                       presence_penalty=0.3, repetition_penalty=1.3)
    cw.sample(zt, p, transposed=True)   # builds penalty state
    cw.sample(zt, p, transposed=True)   # penalties now non-zero
    np.testing.assert_array_equal(zt, before)
    # the stochastic pipeline (temperature/top-k) mutates its working copy
    p2 = SamplingParams(temperature=0.7, top_k=5, frequency_penalty=0.5)
    cw.sample(zt, p2, transposed=True)
    np.testing.assert_array_equal(zt, before)


# ---------------------------------------------------------------------------
# Penalty-state carryover across mixed-batch evictions / reorders
# ---------------------------------------------------------------------------

def test_replica_carries_columns_across_shrink_and_reorder():
    cw = ColumnWiseSampler(V, 4)
    p = SamplingParams(greedy=True, frequency_penalty=1.0)
    rng = np.random.default_rng(12)
    z = rng.normal(size=(4, V)).astype(np.float32)
    ids = cw.sample(z, p, seq_ids=[10, 11, 12, 13])
    rep = cw._replicas[0]
    assert rep.freq.sum() == 4
    # shrink + reorder: 11 evicted, order flipped — columns must follow ids
    z2 = rng.normal(size=(3, V)).astype(np.float32)
    cw.sample(z2, p, seq_ids=[13, 12, 10])
    rep2 = cw._replicas[0]
    assert rep2.seq_ids == [13, 12, 10]
    assert rep2.freq[0, ids[3]] >= 1    # col 0 now holds seq 13's history
    assert rep2.freq[2, ids[0]] >= 1    # col 2 holds seq 10's history
    assert rep2.out_len.tolist()[0] >= 1


# ---------------------------------------------------------------------------
# Per-column (per-request) sampling params in one mixed batch
# ---------------------------------------------------------------------------

MIXED = [
    SamplingParams(greedy=True),
    SamplingParams(greedy=True, frequency_penalty=2.0, presence_penalty=0.5),
    SamplingParams(greedy=True, repetition_penalty=1.7),
]


def test_mixed_params_columns_match_solo_columnwise():
    """Each column of a mixed-params batch must sample exactly as a solo
    sampler running that column alone with its own params — per-request
    SamplingParams are honored per column, not taken from column 0."""
    rng = np.random.default_rng(21)
    cw = ColumnWiseSampler(V, 3, max_len=64)
    solos = [ColumnWiseSampler(V, 1, max_len=64) for _ in MIXED]
    for step in range(16):
        z = _logits(rng, b=3)
        got = cw.sample(z, MIXED, seq_ids=[10, 11, 12])
        for i, sp in enumerate(MIXED):
            want = solos[i].sample(z[i:i + 1], sp, seq_ids=[10 + i])
            assert got[i] == want[0], (
                f"step {step} col {i}: mixed-batch column diverged from its "
                "solo run — its own params were not applied")


def test_mixed_params_columns_match_solo_naive():
    rng = np.random.default_rng(22)
    nv = NaiveSampler(V)
    solos = [NaiveSampler(V) for _ in MIXED]
    for step in range(12):
        z = _logits(rng, b=3)
        got = nv.sample(z, MIXED)
        for i, sp in enumerate(MIXED):
            want = solos[i].sample(z[i:i + 1], sp)
            assert got[i] == want[0], f"step {step} col {i}"


def test_uniform_params_list_is_bit_identical_to_scalar():
    """A per-column list where every entry agrees must take the exact
    scalar fast path — same RNG consumption, same tokens."""
    p = SamplingParams(temperature=0.8, top_k=7, top_p=0.9,
                       frequency_penalty=0.4)
    rng = np.random.default_rng(23)
    a = ColumnWiseSampler(V, B, seed=5)
    b = ColumnWiseSampler(V, B, seed=5)
    for _ in range(8):
        z = _logits(rng)
        np.testing.assert_array_equal(a.sample(z, p),
                                      b.sample(z, [p] * B))


def test_mixed_params_transposed_layout():
    """The column-wise (transposed shard) ingestion path honors
    per-column params too."""
    rng = np.random.default_rng(24)
    cw = ColumnWiseSampler(V, 3, max_len=64)
    solos = [ColumnWiseSampler(V, 1, max_len=64) for _ in MIXED]
    for _ in range(8):
        z = _logits(rng, b=3)
        got = cw.sample(np.ascontiguousarray(z.T), MIXED, transposed=True,
                        seq_ids=[0, 1, 2])
        for i, sp in enumerate(MIXED):
            want = solos[i].sample(z[i:i + 1], sp, seq_ids=[i])
            assert got[i] == want[0]


def test_mixed_params_length_mismatch_rejected():
    cw = ColumnWiseSampler(V, B)
    with pytest.raises(ValueError, match="params length"):
        cw.sample(np.zeros((B, V), np.float32), MIXED)


def test_naive_history_follows_seq_ids_across_recomposition():
    """With seq_ids, NaiveSampler keys output history per sequence: when
    one request finishes and a successor takes its batch column (batch
    size unchanged), the successor must NOT inherit the predecessor's
    penalty history — the continuous-serving recomposition case."""
    rng = np.random.default_rng(30)
    nv = NaiveSampler(V)
    p = SamplingParams(greedy=True, frequency_penalty=1.0)
    first = nv.sample(_logits(rng, b=2), p, seq_ids=[0, 1])
    # seq 0 departs, seq 2 arrives into column 0; batch size unchanged —
    # positional (legacy) history would hand seq 2 seq 0's past here
    z2 = rng.normal(size=(2, V)).astype(np.float32)
    got = nv.sample(z2.copy(), p, seq_ids=[2, 1])
    ref = NaiveSampler(V)
    ref.seq_history[1] = np.asarray([first[1]], np.int64)   # seq 1 history
    want = ref.sample(z2.copy(), p, seq_ids=[2, 1])
    np.testing.assert_array_equal(got, want)
    assert nv.tracked_seq_ids() == {0, 1, 2}
    nv.drop_seq(0)
    assert nv.tracked_seq_ids() == {1, 2}


def test_drop_seq_strips_columns():
    """drop_seq removes exactly the released sequence's penalty state
    (request retired/aborted) and keeps every other column intact."""
    cw = ColumnWiseSampler(V, 3)
    p = SamplingParams(greedy=True, frequency_penalty=1.0)
    rng = np.random.default_rng(25)
    ids = cw.sample(_logits(rng, b=3), p, seq_ids=[7, 8, 9])
    assert cw.tracked_seq_ids() == {7, 8, 9}
    cw.drop_seq(8)
    assert cw.tracked_seq_ids() == {7, 9}
    rep = cw._replicas[0]
    assert rep.seq_ids == [7, 9]
    assert rep.freq[0, ids[0]] >= 1 and rep.freq[1, ids[2]] >= 1
    cw.drop_seq(7)
    cw.drop_seq(9)
    assert not cw._replicas and cw.tracked_seq_ids() == set()


@settings(max_examples=20, deadline=None)
@given(
    rounds=st.integers(2, 10),
    fp=st.floats(0.1, 1.5),
    pp=st.floats(0.0, 1.0),
    seed=st.integers(0, 999),
)
def test_property_carryover_matches_naive_per_seq_history(rounds, fp, pp, seed):
    """Under random evictions, arrivals and reorders the incremental
    sampler must match a NaiveSampler fed each batch's exact per-sequence
    output histories — i.e. penalties follow the sequence, not the column."""
    rng = np.random.default_rng(seed)
    cw = ColumnWiseSampler(V, 8, max_len=64)
    p = SamplingParams(greedy=True, frequency_penalty=fp, presence_penalty=pp)
    hist = {}
    active = list(range(3))
    next_id = 3
    for _ in range(rounds):
        ids = list(active)
        rng.shuffle(ids)
        b = len(ids)
        z = rng.normal(size=(b, V)).astype(np.float32)
        nv = NaiveSampler(V)
        nv.history[0] = [np.asarray(hist.get(s, []), np.int64) for s in ids]
        expect = nv.sample(z.copy(), p)
        got = cw.sample(z.copy(), p, seq_ids=ids)
        np.testing.assert_array_equal(got, expect)
        for s, t in zip(ids, got):
            hist.setdefault(s, []).append(int(t))
        # random recomposition: evict one, admit one
        if len(active) > 1 and rng.random() < 0.5:
            active.remove(active[int(rng.integers(len(active)))])
        if rng.random() < 0.5:
            active.append(next_id)
            next_id += 1
