"""Priority-aware scheduling (docs/http.md): admission order in the
waiting queue, deterministic preemption-victim choice, and the
engine-level guarantee that under KV block pressure a low-priority
request is evicted before any high-priority one — with the evicted
request's resumed output still bit-exact."""
import itertools

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import EngineConfig, SiPipeEngine
from repro.core.sampling_params import SamplingParams
from repro.core.scheduler import Scheduler
from repro.core.sequence import SeqStatus, Sequence
from repro.models import ModelOptions, ShardCtx, build_model
from repro.runtime.paged_kv import BlockSpaceManager

import jax


def _params(priority=0, n_new=4, n=1):
    return SamplingParams(greedy=True, max_new_tokens=n_new, n=n,
                          priority=priority)


def _seq(sid, priority=0, plen=4, n_new=4):
    return Sequence(sid, list(range(1, plen + 1)), _params(priority, n_new))


# ---------------------------------------------------------------------------
# Waiting-queue admission order
# ---------------------------------------------------------------------------

def test_waiting_queue_orders_priority_then_fifo():
    s = Scheduler(max_batch=4, pp_degree=1, max_seq_len=32)
    for sid, pr in enumerate((0, 5, 0, 5, -1)):
        s.add_request(_seq(sid, pr))
    # priority descending; FIFO (= seq id) within a priority level
    assert [q.seq_id for q in s.waiting] == [1, 3, 0, 2, 4]


def test_uniform_priority_stays_pure_fifo():
    s = Scheduler(max_batch=4, pp_degree=1, max_seq_len=32)
    for sid in range(5):
        s.add_request(_seq(sid, priority=0))
    assert [q.seq_id for q in s.waiting] == [0, 1, 2, 3, 4]


def test_newcomer_never_jumps_resume_entries():
    """PREEMPTED sequences and spawned fork children sit at the queue
    front holding tokens/blocks; a high-priority newcomer must slot in
    behind them, not ahead."""
    s = Scheduler(max_batch=4, pp_degree=1, max_seq_len=32)
    s.add_request(_seq(0, priority=0))
    pre = _seq(1, priority=0)
    pre.status = SeqStatus.PREEMPTED
    s.seqs[1] = pre
    s.waiting.appendleft(pre)
    child = _seq(2, priority=0)
    child.forked = True
    child.fork_parent = 0
    s.seqs[2] = child
    s.waiting.appendleft(child)
    s.add_request(_seq(3, priority=99))
    assert [q.seq_id for q in s.waiting] == [2, 1, 3, 0]


def test_admit_next_pops_head_and_reserves_blocks():
    kv = BlockSpaceManager(8, 4)
    s = Scheduler(max_batch=4, pp_degree=1, max_seq_len=16, kv_manager=kv)
    s.add_request(_seq(0, priority=0, plen=6))
    s.add_request(_seq(1, priority=3, plen=6))
    got = s.admit_next()
    assert got.seq_id == 1                     # priority head, not FIFO head
    assert got.status == SeqStatus.RUNNING
    assert kv.has(1) and not kv.has(0)
    assert [q.seq_id for q in s.waiting] == [0]


# ---------------------------------------------------------------------------
# Deterministic preemption victim (satellite: stable under dict order)
# ---------------------------------------------------------------------------

def _running_sched(order, prios):
    """Scheduler with RUNNING block-holding seqs inserted in ``order``."""
    kv = BlockSpaceManager(32, 4)
    s = Scheduler(max_batch=8, pp_degree=1, max_seq_len=64, kv_manager=kv)
    for sid in order:
        seq = _seq(sid, priority=prios[sid], plen=5)
        s.seqs[sid] = seq
        seq.mark_running()
        s.kv_admit(seq)
    return s


@pytest.mark.parametrize("prios,want", [
    ((0, 0, 0), 2),      # equal priority: latest arrival (highest id)
    ((5, 0, 5), 1),      # lowest priority wins regardless of position
    ((1, 1, 0), 2),      # lowest priority, unique
    ((0, 0, 1), 1),      # tie among the low ones -> latest of them
])
def test_preemption_victim_is_insertion_order_independent(prios, want):
    """The victim is a pure function of the candidate set — identical
    across every ``seqs``-dict insertion order."""
    for order in itertools.permutations(range(len(prios))):
        s = _running_sched(order, prios)
        assert s._preemption_victim() == want, f"order={order}"


# ---------------------------------------------------------------------------
# Hybrid tiers: offline sequences are ALWAYS the first victims
# ---------------------------------------------------------------------------

def _tier_seq(sid, tier, priority=0, plen=5):
    return Sequence(sid, list(range(1, plen + 1)),
                    SamplingParams(greedy=True, max_new_tokens=4,
                                   priority=priority, tier=tier))


def _mixed_sched(rows):
    """rows: [(sid, tier, priority), ...] inserted in every permutation
    by the callers; here in the given order."""
    kv = BlockSpaceManager(32, 4)
    s = Scheduler(max_batch=8, pp_degree=1, max_seq_len=64, kv_manager=kv)
    for sid, tier, pr in rows:
        seq = _tier_seq(sid, tier, pr)
        s.seqs[sid] = seq
        seq.mark_running()
        s.kv_admit(seq)
    return s


def test_offline_victim_beats_every_online_priority():
    """An offline seq at priority 5 falls before an online seq at
    priority -3: tier dominates the victim key (docs/hybrid.md)."""
    for rows in itertools.permutations([(0, "online", -3), (1, "offline", 5),
                                        (2, "online", 0)]):
        s = _mixed_sched(rows)
        assert s._preemption_victim() == 1, f"rows={rows}"


def test_offline_victims_order_by_priority_then_newest():
    s = _mixed_sched([(0, "offline", 2), (1, "offline", 0),
                      (2, "offline", 0), (3, "online", -9)])
    assert s._preemption_victim() == 2          # lowest offline prio, newest
    s._preempt(2)
    assert s._preemption_victim() == 1
    s._preempt(1)
    assert s._preemption_victim() == 0          # offline exhausted last
    s._preempt(0)
    assert s._preemption_victim() == 3          # only then online
    assert s.n_offline_preemptions == 3


def test_offline_only_victim_search_skips_online():
    s = _mixed_sched([(0, "online", 0), (1, "online", 5)])
    assert s._preemption_victim(offline_only=True) is None
    assert s._preemption_victim() == 0          # lowest priority value


def test_preempt_offline_seat_picks_member_victim():
    s = _mixed_sched([(0, "online", 0), (1, "offline", 3), (2, "offline", 0)])
    members = [0, 1, 2]
    assert s.preempt_offline_seat(members)
    assert members == [0, 1]                    # lowest-prio offline evicted
    assert s.seqs[2].status == SeqStatus.PREEMPTED
    # the evicted offline seq goes back to its OWN queue, not the online one
    assert [q.seq_id for q in s.waiting_offline] == [2]
    assert not s.waiting
    assert s.preempt_offline_seat(members)
    assert members == [0]
    assert not s.preempt_offline_seat(members)  # online-only: refuses
    assert s.seqs[0].status == SeqStatus.RUNNING
    assert s.n_offline_preemptions == 2


def test_preemption_victim_skips_blockless_and_non_running():
    s = _running_sched((0, 1, 2), (0, 0, 0))
    s.kv.release(2)                       # latest no longer holds blocks
    assert s._preemption_victim() == 1
    s.seqs[1].status = SeqStatus.FINISHED
    assert s._preemption_victim() == 0
    s.kv.release(0)
    assert s._preemption_victim() is None


# ---------------------------------------------------------------------------
# fork_children_of: the abort-target net for the spawn->attach window
# ---------------------------------------------------------------------------

def test_fork_children_of_returns_only_live_children():
    s = Scheduler(max_batch=4, pp_degree=1, max_seq_len=32)
    parent = _seq(0)
    s.seqs[0] = parent
    for sid, status in ((10, SeqStatus.WAITING), (11, SeqStatus.RUNNING),
                        (12, SeqStatus.PREEMPTED), (13, SeqStatus.FINISHED),
                        (14, SeqStatus.ABORTED)):
        child = _seq(sid)
        child.fork_parent = 0
        child.status = status
        s.seqs[sid] = child
    stranger = _seq(20)
    stranger.fork_parent = 7
    s.seqs[20] = stranger
    assert sorted(q.seq_id for q in s.fork_children_of(0)) == [10, 11, 12]
    assert s.fork_children_of(99) == []


# ---------------------------------------------------------------------------
# Engine e2e: low priority preempted before high, resume bit-exact
# ---------------------------------------------------------------------------

def _model():
    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single(), ModelOptions())
    return cfg, model, model.init(jax.random.key(0))


def _prompts(cfg, lens, seed=7):
    rng = np.random.default_rng(seed)
    return [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
            for n in lens]


def _engine(model, params, layout, **kw):
    return SiPipeEngine(model, params, EngineConfig(
        pp_degree=2, max_batch=2, max_seq_len=48, n_samplers=2,
        prefill_chunk_tokens=8, scheduling_policy="chunked",
        kv_layout=layout, **kw))


@pytest.mark.slow
def test_low_priority_preempted_before_high_and_resumes_bit_exact():
    """Under block pressure every preemption victim must be a
    low-priority request while the high-priority ones run undisturbed,
    and the evicted requests' resumed outputs stay bit-exact vs an
    unpressured contiguous run (the acceptance criterion)."""
    cfg, model, params = _model()
    prompts = _prompts(cfg, (20, 16, 12, 9))
    # the two EARLIEST (and largest) requests are low priority — under
    # the old latest-arrival rule the victim would be a later request
    prios = (-1, -1, 2, 2)

    def run(layout, **kw):
        eng = _engine(model, params, layout, **kw)
        rids = [eng.add_request(p, _params(pr, n_new=12))
                for p, pr in zip(prompts, prios)]
        victims = []
        seen = {}
        while eng.has_work:
            eng.step()
            for sid, q in list(eng.scheduler.seqs.items()):
                if q.preemptions > seen.get(sid, 0):
                    victims.append(sid)
                    seen[sid] = q.preemptions
        eng.shutdown()
        outs = {s.seq_id: list(s.output_ids)
                for s in eng.scheduler.finished}
        return [outs[r] for r in rids], eng.metrics(), victims

    ref, _, _ = run("contiguous")
    got, m, victims = run("paged", kv_block_size=4, kv_blocks=14)
    assert m["kv_preemptions"] > 0 and victims
    # every victim is low-priority: no high-priority request was ever
    # evicted while a low-priority one held blocks
    assert all(prios[v] == -1 for v in victims), victims
    assert got == ref                       # resume is bit-exact
    assert m["kv_blocks_free"] == m["kv_blocks_total"]
