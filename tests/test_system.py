"""System-level behaviour: the serving engine and training driver run
end-to-end through their public entry points (the paper's system as a
whole, not individual components)."""
import jax
import numpy as np
import pytest


def test_serve_driver_end_to_end():
    from repro.launch.serve import run

    m = run("stablelm-1.6b", engine="sipipe", pp=2, requests=4, max_batch=2,
            max_new_tokens=4, n_samplers=2, verbose=False)
    assert m["finished"] == 4
    assert m["tokens"] == 16
    assert m["throughput_tok_s"] > 0
    assert len(m["stages"]) == 2


def test_train_driver_loss_decreases():
    from repro.launch.train import run

    out = run("stablelm-1.6b", steps=30, batch=4, seq=64, log_every=1000)
    head = float(np.mean(out["losses"][:5]))
    tail = float(np.mean(out["losses"][-5:]))
    assert np.isfinite(tail)
    assert tail < head  # a real optimization signal on synthetic data


def test_grad_compression_trains():
    from repro.launch.train import run

    out = run("stablelm-1.6b", steps=8, batch=2, seq=32,
              grad_compression=True, log_every=1000)
    assert np.isfinite(out["final_loss"])


def test_benchmark_harness_importable_and_quick():
    """The benchmark entrypoint's cheap benches run without error."""
    import subprocess
    import sys

    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "tsem"],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd=".",
    )
    assert "tsem/token_safe_per_iter" in out.stdout, out.stdout + out.stderr
