"""Scheduling-policy layer: resolution/validation, the disaggregated
phase machine (hysteresis, liveness, no-oscillation), and the e2e
three-policy greedy parity matrix (monolithic / chunked / disaggregated
must be token-identical, including sliding-window and int8-KV configs)."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.engine import EngineConfig, SiPipeEngine
from repro.core.policies import (
    AdaptivePolicy,
    ChunkedPolicy,
    DisaggregatedPolicy,
    MonolithicPolicy,
    make_policy,
)
from repro.core.sampling_params import SamplingParams
from repro.core.scheduler import Scheduler
from repro.core.sequence import SeqStatus, Sequence
from repro.models import ModelOptions, ShardCtx, build_model


# ---------------------------------------------------------------------------
# Resolution + validation
# ---------------------------------------------------------------------------

def test_policy_resolution_auto():
    assert isinstance(make_policy(None), MonolithicPolicy)
    assert isinstance(make_policy("auto"), MonolithicPolicy)
    assert isinstance(make_policy(None, token_budget=8), ChunkedPolicy)
    assert isinstance(make_policy("auto", token_budget=8), ChunkedPolicy)
    assert isinstance(make_policy("disaggregated", token_budget=8),
                      DisaggregatedPolicy)
    assert isinstance(make_policy("adaptive", token_budget=8),
                      AdaptivePolicy)


def test_policy_validation():
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        make_policy("sarathi")
    with pytest.raises(ValueError, match="token budget"):
        make_policy("chunked")
    with pytest.raises(ValueError, match="token budget"):
        make_policy("disaggregated")
    with pytest.raises(ValueError, match="token budget"):
        make_policy("adaptive")
    with pytest.raises(ValueError, match="no token budget"):
        make_policy("monolithic", token_budget=8)
    # the hysteresis knob is a no-op outside disaggregated: reject loudly
    with pytest.raises(ValueError, match="hysteresis"):
        make_policy("chunked", token_budget=8, hysteresis_tokens=4)
    with pytest.raises(ValueError, match="hysteresis"):
        make_policy("monolithic", hysteresis_tokens=4)
    # the TPOT SLO knob applies to adaptive (budget adaptation) and
    # disaggregated (prefill-phase length cap) only
    with pytest.raises(ValueError, match="tpot_slo"):
        make_policy("chunked", token_budget=8, tpot_slo_s=0.01)
    with pytest.raises(ValueError, match="tpot_slo"):
        make_policy("monolithic", tpot_slo_s=0.01)
    assert make_policy("adaptive", token_budget=8,
                       tpot_slo_s=0.01).tpot_slo_s == 0.01
    assert make_policy("disaggregated", token_budget=8,
                       tpot_slo_s=0.01).tpot_slo_s == 0.01


def test_scheduler_exposes_policy():
    s = Scheduler(max_batch=2, pp_degree=1, max_seq_len=64, token_budget=8,
                  policy="disaggregated")
    assert s.policy.name == "disaggregated" and s.chunked
    s = Scheduler(max_batch=2, pp_degree=1, max_seq_len=64, token_budget=8)
    assert s.policy.name == "chunked" and s.chunked
    s = Scheduler(max_batch=2, pp_degree=1, max_seq_len=64)
    assert s.policy.name == "monolithic" and not s.chunked


# ---------------------------------------------------------------------------
# Disaggregated phase machine
# ---------------------------------------------------------------------------

def _drive(s, max_iters=5000, on_iter=None):
    """Run the scheduler to completion, returning per-iteration records."""
    rows = []
    for it in range(max_iters):
        o = s.schedule(it)
        if o is None:
            if not s.has_work:
                break
            continue
        rows.append((it, s.policy.phase, o))
        if on_iter:
            on_iter(it, o)
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, np.full(len(ids), 7, np.int32))
    return rows


def _mk_disagg(plens, max_new, *, max_batch=2, p=2, budget=8, hyst=None,
               max_seq_len=512):
    s = Scheduler(max_batch=max_batch, pp_degree=p, max_seq_len=max_seq_len,
                  token_budget=budget, policy="disaggregated",
                  hysteresis_tokens=hyst)
    for i, pl in enumerate(plens):
        s.add_request(Sequence(i, list(range(1, pl + 1)), SamplingParams(
            greedy=True, max_new_tokens=max_new)))
    return s


def test_phase_purity_and_ordering():
    """Prefill-phase iterations carry only prompt chunks at the full
    budget (zero decode piggybacking); decode-phase iterations are pure
    1-token spans.  (Reads prompt lengths off the SchedulingOutput —
    finished sequences are released from ``Scheduler.seqs`` once their
    slot membership clears, the long-run memory bound.)"""
    s = _mk_disagg([20, 6, 14, 9], 4)
    for it, phase, o in _drive(s):
        if phase == "prefill":
            for (off, c), plen in zip(o.spans, o.prompt_lens):
                assert off + c <= plen
                assert off < plen                     # never a decode span
        else:
            assert o.max_span == 1
            assert all(ns for ns in o.needs_sample)
    assert len(s.finished) == 4
    assert not s.seqs        # released once membership cleared


def test_decode_phase_entry_never_strands_partial_prefill():
    """The PREFILL->DECODE switch requires every running sequence to have
    finished prefill, so a decode phase never contains a half-prefilled
    member."""
    s = _mk_disagg([30, 5, 22, 9, 40, 3], 3, max_batch=2, p=2, budget=8)
    def check(it, o):
        if s.policy.phase == "decode":
            for m in s.slot_members:
                for sid in m:
                    q = s.seqs[sid]
                    if q.status.name == "RUNNING":
                        assert q.prefill_done
    _drive(s, on_iter=check)
    assert len(s.finished) == 6


def test_hysteresis_defers_small_backlog():
    """A waiting prompt below the hysteresis threshold must not flip a
    decode phase back to prefill while decode work remains."""
    s = _mk_disagg([6, 6], 8, max_batch=2, p=1, budget=16, hyst=64)
    # drain the initial prefill phase into decode
    it = 0
    while s.policy.phase == "prefill":
        o = s.schedule(it)
        assert o is not None
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, np.full(len(ids), 7, np.int32))
        it += 1
    assert s.policy.phase == "decode"
    # small arrival (< 64 pending tokens, decode slots busy): stays decode
    s.add_request(Sequence(9, list(range(1, 7)), SamplingParams(
        greedy=True, max_new_tokens=2)))
    o = s.schedule(it)
    assert s.policy.phase == "decode"
    assert 9 not in o.seq_ids
    ids = [o.seq_ids[i] for i in o.sample_indices()]
    s.complete(it, ids, np.full(len(ids), 7, np.int32))
    # once decode work drains, the switch is forced: no starvation
    switched = False
    for it2 in range(it + 1, it + 200):
        o = s.schedule(it2)
        if o is None:
            if not s.has_work:
                break
            continue
        switched = switched or s.policy.phase == "prefill"
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it2, ids, np.full(len(ids), 7, np.int32))
    assert switched
    assert any(q.seq_id == 9 for q in s.finished)


def test_hysteresis_counts_only_admissible_backlog():
    """A deep waiting queue behind a single free seat must NOT fire the
    decode->prefill threshold: only the first free-seat-count prompts are
    admissible, so pausing every decode slot for a one-seat admission
    (then flipping straight back) would be phase thrash."""
    s = _mk_disagg([4, 4, 4], 40, max_batch=2, p=2, budget=8, hyst=8)
    it = 0
    while s.policy.phase == "prefill":          # drain into decode
        o = s.schedule(it)
        assert o is not None
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, np.full(len(ids), 7, np.int32))
        it += 1
    # 3 running decodes over 2 slots -> exactly one free seat; a deep
    # backlog of threshold-sized prompts is NOT admissible beyond seat 1:
    # 1 * 7 < hyst(8) * n_decode_slots(2) -> stay in decode
    for j in range(6):
        s.add_request(Sequence(10 + j, list(range(1, 8)), SamplingParams(
            greedy=True, max_new_tokens=1)))
    for k in range(2 * s.p):
        o = s.schedule(it + k)
        assert s.policy.phase == "decode"
        if o is not None:
            ids = [o.seq_ids[i] for i in o.sample_indices()]
            s.complete(it + k, ids, np.full(len(ids), 7, np.int32))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 10),
    max_batch=st.integers(1, 4),
    p=st.integers(1, 3),
    budget=st.integers(2, 24),
    hyst=st.one_of(st.none(), st.integers(1, 64)),
    seed=st.integers(0, 99),
)
def test_property_no_starvation_and_budget(n, max_batch, p, budget, hyst, seed):
    """Liveness + budget: under random prompt lengths / output budgets,
    every admitted sequence eventually decodes to completion, and spans
    within any phase never exceed the token budget."""
    rng = np.random.default_rng(seed)
    s = Scheduler(max_batch=max_batch, pp_degree=p, max_seq_len=512,
                  token_budget=budget, policy="disaggregated",
                  hysteresis_tokens=hyst)
    plens = {}
    for i in range(n):
        plens[i] = int(rng.integers(1, 60))
        s.add_request(Sequence(i, list(range(1, plens[i] + 1)), SamplingParams(
            greedy=True, max_new_tokens=int(rng.integers(1, 5)))))
    chunks = {i: [] for i in range(n)}
    for it in range(5000):
        o = s.schedule(it)
        if o is None:
            if not s.has_work:
                break
            continue
        assert o.total_tokens <= s.token_budget
        assert len(o.seq_ids) <= max_batch
        for sid, (off, c) in zip(o.seq_ids, o.spans):
            assert c >= 1
            if off + c <= plens[sid]:
                chunks[sid].append((off, c))
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, rng.integers(3, 50, len(ids)).astype(np.int32))
    assert not s.has_work                      # no starvation: all finished
    assert len(s.finished) == n
    for i in range(n):
        off = 0
        for o_, c_ in chunks[i]:               # chunks still tile the prompt
            assert o_ == off
            off += c_
        assert off == plens[i]


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 8),
    max_batch=st.integers(1, 4),
    p=st.integers(1, 3),
    budget=st.integers(2, 24),
    seed=st.integers(0, 99),
)
def test_property_no_oscillation_on_static_workload(n, max_batch, p, budget, seed):
    """Once the workload is static — every request admitted, waiting queue
    empty — the phase switches at most once more (PREFILL -> DECODE) and
    never returns to prefill: the hysteresis cannot oscillate without new
    pending prefill tokens."""
    rng = np.random.default_rng(seed)
    s = Scheduler(max_batch=max_batch, pp_degree=p, max_seq_len=512,
                  token_budget=budget, policy="disaggregated")
    for i in range(n):
        s.add_request(Sequence(i, list(range(1, int(rng.integers(1, 40)) + 1)),
                               SamplingParams(greedy=True,
                                              max_new_tokens=int(rng.integers(1, 6)))))
    switches_when_static = None
    for it in range(5000):
        o = s.schedule(it)
        if not s.waiting and switches_when_static is None:
            switches_when_static = s.policy.phase_switches
        if s.policy.phase == "prefill" and switches_when_static is not None:
            # prefill may only persist from before the workload went static
            assert s.policy.phase_switches == switches_when_static
        if o is None:
            if not s.has_work:
                break
            continue
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, rng.integers(3, 50, len(ids)).astype(np.int32))
    assert s.policy.phase_switches <= (switches_when_static or 0) + 1


# ---------------------------------------------------------------------------
# TPOT-aware prefill-phase length cap (disaggregated; ROADMAP item)
# ---------------------------------------------------------------------------

def _mk_disagg_capped(plens, max_new, *, slo, budget=8, max_batch=2, p=2,
                      tpot=0.01):
    s = Scheduler(max_batch=max_batch, pp_degree=p, max_seq_len=512,
                  token_budget=budget, policy="disaggregated",
                  tpot_slo_s=slo)
    s.tpot_samples.extend([tpot] * 16)      # live feed: one gap per iter
    for i, pl in enumerate(plens):
        s.add_request(Sequence(i, list(range(1, pl + 1)), SamplingParams(
            greedy=True, max_new_tokens=max_new)))
    return s


def test_phase_cap_limits_admission_but_not_progress():
    """A tight SLO caps the prefill phase at ~one iteration's worth of
    tokens: once decodes are in flight (the cap only protects PAUSED
    decodes — a cold phase with nothing to pause admits freely), the
    backlog stops being admitted mid-phase even though seats are free,
    and is spread over later phases with decode bursts between them —
    everything still finishes."""
    # est cost/token = median_tpot / budget = 0.01/8; cap = 4*slo/est
    slo = 0.01 * 8 / 8            # cap ~ 4 * budget = 32 tokens/phase
    # 8 seats over 2 slots: seats stay FREE while early admissions turn
    # into decodes — only the cap can hold the rest of the queue back
    s = _mk_disagg_capped([24, 24, 24, 24, 24, 24], 2, slo=slo, budget=8,
                          max_batch=4)
    capped_pol = s.policy
    _drive(s)
    assert len(s.finished) == 6                    # liveness under the cap
    assert capped_pol.metrics()["phase_token_cap"] >= s.token_budget
    assert capped_pol.metrics()["capped_phases"] >= 1
    assert capped_pol.metrics()["phase_switches"] >= 3  # phases alternated


def test_phase_cap_cannot_livelock_when_phase_members_all_finish():
    """Regression: a capped phase whose admitted sequences ALL finish
    (e.g. max_new_tokens=1: the prefill-completing sample is the last
    token) leaves no decode work to switch to; the cap must reset rather
    than block admission forever with the backlog stranded."""
    s = _mk_disagg_capped([40] * 8, 1, slo=0.01, budget=8)
    _drive(s)
    assert not s.has_work
    assert len(s.finished) == 8


def test_phase_cap_never_below_one_iteration():
    """Even an absurdly tight SLO leaves room for one full prefill
    iteration per phase — the cap bounds pause length, not progress."""
    s = _mk_disagg_capped([40, 40], 2, slo=1e-9, budget=8, tpot=0.5)
    _drive(s)
    assert s.policy._phase_cap == s.token_budget
    assert len(s.finished) == 2


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 8),
    max_batch=st.integers(1, 3),
    p=st.integers(1, 3),
    budget=st.integers(2, 16),
    slo_scale=st.floats(0.1, 20.0),
    seed=st.integers(0, 99),
)
def test_property_capped_phase_never_strands_half_prefill(
        n, max_batch, p, budget, slo_scale, seed):
    """The cap may end a prefill phase early, but entering decode still
    requires every running prefill to be complete — no decode-phase
    member is ever half-prefilled, and nothing starves."""
    rng = np.random.default_rng(seed)
    s = Scheduler(max_batch=max_batch, pp_degree=p, max_seq_len=512,
                  token_budget=budget, policy="disaggregated",
                  tpot_slo_s=0.01 * slo_scale)
    s.tpot_samples.extend([0.01] * 16)
    plens = {}
    for i in range(n):
        plens[i] = int(rng.integers(1, 50))
        s.add_request(Sequence(i, list(range(1, plens[i] + 1)),
                               SamplingParams(greedy=True,
                                              max_new_tokens=int(
                                                  rng.integers(1, 5)))))
    for it in range(5000):
        o = s.schedule(it)
        if s.policy.phase == "decode":
            for m in s.slot_members:
                for sid in m:
                    q = s.seqs.get(sid)
                    if q is not None and q.status == SeqStatus.RUNNING:
                        assert q.prefill_done   # never stranded
        if o is None:
            if not s.has_work:
                break
            continue
        assert o.total_tokens <= s.token_budget
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, rng.integers(3, 50, len(ids)).astype(np.int32))
        s.tpot_samples.append(0.01)       # keep the live feed warm
    assert not s.has_work                 # liveness: the cap cannot starve
    assert len(s.finished) == n


# ---------------------------------------------------------------------------
# Adaptive token-budget policy (latency-SLO driven)
# ---------------------------------------------------------------------------

def _mk_adaptive(budget=32, slo=0.01, max_batch=2, p=1, n=4,
                 max_new=10 ** 6):
    s = Scheduler(max_batch=max_batch, pp_degree=p, max_seq_len=4096,
                  token_budget=budget, policy="adaptive", tpot_slo_s=slo)
    for i in range(n):
        s.add_request(Sequence(i, list(range(1, 400)), SamplingParams(
            greedy=True, max_new_tokens=max_new)))
    return s


def _spin(s, start, rounds):
    """Run `rounds` scheduler iterations, completing sampled columns."""
    for it in range(start, start + rounds):
        o = s.schedule(it)
        if o is None:
            continue
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, np.full(len(ids), 7, np.int32))
    return start + rounds


def test_adaptive_budget_shrinks_on_slo_breach_and_grows_back():
    """TPOT above the SLO shrinks the chunk budget (decodes win back
    inter-token latency); TPOT far below it grows the budget back toward
    the configured maximum.  The budget never leaves
    [max_batch + 1, initial budget]."""
    # single-token outputs never produce an inter-token gap, so the
    # injected tpot_samples window fully controls the policy here
    s = _mk_adaptive(budget=32, slo=0.01, max_new=1)
    pol = s.policy
    it = _spin(s, 0, 2)                      # bind the policy to the budget
    assert pol._budget == 32
    # live TPOT breaches the SLO -> shrink at the next evaluation window
    s.tpot_samples.extend([0.05] * 16)
    it = _spin(s, it, 2 * pol.WINDOW)
    assert pol._budget < 32
    assert pol.budget_adjustments >= 1
    shrunk = pol._budget
    # persistent breach walks the budget down to the floor, never below
    it = _spin(s, it, 6 * pol.WINDOW)
    assert s.max_batch + 1 <= pol._budget <= shrunk
    # headroom: TPOT far under the SLO -> grow back, capped at the initial
    for _ in range(8):
        s.tpot_samples.clear()
        s.tpot_samples.extend([0.0001] * 16)
        it = _spin(s, it, pol.WINDOW)
    assert pol._budget == 32


def test_adaptive_budget_is_respected_by_iterations():
    """Every scheduled iteration obeys the CURRENT (adapted) budget."""
    s = _mk_adaptive(budget=24, slo=0.001)
    for it in range(200):
        s.tpot_samples.append(0.1)           # constant breach: keep shrinking
        o = s.schedule(it)
        if o is None:
            continue
        assert o.total_tokens <= s.token_budget
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, np.full(len(ids), 7, np.int32))
    assert s.token_budget == s.max_batch + 1     # floor reached
    assert s.policy.metrics()["budget"] == s.token_budget


def test_adaptive_self_calibrates_slo():
    """With no explicit SLO the first full window sets one from the
    observed median — the policy works without knowing absolute hardware
    latency up front."""
    s = _mk_adaptive(budget=16, slo=None, max_new=1)
    pol = s.policy
    assert pol.tpot_slo_s is None
    s.tpot_samples.extend([0.004] * 16)
    _spin(s, 0, 2 * pol.WINDOW)
    assert pol.tpot_slo_s == pytest.approx(pol.SLO_CALIB * 0.004)


# ---------------------------------------------------------------------------
# pp_sim: per-stage heterogeneity (Obs. 3 jitter)
# ---------------------------------------------------------------------------

def test_mixed_workload_jitter_makes_stages_heterogeneous():
    """fwd_jitter feeds the PipeCosts Obs. 3 convention into the mixed-
    workload simulation: odd stages run slower than even ones, so the
    per-stage busy times diverge instead of charging identical durations
    — while the scheduling trace itself is timing-independent."""
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.pp_sim import simulate_mixed_workload

    kw = dict(p=2, max_batch=2, token_budget=16, prompt_lens=[40, 30, 8],
              max_new_tokens=8, t_token=1e-4, t_fixed=5e-4)
    for policy in ("monolithic", "chunked", "disaggregated"):
        flat = simulate_mixed_workload(policy=policy, **kw)
        jit = simulate_mixed_workload(policy=policy, fwd_jitter=0.2, **kw)
        assert flat.stage_busy[0] == pytest.approx(flat.stage_busy[1])
        # stage 1 charges 1.2x nominal, stage 0 charges 0.8x
        assert jit.stage_busy[1] / jit.stage_busy[0] == pytest.approx(1.5)
        # same schedule, same tokens — only the timing model changed
        assert jit.iteration_tokens == flat.iteration_tokens
        # the slow stage paces the pipeline: jittered wall >= uniform wall
        assert jit.wall_s > flat.wall_s * 0.99


# ---------------------------------------------------------------------------
# E2E three-policy greedy parity (acceptance criterion)
# ---------------------------------------------------------------------------

def _engine_outputs(model, params, prompts, n_new, *, policy, chunk,
                    pp=2, max_batch=2):
    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=pp, max_batch=max_batch, max_seq_len=64, n_samplers=2,
        prefill_chunk_tokens=chunk, scheduling_policy=policy))
    for p in prompts:
        eng.add_request(p, SamplingParams(greedy=True, max_new_tokens=n_new))
    done = sorted(eng.run(), key=lambda s: s.seq_id)
    assert len(done) == len(prompts)
    m = eng.metrics()
    assert m["policy"] == (policy if policy != "auto"
                           else ("chunked" if chunk else "monolithic"))
    return [s.output_ids for s in done]


def test_disaggregated_token_identical_to_monolithic():
    """Fast parity pin: greedy outputs must be identical between the
    monolithic and disaggregated policies on the same trace."""
    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single())
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
               for n in (13, 5)]
    mono = _engine_outputs(model, params, prompts, 5, policy="auto", chunk=None)
    dis = _engine_outputs(model, params, prompts, 5, policy="disaggregated",
                          chunk=6)
    assert dis == mono
    ada = _engine_outputs(model, params, prompts, 5, policy="adaptive",
                          chunk=6)
    assert ada == mono      # budget adaptation never changes greedy tokens


@pytest.mark.slow
@pytest.mark.parametrize("arch,kv_quant,key,lens", [
    ("stablelm-1.6b-smoke", False, 0, (13, 5, 9)),   # dense, full cache
    ("mixtral-8x7b-smoke", False, 3, (13, 13)),      # moe, sliding window
    ("stablelm-1.6b-smoke", True, 4, (11, 5)),       # int8 KV cache
])
def test_three_policy_parity_matrix(arch, kv_quant, key, lens):
    """Greedy outputs must be token-identical across monolithic, chunked
    and disaggregated on the same request trace — including a
    sliding-window (rolling-cache) config and an int8-KV config."""
    cfg = get_config(arch)
    model = build_model(cfg, ShardCtx.single(), ModelOptions(kv_quant=kv_quant))
    params = model.init(jax.random.key(key))
    rng = np.random.default_rng(key)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
               for n in lens]
    outs = {
        "monolithic": _engine_outputs(model, params, prompts, 4,
                                      policy="monolithic", chunk=None),
        "chunked": _engine_outputs(model, params, prompts, 4,
                                   policy="chunked", chunk=6),
        "disaggregated": _engine_outputs(model, params, prompts, 4,
                                         policy="disaggregated", chunk=6),
    }
    assert outs["chunked"] == outs["monolithic"]
    assert outs["disaggregated"] == outs["monolithic"]


# ---------------------------------------------------------------------------
# Simulator: the recorded acceptance comparison
# ---------------------------------------------------------------------------

def test_simulate_disaggregated_beats_chunked_on_prefill_heavy_trace():
    """The BENCH_chunked.json prefill-heavy comparison: disaggregated's
    sampling-free prefill phases stream through the pipeline, clearing
    >= 1.2x wall-clock over chunked piggybacking (and monolithic)."""
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.pp_sim import simulate_disaggregated, simulate_mixed_workload

    trace = [2400, 40, 2000, 30, 2200, 50, 1800, 60]
    kw = dict(p=2, max_batch=4, token_budget=512, prompt_lens=trace,
              max_new_tokens=16, t_token=4.4e-5, t_fixed=2.6e-3)
    chunk = simulate_mixed_workload(policy="chunked", **kw)
    mono = simulate_mixed_workload(policy="monolithic", **kw)
    dis = simulate_disaggregated(**kw)
    assert chunk.wall_s / dis.wall_s >= 1.2
    assert mono.wall_s / dis.wall_s >= 1.2
