"""Per-architecture smoke tests: every assigned arch instantiates a reduced
same-family config and runs forward/train + prefill + one decode step on
CPU, asserting shapes and finiteness.  Also checks prefill->decode cache
consistency against the full forward for cache-bearing families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import ModelOptions, ShardCtx, build_model
from repro.models.transformer import cfg_n_patches

ARCHS = list_archs()


def _batch(cfg, b=2, s=16):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(2, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(2, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg_n_patches(cfg), cfg.d_model)) * 0.02,
            jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)) * 0.02, jnp.bfloat16)
        batch["tokens"] = batch["tokens"][:, :4]
        batch["labels"] = batch["labels"][:, :4]
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_train_step_finite(arch):
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg, ShardCtx.single(), enc_len=16)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits = jax.jit(model.forward_train)(params, batch)
    s = batch["tokens"].shape[1]
    assert logits.shape == (2, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one real gradient step must stay finite
    def loss_fn(p):
        lg = model.forward_train(p, batch)
        lp = jax.nn.log_softmax(lg.astype(jnp.float32), -1)
        return -jnp.mean(jnp.take_along_axis(lp, batch["labels"][..., None], -1))

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_prefill_decode_finite(arch):
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg, ShardCtx.single(), enc_len=16)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg)
    b = 2
    lg, cache = jax.jit(model.prefill)(params, batch)
    assert lg.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    plen = batch["tokens"].shape[1]
    dcache = model.init_cache(b, 32)
    dbatch = {"token": jnp.full((b,), 5, jnp.int32),
              "positions": jnp.full((b,), plen, jnp.int32)}
    lg2, dcache = jax.jit(model.decode)(params, dcache, dbatch)
    assert lg2.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "mixtral-8x7b", "glm4-9b",
                                  "recurrentgemma-9b", "xlstm-1.3b"])
def test_prefill_then_decode_matches_full_forward(arch):
    """Strong cache-correctness check: prefill S tokens, decode token S+1;
    the decode logits must match forward_train on the S+1 prefix."""
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg, ShardCtx.single())
    params = model.init(jax.random.key(2))
    rng = np.random.default_rng(3)
    b, s = 2, 12
    toks = rng.integers(2, cfg.vocab_size, (b, s + 1))

    full = jax.jit(model.forward_train)(
        params, {"tokens": jnp.asarray(toks, jnp.int32)})
    want = np.asarray(full[:, -1], np.float32)          # logits after token s

    _, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(toks[:, :s], jnp.int32)})
    # pad the prefill cache out to a longer decode buffer
    dcache = model.init_cache(b, s + 8)

    def pad_into(dst, src):
        if dst.shape == src.shape:
            return src
        sl = tuple(slice(0, d) for d in src.shape)
        return dst.at[sl].set(src)

    dcache = jax.tree.map(pad_into, dcache, cache)
    got, _ = jax.jit(model.decode)(params, dcache, {
        "token": jnp.asarray(toks[:, s], jnp.int32),
        "positions": jnp.full((b,), s, jnp.int32)})
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=0.15, atol=0.15)
