"""Paged KV-cache allocator: allocation, growth, CoW forks, invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.runtime.paged_kv import (
    PagedAllocator,
    gather_cache,
    init_paged_cache,
    write_token,
)


def test_allocate_and_free():
    a = PagedAllocator(n_blocks=8, block_size=4)
    t = a.allocate(0, seq_len=10)        # ceil(10/4) = 3 blocks
    assert len(t) == 3 and a.free_blocks == 5
    a.free(0)
    assert a.free_blocks == 8
    a.check_invariants()


def test_append_grows_at_block_boundary():
    a = PagedAllocator(8, 4)
    a.allocate(0, 4)
    assert a.append_token(0, 5) is not None     # crosses into block 2
    assert a.append_token(0, 6) is None         # still fits
    assert len(a.table(0)) == 2
    a.check_invariants()


def test_oom_raises():
    a = PagedAllocator(2, 4)
    a.allocate(0, 8)
    with pytest.raises(MemoryError):
        a.allocate(1, 1)
    assert not a.can_allocate(1)


def test_fork_shares_then_cow_copies():
    a = PagedAllocator(8, 4)
    a.allocate(0, 8)
    a.fork(0, 1)
    assert a.table(0) == a.table(1)
    assert a.free_blocks == 6                   # shared, no new blocks
    phys, copied_from = a.cow(1, 0)
    assert copied_from == a.table(0)[0]
    assert a.table(1)[0] != a.table(0)[0]       # diverged
    assert a.free_blocks == 5
    a.check_invariants()
    a.free(0)
    a.free(1)
    assert a.free_blocks == 8


def test_write_and_gather_roundtrip():
    a = PagedAllocator(6, 4)
    table = a.allocate(0, 6)
    cache = init_paged_cache(n_layers=2, n_blocks=6, block_size=4,
                             kv_heads=2, head_dim=8)
    rng = np.random.default_rng(0)
    ks = rng.normal(size=(6, 2, 8)).astype(np.float32)
    for pos in range(6):
        blk, off = table[pos // 4], pos % 4
        cache = write_token(cache, 1, blk, off,
                            jnp.asarray(ks[pos], jnp.bfloat16),
                            jnp.asarray(ks[pos] * 2, jnp.bfloat16))
    k, v = gather_cache(cache, 1, np.array(table), 6, 4)
    np.testing.assert_allclose(np.asarray(k, np.float32), ks, atol=0.02)
    np.testing.assert_allclose(np.asarray(v, np.float32), ks * 2, atol=0.05)


@settings(max_examples=40, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["alloc", "free", "append",
                                               "fork", "cow"]),
                              st.integers(0, 5)), min_size=1, max_size=40),
       n_blocks=st.integers(4, 24))
def test_property_allocator_invariants(ops, n_blocks):
    a = PagedAllocator(n_blocks, 4)
    lens = {}
    next_id = 0
    for op, arg in ops:
        try:
            if op == "alloc":
                sid = next_id
                next_id += 1
                a.allocate(sid, (arg % 3) * 4 + 1)
                lens[sid] = (arg % 3) * 4 + 1
            elif op == "free" and lens:
                sid = sorted(lens)[arg % len(lens)]
                a.free(sid)
                del lens[sid]
            elif op == "append" and lens:
                sid = sorted(lens)[arg % len(lens)]
                lens[sid] += 1
                a.append_token(sid, lens[sid])
            elif op == "fork" and lens:
                src = sorted(lens)[arg % len(lens)]
                a.fork(src, next_id)
                lens[next_id] = lens[src]
                next_id += 1
            elif op == "cow" and lens:
                sid = sorted(lens)[arg % len(lens)]
                a.cow(sid, 0)
        except MemoryError:
            pass
        a.check_invariants()


@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["alloc", "free", "append"]),
                              st.integers(0, 7)), min_size=1, max_size=60),
       n_blocks=st.integers(4, 20),
       bs=st.sampled_from([2, 4, 8]))
def test_property_churn_never_double_assigns(ops, n_blocks, bs):
    """Fragmentation churn: under interleaved allocate/free/append, no
    physical block is ever owned by two live sequences (nor simultaneously
    free and owned), and freeing returns exactly the owned blocks."""
    a = PagedAllocator(n_blocks, bs)
    lens = {}
    next_id = 0
    for op, arg in ops:
        try:
            if op == "alloc":
                sid, next_id = next_id, next_id + 1
                n = arg * bs // 2 + 1
                a.allocate(sid, n)
                lens[sid] = n
            elif op == "free" and lens:
                sid = sorted(lens)[arg % len(lens)]
                a.free(sid)
                del lens[sid]
            elif op == "append" and lens:
                sid = sorted(lens)[arg % len(lens)]
                lens[sid] += 1
                a.append_token(sid, lens[sid])
        except MemoryError:
            pass
        # explicit double-assignment check (stronger than refcounts: no
        # CoW here, so every block has exactly one owner)
        owned = [b for t in a._tables.values() for b in t]
        assert len(owned) == len(set(owned)), "block owned twice"
        assert not set(owned) & set(a._free), "block free AND owned"
        a.check_invariants()
    for sid in list(lens):
        a.free(sid)
    assert a.free_blocks == n_blocks


@settings(max_examples=30, deadline=None)
@given(n_forks=st.integers(1, 5), writes=st.integers(0, 8),
       seed=st.integers(0, 99))
def test_property_cow_forks_free_correctly(n_forks, writes, seed):
    """Refcounted CoW: fork shares blocks, cow() diverges exactly the
    written block, and freeing every fork (in any order) restores the
    full free list."""
    rng = np.random.default_rng(seed)
    a = PagedAllocator(32, 4)
    a.allocate(0, 12)            # 3 blocks
    forks = list(range(1, n_forks + 1))
    for f in forks:
        a.fork(0, f)
        assert a.table(f) == a.table(0)
    for _ in range(writes):
        f = int(rng.choice(forks))
        blk = int(rng.integers(0, 3))
        before = a.table(f)[blk]
        phys, copied = a.cow(f, blk)
        owners = sum(1 for t in a._tables.values() for x in t if x == before)
        if copied is not None:          # was shared -> diverged
            assert phys != before
        else:                           # already exclusive -> kept
            assert phys == before and owners == 1
        a.check_invariants()
    order = list(rng.permutation([0] + forks))
    for sid in order:
        a.free(sid)
        a.check_invariants()
    assert a.free_blocks == 32


@settings(max_examples=40, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["alloc", "free", "append",
                                               "fork"]),
                              st.integers(0, 7)), min_size=1, max_size=60),
       n_blocks=st.integers(4, 24))
def test_property_append_never_writes_shared_block(ops, n_blocks):
    """Shared-block write hazard (satellite): under interleaved
    fork/append/free churn, the block an appended token lands in is
    always exclusively owned afterwards — growth CoWs a shared tail
    instead of writing through it."""
    a = PagedAllocator(n_blocks, 4)
    lens = {}
    next_id = 0
    for op, arg in ops:
        try:
            if op == "alloc":
                sid, next_id = next_id, next_id + 1
                a.allocate(sid, arg % 8 + 1)
                lens[sid] = arg % 8 + 1
            elif op == "free" and lens:
                sid = sorted(lens)[arg % len(lens)]
                a.free(sid)
                del lens[sid]
            elif op == "fork" and lens:
                src = sorted(lens)[arg % len(lens)]
                a.fork(src, next_id)
                lens[next_id] = lens[src]
                next_id += 1
            elif op == "append" and lens:
                sid = sorted(lens)[arg % len(lens)]
                a.append_token(sid, lens[sid] + 1)
                lens[sid] += 1
                wb = a._tables[sid][(lens[sid] - 1) // 4]
                assert a._refs[wb] == 1, "append wrote into a shared block"
        except MemoryError:
            pass
        a.check_invariants()


def test_grow_to_all_or_nothing_includes_cow():
    """grow_to must count the CoW of a shared write block against the
    free list together with growth: when the pool cannot cover both, it
    returns False having allocated and copied nothing."""
    a = PagedAllocator(3, 4)
    a.allocate(0, 8)                       # 2 blocks, 1 free
    a.fork(0, 1)
    # growth (1 block) + CoW of the shared write block = 2 > 1 free
    assert not a.grow_to(1, 9, write_slot=7)
    assert len(a.table(1)) == 2 and a.free_blocks == 1
    assert not a._pending_copies           # nothing copied on failure
    a.check_invariants()
    # CoW alone fits: write slot 7 is block 1, shared -> diverges
    assert a.grow_to(1, 8)
    assert a.table(1)[0] == a.table(0)[0]
    assert a.table(1)[1] != a.table(0)[1]
    assert a.drain_copies() == [(a.table(0)[1], a.table(1)[1])]
    assert a.free_blocks == 0
    # now growth alone cannot fit
    assert not a.grow_to(0, 9)
    assert len(a.table(0)) == 2
    a.free(1)
    assert a.grow_to(0, 9) and len(a.table(0)) == 3
    a.check_invariants()


def test_manager_fork_cow_on_growth_drains_copies():
    from repro.runtime.paged_kv import BlockSpaceManager

    m = BlockSpaceManager(8, 4)
    m.admit(0, 8)                          # 2 blocks
    assert m.fork(0, 1)
    assert not m.fork(0, 1)                # dst exists
    assert not m.fork(9, 2)                # unknown src
    assert m.prefix_stats()["forks"] == 1
    # the fork's first decode writes slot 7 -> shared block 1 -> CoW
    assert m.ensure(1, 8)
    t0, t1 = m.table(0), m.table(1)
    assert t0[0] == t1[0] and t0[1] != t1[1]
    copies = m.drain_copies()
    assert copies is not None and copies.shape == (1, 2)
    assert list(copies[0]) == [t0[1], t1[1]]
    assert m.drain_copies() is None        # drained exactly once
    assert m.prefix_stats()["cow_copies"] == 1
    m.alloc.check_invariants()
    m.release(0)
    m.release(1)
    assert m.free_blocks == 8


def test_manager_ensure_cow_exhaustion_returns_false_then_recovers():
    """Satellite: CoW exhaustion is a recoverable admission-style failure
    (ensure -> False -> the scheduler preempts and retries), not a raised
    MemoryError."""
    from repro.runtime.paged_kv import BlockSpaceManager

    m = BlockSpaceManager(4, 4)
    m.admit(0, 8)                          # 2 blocks
    m.fork(0, 1)
    m.admit(2, 8)                          # pool now full
    assert m.free_blocks == 0
    assert not m.ensure(1, 8)              # CoW needs a block; none free
    assert m.table(1) == m.table(0)        # nothing taken, still shared
    m.alloc.check_invariants()
    m.release(2)                           # preemption frees the victim
    assert m.ensure(1, 8)                  # retry succeeds
    assert m.table(1)[1] != m.table(0)[1]
    m.alloc.check_invariants()


def test_prefix_cache_admit_register_hit_and_eviction():
    from repro.runtime.paged_kv import BlockSpaceManager

    m = BlockSpaceManager(8, 4, prefix_cache=True)
    toks = list(range(100, 116))           # 16 tokens = 4 full blocks
    assert m.admit(0, 16, token_ids=toks) == 0          # cold miss
    assert m.prefix_stats()["prefix_misses"] == 1
    m.register_prefix(0, toks, 16)
    m.register_prefix(0, toks, 16)                      # idempotent
    assert m.prefix_stats()["prefix_cached_blocks"] == 4
    m.release(0)
    # cached blocks survive release, pinned by the cache
    assert m.free_blocks == 4
    assert m.reclaimable_cached_blocks == 4
    m.alloc.check_invariants()
    # warm admission: match capped at (16-1)//4 = 3 blocks, so the last
    # prompt token is always computed (the seq needs its logits)
    assert m.admit(1, 16, token_ids=toks) == 12
    assert m.prefix_stats()["prefix_hits"] == 1
    assert m.prefix_stats()["prefix_tokens_served"] == 12
    # divergent tail matches only the common leading blocks
    toks2 = toks[:8] + [999] * 8
    assert m.admit(2, 16, token_ids=toks2) == 8
    m.alloc.check_invariants()
    m.release(1)
    m.release(2)
    # admission under pressure evicts LRU cached blocks on demand
    assert m.free_blocks == 4
    cold = [7] * 24                        # 6 blocks > 4 free
    assert m.can_admit(24, token_ids=cold)
    assert m.admit(3, 24, token_ids=cold) == 0
    st = m.prefix_stats()
    assert st["prefix_evictions"] == 2
    assert st["prefix_cached_blocks"] == 2
    m.alloc.check_invariants()
    m.release(3)


def test_prefix_cache_collision_degrades_to_miss():
    """A content-mismatched hash collision must never serve wrong K/V:
    the entry stays as-is and the new chain stops registering."""
    from repro.runtime.paged_kv import BlockSpaceManager, PrefixCache

    px = PrefixCache(4)
    k1, created = px.register(None, (1, 2, 3, 4), 0)
    assert created
    # force a colliding key with different content
    px._entries[px._key(None, (9, 9, 9, 9))] = px._entries[k1]
    assert px.match([9, 9, 9, 9]) == []    # token-verify rejects it

    m = BlockSpaceManager(8, 4, prefix_cache=True)
    toks = list(range(8))
    m.admit(0, 8, token_ids=toks)
    m.register_prefix(0, toks, 8)
    # simulate a collision on seq 1's first block: registration bails
    m.admit(1, 8, token_ids=[5] * 8)
    m._prefix._entries[m._prefix._key(None, (5, 5, 5, 5))] = \
        m._prefix._entries[m._prefix._key(None, tuple(toks[:4]))]
    m.register_prefix(1, [5] * 8, 8)
    from repro.runtime.paged_kv import _CHAIN_BROKEN
    assert m._reg[1][1] is _CHAIN_BROKEN   # chain stops, never corrupts
    m.alloc.check_invariants()


def test_prefix_cache_rejects_rolling_window():
    from repro.runtime.paged_kv import BlockSpaceManager

    with pytest.raises(ValueError, match="rolling"):
        BlockSpaceManager(8, 4, slot_cap=16, prefix_cache=True)


def test_padded_tables_ladder_extends_deterministically():
    """Satellite: a table wider than the capped ladder extends it with
    the next power-of-two rung (recorded in table_widths) instead of
    emitting a one-off off-ladder width."""
    from repro.runtime.paged_kv import BlockSpaceManager

    m = BlockSpaceManager(16, 8, max_slots=32, max_table_buckets=2)
    assert m.table_widths == [2, 4]
    m.admit(0, 8)
    assert m.padded_tables([0]).shape == (1, 2)     # smallest rung
    m.admit(1, 40)                                  # 5 blocks > cap 4
    t = m.padded_tables([0, 1])
    assert t.shape == (2, 8)                        # next pow2, on-ladder
    assert m.table_widths == [2, 4, 8]
    assert m.ladder_extensions == 1
    m.padded_tables([1])
    assert m.ladder_extensions == 1                 # extended exactly once
    # every emitted width is on the ladder
    for ids in ([0], [1], [0, 1]):
        assert m.padded_tables(ids).shape[1] in m.table_widths


def test_padded_tables_mask_shared_blocks():
    from repro.runtime.paged_kv import BlockSpaceManager

    m = BlockSpaceManager(8, 4)
    m.admit(0, 8)
    m.fork(0, 1)
    assert m.ensure(1, 8)          # write slot 7 -> shared tail CoW'd
    assert m.ensure(1, 9)          # then a fresh 3rd block
    m.drain_copies()
    t = m.padded_tables([1], mask_shared=True)[0]
    assert t[0] == m.pad_block                      # shared -> trash
    assert t[1] == m.table(1)[1] != m.pad_block     # CoW'd -> writable
    assert t[2] == m.table(1)[2]
    plain = m.padded_tables([1])[0]
    assert list(plain[:3]) == m.table(1)


def test_block_space_manager_slots_cap_and_growth():
    from repro.runtime.paged_kv import BlockSpaceManager

    m = BlockSpaceManager(8, 4, slot_cap=16)      # window 16 -> max 4 blocks
    assert m.blocks_for(3) == 1 and m.blocks_for(17) == 4
    assert m.blocks_for(1000) == 4                # capped by the window
    m.admit(0, 6)
    assert len(m.table(0)) == 2
    assert m.ensure(0, 9)                         # grow to 3 blocks
    assert len(m.table(0)) == 3
    assert m.ensure(0, 100) and len(m.table(0)) == 4   # capped
    m.admit(1, 16)                                # takes the rest
    assert m.free_blocks == 0
    assert not m.ensure(2, 4)                     # unknown seq: no blocks
    m.release(0)
    m.release(0)                                  # idempotent
    assert m.free_blocks == 4
    # padded tables: trash-padded, power-of-two width capped at W/bs
    t = m.padded_tables([1, 0])
    assert t.shape == (2, 4)
    assert list(t[0]) == m.table(1)
    assert (t[1] == m.pad_block).all()            # released -> all trash


def test_block_space_manager_ensure_all_or_nothing():
    from repro.runtime.paged_kv import BlockSpaceManager

    m = BlockSpaceManager(4, 2)
    m.admit(0, 2)
    m.admit(1, 6)                # 3 blocks -> pool full
    assert m.free_blocks == 0
    assert not m.ensure(0, 8)    # needs 3 more than it has; nothing taken
    assert len(m.table(0)) == 1
    m.release(1)
    assert m.ensure(0, 8) and len(m.table(0)) == 4


def test_block_size_must_divide_window():
    from repro.runtime.paged_kv import BlockSpaceManager

    with pytest.raises(ValueError, match="divide"):
        BlockSpaceManager(8, 3, slot_cap=16)
