"""Paged KV-cache allocator: allocation, growth, CoW forks, invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.runtime.paged_kv import (
    PagedAllocator,
    gather_cache,
    init_paged_cache,
    write_token,
)


def test_allocate_and_free():
    a = PagedAllocator(n_blocks=8, block_size=4)
    t = a.allocate(0, seq_len=10)        # ceil(10/4) = 3 blocks
    assert len(t) == 3 and a.free_blocks == 5
    a.free(0)
    assert a.free_blocks == 8
    a.check_invariants()


def test_append_grows_at_block_boundary():
    a = PagedAllocator(8, 4)
    a.allocate(0, 4)
    assert a.append_token(0, 5) is not None     # crosses into block 2
    assert a.append_token(0, 6) is None         # still fits
    assert len(a.table(0)) == 2
    a.check_invariants()


def test_oom_raises():
    a = PagedAllocator(2, 4)
    a.allocate(0, 8)
    with pytest.raises(MemoryError):
        a.allocate(1, 1)
    assert not a.can_allocate(1)


def test_fork_shares_then_cow_copies():
    a = PagedAllocator(8, 4)
    a.allocate(0, 8)
    a.fork(0, 1)
    assert a.table(0) == a.table(1)
    assert a.free_blocks == 6                   # shared, no new blocks
    phys, copied_from = a.cow(1, 0)
    assert copied_from == a.table(0)[0]
    assert a.table(1)[0] != a.table(0)[0]       # diverged
    assert a.free_blocks == 5
    a.check_invariants()
    a.free(0)
    a.free(1)
    assert a.free_blocks == 8


def test_write_and_gather_roundtrip():
    a = PagedAllocator(6, 4)
    table = a.allocate(0, 6)
    cache = init_paged_cache(n_layers=2, n_blocks=6, block_size=4,
                             kv_heads=2, head_dim=8)
    rng = np.random.default_rng(0)
    ks = rng.normal(size=(6, 2, 8)).astype(np.float32)
    for pos in range(6):
        blk, off = table[pos // 4], pos % 4
        cache = write_token(cache, 1, blk, off,
                            jnp.asarray(ks[pos], jnp.bfloat16),
                            jnp.asarray(ks[pos] * 2, jnp.bfloat16))
    k, v = gather_cache(cache, 1, np.array(table), 6, 4)
    np.testing.assert_allclose(np.asarray(k, np.float32), ks, atol=0.02)
    np.testing.assert_allclose(np.asarray(v, np.float32), ks * 2, atol=0.05)


@settings(max_examples=40, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["alloc", "free", "append",
                                               "fork", "cow"]),
                              st.integers(0, 5)), min_size=1, max_size=40),
       n_blocks=st.integers(4, 24))
def test_property_allocator_invariants(ops, n_blocks):
    a = PagedAllocator(n_blocks, 4)
    lens = {}
    next_id = 0
    for op, arg in ops:
        try:
            if op == "alloc":
                sid = next_id
                next_id += 1
                a.allocate(sid, (arg % 3) * 4 + 1)
                lens[sid] = (arg % 3) * 4 + 1
            elif op == "free" and lens:
                sid = sorted(lens)[arg % len(lens)]
                a.free(sid)
                del lens[sid]
            elif op == "append" and lens:
                sid = sorted(lens)[arg % len(lens)]
                lens[sid] += 1
                a.append_token(sid, lens[sid])
            elif op == "fork" and lens:
                src = sorted(lens)[arg % len(lens)]
                a.fork(src, next_id)
                lens[next_id] = lens[src]
                next_id += 1
            elif op == "cow" and lens:
                sid = sorted(lens)[arg % len(lens)]
                a.cow(sid, 0)
        except MemoryError:
            pass
        a.check_invariants()
