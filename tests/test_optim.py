"""Optimizer, schedules and gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import optim


def test_adamw_minimizes_quadratic():
    cfg = optim.AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = optim.init_opt_state(params, cfg)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state = optim.adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["x"]).max()) < 0.1


def test_adamw_weight_decay_shrinks():
    cfg = optim.AdamWConfig(lr=0.05, weight_decay=0.5, grad_clip=0.0)
    params = {"x": jnp.array([4.0])}
    state = optim.init_opt_state(params, cfg)
    for _ in range(50):
        params, state = optim.adamw_update(params, {"x": jnp.zeros(1)}, state, cfg)
    assert float(params["x"][0]) < 4.0


def test_grad_clip_bounds_update():
    cfg = optim.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"x": jnp.zeros(4)}
    state = optim.init_opt_state(params, cfg)
    huge = {"x": jnp.full(4, 1e9)}
    p1, _ = optim.adamw_update(params, huge, state, cfg)
    assert np.isfinite(np.asarray(p1["x"])).all()


def test_bf16_moments_roundtrip():
    cfg = optim.AdamWConfig(moment_dtype=jnp.bfloat16)
    params = {"x": jnp.ones(8, jnp.bfloat16)}
    state = optim.init_opt_state(params, cfg)
    assert state["m"]["x"].dtype == jnp.bfloat16
    p1, s1 = optim.adamw_update(params, {"x": jnp.ones(8, jnp.bfloat16)},
                                state, cfg)
    assert p1["x"].dtype == jnp.bfloat16 and s1["v"]["x"].dtype == jnp.bfloat16


def test_cosine_schedule_shape():
    f = optim.cosine_schedule(warmup=10, total=100, min_frac=0.1)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1.0) < 1e-6
    assert float(f(100)) == pytest.approx(0.1, abs=1e-3)
    vals = [float(f(s)) for s in range(10, 101, 10)]
    assert all(a >= b - 1e-6 for a, b in zip(vals, vals[1:]))  # monotone decay


def test_wsd_schedule_shape():
    f = optim.wsd_schedule(warmup=10, stable=60, decay=30, min_frac=0.1)
    assert abs(float(f(10)) - 1.0) < 1e-6
    assert abs(float(f(69)) - 1.0) < 1e-6          # stable plateau
    assert float(f(100)) == pytest.approx(0.1, abs=1e-3)


def test_int8_quantize_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=512), jnp.float32)
    q, s = optim.quantize_int8(x)
    err = np.abs(np.asarray(optim.dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6      # half-ulp of the int8 grid


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 500), steps=st.integers(2, 10))
def test_property_error_feedback_preserves_mean_signal(seed, steps):
    """With error feedback, the cumulative applied gradient converges to the
    cumulative true gradient (residual stays bounded by one quantum)."""
    rng = np.random.default_rng(seed)
    g_true = {"w": jnp.asarray(rng.normal(size=64), jnp.float32)}
    err = None
    applied = np.zeros(64)
    for _ in range(steps):
        comp, err = optim.compress_grads_with_feedback(g_true, err)
        applied += np.asarray(comp["w"])
    total_true = steps * np.asarray(g_true["w"])
    scale = np.abs(np.asarray(g_true["w"])).max() / 127.0
    assert np.abs(applied - total_true).max() <= scale * 1.01 + 1e-6
