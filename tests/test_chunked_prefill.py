"""Chunked-prefill scheduling: engine-level token equivalence with the
monolithic path, span metadata construction, staging layout, and the
occupancy win on a mixed long-prompt/decode workload."""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import EngineConfig, NaivePPEngine, SiPipeEngine
from repro.core.sampling_params import SamplingParams
from repro.core.scheduler import Scheduler, SchedulingOutput
from repro.core.sequence import Sequence
from repro.core.tsem import BatchMetadataCache, VersionedStaging
from repro.models import ModelOptions, ShardCtx, build_model

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.pp_sim import simulate_mixed_workload  # noqa: E402


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single())
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _run_engine(model, params, prompts, n_new, *, Eng=SiPipeEngine,
                chunk=None, pp=2, max_batch=2):
    eng = Eng(model, params, EngineConfig(
        pp_degree=pp, max_batch=max_batch, max_seq_len=64, n_samplers=2,
        prefill_chunk_tokens=chunk))
    for p in prompts:
        eng.add_request(p, SamplingParams(greedy=True, max_new_tokens=n_new))
    done = sorted(eng.run(), key=lambda s: s.seq_id)
    assert len(done) == len(prompts)
    return [s.output_ids for s in done]


# ---------------------------------------------------------------------------
# End-to-end equivalence (acceptance: chunked == monolithic under greedy)
# ---------------------------------------------------------------------------

def test_chunked_token_identical_to_monolithic(model_and_params):
    """Greedy decode must be bit-identical whether prompts are prefilled
    monolithically or split into budget-sized chunks."""
    cfg, model, params = model_and_params
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
               for n in (13, 5)]
    mono = _run_engine(model, params, prompts, 5, chunk=None)
    chunked = _run_engine(model, params, prompts, 5, chunk=6)
    assert chunked == mono


def test_sipipe_and_naive_agree_with_chunking(model_and_params):
    """SiPipeEngine vs NaivePPEngine: token-identical greedy decodes on a
    tiny model (p=2), with chunked prefill enabled on both."""
    cfg, model, params = model_and_params
    rng = np.random.default_rng(1)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
               for n in (11, 4)]
    sip = _run_engine(model, params, prompts, 4, Eng=SiPipeEngine, chunk=6)
    nai = _run_engine(model, params, prompts, 4, Eng=NaivePPEngine, chunk=6)
    assert sip == nai


def test_small_budget_piggybacks_decodes(model_and_params):
    """A tight budget forces multi-chunk prefills interleaved with decode
    steps of already-running sequences; output must stay identical."""
    cfg, model, params = model_and_params
    rng = np.random.default_rng(2)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
               for n in (4, 14, 3, 9)]
    mono = _run_engine(model, params, prompts, 4, chunk=None)
    chunked = _run_engine(model, params, prompts, 4, chunk=5)
    assert chunked == mono


# ---------------------------------------------------------------------------
# Packed metadata + staging layout
# ---------------------------------------------------------------------------

def _sched_with(spans, span_tokens, needs_sample=None):
    b = len(spans)
    return SchedulingOutput(
        iteration=0, slot=0, seq_ids=list(range(b)),
        positions=np.array([off for off, _ in spans], np.int32),
        tokens=np.array([t[0] for t in span_tokens], np.int32),
        is_prefill=False, spans=spans, span_tokens=span_tokens,
        needs_sample=needs_sample or [True] * b)


def test_batch_metadata_packed_layout_and_padding():
    """The packed [W] vectors concatenate the valid span tokens; bucket
    padding duplicates the LAST valid element (token, position AND row),
    so duplicate cache scatters write identical values."""
    mc = BatchMetadataCache(1)
    sched = _sched_with([(0, 3), (7, 1)], [[10, 11, 12], [99]])
    meta = mc.update(sched, np.array([0, 1], np.int32))
    assert meta.width == 8 and meta.n_valid == 4    # bucket floor
    np.testing.assert_array_equal(meta.pack_tokens,
                                  [10, 11, 12, 99, 99, 99, 99, 99])
    np.testing.assert_array_equal(meta.pack_positions,
                                  [0, 1, 2, 7, 7, 7, 7, 7])
    np.testing.assert_array_equal(meta.pack_seq, [0, 0, 0, 1, 1, 1, 1, 1])
    np.testing.assert_array_equal(meta.last_index, [2, 3])


def test_packed_bucket_is_power_of_two():
    sched = _sched_with([(0, 9), (7, 1)], [list(range(1, 10)), [99]])
    assert sched.total_tokens == 10
    assert sched.packed_width == 16
    decode = _sched_with([(3, 1), (7, 1)], [[5], [7]])
    assert decode.packed_width == 1                 # flat decode fast path


def test_incremental_fast_path_only_for_pure_decode():
    """Chunked iterations rebuild; pure-decode n/n+p pairs advance in place."""
    mc = BatchMetadataCache(1)
    rows = np.array([0, 1], np.int32)
    chunked = _sched_with([(0, 2), (5, 1)], [[3, 4], [9]])
    mc.update(chunked, rows)
    assert (mc.rebuilds, mc.incremental_hits) == (1, 0)
    # same seq set, now pure decode -> still a rebuild (layout change)...
    decode = _sched_with([(2, 1), (6, 1)], [[5], [7]])
    m1 = mc.update(decode, rows)
    assert (mc.rebuilds, mc.incremental_hits) == (2, 0)
    # ...then the steady decode state hits the incremental path
    decode2 = _sched_with([(3, 1), (7, 1)], [[6], [8]])
    m2 = mc.update(decode2, rows)
    assert (mc.rebuilds, mc.incremental_hits) == (2, 1)
    assert m2 is m1
    np.testing.assert_array_equal(m2.positions, [3, 7])


def test_versioned_staging_packed_buffers():
    st = VersionedStaging()
    flat = st.buffers(0, 4)
    assert set(flat) == {"tokens", "positions", "rows"}
    wide = st.buffers(0, 4, width=8)
    assert wide["pack_tokens"].shape == (8,)
    assert wide["pack_positions"].shape == (8,)
    assert wide["pack_seq"].shape == (8,)
    assert wide["last_index"].shape == (4,)
    assert wide["n_valid"].shape == (1,)
    # distinct keys: flat and packed staging never alias
    assert st.buffers(0, 4) is flat
    assert st.buffers(0, 4, width=8) is wide
    assert st.buffers(1, 4, width=8) is not wide
    assert st.buffers(0, 4, width=16) is not wide   # per-bucket buffers


# ---------------------------------------------------------------------------
# Sliding-window + int8-KV chunk modes (formerly NotImplementedError)
# ---------------------------------------------------------------------------

def test_chunked_sliding_window_token_identical_to_monolithic():
    """Windowed (rolling-cache) models: chunked prefill must reproduce the
    monolithic path's greedy tokens exactly (two-source span attention)."""
    cfg = get_config("mixtral-8x7b-smoke")          # moe, window=32
    assert cfg.window > 0
    model = build_model(cfg, ShardCtx.single())
    params = model.init(jax.random.key(3))
    rng = np.random.default_rng(3)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=13)))
               for _ in range(2)]
    mono = _run_engine(model, params, prompts, 5, chunk=None)
    chunked = _run_engine(model, params, prompts, 5, chunk=6)
    assert chunked == mono


def test_fill_rolling_cache_ragged_matches_per_row():
    """Per-row gather variant == fill_rolling_cache applied to each row's
    unpadded length, with zeroed slots for rows shorter than the window
    (the state per-token scatters would have produced)."""
    from repro.models.attention import fill_rolling_cache, fill_rolling_cache_ragged

    rng = np.random.default_rng(0)
    w, s, kv, hd = 8, 21, 2, 4
    lens = np.array([21, 5, 13], np.int32)
    k = jnp.asarray(rng.normal(size=(3, s, kv, hd)).astype(np.float32))
    ragged = fill_rolling_cache_ragged(k, w, jnp.asarray(lens))
    for i, L in enumerate(lens):
        per_row = fill_rolling_cache(k[i:i + 1, :L], w)
        np.testing.assert_allclose(np.asarray(ragged[i]),
                                   np.asarray(per_row[0]), rtol=0, atol=0)


@pytest.mark.slow
def test_ragged_windowed_monolithic_matches_per_seq_prefill():
    """ROADMAP bug regression: monolithic prefill of a RAGGED batch on a
    sliding-window model used to roll pad-tail K/V into live rolling
    slots (fill_rolling_cache assumed an unpadded [B, S] batch).  With
    the per-row ragged fill, batched ragged prefill must match
    prefilling each sequence alone — and the (unaffected) chunked path."""
    cfg = get_config("mixtral-8x7b-smoke")
    assert cfg.window > 0
    model = build_model(cfg, ShardCtx.single())
    params = model.init(jax.random.key(3))
    rng = np.random.default_rng(3)
    # lengths straddle the window (37 > W=32 > 9) to exercise both the
    # wrapped-tail and the shorter-than-window fill paths
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
               for n in (37, 9)]
    ragged_mono = _run_engine(model, params, prompts, 5, chunk=None)
    per_seq = [_run_engine(model, params, [p], 5, chunk=None, pp=2,
                           max_batch=1)[0] for p in prompts]
    chunked = _run_engine(model, params, prompts, 5, chunk=6)
    assert ragged_mono == per_seq
    assert chunked == per_seq


def test_chunked_window_budget_must_fit_window():
    cfg = get_config("mixtral-8x7b-smoke")
    model = build_model(cfg, ShardCtx.single())
    params = model.init(jax.random.key(0))
    with pytest.raises(ValueError, match="window"):
        SiPipeEngine(model, params, EngineConfig(
            pp_degree=1, max_batch=2, max_seq_len=64,
            prefill_chunk_tokens=cfg.window + 1))


def test_chunked_int8_kv_token_identical_to_monolithic():
    """int8-KV chunk mode: per-token quantization makes the chunked cache
    bit-identical to the monolithic one, so all decode steps see the same
    state.  Prompt-final logits are NOT structurally identical (monolithic
    prefill attends full-precision K/V, chunks attend the int8 cache), but
    the ~1% quantization error is far below this model's logit gaps, so
    greedy tokens match; this is a fixed-seed regression pin of that."""
    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single(), ModelOptions(kv_quant=True))
    params = model.init(jax.random.key(4))
    rng = np.random.default_rng(4)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
               for n in (11, 5)]
    mono = _run_engine(model, params, prompts, 4, chunk=None)
    chunked = _run_engine(model, params, prompts, 4, chunk=6)
    assert chunked == mono


# ---------------------------------------------------------------------------
# Penalty carryover across the sampler pool
# ---------------------------------------------------------------------------

def _run_engine_penalized(model, params, prompts, n_new, *, chunk, n_samplers):
    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=2, max_batch=2, max_seq_len=64, n_samplers=n_samplers,
        prefill_chunk_tokens=chunk))
    for p in prompts:
        eng.add_request(p, SamplingParams(
            greedy=True, max_new_tokens=n_new, frequency_penalty=0.9,
            presence_penalty=0.4))
    return [s.output_ids for s in sorted(eng.run(), key=lambda s: s.seq_id)]


def test_penalties_survive_pool_size_and_recomposition(model_and_params):
    """Frequency/presence penalties must follow the *sequence*: columns
    are partitioned over the sampler pool by seq id, and replica rebuilds
    carry per-sequence state, so greedy-with-penalties output is
    invariant to the pool size even as chunked prefill recomposes the
    eligible set every iteration (staggered prompt lengths + finishes)."""
    cfg, model, params = model_and_params
    rng = np.random.default_rng(5)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
               for n in (13, 4, 9)]
    one = _run_engine_penalized(model, params, prompts, 6, chunk=6,
                                n_samplers=1)
    two = _run_engine_penalized(model, params, prompts, 6, chunk=6,
                                n_samplers=2)
    assert one == two
    assert all(len(o) == 6 for o in one)


# ---------------------------------------------------------------------------
# Packed vs padded execution (stage-level token identity)
# ---------------------------------------------------------------------------

def test_packed_matches_padded_chunk_execution(model_and_params):
    """The packed ragged layout must be compute-equivalent to the padded
    [B, C] layout it replaces: running the same mixed batch clamp-padded
    to full width (the old dense execution, expressible as a packed batch
    of B*C duplicate-padded tokens) yields identical last-token logits."""
    cfg, model, params = model_and_params
    from repro.core.engine import split_for_pp

    stage = split_for_pp(model, params, 1)[0]
    b, s_max = 3, 32
    cache = stage.init_cache(b, s_max)
    rng = np.random.default_rng(7)
    spans = [(0, 5), (8, 1), (3, 2)]                # 1 chunk + decode + chunk
    tok = {i: rng.integers(2, cfg.vocab_size, s_max) for i in range(b)}

    def run(pad_to):
        pt, pp_, ps, last = [], [], [], []
        for i, (off, n) in enumerate(spans):
            width = max(n, pad_to)
            idx = np.minimum(np.arange(width), n - 1)
            pt.extend(tok[i][off + idx])
            pp_.extend(off + idx)
            ps.extend([i] * width)
            last.append(len(pt) - (width - n) - 1)
        t = len(pt)
        logits, _ = stage.chunk_fn(
            stage.params, cache, jnp.asarray(pt, jnp.int32),
            jnp.asarray(pp_, jnp.int32), jnp.asarray(ps, jnp.int32),
            jnp.asarray([off for off, _ in spans], jnp.int32),
            jnp.asarray(last, jnp.int32), jnp.asarray(t, jnp.int32))
        return np.asarray(logits, np.float32)

    packed = run(pad_to=0)                          # ragged: T = 8 tokens
    padded = run(pad_to=5)                          # dense:  B x C = 15
    np.testing.assert_array_equal(packed.argmax(-1), padded.argmax(-1))
    np.testing.assert_allclose(packed, padded, rtol=2e-4, atol=2e-4)


def test_sampling_only_fires_on_prefill_completion():
    """needs_sample marks exactly the prompt-completing chunk + decodes."""
    s = Scheduler(max_batch=2, pp_degree=1, max_seq_len=128, token_budget=8)
    s.add_request(Sequence(0, list(range(1, 21)),
                           SamplingParams(greedy=True, max_new_tokens=3)))
    samples = []
    for it in range(12):
        o = s.schedule(it)
        if o is None:
            break
        samples.append(list(o.needs_sample))
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        s.complete(it, ids, np.full(len(ids), 5, np.int32))
    # 20-token prompt / budget 8 -> chunks 8, 8, 4: sampling fires on the
    # third chunk only, then on each decode step
    assert samples[:3] == [[False], [False], [True]]
    assert all(ns == [True] for ns in samples[3:])
    assert s.finished and s.finished[0].output_ids == [5, 5, 5]


# ---------------------------------------------------------------------------
# Occupancy (acceptance: fewer bubble ticks on a mixed workload)
# ---------------------------------------------------------------------------

def test_chunked_improves_occupancy_and_bubbles():
    prompts = [200, 8, 150, 6, 180, 10, 90, 120, 5, 160, 7, 140]
    mono = simulate_mixed_workload(p=2, max_batch=4, token_budget=32,
                                   prompt_lens=prompts, max_new_tokens=24,
                                   chunked=False)
    chunk = simulate_mixed_workload(p=2, max_batch=4, token_budget=32,
                                    prompt_lens=prompts, max_new_tokens=24,
                                    chunked=True)
    assert chunk.occupancy > mono.occupancy
    assert chunk.bubble_ticks < mono.bubble_ticks
    assert max(chunk.bubble_fracs) < max(mono.bubble_fracs)
    assert chunk.prefill_block_s == 0.0 and mono.prefill_block_s > 0.0
