"""The HTTP serving front-end (docs/http.md): golden-byte SSE framing,
completions JSON schema, request parsing, Prometheus rendering — then
live-server tests over a real socket (MockEngine replicas: streaming,
429-on-full, disconnect-mid-stream -> abort) and real-engine e2e
(bit-exactness vs direct generate(), block reclamation, the
abort-inside-fork-spawn-window regression)."""
import http.client
import json
import time

import jax
import pytest

from repro.configs import get_config
from repro.core.engine import EngineConfig, SiPipeEngine
from repro.core.sampling_params import SamplingParams
from repro.models import ModelOptions, ShardCtx, build_model
from repro.serving import protocol as proto
from repro.serving.mock import MockEngine
from repro.serving.protocol import ProtocolError
from repro.serving.router import EngineReplica, Router
from repro.serving.server import CompletionServer


# ---------------------------------------------------------------------------
# Golden bytes: SSE framing is part of the wire contract
# ---------------------------------------------------------------------------

def test_sse_chunk_golden_bytes():
    chunk = proto.completion_chunk(7, 1234, "m", 0, [3, 4])
    assert proto.sse_event(chunk) == (
        b'data: {"choices":[{"finish_reason":null,"index":0,"logprobs":null,'
        b'"text":"3 4","token_ids":[3,4]}],"created":1234,"id":"cmpl-7",'
        b'"model":"m","object":"text_completion.chunk"}\n\n')


def test_sse_terminal_chunk_golden_bytes():
    chunk = proto.completion_chunk(7, 1234, "m", 1, [], "length")
    assert proto.sse_event(chunk) == (
        b'data: {"choices":[{"finish_reason":"length","index":1,'
        b'"logprobs":null,"text":"","token_ids":[]}],"created":1234,'
        b'"id":"cmpl-7","model":"m","object":"text_completion.chunk"}\n\n')
    assert proto.SSE_DONE == b"data: [DONE]\n\n"


def test_completion_response_schema_and_usage():
    resp = proto.completion_response(
        9, 1234, "m",
        [{"token_ids": [5, 6, 7], "finish_reason": "length"},
         {"token_ids": [8], "finish_reason": "stop"}],
        prompt_tokens=4)
    assert resp["id"] == "cmpl-9" and resp["object"] == "text_completion"
    assert [c["index"] for c in resp["choices"]] == [0, 1]
    assert resp["choices"][0]["text"] == "5 6 7"
    assert resp["choices"][1]["finish_reason"] == "stop"
    assert resp["usage"] == {"prompt_tokens": 4, "completion_tokens": 4,
                             "total_tokens": 8}


# ---------------------------------------------------------------------------
# Request parsing
# ---------------------------------------------------------------------------

def test_parse_accepts_token_ids_and_strings():
    r = proto.parse_completion_request({"prompt": [3, 5, 7]}, 64)
    assert r.prompt_ids == [3, 5, 7] and r.tenant == "anonymous"
    r2 = proto.parse_completion_request({"prompt": "hi"}, 64)
    assert r2.prompt_ids == [2 + (b % 62) for b in b"hi"]


@pytest.mark.parametrize("body,match", [
    ({}, "prompt"),
    ({"prompt": []}, "prompt"),
    ({"prompt": [999]}, "out of range"),
    ({"prompt": [1], "max_tokens": 0}, "max_tokens"),
    ({"prompt": [1], "max_tokens": "4"}, "max_tokens"),
    ({"prompt": [1], "n": 0}, "n must"),
    ({"prompt": [1], "n": True}, "n"),          # bool is not an int here
    ({"prompt": [1], "temperature": -1.0}, "temperature"),
    ({"prompt": [1], "top_p": 0.0}, "top_p"),
    ({"prompt": [1], "stream": 1}, "stream"),
])
def test_parse_rejects_malformed(body, match):
    with pytest.raises(ProtocolError, match=match):
        proto.parse_completion_request(body, 64)


def test_parse_greedy_and_priority_thread_into_params():
    r = proto.parse_completion_request(
        {"prompt": [1], "temperature": 0.0, "priority": 3,
         "max_tokens": 5}, 64)
    p = r.sampling_params()
    assert p.greedy and p.priority == 3 and p.max_new_tokens == 5


def test_parse_tenant_precedence_and_cap():
    body = {"prompt": [1], "user": "body-user", "max_tokens": 100}
    assert proto.parse_completion_request(body, 64).tenant == "body-user"
    r = proto.parse_completion_request(body, 64, tenant="key-9",
                                       max_tokens_cap=8)
    assert r.tenant == "key-9" and r.max_tokens == 8


def test_render_prometheus_labels_and_filtering():
    text = proto.render_prometheus(
        {"r0": {"a": 1, "flag": True, "nested": {"x": 1}, "f": 2.5}},
        {"c": 3})
    assert text == ('repro_a{replica="r0"} 1\n'
                    'repro_f{replica="r0"} 2.5\n'
                    'repro_c 3\n')


# ---------------------------------------------------------------------------
# Live server over MockEngine replicas
# ---------------------------------------------------------------------------

def _server(**kw):
    reps = [EngineReplica("r0", MockEngine())]
    srv = CompletionServer(Router(reps), vocab_size=64, model_name="mock",
                           **kw).start()
    return srv, reps[0].engine


def _request(addr, body=None, method="POST", path="/v1/completions",
             headers=None, timeout=30.0):
    conn = http.client.HTTPConnection(*addr, timeout=timeout)
    conn.request(method, path, json.dumps(body) if body is not None else None,
                 {"Content-Type": "application/json", **(headers or {})})
    return conn, conn.getresponse()


def _read_sse(resp):
    events, done = [], False
    while True:
        line = resp.readline()
        if not line:
            break
        if line == b"\n":
            continue
        assert line.startswith(b"data: "), line
        payload = line[len(b"data: "):].rstrip(b"\n")
        if payload == b"[DONE]":
            done = True
            break
        events.append(json.loads(payload))
    return events, done


def test_http_streamed_completion_over_the_wire():
    srv, eng = _server()
    try:
        conn, resp = _request(srv.address, {
            "prompt": [3, 5], "max_tokens": 4, "stream": True})
        assert resp.status == 200
        assert resp.headers["Content-Type"] == "text/event-stream"
        events, done = _read_sse(resp)
        conn.close()
        assert done
        toks = [t for e in events for c in e["choices"]
                for t in c["token_ids"] if c["index"] == 0]
        assert toks == [(8 + k) % 64 for k in range(4)]
        finals = [c for e in events for c in e["choices"]
                  if c["finish_reason"]]
        assert [c["finish_reason"] for c in finals] == ["length"]
        assert all(e["id"].startswith("cmpl-") for e in events)
    finally:
        srv.close()


def test_http_nonstream_aggregates_with_usage():
    srv, eng = _server()
    try:
        conn, resp = _request(srv.address, {
            "prompt": [3, 5], "max_tokens": 4, "n": 2, "stream": False})
        assert resp.status == 200
        out = json.loads(resp.read())
        conn.close()
        assert out["object"] == "text_completion"
        assert len(out["choices"]) == 2
        assert out["choices"][0]["token_ids"] == [(8 + k) % 64
                                                  for k in range(4)]
        assert out["choices"][1]["token_ids"] == [(8 + 31 + k) % 64
                                                  for k in range(4)]
        assert all(c["finish_reason"] == "length" for c in out["choices"])
        assert out["usage"] == {"prompt_tokens": 2, "completion_tokens": 8,
                                "total_tokens": 10}
    finally:
        srv.close()


def test_http_429_when_queue_full():
    srv, eng = _server(max_queue=0)
    try:
        conn, resp = _request(srv.address, {"prompt": [3], "max_tokens": 2})
        assert resp.status == 429
        assert resp.headers["Retry-After"] == "1"
        err = json.loads(resp.read())
        conn.close()
        assert err["error"]["code"] == 429
        assert eng.n_steps == 0           # rejected before any engine work
    finally:
        srv.close()


def test_http_400_and_404():
    srv, _ = _server()
    try:
        conn = http.client.HTTPConnection(*srv.address, timeout=10)
        conn.request("POST", "/v1/completions", b"{not json",
                     {"Content-Type": "application/json"})
        assert conn.getresponse().status == 400
        conn.close()
        conn, resp = _request(srv.address, {"prompt": [1]},
                              path="/v1/nonesuch")
        assert resp.status == 404
        conn.close()
        conn, resp = _request(srv.address, {"max_tokens": 2})
        assert resp.status == 400
        body = json.loads(resp.read())
        conn.close()
        assert "prompt" in body["error"]["message"]
    finally:
        srv.close()


def test_http_health_models_metrics():
    srv, _ = _server()
    try:
        conn, resp = _request(srv.address, method="GET", path="/health")
        assert resp.status == 200
        h = json.loads(resp.read())
        conn.close()
        assert h["status"] == "ok" and h["replicas"]["r0"]["healthy"]

        conn, resp = _request(srv.address, method="GET", path="/v1/models")
        models = json.loads(resp.read())
        conn.close()
        assert models["data"][0]["id"] == "mock"

        conn, resp = _request(srv.address, method="GET", path="/metrics")
        assert resp.headers["Content-Type"].startswith("text/plain")
        text = resp.read().decode()
        conn.close()
        assert 'repro_kv_blocks_total{replica="r0"} 64' in text
        assert "repro_admission_admitted_total 0" in text
        assert "repro_http_disconnects_total 0" in text
    finally:
        srv.close()


def test_http_disconnect_mid_stream_aborts_and_reclaims():
    srv, eng = _server()
    try:
        conn, resp = _request(srv.address, {
            "prompt": [3], "max_tokens": 100_000, "stream": True})
        assert resp.status == 200
        first = resp.readline()           # one event, then walk away
        assert first.startswith(b"data: ")
        # the response's makefile holds the socket fd: close BOTH, or no
        # FIN/RST ever reaches the server and it can't see us leave
        resp.close()
        conn.close()
        deadline = time.monotonic() + 15.0
        while time.monotonic() < deadline:
            if (eng.n_aborts == 1
                    and eng.load()["kv_blocks_free"] == eng.kv_blocks):
                break
            time.sleep(0.01)
        assert eng.n_aborts == 1
        assert eng.load()["kv_blocks_free"] == eng.kv_blocks
        assert srv.n_disconnects == 1
    finally:
        srv.close()


def test_http_close_rejects_new_requests():
    srv, _ = _server()
    srv.admission.close()                 # draining: listener still up
    try:
        conn, resp = _request(srv.address, {"prompt": [1]}, timeout=10.0)
        assert resp.status == 503
        assert "draining" in json.loads(resp.read())["error"]["message"]
        conn.close()
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# Real-engine e2e (slow): parity, reclamation, fork-window abort
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single(), ModelOptions())
    return cfg, model, model.init(jax.random.key(0))


def _paged_engine(model, params, **kw):
    return SiPipeEngine(model, params, EngineConfig(
        pp_degree=2, max_batch=2, max_seq_len=64, n_samplers=2,
        kv_layout="paged", kv_block_size=8, **kw))


def _http_over(cfg, model, params, **kw):
    from repro.launch.serve import build_http_server
    _, srv = build_http_server(
        "stablelm-1.6b-smoke", pp=2, max_batch=2, max_seq_len=64,
        kv_layout="paged", block_size=8,
        prebuilt=(cfg, model, params), **kw)
    return srv.start()


@pytest.mark.slow
def test_http_greedy_tokens_bit_identical_to_direct_generate(
        model_and_params):
    """The transport adds nothing: greedy tokens streamed over HTTP are
    the same bytes a direct engine.generate() call produces."""
    cfg, model, params = model_and_params
    prompts = [[5, 9, 13, 17, 21], [7, 11, 2]]
    sp = SamplingParams(greedy=True, max_new_tokens=8)
    eng = _paged_engine(model, params)
    direct = {}
    for out in eng.generate(prompts, sp):
        if out.finished:
            direct[out.request_id] = out.token_ids.to_list()
    eng.shutdown()
    ref = [direct[k] for k in sorted(direct)]

    srv = _http_over(cfg, model, params)
    try:
        got = []
        for p in prompts:
            conn, resp = _request(srv.address, {
                "prompt": p, "max_tokens": 8, "temperature": 0.0,
                "stream": True}, timeout=120.0)
            assert resp.status == 200
            events, done = _read_sse(resp)
            conn.close()
            assert done
            got.append([t for e in events for c in e["choices"]
                        for t in c["token_ids"]])
        assert got == ref
    finally:
        srv.close()


@pytest.mark.slow
def test_http_disconnect_reclaims_real_engine_blocks(model_and_params):
    cfg, model, params = model_and_params
    srv = _http_over(cfg, model, params)
    eng = srv.router.replicas[0].engine
    try:
        conn, resp = _request(srv.address, {
            "prompt": [5, 9, 13], "max_tokens": 50, "temperature": 0.0,
            "stream": True}, timeout=120.0)
        assert resp.status == 200
        assert resp.readline().startswith(b"data: ")
        resp.close()                      # mid-stream disconnect (both
        conn.close()                      # handles share the socket fd)
        deadline = time.monotonic() + 60.0
        while time.monotonic() < deadline:
            snap = eng.load()
            if (snap["active_requests"] == 0
                    and snap["kv_blocks_free"] == snap["kv_blocks_total"]):
                break
            time.sleep(0.05)
        snap = eng.load()
        assert snap["active_requests"] == 0
        assert snap["kv_blocks_free"] == snap["kv_blocks_total"]
    finally:
        srv.close()


@pytest.mark.slow
def test_abort_inside_fork_spawn_window_leaks_nothing(model_and_params):
    """Satellite regression: abort landing BETWEEN the scheduler spawning
    fork children (first token) and the engine attaching them to the
    Request must still reclaim every block — the children live only in
    scheduler state in that window."""
    cfg, model, params = model_and_params
    eng = _paged_engine(model, params)
    hold = {"on": True}
    real_attach = eng._attach_forks
    eng._attach_forks = lambda: None if hold["on"] else real_attach()
    rid = eng.add_request([5, 9, 13, 17],
                          SamplingParams(greedy=True, max_new_tokens=12, n=3))
    for _ in range(10_000):
        eng.step()
        if eng.scheduler.fork_children_of(rid):
            break
    assert eng.scheduler.fork_children_of(rid), "forks never spawned"
    assert eng.requests[rid].forks == []      # the attach window is open
    assert eng.abort(rid)
    hold["on"] = False                        # attach path back to normal
    for _ in range(10_000):
        if not eng.has_work:
            break
        eng.step()
    eng.shutdown()
    m = eng.metrics()
    assert not eng.has_work
    assert m["kv_blocks_free"] == m["kv_blocks_total"]
