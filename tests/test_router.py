"""Engine fleet: EngineReplica loop threading + Router least-loaded-KV
placement (docs/http.md §Router).  Runs on the deterministic MockEngine
— no JAX compile — so the full submit/stream/abort/drain surface is
exercised in milliseconds."""
import threading
import time

import pytest

from repro.core.sampling_params import SamplingParams
from repro.serving.mock import MockEngine
from repro.serving.router import EngineReplica, ReplicaUnavailable, Router


def _params(n_new=4, n=1, priority=0):
    return SamplingParams(greedy=True, max_new_tokens=n_new, n=n,
                          priority=priority)


def _drain_stream(out_q, timeout=10.0):
    outs = []
    while True:
        out = out_q.get(timeout=timeout)
        if isinstance(out, BaseException):
            raise out
        outs.append(out)
        if out.finished:
            return outs


# ---------------------------------------------------------------------------
# Router.pick ranking (stub replicas: pure placement logic)
# ---------------------------------------------------------------------------

class _Stub:
    def __init__(self, name, free, depth=0, active=0, healthy=True):
        self.name = name
        self._snap = {"kv_blocks_free": free, "queue_depth": depth,
                      "active_requests": active, "kv_blocks_total": 64}
        self.healthy = healthy

    def load(self):
        return dict(self._snap)


def test_pick_prefers_most_free_blocks():
    r = Router([_Stub("a", free=10), _Stub("b", free=30), _Stub("c", free=20)])
    assert r.pick().name == "b"


def test_pick_ties_fall_to_load_then_order():
    r = Router([_Stub("a", free=10, depth=3), _Stub("b", free=10, depth=1),
                _Stub("c", free=10, depth=1)])
    assert r.pick().name == "b"           # least load; order breaks b vs c


def test_pick_skips_unhealthy_and_raises_when_none():
    r = Router([_Stub("a", free=50, healthy=False), _Stub("b", free=1)])
    assert r.pick().name == "b"
    r2 = Router([_Stub("a", free=50, healthy=False)])
    with pytest.raises(ReplicaUnavailable):
        r2.pick()


def test_router_requires_replicas():
    with pytest.raises(ValueError):
        Router([])


# ---------------------------------------------------------------------------
# EngineReplica loop: submit / stream / abort / drain
# ---------------------------------------------------------------------------

def test_replica_streams_deterministic_tokens():
    rep = EngineReplica("r0", MockEngine()).start()
    try:
        rid, out_q = rep.submit([3, 5], _params(n_new=4))
        outs = _drain_stream(out_q)
        assert outs[-1].finished and outs[-1].finish_reason == "length"
        got = [t for o in outs for t in o.new_token_ids]
        assert got == [(8 + k) % 64 for k in range(4)]
        assert outs[-1].metrics is not None
    finally:
        assert rep.drain()
    assert not rep.healthy


def test_replica_abort_mid_stream_reclaims():
    eng = MockEngine()
    rep = EngineReplica("r0", eng).start()
    try:
        rid, out_q = rep.submit([2], _params(n_new=10_000))
        first = out_q.get(timeout=10.0)
        assert not first.finished
        rep.abort(rid)
        outs = _drain_stream(out_q)
        assert outs[-1].finish_reason == "abort"
        assert eng.n_aborts == 1
        # all KV back: nothing live on the engine after the abort lands
        assert eng.load()["kv_blocks_free"] == eng.kv_blocks
    finally:
        rep.drain()


def test_replica_fork_streams_ride_along():
    rep = EngineReplica("r0", MockEngine()).start()
    try:
        rid, out_q = rep.submit([4], _params(n_new=3, n=2))
        outs = _drain_stream(out_q)
        assert outs[-1].forks and outs[-1].forks[0].finished
        fork_toks = [t for o in outs for t in o.forks[0].new_token_ids]
        assert fork_toks == [(4 + 31 + k) % 64 for k in range(3)]
    finally:
        rep.drain()


def test_replica_crash_marks_unhealthy_and_fails_streams():
    class Exploding(MockEngine):
        def step(self):
            raise RuntimeError("boom")

    rep = EngineReplica("r0", Exploding()).start()
    rid, out_q = rep.submit([1], _params())
    with pytest.raises(RuntimeError, match="boom"):
        _drain_stream(out_q)
    rep._thread.join(5.0)
    assert not rep.healthy and rep.error is not None
    with pytest.raises(ReplicaUnavailable):
        rep.submit([1], _params())


def test_drain_finishes_inflight_work():
    rep = EngineReplica("r0", MockEngine()).start()
    rid, out_q = rep.submit([6], _params(n_new=8))
    assert rep.drain()
    outs = _drain_stream(out_q, timeout=1.0)
    assert outs[-1].finished and len(outs[-1].token_ids) == 8


# ---------------------------------------------------------------------------
# Router over live replicas: spread + counters
# ---------------------------------------------------------------------------

class _Gated(MockEngine):
    """MockEngine that holds decode until released, so KV occupancy is
    frozen while the routing decisions under test are being made."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.gate = threading.Event()

    def step(self):
        if not self.gate.is_set():
            time.sleep(0.001)
            return []
        return super().step()


def test_router_spreads_by_free_blocks():
    reps = [EngineReplica(f"r{i}", _Gated(start_id=100 * i))
            for i in range(2)]
    router = Router(reps).start()
    try:
        qs = []
        for _ in range(4):
            _, rid, out_q = router.submit([8] * 8, _params(n_new=8))
            qs.append((rid, out_q))
        assert router.routed == {"r0": 2, "r1": 2}
        for rep in reps:
            assert rep.engine.load()["active_requests"] == 2
        for rep in reps:
            rep.engine.gate.set()
        for rid, out_q in qs:
            _drain_stream(out_q)
    finally:
        router.shutdown(drain=True)


def test_router_health_and_metrics_views():
    reps = [EngineReplica("r0", MockEngine())]
    router = Router(reps).start()
    try:
        h = router.health()
        assert h["r0"]["healthy"] and "kv_blocks_free" in h["r0"]
        m = router.metrics()
        assert m["r0"]["requests_finished"] == 0
    finally:
        router.shutdown(drain=True)
    assert not router.health()["r0"]["healthy"]
