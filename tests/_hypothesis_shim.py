"""Minimal vendored fallback for the ``hypothesis`` API surface this
test suite uses, loaded by conftest.py only when the real package is
absent.  It is NOT a property-based testing engine: it draws a fixed
number of deterministic pseudo-random examples per test (seeded from the
test's qualified name), which keeps the suite green and still exercises
the properties across a spread of inputs.

Supported surface:
  given(**kwargs)                        keyword-style strategies only
  settings(max_examples=, deadline=, ...)
  strategies.integers(min, max)
  strategies.floats(min, max)
  strategies.booleans()
  strategies.sampled_from(seq)
  strategies.lists(elem, min_size=, max_size=)
  strategies.tuples(*elems)
  strategies.just(v) / strategies.none() / strategies.one_of(*strats)

On a failing example the draw is attached to the exception message so
the failure is reproducible (seeds are stable across runs).
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 20


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd: random.Random):
        return self._draw(rnd)

    def map(self, fn):
        return SearchStrategy(lambda rnd: fn(self._draw(rnd)))

    def filter(self, pred, _tries: int = 100):
        def draw(rnd):
            for _ in range(_tries):
                v = self._draw(rnd)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")

        return SearchStrategy(draw)


def integers(min_value=-(2 ** 31), max_value=2 ** 31 - 1) -> SearchStrategy:
    lo, hi = int(min_value), int(max_value)
    # bias toward the boundaries: they are where invariants break
    def draw(rnd):
        r = rnd.random()
        if r < 0.10:
            return lo
        if r < 0.20:
            return hi
        return rnd.randint(lo, hi)

    return SearchStrategy(draw)


def floats(min_value=0.0, max_value=1.0, **_) -> SearchStrategy:
    lo, hi = float(min_value), float(max_value)

    def draw(rnd):
        r = rnd.random()
        if r < 0.10:
            return lo
        if r < 0.20:
            return hi
        return lo + (hi - lo) * rnd.random()

    return SearchStrategy(draw)


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.random() < 0.5)


def sampled_from(elements) -> SearchStrategy:
    elems = list(elements)
    if not elems:
        raise ValueError("sampled_from requires a non-empty sequence")
    return SearchStrategy(lambda rnd: elems[rnd.randrange(len(elems))])


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 10, **_) -> SearchStrategy:
    def draw(rnd):
        n = rnd.randint(min_size, max_size)
        return [elements.example(rnd) for _ in range(n)]

    return SearchStrategy(draw)


def tuples(*elems: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rnd: tuple(e.example(rnd) for e in elems))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rnd: value)


def none() -> SearchStrategy:
    return SearchStrategy(lambda rnd: None)


def one_of(*strategies) -> SearchStrategy:
    opts = list(strategies)
    return SearchStrategy(lambda rnd: opts[rnd.randrange(len(opts))].example(rnd))


def _stable_seed(fn) -> int:
    name = f"{getattr(fn, '__module__', '')}.{getattr(fn, '__qualname__', fn)}"
    return zlib.crc32(name.encode())


def given(*gargs, **gkwargs):
    if gargs:
        raise TypeError("shim supports keyword-style given(...) only")

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", DEFAULT_MAX_EXAMPLES)
            rnd = random.Random(_stable_seed(fn))
            for i in range(n):
                drawn = {k: s.example(rnd) for k, s in gkwargs.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except _Unsatisfied:
                    continue  # assume() rejected this example
                except Exception as e:  # attach the falsifying example
                    e.args = (f"falsifying example #{i}: {drawn!r} -> "
                              f"{e.args[0] if e.args else e!r}",) + e.args[1:]
                    raise

        # hide the drawn parameters from pytest's fixture resolution:
        # expose only the non-strategy parameters (fixtures) in the
        # signature, and drop __wrapped__ so introspection stops here
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in gkwargs])
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return decorate


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def decorate(fn):
        fn._shim_max_examples = max_examples
        return fn

    return decorate


class HealthCheck:  # referenced by some suites via settings(suppress_health_check=…)
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    data_too_large = "data_too_large"


def assume(condition) -> bool:
    """True-path passthrough; failing assumptions just skip the example."""
    if not condition:
        raise _Unsatisfied()
    return True


class _Unsatisfied(Exception):
    pass


# Module object that mirrors ``hypothesis.strategies`` for
# ``from hypothesis import strategies as st`` / ``import hypothesis.strategies``.
strategies = types.ModuleType("hypothesis.strategies")
for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
              "tuples", "just", "none", "one_of", "SearchStrategy"):
    setattr(strategies, _name, globals()[_name])


def install() -> None:
    """Register this shim as ``hypothesis`` in sys.modules."""
    mod = sys.modules.get("hypothesis")
    if mod is not None and getattr(mod, "__shim__", False):
        return
    shim = types.ModuleType("hypothesis")
    shim.__shim__ = True
    shim.given = given
    shim.settings = settings
    shim.assume = assume
    shim.HealthCheck = HealthCheck
    shim.strategies = strategies
    sys.modules["hypothesis"] = shim
    sys.modules["hypothesis.strategies"] = strategies
