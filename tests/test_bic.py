"""Buffered IPC channels (§6): ordering, lock-ahead, multi-producer combine."""
import os
import threading
import time

import numpy as np
import pytest

from repro.core.bic import LocalRing, ShmRing, SubSlotRing


def test_local_ring_order():
    r = LocalRing(4)
    for i in range(10):
        r.put({"i": i})
    # ring of 4: only the last 4 slots retrievable
    for i in range(6, 10):
        assert r.get(i)["i"] == i


def test_local_ring_blocks_until_produced():
    r = LocalRing(4)
    out = {}

    def consumer():
        out["v"] = r.get(0, timeout=5)

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.05)
    r.put("hello")
    t.join(timeout=5)
    assert out["v"] == "hello"


def test_local_ring_overwrite_detection():
    r = LocalRing(2)
    for i in range(5):
        r.put(i)
    with pytest.raises((RuntimeError, TimeoutError)):
        r.get(0, timeout=0.1)


def test_shm_ring_same_process_roundtrip(tmp_path):
    ring = ShmRing(slot_bytes=1 << 16, n_slots=4, path=str(tmp_path / "bic"))
    payload = {"logits": np.arange(100, dtype=np.float32)}
    for i in range(6):
        ring.put({"seq": i, **payload})
    got = ring.get(5)
    assert got["seq"] == 5
    np.testing.assert_array_equal(got["logits"], payload["logits"])
    ring.close(unlink=True)


def test_shm_ring_cross_process(tmp_path):
    """Producer in a forked child, consumer in the parent (BIC-I pattern)."""
    path = str(tmp_path / "bic2")
    ring = ShmRing(slot_bytes=1 << 12, n_slots=4, path=path)
    pid = os.fork()
    if pid == 0:  # child = producer
        try:
            child = ShmRing(slot_bytes=1 << 12, n_slots=4, path=path,
                            create=False)
            for i in range(3):
                child.put({"i": i, "msg": f"m{i}"})
            child.close()
        finally:
            os._exit(0)
    try:
        for i in range(3):
            got = ring.get(i, timeout=10)
            assert got == {"i": i, "msg": f"m{i}"}
    finally:
        os.waitpid(pid, 0)
        ring.close(unlink=True)


def test_subslot_ring_combine():
    r = SubSlotRing(n_producers=3, n_slots=4)
    results = {}

    def consumer():
        results["v"] = r.get(0, timeout=5)

    t = threading.Thread(target=consumer)
    t.start()
    for j in (2, 0, 1):
        time.sleep(0.01)
        r.put(0, j, f"tok{j}")
    t.join(5)
    assert results["v"] == ["tok0", "tok1", "tok2"]


def test_subslot_ring_incomplete_times_out():
    r = SubSlotRing(n_producers=2, n_slots=2)
    r.put(0, 0, "only-one")
    with pytest.raises(TimeoutError):
        r.get(0, timeout=0.1)
