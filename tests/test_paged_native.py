"""Paged-native execution path: the per-tile block-table natives must be
BIT-exact to the gather-then-attend oracles (same values, not just close),
and the engine's dirty-block write-back must touch exactly the physical
blocks a span's slots map to — everything else in the pool, including
garbage-filled free blocks, stays bit-identical."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import EngineConfig, NaivePPEngine
from repro.core.sampling_params import SamplingParams
from repro.models import ModelOptions, ShardCtx, build_model
from repro.models import attention as A


def _rand(rng, shape, dtype=jnp.bfloat16):
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


def _packed_batch(rng, b, s, t):
    seq = np.sort(rng.integers(0, b, t)).astype(np.int32)
    pos = rng.integers(0, s, t).astype(np.int32)
    return jnp.asarray(pos), jnp.asarray(seq)


def _paged_layout(rng, b, s, bs, n_extra=3):
    """Shuffled physical placement + n_extra unused garbage blocks."""
    nb = -(-s // bs)
    n_phys = b * nb + n_extra
    perm = rng.permutation(n_phys)[:b * nb].reshape(b, nb).astype(np.int32)
    return perm, n_phys, nb


def _scatter_blocks(contig, tables, bs, n_phys, rng):
    """Physical [n_phys, bs, ...] cache whose gather under ``tables``
    reproduces ``contig`` [B, S, ...]; unused blocks hold garbage."""
    b, s = contig.shape[:2]
    nb = tables.shape[1]
    pad = nb * bs - s
    if pad:
        widths = [(0, 0), (0, pad)] + [(0, 0)] * (contig.ndim - 2)
        contig = np.pad(np.asarray(contig, np.float32), widths)
    phys = rng.normal(size=(n_phys, bs) + contig.shape[2:]).astype(np.float32)
    blocks = np.asarray(contig, np.float32).reshape(b, nb, bs,
                                                    *contig.shape[2:])
    for i in range(b):
        for j in range(nb):
            phys[tables[i, j]] = blocks[i, j]
    return phys


def _bits(x):
    """Raw-bit view for exact equality across float dtypes."""
    a = np.asarray(jax.device_get(x))
    return a.view(np.uint8) if a.dtype == np.dtype("bfloat16") else a


# ---------------------------------------------------------------------------
# Natives vs. gather-then-attend oracles: bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [0, 32])
def test_paged_native_bitexact_to_oracle(window):
    b, s, h, kv, hd, t, bs = 3, 64, 4, 2, 32, 10, 16
    rng = np.random.default_rng(21)
    kc = np.asarray(_rand(rng, (b, s, kv, hd), jnp.float32))
    vc = np.asarray(_rand(rng, (b, s, kv, hd), jnp.float32))
    q = _rand(rng, (t, h, hd))
    pos, seq = _packed_batch(rng, b, s, t)
    tables, n_phys, nb = _paged_layout(rng, b, s, bs)
    kp = jnp.asarray(_scatter_blocks(kc, tables, bs, n_phys, rng),
                     jnp.bfloat16)
    vp = jnp.asarray(_scatter_blocks(vc, tables, bs, n_phys, rng),
                     jnp.bfloat16)
    tb = jnp.asarray(tables)
    o = A.paged_span_attention_native(q, kp, vp, tb, pos, seq,
                                      window=window, kv_block=bs)
    o_ref = A.paged_span_attention(q, kp, vp, tb, pos, seq,
                                   window=window, kv_block=bs)
    np.testing.assert_array_equal(_bits(o), _bits(o_ref))


def test_paged_quant_native_bitexact_to_oracle():
    b, s, h, kv, hd, t, bs = 2, 64, 4, 2, 32, 8, 16
    rng = np.random.default_rng(22)
    kc = _rand(rng, (b, s, kv, hd), jnp.float32)
    vc = _rand(rng, (b, s, kv, hd), jnp.float32)
    k8c, ksc = A.quantize_kv(kc)
    v8c, vsc = A.quantize_kv(vc)
    q = _rand(rng, (t, h, hd))
    pos, seq = _packed_batch(rng, b, s, t)
    tables, n_phys, nb = _paged_layout(rng, b, s, bs)
    tb = jnp.asarray(tables)
    k8 = jnp.asarray(_scatter_blocks(np.asarray(k8c, np.float32), tables,
                                     bs, n_phys, rng), jnp.int8)
    v8 = jnp.asarray(_scatter_blocks(np.asarray(v8c, np.float32), tables,
                                     bs, n_phys, rng), jnp.int8)
    ks = jnp.asarray(_scatter_blocks(np.asarray(ksc, np.float32), tables,
                                     bs, n_phys, rng), jnp.bfloat16)
    vs = jnp.asarray(_scatter_blocks(np.asarray(vsc, np.float32), tables,
                                     bs, n_phys, rng), jnp.bfloat16)
    o = A.paged_span_attention_quant_native(q, k8, ks, v8, vs, tb, pos, seq,
                                            kv_block=bs)
    o_ref = A.paged_span_attention_quant(q, k8, ks, v8, vs, tb, pos, seq,
                                         kv_block=bs)
    np.testing.assert_array_equal(_bits(o), _bits(o_ref))


def test_paged_rolling_native_bitexact_to_oracle():
    b, w, kv, g, hd, t, bs = 2, 32, 2, 2, 32, 6, 8
    h = kv * g
    rng = np.random.default_rng(23)
    kroll = np.asarray(_rand(rng, (b, w, kv, hd), jnp.float32))
    vroll = np.asarray(_rand(rng, (b, w, kv, hd), jnp.float32))
    q = _rand(rng, (t, h, hd))
    ksp = _rand(rng, (t, kv, hd))
    vsp = _rand(rng, (t, kv, hd))
    offs = jnp.asarray([40, 40, 40, 7, 7, 7], jnp.int32)  # row0 wrapped
    pos = jnp.asarray([40, 41, 42, 7, 8, 9], jnp.int32)
    seq = jnp.asarray([0, 0, 0, 1, 1, 1], jnp.int32)
    tables, n_phys, nb = _paged_layout(rng, b, w, bs)
    tb = jnp.asarray(tables)
    kp = jnp.asarray(_scatter_blocks(kroll, tables, bs, n_phys, rng),
                     jnp.bfloat16)
    vp = jnp.asarray(_scatter_blocks(vroll, tables, bs, n_phys, rng),
                     jnp.bfloat16)
    o = A.paged_span_attention_rolling_native(
        q, kp, vp, ksp, vsp, tb, pos, seq, offs, t, window=w, kv_block=bs)
    o_ref = A.paged_span_attention_rolling(
        q, kp, vp, ksp, vsp, tb, pos, seq, offs, t, window=w, kv_block=bs)
    np.testing.assert_array_equal(_bits(o), _bits(o_ref))


def test_paged_rolling_quant_native_bitexact_to_oracle():
    b, w, kv, g, hd, t, bs = 2, 16, 1, 2, 16, 4, 8
    h = kv * g
    rng = np.random.default_rng(24)
    kroll = _rand(rng, (b, w, kv, hd), jnp.float32)
    vroll = _rand(rng, (b, w, kv, hd), jnp.float32)
    k8c, ksc = A.quantize_kv(kroll)
    v8c, vsc = A.quantize_kv(vroll)
    q = _rand(rng, (t, h, hd))
    ksp = _rand(rng, (t, kv, hd))
    vsp = _rand(rng, (t, kv, hd))
    offs = jnp.asarray([20, 20, 5, 5], jnp.int32)
    pos = jnp.asarray([20, 21, 5, 6], jnp.int32)
    seq = jnp.asarray([0, 0, 1, 1], jnp.int32)
    tables, n_phys, nb = _paged_layout(rng, b, w, bs)
    tb = jnp.asarray(tables)
    k8 = jnp.asarray(_scatter_blocks(np.asarray(k8c, np.float32), tables,
                                     bs, n_phys, rng), jnp.int8)
    v8 = jnp.asarray(_scatter_blocks(np.asarray(v8c, np.float32), tables,
                                     bs, n_phys, rng), jnp.int8)
    ks = jnp.asarray(_scatter_blocks(np.asarray(ksc, np.float32), tables,
                                     bs, n_phys, rng), jnp.bfloat16)
    vs = jnp.asarray(_scatter_blocks(np.asarray(vsc, np.float32), tables,
                                     bs, n_phys, rng), jnp.bfloat16)
    o = A.paged_span_attention_rolling_quant_native(
        q, k8, ks, v8, vs, ksp, vsp, tb, pos, seq, offs, t,
        window=w, kv_block=bs)
    o_ref = A.paged_span_attention_rolling_quant(
        q, k8, ks, v8, vs, ksp, vsp, tb, pos, seq, offs, t,
        window=w, kv_block=bs)
    np.testing.assert_array_equal(_bits(o), _bits(o_ref))


# ---------------------------------------------------------------------------
# Engine: the dirty-block write-back scatter set
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single())
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _snapshot(worker):
    return [np.asarray(jax.device_get(c))
            for c in jax.tree.leaves(worker.cache)]


def _changed_blocks(before, after):
    """Physical block indices whose content differs in any cache leaf."""
    changed = set()
    for old, new in zip(before, after):
        # leaf [groups, n_blocks + 1, bs, ...]
        diff = (old != new).reshape(old.shape[0], old.shape[1], -1).any((0, 2))
        changed.update(np.flatnonzero(diff).tolist())
    return changed


def _expected_blocks(sched, bs):
    """Blocks a scheduled iteration's slots map to under its own table
    snapshot (no window in the smoke arch: slot == position)."""
    tables = np.asarray(sched.block_tables)
    out = set()
    if sched.packed_width > 1:
        tok, pos, seq, _last = sched.packed_layout()
        for p, s in zip(pos, seq):
            out.add(int(tables[s, min(p // bs, tables.shape[1] - 1)]))
    else:
        for i, p in enumerate(np.asarray(sched.positions)):
            out.add(int(tables[i, min(p // bs, tables.shape[1] - 1)]))
    return out


def test_chunk_scatter_set_equals_touched_blocks(model_and_params):
    """Property: after each iteration, the set of physical blocks that
    changed is exactly the set the iteration's span slots map to (plus,
    possibly, the trash block that absorbs pad-entry writes).  Runs a
    mixed chunked-prefill + decode workload so chunk-carrying and pure
    decode iterations both get checked."""
    cfg, model, params = model_and_params
    rng = np.random.default_rng(31)
    prompts = [list(rng.integers(2, cfg.vocab_size, size=n))
               for n in (21, 13, 5)]
    eng = NaivePPEngine(model, params, EngineConfig(
        pp_degree=1, max_batch=2, max_seq_len=64, kv_layout="paged",
        kv_block_size=8, prefill_chunk_tokens=8))
    bs = eng.cfg.kv_block_size
    trash = eng.kv_manager.pad_block
    for p in prompts:
        eng.add_request(p, SamplingParams(greedy=True, max_new_tokens=4))

    scheds = []
    orig = eng.scheduler.schedule

    def record(it):
        out = orig(it)
        if out is not None:
            scheds.append(out)
        return out

    eng.scheduler.schedule = record
    worker = eng.stages[0]
    checked = mixed = 0
    while eng.has_work:
        before = _snapshot(worker)
        n0 = len(scheds)
        eng.step()
        after = _snapshot(worker)
        changed = _changed_blocks(before, after)
        expected = set()
        for sched in scheds[n0:]:
            expected |= _expected_blocks(sched, bs)
        assert changed - {trash} == expected - {trash}, \
            (changed, expected, trash)
        if scheds[n0:]:
            checked += 1
            mixed += any(s.packed_width > 1 and len(s.seq_ids) > 1
                         for s in scheds[n0:])
    eng.shutdown()
    assert checked >= 4          # the property actually ran
    assert mixed >= 1            # incl. a mixed chunk + decode iteration


def test_untouched_blocks_survive_garbage_poking(model_and_params):
    """E2E pin: free physical blocks are never READ either — poisoning
    every free block before each step leaves the greedy token stream
    identical to the contiguous layout's."""
    cfg, model, params = model_and_params
    rng = np.random.default_rng(32)
    prompts = [list(rng.integers(2, cfg.vocab_size, size=n))
               for n in (17, 9)]
    n_new = 5

    def run(layout, poison):
        eng = NaivePPEngine(model, params, EngineConfig(
            pp_degree=1, max_batch=2, max_seq_len=64, kv_layout=layout,
            kv_block_size=8, prefill_chunk_tokens=8))
        for p in prompts:
            eng.add_request(p, SamplingParams(greedy=True,
                                              max_new_tokens=n_new))
        worker = eng.stages[0]
        done = {}
        while eng.has_work:
            if poison:
                free = jnp.asarray(list(eng.kv_manager.alloc._free),
                                   jnp.int32)
                if free.size:
                    worker.cache = jax.tree.map(
                        lambda c: c.at[:, free].set(
                            127 if c.dtype == jnp.int8 else 1e3),
                        worker.cache)
            for out in eng.step():
                if out.finished:
                    done[out.seq.seq_id] = tuple(out.seq.output_ids)
        eng.shutdown()
        return sorted(done.items())

    assert run("paged", poison=True) == run("contiguous", poison=False)
