"""Continuous-serving request API: step()/generate() vs the offline run()
wrapper, per-request sampling params in mixed batches, abort semantics
(KV-row + sampler-column reclamation), mid-run admission with monotonic
ids, and the request-lifecycle property over random arrival/abort
schedules across every scheduling policy (docs/serving.md)."""
import itertools

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.engine import EngineConfig, NaivePPEngine, SiPipeEngine
from repro.core.request import RequestState
from repro.core.sampling_params import SamplingParams
from repro.core.scheduler import Scheduler
from repro.core.sequence import SeqStatus, Sequence, SequenceCache
from repro.models import ShardCtx, build_model


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single())
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _engine(model, params, *, pp=2, max_batch=2, policy="auto", chunk=None,
            n_samplers=2, max_seq_len=64):
    return SiPipeEngine(model, params, EngineConfig(
        pp_degree=pp, max_batch=max_batch, max_seq_len=max_seq_len,
        n_samplers=n_samplers, prefill_chunk_tokens=chunk,
        scheduling_policy=policy))


def _drain_steps(eng, max_steps=5000):
    """Drive step() until idle; returns all RequestOutputs in order."""
    outs = []
    for _ in range(max_steps):
        outs.extend(eng.step())
        if not eng.has_work:
            break
    return outs


# ---------------------------------------------------------------------------
# run() == generate()-drained parity (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,chunk", [
    ("monolithic", None),
    ("chunked", 6),
    ("disaggregated", 6),
    ("adaptive", 6),
])
def test_run_equals_generate_streamed(model_and_params, policy, chunk):
    """The offline run() wrapper and the streaming generate() iterator
    must produce token-identical greedy output on every policy, and the
    stream must be a monotonic prefix chain (each increment extends the
    previous cumulative output)."""
    cfg, model, params = model_and_params
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
               for n in (13, 5)]
    sp = SamplingParams(greedy=True, max_new_tokens=4)

    eng_a = _engine(model, params, policy=policy, chunk=chunk)
    for p in prompts:
        eng_a.add_request(p, sp)
    offline = {s.seq_id: s.output_ids for s in eng_a.run()}

    eng_b = _engine(model, params, policy=policy, chunk=chunk)
    streamed = {}
    finished = set()
    for out in eng_b.generate(prompts, sp):
        prev = streamed.setdefault(out.request_id, [])
        assert out.token_ids == prev + out.new_token_ids   # prefix chain
        assert out.request_id not in finished              # nothing after final
        streamed[out.request_id] = out.token_ids
        if out.finished:
            finished.add(out.request_id)
            assert out.state == RequestState.FINISHED
            assert out.metrics is not None
            assert out.metrics.ttft_s is not None and out.metrics.ttft_s >= 0
    eng_b.shutdown()
    assert finished == set(streamed)
    assert streamed == offline


# ---------------------------------------------------------------------------
# Per-request sampling params in mixed batches (satellite regression)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Eng", [SiPipeEngine, NaivePPEngine],
                         ids=["columnwise-pool", "naive-sampler"])
def test_per_request_params_honored_in_mixed_batches(model_and_params, Eng):
    """Two requests with different penalty params decoding in ONE batch
    must each sample with their own params.  Pre-redesign, the engine's
    batch-level `_params_for` applied seq_ids[0]'s params to every
    column, so request 1's frequency penalty was silently dropped and it
    decoded as if it were plain greedy — both sampler pools must honor
    the per-column contract now."""
    cfg, model, params = model_and_params
    rng = np.random.default_rng(7)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
               for n in (6, 9)]
    plain = SamplingParams(greedy=True, max_new_tokens=6)
    # a strong frequency penalty forces a distinct greedy trajectory
    penal = SamplingParams(greedy=True, max_new_tokens=6,
                           frequency_penalty=1000.0, presence_penalty=5.0)

    def solo(prompt, sp):
        eng = Eng(model, params, EngineConfig(
            pp_degree=1, max_batch=1, max_seq_len=64, n_samplers=1))
        eng.add_request(prompt, sp)
        (done,) = eng.run()
        return done.output_ids

    want0, want1 = solo(prompts[0], plain), solo(prompts[1], penal)
    assert want0[1:] != want1[1:] or prompts[0] != prompts[1]

    # request 0 (plain) is seq_ids[0]: the pre-fix engine would have
    # applied ITS params batch-wide, turning request 1 into plain greedy
    eng = Eng(model, params, EngineConfig(
        pp_degree=1, max_batch=2, max_seq_len=64, n_samplers=2))
    eng.add_request(prompts[0], plain)
    eng.add_request(prompts[1], penal)
    done = sorted(eng.run(), key=lambda s: s.seq_id)
    assert done[0].output_ids == want0, \
        "plain-greedy request perturbed by batchmate's params"
    assert done[1].output_ids == want1, (
        "request 1's own penalties were not applied inside the mixed "
        "batch — the pre-fix engine sampled every column with "
        "seq_ids[0]'s SamplingParams")


# ---------------------------------------------------------------------------
# Abort semantics
# ---------------------------------------------------------------------------

def test_abort_mid_decode_frees_rows_and_preserves_survivors(model_and_params):
    """abort() mid-decode: the aborted request stops with partial output,
    its KV row and sampler penalty columns are reclaimed, and the
    surviving request's tokens are bit-identical to a solo run."""
    cfg, model, params = model_and_params
    rng = np.random.default_rng(3)
    pa = list(map(int, rng.integers(2, cfg.vocab_size, size=5)))
    pb = list(map(int, rng.integers(2, cfg.vocab_size, size=7)))
    sp = SamplingParams(greedy=True, max_new_tokens=10)

    solo = _engine(model, params, pp=1, max_batch=1, n_samplers=1)
    solo.add_request(pa, sp)
    (want_a,) = solo.run()

    eng = _engine(model, params, pp=1, max_batch=2, n_samplers=2)
    rid_a = eng.add_request(pa, sp)
    rid_b = eng.add_request(pb, sp)
    outs, aborted_at = [], None
    for _ in range(5000):
        for out in eng.step():
            outs.append(out)
            if out.request_id == rid_b and out.token_ids and aborted_at is None:
                aborted_at = len(out.token_ids)
                assert eng.abort(rid_b)
        if not eng.has_work:
            break
    eng.shutdown()

    final = {o.request_id: o for o in outs if o.finished}
    assert final[rid_a].token_ids == want_a.output_ids   # survivor untouched
    b = final[rid_b]
    assert b.state == RequestState.ABORTED
    assert b.finish_reason == "abort"
    assert aborted_at <= len(b.token_ids) < 10           # stopped early
    # resource reclamation: KV rows, sampler columns, scheduler records
    assert eng.seq_cache.free_rows == eng.seq_cache.max_rows
    for smp in eng.samplers:
        assert not smp.tracked_seq_ids()
    assert not eng.scheduler.seqs and not eng.requests
    m = eng.metrics()
    assert m["requests_aborted"] == 1 and m["requests_finished"] == 1


def test_abort_queued_and_unknown(model_and_params):
    """Aborting a QUEUED request drops it before it ever runs; unknown /
    already-finished ids return False."""
    cfg, model, params = model_and_params
    rng = np.random.default_rng(4)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=4)))
               for _ in range(2)]
    sp = SamplingParams(greedy=True, max_new_tokens=2)
    eng = _engine(model, params, pp=1, max_batch=1, n_samplers=1)
    rid0 = eng.add_request(prompts[0], sp)
    rid1 = eng.add_request(prompts[1], sp)   # queued behind rid0 (1 seat)
    assert not eng.abort(999)                # unknown id
    assert eng.abort(rid1)                   # still WAITING
    assert not eng.abort(rid1)               # idempotent: already aborted
    outs = _drain_steps(eng)
    final = {o.request_id: o for o in outs if o.finished}
    assert final[rid1].state == RequestState.ABORTED
    assert final[rid1].token_ids == []
    assert len(final[rid0].token_ids) == 2
    assert not eng.abort(rid0)               # finished: no-op
    assert eng.seq_cache.free_rows == eng.seq_cache.max_rows
    # abort straight out of the queue on an otherwise-idle engine: the
    # final ABORTED output must still be delivered — has_work covers
    # requests with an undrained terminal output
    rid2 = eng.add_request(prompts[0], sp)
    assert eng.abort(rid2)
    assert eng.has_work
    outs2 = _drain_steps(eng)
    eng.shutdown()
    final2 = {o.request_id: o for o in outs2 if o.finished}
    assert final2[rid2].state == RequestState.ABORTED
    assert not eng.has_work and not eng.requests


# ---------------------------------------------------------------------------
# Mid-run admission + monotonic request ids
# ---------------------------------------------------------------------------

def test_mid_run_admission_and_monotonic_ids(model_and_params):
    """step() is re-entrant: requests admitted after the first wave has
    fully drained still run, and ids stay monotonic (never reused) even
    though the scheduler released the earlier sequences' state."""
    cfg, model, params = model_and_params
    rng = np.random.default_rng(5)
    sp = SamplingParams(greedy=True, max_new_tokens=3)

    eng = _engine(model, params, pp=1, max_batch=2, n_samplers=2, chunk=6,
                  policy="chunked")
    mk = lambda: list(map(int, rng.integers(2, cfg.vocab_size, size=5)))
    wave1 = [eng.add_request(mk(), sp) for _ in range(2)]
    outs1 = _drain_steps(eng)
    assert not eng.scheduler.seqs            # wave-1 state released
    wave2 = [eng.add_request(mk(), sp) for _ in range(2)]
    outs2 = _drain_steps(eng)
    eng.shutdown()

    assert wave1 == [0, 1] and wave2 == [2, 3]   # monotonic, no collision
    fin1 = {o.request_id for o in outs1 if o.finished}
    fin2 = {o.request_id for o in outs2 if o.finished}
    assert fin1 == set(wave1) and fin2 == set(wave2)
    for o in outs1 + outs2:
        if o.finished:
            assert len(o.token_ids) == 3
    assert eng.seq_cache.free_rows == eng.seq_cache.max_rows
    m = eng.metrics()
    assert m["requests_submitted"] == 4 and m["requests_finished"] == 4
    assert set(m["requests"]) == {0, 1, 2, 3}
    for r in m["requests"].values():
        assert r["queue_s"] >= 0 and r["ttft_s"] >= r["queue_s"]


# ---------------------------------------------------------------------------
# Request-lifecycle property: random arrival/abort schedules, all policies
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    policy=st.sampled_from(["monolithic", "chunked", "disaggregated",
                            "adaptive"]),
    n=st.integers(1, 8),
    max_batch=st.integers(1, 3),
    p=st.integers(1, 3),
    budget=st.integers(4, 16),
    seed=st.integers(0, 999),
)
def test_property_lifecycle_no_leaks(policy, n, max_batch, p, budget, seed):
    """Scheduler + SequenceCache lifecycle under random arrivals and
    aborts, mirroring the engine's admission/reclaim protocol: at drain,
    FINISHED ⊎ ABORTED partitions the admitted set, every KV row is
    back in the free list, per-request token streams only ever grew, and
    the scheduler retains no sequence state (the long-run memory bound).
    """
    rng = np.random.default_rng(seed)
    s = Scheduler(max_batch=max_batch, pp_degree=p, max_seq_len=256,
                  token_budget=(budget if policy != "monolithic" else None),
                  policy=policy)
    cache = SequenceCache(max_batch * p)
    alloc = itertools.count()
    plan = []
    for _ in range(n):
        sid = next(alloc)
        plan.append((int(rng.integers(0, 20)), Sequence(
            sid, list(range(1, int(rng.integers(1, 30)) + 1)),
            SamplingParams(greedy=True,
                           max_new_tokens=int(rng.integers(1, 5))))))
    aborts = {seq.seq_id: int(rng.integers(0, 40))
              for _, seq in plan if rng.random() < 0.4}
    admitted, aborted = set(), set()
    out_lens = {}
    for it in range(3000):
        for t_arr, seq in plan:
            if t_arr == it:
                s.add_request(seq)
                admitted.add(seq.seq_id)
        for sid, t_ab in list(aborts.items()):
            if t_ab == it:
                seq = s.abort(sid)
                del aborts[sid]
                if seq is not None:          # not already finished
                    aborted.add(sid)
                    cache.release(sid)       # engine reap (no in-flight here)
        o = s.schedule(it)
        if o is None:
            if not s.has_work and all(t_arr <= it for t_arr, _ in plan):
                break                        # drained and no more arrivals
            continue
        if o.is_prefill:                     # monolithic admission
            new = [sid for sid in o.seq_ids if cache.lookup(sid) is None]
            for sid in new:
                cache.admit(sid, s.seqs[sid].prompt_len)
            done = s.complete(it, new, rng.integers(3, 50, len(new)).astype(np.int32))
            for sid in done:
                cache.release(sid)
            o = s.schedule(it)
            if o is None:
                continue
        for sid in o.seq_ids:
            if cache.lookup(sid) is None:    # lazy row admission (span path)
                cache.admit(sid, s.seqs[sid].prompt_len)
        ids = [o.seq_ids[i] for i in o.sample_indices()]
        done = s.complete(it, ids, rng.integers(3, 50, len(ids)).astype(np.int32))
        for sid in done:
            cache.release(sid)
        for sid in o.seq_ids:
            seq = s.seqs.get(sid)
            if seq is not None:
                assert len(seq.output_ids) >= out_lens.get(sid, 0)  # monotonic
                out_lens[sid] = len(seq.output_ids)
    finished = {q.seq_id for q in s.finished}
    # FINISHED ⊎ ABORTED = admitted (disjoint union)
    assert finished | aborted == admitted
    assert not (finished & aborted)
    assert cache.free_rows == cache.max_rows      # no KV-row leak
    assert not s.seqs                             # scheduler state released
    assert not s.waiting


def test_generate_rejects_mismatched_params(model_and_params):
    cfg, model, params = model_and_params
    eng = _engine(model, params, pp=1, max_batch=1, n_samplers=1)
    with pytest.raises(ValueError, match="sampling params"):
        next(eng.generate([[3, 4], [5, 6]],
                          [SamplingParams(greedy=True)]))
    eng.shutdown()
