"""Heartbeats, straggler detection, retry, bubble accounting."""
import pytest

from repro.runtime.fault_tolerance import (
    BubbleAccounting,
    HeartbeatMonitor,
    RetryPolicy,
    StragglerDetector,
)


def test_heartbeat_detects_dead_worker():
    hb = HeartbeatMonitor(timeout_s=1.0)
    hb.beat("w0", now=100.0)
    hb.beat("w1", now=100.5)
    assert hb.dead_workers(now=100.9) == []
    assert hb.dead_workers(now=101.2) == ["w0"]
    assert set(hb.dead_workers(now=102.0)) == {"w0", "w1"}
    hb.forget("w0")
    assert hb.dead_workers(now=102.0) == ["w1"]


def test_straggler_detection():
    sd = StragglerDetector(min_samples=3, threshold=1.5)
    for _ in range(10):
        for s, lat in ((0, 0.10), (1, 0.11), (2, 0.10), (3, 0.30)):
            sd.observe(s, lat)
    assert sd.stragglers() == [3]
    shares = sd.rebalance_shares(4)
    assert shares[3] < min(shares[:3])         # straggler gets less work
    assert abs(sum(shares) - 1.0) < 1e-9


def test_straggler_needs_samples():
    sd = StragglerDetector(min_samples=5)
    sd.observe(0, 0.1)
    sd.observe(1, 9.9)
    assert sd.stragglers() == []


def test_retry_policy_eventually_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise ValueError("transient")
        return "ok"

    rp = RetryPolicy(max_attempts=3, base_delay_s=0.0)
    assert rp.run(flaky) == "ok"
    assert calls["n"] == 3


def test_retry_policy_gives_up():
    rp = RetryPolicy(max_attempts=2, base_delay_s=0.0)
    with pytest.raises(RuntimeError):
        rp.run(lambda: (_ for _ in ()).throw(ValueError("always")))


def test_bubble_accounting():
    ba = BubbleAccounting(2)
    ba.record(0, 0.0, 1.0)
    ba.record(0, 2.0, 3.0)
    ba.record(1, 0.0, 3.0)
    rep = ba.report()
    assert rep["stage0_busy_frac"] == pytest.approx(2 / 3)
    assert rep["stage1_busy_frac"] == pytest.approx(1.0)
    assert rep["pipeline_bubble_frac"] == pytest.approx(1 - (2 / 3 + 1) / 2)
