"""Train a ~100M-param model for a few hundred steps with the production
substrate: sharded AdamW, WSD schedule, deterministic restartable data,
periodic checkpoints and a simulated crash + restart.

  PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import shutil
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--crash-demo", action="store_true",
                    help="simulate a mid-run crash and restart from the "
                         "latest checkpoint")
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro_train_")
    try:
        if args.crash_demo:
            try:
                run("stablelm-1.6b", steps=args.steps, batch=8, seq=128,
                    ckpt_dir=ckpt, ckpt_every=20,
                    simulate_crash_at=args.steps // 2, schedule="wsd")
            except RuntimeError as e:
                print(f"[demo] crashed as requested: {e}; restarting...")
        out = run("stablelm-1.6b", steps=args.steps, batch=8, seq=128,
                  ckpt_dir=ckpt, ckpt_every=20, schedule="wsd")
        first, last = out["losses"][0], out["losses"][-1]
        print(f"loss: {first:.3f} -> {last:.3f} over {len(out['losses'])} "
              f"steps ({out['wall_s']:.0f}s)")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
