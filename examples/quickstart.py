"""Quickstart: build an assigned architecture, run one forward pass, one
prefill and a few decode steps through the public API.

  PYTHONPATH=src python examples/quickstart.py [--arch glm4-9b]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import ModelOptions, ShardCtx, build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b", choices=list_archs())
    args = ap.parse_args()

    # the -smoke suffix selects the reduced same-family config (CPU-sized)
    cfg = get_config(args.arch + "-smoke")
    print(f"arch={cfg.name} family={cfg.family} L={cfg.num_layers} "
          f"d={cfg.d_model} V={cfg.vocab_size}")

    model = build_model(cfg, ShardCtx.single(), ModelOptions(), enc_len=32)
    params = model.init(jax.random.key(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"params: {n/1e6:.2f}M")

    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab_size, (1, 12))
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)}
    if cfg.family == "vlm":
        from repro.models.transformer import cfg_n_patches
        batch["patches"] = jnp.zeros((1, cfg_n_patches(cfg), cfg.d_model),
                                     jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((1, 32, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = batch["tokens"][:, :4]

    logits, cache = jax.jit(model.prefill)(params, batch)
    print(f"prefill logits: {logits.shape}, cache leaves: "
          f"{len(jax.tree.leaves(cache))}")

    dcache = model.init_cache(1, 64)
    def pad_into(dst, src):
        if dst.shape == src.shape:
            return src
        return dst.at[tuple(slice(0, d) for d in src.shape)].set(src)
    dcache = jax.tree.map(pad_into, dcache, cache)

    pos = batch["tokens"].shape[1]
    tok = int(np.asarray(logits).argmax(-1)[0])
    generated = [tok]
    decode = jax.jit(model.decode)
    for _ in range(8):
        logits, dcache = decode(params, dcache, {
            "token": jnp.asarray([tok], jnp.int32),
            "positions": jnp.asarray([pos], jnp.int32)})
        tok = int(np.asarray(logits).argmax(-1)[0])
        generated.append(tok)
        pos += 1
    print("greedy continuation:", generated)


if __name__ == "__main__":
    main()
