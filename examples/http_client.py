"""Raw-socket streaming client for the HTTP front-end (docs/http.md).

Speaks the wire protocol by hand — one TCP socket, a hand-written POST,
and incremental SSE parsing — so you can see exactly what travels over
the connection.  Start a server first:

    PYTHONPATH=src python -m repro.launch.serve \
        --arch stablelm-1.6b --http --port 8000

then:

    python examples/http_client.py --port 8000 --prompt 5,9,13 \
        --max-tokens 16 --temperature 0.0

The prompt is a comma-separated list of token ids (the repo has no real
tokenizer; a plain string also works — the server stub-encodes it).
"""
import argparse
import json
import socket
import sys


def stream_completion(host: str, port: int, body: dict):
    """Yield parsed SSE events for one streamed completion."""
    payload = json.dumps({**body, "stream": True}).encode()
    request = (
        f"POST /v1/completions HTTP/1.1\r\n"
        f"Host: {host}:{port}\r\n"
        f"Content-Type: application/json\r\n"
        f"Content-Length: {len(payload)}\r\n"
        f"Connection: close\r\n"
        f"\r\n"
    ).encode() + payload

    with socket.create_connection((host, port), timeout=300) as sock:
        sock.sendall(request)
        f = sock.makefile("rb")
        status = f.readline().decode().strip()        # HTTP/1.1 200 OK
        if " 200 " not in status + " ":
            rest = f.read().decode(errors="replace")
            raise RuntimeError(f"{status}\n{rest}")
        while f.readline() not in (b"\r\n", b"\n", b""):
            pass                                      # drain headers
        for line in f:
            line = line.rstrip(b"\r\n")
            if not line.startswith(b"data: "):
                continue
            data = line[len(b"data: "):]
            if data == b"[DONE]":
                return
            yield json.loads(data)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--prompt", default="5,9,13",
                    help="comma-separated token ids, or a plain string")
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0.0 = greedy (deterministic)")
    ap.add_argument("--n", type=int, default=1,
                    help="parallel completions (CoW-forked streams)")
    ap.add_argument("--priority", type=int, default=0)
    args = ap.parse_args()

    try:
        prompt = [int(t) for t in args.prompt.split(",")]
    except ValueError:
        prompt = args.prompt                          # stub-encoded string

    body = {"prompt": prompt, "max_tokens": args.max_tokens,
            "temperature": args.temperature, "n": args.n,
            "priority": args.priority}
    per_choice: dict = {}
    for event in stream_completion(args.host, args.port, body):
        for choice in event["choices"]:
            idx = choice["index"]
            per_choice.setdefault(idx, []).extend(choice["token_ids"])
            if choice["token_ids"]:
                print(f"[{idx}] += {choice['token_ids']}", flush=True)
            if choice["finish_reason"]:
                print(f"[{idx}] finished: {choice['finish_reason']}")
    for idx in sorted(per_choice):
        print(f"choice {idx}: {per_choice[idx]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
