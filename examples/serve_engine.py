"""End-to-end serving driver: the SiPipe engine vs the naive PP baseline on
a real (reduced) model with a ShareGPT-shaped batched workload — the
paper's architecture running for real: scheduler -> BIC-I -> stage workers
(TSEM CPU/device executors) -> SAT channels -> CPU sampler pool -> BIC-O.

  PYTHONPATH=src python examples/serve_engine.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.launch.serve import run


def main():
    for engine in ("naive", "sipipe"):
        print(f"\n=== engine: {engine} ===")
        m = run("stablelm-1.6b", engine=engine, pp=2, requests=6,
                max_batch=3, max_new_tokens=8, n_samplers=2)
        print(f"-> {m['finished']} finished, "
              f"{m['throughput_tok_s']:.1f} tok/s, "
              f"incremental metadata hits {m['incremental_hits']} "
              f"vs rebuilds {m['meta_rebuilds']}")


if __name__ == "__main__":
    main()
