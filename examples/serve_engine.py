"""End-to-end serving driver: the SiPipe engine vs the naive PP baseline on
a real (reduced) model with a ShareGPT-shaped batched workload — the
paper's architecture running for real: scheduler -> BIC-I -> stage workers
(TSEM CPU/device executors) -> SAT channels -> CPU sampler pool -> BIC-O.
Plus a taste of the continuous-serving request API (docs/serving.md):
streaming generate(), per-request sampling params and mid-flight abort.

  PYTHONPATH=src python examples/serve_engine.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import EngineConfig, SiPipeEngine
from repro.core.sampling_params import SamplingParams
from repro.launch.serve import run
from repro.models import ShardCtx, build_model


def streaming_demo():
    """generate() streams tokens incrementally; each request carries its
    own SamplingParams; abort() cancels mid-decode."""
    print("\n=== streaming request API ===")
    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single())
    params = model.init(jax.random.key(0))
    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=2, max_batch=2, max_seq_len=64))
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=6)))
               for _ in range(2)]
    per_request = [SamplingParams(greedy=True, max_new_tokens=8),
                   SamplingParams(temperature=0.7, top_k=40,
                                  frequency_penalty=0.5, max_new_tokens=8)]
    for out in eng.generate(prompts, per_request):
        print(f"  req{out.request_id} +{out.new_token_ids}"
              + (f"  [done: {out.finish_reason}, "
                 f"ttft={out.metrics.ttft_s * 1e3:.0f}ms]"
                 if out.finished else ""))
    eng.shutdown()


def main():
    for engine in ("naive", "sipipe"):
        print(f"\n=== engine: {engine} ===")
        m = run("stablelm-1.6b", engine=engine, pp=2, requests=6,
                max_batch=3, max_new_tokens=8, n_samplers=2)
        print(f"-> {m['finished']} finished, "
              f"{m['throughput_tok_s']:.1f} tok/s, "
              f"p50 ttft {m['ttft_p50_s'] * 1e3:.0f}ms, "
              f"incremental metadata hits {m['incremental_hits']} "
              f"vs rebuilds {m['meta_rebuilds']}")
    streaming_demo()


if __name__ == "__main__":
    main()
