"""Column-wise incremental CPU sampling in isolation: run every sampling
strategy (temperature / top-k / top-p / min-p / penalties) and show the
incremental-vs-recompute cost gap grow with sequence length.

  PYTHONPATH=src python examples/sampler_playground.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import numpy as np

from repro.core.sampler import ColumnWiseSampler, NaiveSampler
from repro.core.sampling_params import SamplingParams

V, B = 32_000, 64


def main():
    rng = np.random.default_rng(0)
    z = rng.normal(size=(B, V)).astype(np.float32)

    print("strategy demonstration (all vLLM-style strategies):")
    for name, p in {
        "greedy": SamplingParams(greedy=True),
        "temp0.7": SamplingParams(temperature=0.7),
        "top_k40": SamplingParams(top_k=40),
        "top_p0.9": SamplingParams(top_p=0.9),
        "min_p0.1": SamplingParams(min_p=0.1),
        "penalties": SamplingParams(frequency_penalty=0.5,
                                    presence_penalty=0.2,
                                    repetition_penalty=1.1),
    }.items():
        s = ColumnWiseSampler(V, B)
        ids = s.sample(z.copy(), p)
        print(f"  {name:10s} -> ids[:5] = {ids[:5]}")

    print("\nincremental vs naive-recompute, growing history:")
    p = SamplingParams(greedy=True, frequency_penalty=0.5, presence_penalty=0.2)
    for hist in (0, 128, 512, 2048):
        cw = ColumnWiseSampler(V, B, max_len=4096)
        nv = NaiveSampler(V)
        if hist:
            h = [rng.integers(0, V, hist) for _ in range(B)]
            cw.seed_prompt(0, B, list(range(B)), h)
            nv.history[0] = [x.astype(np.int64) for x in h]
        t0 = time.perf_counter(); cw.sample(z.copy(), p); t_cw = time.perf_counter() - t0
        t0 = time.perf_counter(); nv.sample(z.copy(), p); t_nv = time.perf_counter() - t0
        print(f"  history={hist:5d}: incremental {t_cw*1e3:7.1f} ms | "
              f"naive {t_nv*1e3:7.1f} ms | {t_nv/t_cw:5.2f}x")


if __name__ == "__main__":
    main()
