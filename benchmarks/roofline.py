"""Roofline table generation from the dry-run cell JSONs.

  PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun] [--tag baseline]

Emits the EXPERIMENTS.md §Roofline markdown table: per (arch x shape),
the three roofline terms (seconds), dominant bottleneck, MODEL_FLOPS,
useful-compute ratio, and the one-line "what would move it" note.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List

MOVE_NOTES = {
    "memory_s": "raise arithmetic intensity: in-place cache update, larger "
                "per-chip batch, weight-traffic amortization (PP rounds)",
    "compute_s": "cut redundant FLOPs: triangular attention schedule, less "
                 "remat recompute, head-padding removal",
    "collective_s": "cheaper collective schedule: overlap psum with compute, "
                    "reduce-scatter instead of all-reduce, wider microbatch",
}


def load_cells(d: Path, tag: str) -> List[dict]:
    cells = []
    for f in sorted(d.glob(f"{tag}__*.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def fmt_table(cells: List[dict], mesh: str = "pod16x16") -> str:
    rows = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
            "| MODEL_FLOPS | useful ratio | mfu bound |",
            "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if c.get("skipped"):
            rows.append(f"| {c['arch']} | {c['shape']} | — | — | — | skipped |"
                        f" — | — | {c.get('reason','')[:60]} |")
            continue
        if not c.get("ok"):
            rows.append(f"| {c['arch']} | {c['shape']} | FAILED | | | | | | |")
            continue
        r = c["roofline"]
        ur = r.get("useful_ratio")
        rows.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['dominant'].replace('_s','')} | {c['model_flops']:.2e} | "
            f"{ur if ur is None else format(ur, '.3f')} | {r['mfu_bound']:.4f} |")
    return "\n".join(rows)


def summarize(cells: List[dict]) -> Dict:
    ok = [c for c in cells if c.get("ok") and not c.get("skipped")
          and c.get("mesh") == "pod16x16"]
    worst = sorted(ok, key=lambda c: c["roofline"]["mfu_bound"])[:5]
    coll = sorted(ok, key=lambda c: -c["roofline"]["collective_s"] /
                  max(c["roofline"]["step_s_lower_bound"], 1e-12))[:5]
    return {
        "n_ok": len(ok),
        "worst_mfu": [(c["arch"], c["shape"], c["roofline"]["mfu_bound"])
                      for c in worst],
        "most_collective_bound": [
            (c["arch"], c["shape"],
             c["roofline"]["collective_s"] / max(
                 c["roofline"]["step_s_lower_bound"], 1e-12))
            for c in coll],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    cells = load_cells(Path(args.dir), args.tag)
    print(fmt_table(cells, args.mesh))
    print()
    s = summarize(cells)
    print(f"-- {s['n_ok']} ok cells; worst mfu_bound:")
    for a, sh, m in s["worst_mfu"]:
        print(f"   {a} x {sh}: {m:.4f}")
    print("-- most collective-bound (fraction of step):")
    for a, sh, f in s["most_collective_bound"]:
        print(f"   {a} x {sh}: {f:.3f}")


if __name__ == "__main__":
    main()
