"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Component latencies used by
the simulator are MEASURED from this repo's real implementations (sampler,
SAT channels, TSEM executors); the pipeline-level reproductions of the
paper's H100 figures come from the calibrated discrete-event simulator
(benchmarks/pp_sim.py) since this container exposes one CPU device.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only sampler,ablation
"""
from __future__ import annotations

import argparse
import time
from typing import Callable, Dict, List

import numpy as np

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _time(fn: Callable, *args, reps: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args)
    return (time.perf_counter() - t0) / reps


# ---------------------------------------------------------------------------
# §5.1 — column-wise CPU sampling microbenchmark (real measurement)
# ---------------------------------------------------------------------------

def bench_sampler() -> Dict[str, float]:
    """CPU sampling cost — incremental vs naive recompute at serving scale
    (V ~ 152k, B up to 256) and realistic history depth (512 generated +
    prompt tokens, where the naive path's per-iteration recompute hurts)."""
    from repro.core.sampler import ColumnWiseSampler, NaiveSampler
    from repro.core.sampling_params import SamplingParams

    out = {}
    params = SamplingParams(temperature=0.8, top_k=50,
                            frequency_penalty=0.5, presence_penalty=0.2)
    HIST = 512
    for v, b in ((151_936, 64), (151_936, 256), (32_000, 256)):
        rng = np.random.default_rng(0)
        z = rng.normal(size=(b, v)).astype(np.float32)
        cw = ColumnWiseSampler(v, b, max_len=4096)
        nv = NaiveSampler(v)
        # seed realistic histories: incremental folds them once; naive will
        # recompute them on every subsequent iteration
        hist = [rng.integers(0, v, HIST) for _ in range(b)]
        cw.seed_prompt(0, b, list(range(b)), hist)
        nv.history[0] = [h.astype(np.int64) for h in hist]
        t_cw = _time(lambda: cw.sample(z, params), reps=3)
        t_nv = _time(lambda: nv.sample(z, params), reps=3)
        emit(f"sampler/incremental_v{v}_b{b}", t_cw * 1e6,
             f"hist={HIST} speedup_vs_naive={t_nv / t_cw:.2f}x")
        emit(f"sampler/naive_recompute_v{v}_b{b}", t_nv * 1e6, f"hist={HIST}")
        # penalty-path isolation (greedy: no softmax/top-k in either path)
        g = SamplingParams(greedy=True, frequency_penalty=0.5,
                           presence_penalty=0.2)
        t_cwp = _time(lambda: cw.sample(z, g), reps=3)
        t_nvp = _time(lambda: nv.sample(z, g), reps=3)
        emit(f"sampler/penalty_only_incremental_v{v}_b{b}", t_cwp * 1e6,
             f"speedup_vs_naive={t_nvp / t_cwp:.2f}x")
        # transposed-shard ingestion path (§5.1(3))
        zt = np.ascontiguousarray(z.T)
        cw_t = ColumnWiseSampler(v, b, max_len=4096)
        t_cwt = _time(lambda: cw_t.sample(zt, params, transposed=True), reps=3)
        emit(f"sampler/transposed_shards_v{v}_b{b}", t_cwt * 1e6,
             "zero-gather TP-shard concat path")
        out[f"cw_{v}_{b}"] = t_cw
    return out


# ---------------------------------------------------------------------------
# §5.3 — SAT vs structure-unaware transmission (real channel objects)
# ---------------------------------------------------------------------------

def bench_sat() -> Dict[str, float]:
    from repro.core.sat import StructureAwareChannel, StructureUnawareChannel

    b, d = 256, 8192
    tensors = {"hidden": np.zeros((b, d), np.float16),
               "residual": np.zeros((b, d), np.float16)}
    round_lat = 0.0007  # 0.7 ms per synchronous round (RDMA-scale, §5.3)

    def unaware_iter():
        ch = StructureUnawareChannel(round_lat)
        ch.send(tensors)
        ch.recv()

    aware = StructureAwareChannel(round_lat)
    aware.send(tensors)
    aware.recv()  # capture iteration

    def aware_iter():
        aware.send(tensors)
        aware.recv()

    t_u = _time(unaware_iter, reps=3)
    t_a = _time(aware_iter, reps=3)
    emit("sat/structure_unaware_per_edge", t_u * 1e6, "rounds=4")
    emit("sat/structure_aware_per_edge", t_a * 1e6,
         f"rounds=1 speedup={t_u / t_a:.2f}x")
    return {"t_edge_unaware": t_u, "t_edge_aware": t_a}


# ---------------------------------------------------------------------------
# §5.2 — TSEM overlap (real executor threads)
# ---------------------------------------------------------------------------

def bench_tsem() -> None:
    from repro.core.scheduler import SchedulingOutput
    from repro.core.tsem import SynchronousExecutor, TokenSafeExecutor

    PREP = EXEC = 0.004
    N = 24

    def prepare(s, bufs):
        time.sleep(PREP)

    def execute(d, bufs):
        time.sleep(EXEC)
        return True

    def sched(it):
        return SchedulingOutput(it, 0, [0], np.zeros(1, np.int32),
                                np.zeros(1, np.int32), False)

    sync = SynchronousExecutor(prepare, execute)
    t0 = time.perf_counter()
    for it in range(N):
        sync.run(sched(it))
    t_sync = (time.perf_counter() - t0) / N

    ex = TokenSafeExecutor(prepare, execute)
    ex.start()
    t0 = time.perf_counter()
    for it in range(N):
        ex.submit(sched(it))
    for it in range(N):
        ex.result(it, timeout=30)
    t_tsem = (time.perf_counter() - t0) / N
    ex.stop()
    emit("tsem/synchronous_per_iter", t_sync * 1e6, "prep+exec serialized")
    emit("tsem/token_safe_per_iter", t_tsem * 1e6,
         f"overlap_gain={t_sync / t_tsem:.2f}x")


# ---------------------------------------------------------------------------
# Fig. 1 / 8 — throughput across engines and parallel configs (simulator)
# ---------------------------------------------------------------------------

PAPER_SAMPLE_S = 0.0015 * 48  # the paper's engineered samplers finish a
# microbatch in 1-2 ms *per sampler*; expressed pre-pool-division


def bench_throughput(measured: Dict[str, float]) -> None:
    """Two calibrations of the async sampling latency:
      paper  — the paper's engineered C-level samplers (1.5 ms pooled)
      meas   — this repo's numpy sampler (single-core full batch / pool)
    """
    from benchmarks.pp_sim import paper_costs, simulate

    t_meas = measured.get("cw_151936_256", 0.10)
    for model in ("qwen-2.5-72b", "llama-3.1-70b", "mixtral-8x7b",
                  "deepseek-v3", "llama-3.1-405b"):
        for p in (2, 4):
            base = simulate(paper_costs(model, p,
                                        measured_cpu_sample_s=PAPER_SAMPLE_S),
                            sipipe=False)
            emit(f"throughput/{model}_p{p}_baseline",
                 1e6 / base.tokens_per_s, f"iters_per_s={base.tokens_per_s:.1f}")
            for calib, t_s in (("paper", PAPER_SAMPLE_S), ("meas", t_meas)):
                sip = simulate(paper_costs(model, p, measured_cpu_sample_s=t_s,
                                           sipipe=True), sipipe=True)
                emit(f"throughput/{model}_p{p}_sipipe_{calib}",
                     1e6 / sip.tokens_per_s,
                     f"iters_per_s={sip.tokens_per_s:.1f} "
                     f"speedup={sip.tokens_per_s / base.tokens_per_s:.2f}x")


# ---------------------------------------------------------------------------
# Fig. 3 / 4 / 11 — per-stage bubble anatomy (simulator timelines)
# ---------------------------------------------------------------------------

def bench_bubbles(measured: Dict[str, float]) -> None:
    from benchmarks.pp_sim import paper_costs, simulate

    t_cpu = PAPER_SAMPLE_S
    for name, sip in (("baseline", False), ("sipipe", True)):
        r = simulate(paper_costs("deepseek-v3", 4,
                                 measured_cpu_sample_s=t_cpu, sipipe=sip),
                     sipipe=sip)
        fr = " ".join(f"s{i}={f:.2f}" for i, f in enumerate(r.bubble_fracs))
        emit(f"bubbles/deepseek-v3_p4_{name}", r.tpot_mean * 1e6,
             f"bubble_fracs: {fr}")


# ---------------------------------------------------------------------------
# Fig. 9 — batch size sweep  /  Fig. 10 — GPU-count scalability
# ---------------------------------------------------------------------------

def bench_batch_sweep(measured: Dict[str, float]) -> None:
    import dataclasses as dc

    from benchmarks.pp_sim import paper_costs, simulate

    t_cpu = PAPER_SAMPLE_S
    for bs_scale, tag in ((0.5, "b256"), (1.0, "b512"), (2.0, "b1024")):
        for sip in (False, True):
            c = paper_costs("qwen-2.5-72b", 4, measured_cpu_sample_s=t_cpu,
                            sipipe=sip)
            c = dc.replace(c, t_fwd=c.t_fwd * (0.6 + 0.4 * bs_scale),
                           t_sample_stage=c.t_sample_stage * bs_scale,
                           t_sample_async=c.t_sample_async * bs_scale)
            r = simulate(c, sipipe=sip)
            emit(f"batch_sweep/qwen72b_{tag}_{'sipipe' if sip else 'baseline'}",
                 1e6 / r.tokens_per_s, f"iters_per_s={r.tokens_per_s:.1f}")


def bench_scalability(measured: Dict[str, float]) -> None:
    from benchmarks.pp_sim import paper_costs, simulate

    t_cpu = PAPER_SAMPLE_S
    tput = {}
    for p in (2, 4, 8):
        for sip in (False, True):
            r = simulate(paper_costs("llama-3.1-70b", p,
                                     measured_cpu_sample_s=t_cpu, sipipe=sip),
                         sipipe=sip)
            key = "sipipe" if sip else "baseline"
            tput[(key, p)] = r.tokens_per_s
            scale = r.tokens_per_s / tput.get((key, p // 2), r.tokens_per_s)
            emit(f"scalability/llama70b_p{p}_{key}", 1e6 / r.tokens_per_s,
                 f"iters_per_s={r.tokens_per_s:.1f} scale_vs_half={scale:.2f}x")


# ---------------------------------------------------------------------------
# Fig. 12 / 13 — TPOT distribution (per-iteration latency percentiles)
# ---------------------------------------------------------------------------

def bench_tpot_cdf(measured: Dict[str, float]) -> None:
    from benchmarks.pp_sim import paper_costs, simulate

    t_cpu = PAPER_SAMPLE_S
    for model, p in (("qwen-2.5-72b", 4), ("deepseek-v3", 4)):
        for sip in (False, True):
            r = simulate(paper_costs(model, p, measured_cpu_sample_s=t_cpu,
                                     sipipe=sip), sipipe=sip, n_iters=128)
            ts = np.array(r.iteration_times)
            pct = {q: float(np.percentile(ts, q)) for q in (50, 90, 99)}
            emit(f"tpot/{model}_p{p}_{'sipipe' if sip else 'baseline'}",
                 r.tpot_mean * 1e6,
                 f"p50={pct[50]*1e3:.1f}ms p90={pct[90]*1e3:.1f}ms "
                 f"p99={pct[99]*1e3:.1f}ms")


# ---------------------------------------------------------------------------
# Fig. 16 — per-component ablation
# ---------------------------------------------------------------------------

def bench_ablation(measured: Dict[str, float]) -> None:
    """Reproduces Fig. 16's component ordering under the paper's sampler
    calibration (bench_throughput reports the measured calibration)."""
    from benchmarks.pp_sim import ablation_variants, simulate_variant

    for model in ("qwen-2.5-72b", "mixtral-8x7b", "deepseek-v3"):
        variants = ablation_variants(model, 4, PAPER_SAMPLE_S)
        base_tput = None
        for name, (costs, mode) in variants.items():
            r = simulate_variant(costs, mode)
            if base_tput is None:
                base_tput = r.tokens_per_s
            emit(f"ablation/{model}_{name}", 1e6 / r.tokens_per_s,
                 f"gain_vs_baseline={r.tokens_per_s / base_tput:.2f}x")


# ---------------------------------------------------------------------------
# Chunked prefill vs monolithic prefill on a mixed long-prompt workload
# ---------------------------------------------------------------------------

def _time_chunk_step(stage, spans, bucket, s_max=160):
    """Wall time of one real packed chunk step carrying ``spans``, with
    the packed vectors padded (last-valid duplicates) to ``bucket``."""
    import jax
    import jax.numpy as jnp

    b = len(spans)
    cache = stage.init_cache(b, s_max)
    pt, pp_, ps, last = [], [], [], []
    for i, (off, n) in enumerate(spans):
        pt.extend([3] * n)
        pp_.extend(range(off, off + n))
        ps.extend([i] * n)
        last.append(len(pt) - 1)
    t = len(pt)
    while len(pt) < bucket:
        pt.append(pt[-1])
        pp_.append(pp_[-1])
        ps.append(ps[-1])
    args = (stage.params, cache, jnp.asarray(pt, jnp.int32),
            jnp.asarray(pp_, jnp.int32), jnp.asarray(ps, jnp.int32),
            jnp.asarray([off for off, _ in spans], jnp.int32),
            jnp.asarray(last, jnp.int32), jnp.asarray(t, jnp.int32))

    def call():
        out, _ = stage.chunk_fn(*args)
        jax.block_until_ready(out)

    return _time(call, reps=3, warmup=2)


def bench_chunked_prefill() -> None:
    """Packed-vs-padded model time on a skewed mixed batch, plus the
    mixed-workload simulation with t_token/t_fixed CALIBRATED from the
    measured chunk-step latencies of the real engine stage (rather than
    the previous hard-coded guesses), all recorded in BENCH_chunked.json.

    Since PR 3 this is a THREE-way scheduling-policy comparison
    (monolithic / chunked / disaggregated, docs/scheduling.md
    §Scheduling policies), plus a prefill-heavy long-prompt trace where
    TD-Pipe-style temporal disaggregation beats chunked piggybacking:
    its prefill phases carry no sampling, so phase chunks stream through
    the pipeline without the per-slot sampler round-trip."""
    import json

    import jax

    from benchmarks.pp_sim import simulate_mixed_workload
    from repro.configs import get_config
    from repro.core.engine import split_for_pp
    from repro.models import ShardCtx, build_model

    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single())
    params = model.init(jax.random.key(0))
    stage = split_for_pp(model, params, 1)[0]

    # -- calibration: stage latency is ~ t_fixed + t_token * tokens --------
    t_small = _time_chunk_step(stage, [(0, 8)], 8)
    t_large = _time_chunk_step(stage, [(0, 64)], 64)
    t_token = max((t_large - t_small) / (64 - 8), 1e-7)
    t_fixed = max(t_small - 8 * t_token, 1e-6)
    emit("chunked_prefill/calibration", t_large * 1e6,
         f"t_token_us={t_token * 1e6:.2f} t_fixed_us={t_fixed * 1e6:.2f}")

    # -- packed vs padded: 1 long chunk piggybacked on 7 decodes ----------
    budget = 32
    skewed = [(0, budget - 7)] + [(100, 1)] * 7      # T = 32 valid tokens
    t_packed = _time_chunk_step(stage, skewed, budget)
    # the padded [B, C] execution the packed layout replaced is exactly a
    # packed batch clamp-padded to B x C duplicate tokens
    t_padded = _time_chunk_step(stage, skewed, len(skewed) * budget)
    reduction = 1.0 - t_packed / t_padded
    emit("chunked_prefill/packed_model_time", t_packed * 1e6,
         f"tokens={budget}")
    emit("chunked_prefill/padded_model_time", t_padded * 1e6,
         f"tokens={len(skewed) * budget} reduction={reduction:.2%}")

    POLICIES = ("monolithic", "chunked", "disaggregated")
    prompts = [200, 8, 150, 6, 180, 10, 90, 120, 5, 160, 7, 140]
    # per-stage heterogeneity (Obs. 3): the same deterministic alternating
    # jitter paper_costs feeds PipeCosts — stages no longer charge
    # identical durations, so the slowest stage paces every policy
    JITTER = 0.05
    sim = {"fwd_jitter": JITTER}
    for p in (2, 4):
        results = {}
        for policy in POLICIES:
            r = simulate_mixed_workload(
                p=p, max_batch=4, token_budget=budget, prompt_lens=prompts,
                max_new_tokens=24, policy=policy,
                t_token=t_token, t_fixed=t_fixed, fwd_jitter=JITTER)
            results[policy] = r
            emit(f"chunked_prefill/p{p}_{policy}", r.wall_s * 1e6,
                 f"occupancy={r.occupancy:.3f} bubble_ticks={r.bubble_ticks} "
                 f"bubble_frac={max(r.bubble_fracs):.3f} "
                 f"prefill_block_ms={r.prefill_block_s * 1e3:.1f}")
        gain = results["monolithic"].wall_s / results["chunked"].wall_s
        emit(f"chunked_prefill/p{p}_speedup", 0.0,
             f"wall_gain={gain:.2f}x occupancy "
             f"{results['monolithic'].occupancy:.3f}->"
             f"{results['chunked'].occupancy:.3f}")
        sim[f"p{p}"] = {
            "wall_gain": gain,
            "wall_s": {k: results[k].wall_s for k in POLICIES},
            "occupancy_monolithic": results["monolithic"].occupancy,
            "occupancy_chunked": results["chunked"].occupancy,
            "occupancy_disaggregated": results["disaggregated"].occupancy,
            "bubble_ticks_monolithic": results["monolithic"].bubble_ticks,
            "bubble_ticks_chunked": results["chunked"].bubble_ticks,
            "bubble_ticks_disaggregated": results["disaggregated"].bubble_ticks,
        }

    # -- prefill-heavy long-prompt trace: the TD-Pipe regime --------------
    # chunked piggybacks decodes into every iteration, so every iteration
    # pays the per-slot pipeline+sampler round-trip before the slot's next
    # batch can be built; disaggregated prefill phases sample nothing and
    # stream their chunks back-to-back (engine run-loop per-slot gate)
    heavy = [2400, 40, 2000, 30, 2200, 50, 1800, 60]
    heavy_budget, heavy_new = 512, 16
    hres = {}
    for policy in POLICIES:
        r = simulate_mixed_workload(
            p=2, max_batch=4, token_budget=heavy_budget, prompt_lens=heavy,
            max_new_tokens=heavy_new, policy=policy,
            t_token=t_token, t_fixed=t_fixed, fwd_jitter=JITTER)
        hres[policy] = r
        emit(f"chunked_prefill/prefill_heavy_{policy}", r.wall_s * 1e6,
             f"occupancy={r.occupancy:.3f} iterations={r.iterations}")
    d_vs_c = hres["chunked"].wall_s / hres["disaggregated"].wall_s
    d_vs_m = hres["monolithic"].wall_s / hres["disaggregated"].wall_s
    emit("chunked_prefill/prefill_heavy_disagg_gain", 0.0,
         f"wall_gain_vs_chunked={d_vs_c:.2f}x vs_monolithic={d_vs_m:.2f}x")

    # -- overlapped CPU sampling on the calibrated trace: t_sample is
    # the MEASURED smoke-scale ColumnWiseSampler latency; the overlap
    # frees the last stage at forward-end (engine SamplingWorker), so
    # the sampling bubble closes for every slot but the sampled one
    from repro.core.sampler import ColumnWiseSampler
    from repro.core.sampling_params import SamplingParams

    smp = ColumnWiseSampler(cfg.vocab_size, 4, max_len=512)
    z = np.random.default_rng(0).normal(
        size=(4, cfg.vocab_size)).astype(np.float32)
    t_sample = _time(lambda: smp.sample(
        z, SamplingParams(temperature=0.8, top_k=40)), reps=3)
    ores = {}
    for ov in (True, False):
        ores[ov] = simulate_mixed_workload(
            p=2, max_batch=4, token_budget=budget, prompt_lens=prompts,
            max_new_tokens=24, policy="chunked", t_token=t_token,
            t_fixed=t_fixed, t_sample=t_sample, overlap_sampling=ov,
            fwd_jitter=JITTER)
    ov_gain = ores[False].wall_s / ores[True].wall_s
    emit("chunked_prefill/sampling_overlap", ores[True].wall_s * 1e6,
         f"t_sample_us={t_sample * 1e6:.1f} sync_wall_us="
         f"{ores[False].wall_s * 1e6:.0f} closed_bubble_gain={ov_gain:.3f}x")

    with open("BENCH_chunked.json", "w") as f:
        json.dump({
            "calibration": {"t_token_s": t_token, "t_fixed_s": t_fixed,
                            "source": "measured stablelm-smoke stage "
                                      "chunk_fn latency at widths 8/64"},
            "packed_vs_padded": {
                "skewed_batch": "1 long chunk (25 tok) + 7 decodes",
                "packed_tokens": budget,
                "padded_tokens": len(skewed) * budget,
                "t_packed_us": t_packed * 1e6,
                "t_padded_us": t_padded * 1e6,
                "model_time_reduction": reduction,
            },
            "simulation": sim,
            "sampling_overlap": {
                "t_sample_s": t_sample,
                "wall_s_overlap": ores[True].wall_s,
                "wall_s_sync": ores[False].wall_s,
                "closed_bubble_gain": ov_gain,
                "bubble_fracs_overlap": ores[True].bubble_fracs,
                "bubble_fracs_sync": ores[False].bubble_fracs,
            },
            "prefill_heavy": {
                "trace": heavy,
                "token_budget": heavy_budget,
                "max_new_tokens": heavy_new,
                "p": 2,
                "wall_s": {k: hres[k].wall_s for k in POLICIES},
                "wall_gain_disaggregated_vs_chunked": d_vs_c,
                "wall_gain_disaggregated_vs_monolithic": d_vs_m,
            },
        }, f, indent=2)
    emit("chunked_prefill/bench_json", 0.0, "wrote BENCH_chunked.json")


# ---------------------------------------------------------------------------
# Online continuous serving (step-driven request API, Poisson arrivals)
# ---------------------------------------------------------------------------

def bench_serving() -> None:
    """Online Poisson-arrival serving on the REAL engine through the
    step-driven request API (serve.py run_online, docs/serving.md):
    throughput + p50/p99 TTFT and TPOT per scheduling policy, recorded
    in BENCH_serving.json.  CPU-scale absolute numbers; the point is the
    per-policy latency SHAPE — chunked keeps TPOT flat, disaggregated
    trades TPOT tails for prefill streaming, adaptive walks its chunk
    budget to the live TPOT."""
    import json

    import jax

    from repro.configs import get_config
    from repro.launch.serve import run_online
    from repro.models import ShardCtx, build_model

    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single())
    prebuilt = (cfg, model, model.init(jax.random.key(0)))
    results = {}
    for policy in ("chunked", "disaggregated", "adaptive"):
        m = run_online("stablelm-1.6b", policy=policy, pp=2, requests=10,
                       max_batch=2, max_new_tokens=8, chunk_tokens=16,
                       arrival_rate=8.0, seed=0, verbose=False,
                       prebuilt=prebuilt)
        keep = {
            "throughput_tok_s": m["throughput_tok_s"],
            "ttft_p50_s": m["ttft_p50_s"],
            "ttft_p99_s": m["ttft_p99_s"],
            "tpot_p50_s": m["tpot_p50_s"],
            "tpot_p99_s": m["tpot_p99_s"],
            "queue_mean_s": m["queue_mean_s"],
            "requests_finished": m["requests_finished"],
            "wall_s": m["wall_s"],
        }
        for k in [k for k in m if k.startswith("policy_")]:
            keep[k] = m[k]
        results[policy] = keep
        emit(f"serving/{policy}_ttft_p50", m["ttft_p50_s"] * 1e6,
             f"tok_per_s={m['throughput_tok_s']:.2f} "
             f"ttft_p99_ms={m['ttft_p99_s'] * 1e3:.0f} "
             f"tpot_p99_ms={m['tpot_p99_s'] * 1e3:.0f}")

    # -- overlapped CPU sampling on/off (docs/serving.md §Overlapped
    # sampling): same trace, sampling either on the host worker (the
    # logits hand-off frees the last stage at forward-end) or dispatched
    # synchronously inside emit_logits.  Token streams are identical;
    # the delta is the per-iteration sampling bubble the worker closes.
    ov = {}
    for overlap in (True, False):
        m = run_online("stablelm-1.6b", policy="chunked", pp=2, requests=10,
                       max_batch=2, max_new_tokens=8, chunk_tokens=16,
                       arrival_rate=8.0, seed=0, verbose=False,
                       overlap_sampling=overlap, prebuilt=prebuilt)
        ov["overlap_on" if overlap else "overlap_off"] = {
            "wall_s": m["wall_s"],
            "throughput_tok_s": m["throughput_tok_s"],
            "tpot_p50_s": m["tpot_p50_s"],
            "tpot_p99_s": m["tpot_p99_s"],
        }
    gain = (ov["overlap_off"]["wall_s"] / ov["overlap_on"]["wall_s"]
            if ov["overlap_on"]["wall_s"] else 0.0)
    ov["wall_gain"] = gain
    emit("serving/overlap_sampling", ov["overlap_on"]["wall_s"] * 1e6,
         f"wall_gain_vs_sync={gain:.3f}x "
         f"tok_per_s={ov['overlap_on']['throughput_tok_s']:.2f}")

    with open("BENCH_serving.json", "w") as f:
        json.dump({
            "workload": {"arch": "stablelm-1.6b-smoke", "requests": 10,
                         "arrival_rate_rps": 8.0, "max_new_tokens": 8,
                         "token_budget": 16, "pp": 2, "max_batch": 2},
            "policies": results,
            "overlap_sampling": ov,
        }, f, indent=2)
    emit("serving/bench_json", 0.0, "wrote BENCH_serving.json")


# ---------------------------------------------------------------------------
# Paged vs contiguous KV at equal cache budget (memory-pressure scenario)
# ---------------------------------------------------------------------------

def bench_paged() -> None:
    """Paged-vs-contiguous on the REAL engine, recorded in
    BENCH_paged.json.  Two stories:

    CAPACITY (equal cache budget): contiguous rows reserve a worst-case
    ``max_seq_len`` row per sequence, hard-capping concurrency at the
    row count; the paged layout holds sequences at their ACTUAL lengths
    in blocks, admits by block budget, and preempts (recompute) under
    decode growth — strictly more concurrency on a mixed-length trace,
    greedy outputs bit-identical.

    SPEED (equal composition): same max_batch, ample blocks — isolates
    what the paged-native hot path (in-kernel block gather + dirty-block
    write-back + bucket-capped table widths) costs per token against
    contiguous rows.  Reported as STEADY-STATE tok/s over the steps that
    paid no XLA compile (per-step ``engine.compile_stats()`` window), so
    the paged run's extra (batch, nb)-shape warmup compiles don't
    pollute the per-token comparison.  The kv_layout='auto' default rides on this ratio
    staying near 1x."""
    import json

    import jax

    from repro.configs import get_config
    from repro.core.engine import EngineConfig, SiPipeEngine
    from repro.core.sampling_params import SamplingParams
    from repro.core.sequence import SeqStatus
    from repro.models import ShardCtx, build_model

    ARCH, PP, MSL, BS = "stablelm-1.6b-smoke", 2, 64, 8
    ROWS = 2                     # contiguous: max_batch(1) x pp(2) rows
    SLOT_BUDGET = ROWS * MSL     # 128 KV slots for BOTH layouts
    N_NEW = 20                   # decode growth deep enough to hit the pool
    cfg = get_config(ARCH)
    model = build_model(cfg, ShardCtx.single())
    # key/seed 1: a trace with no greedy near-ties, so the pressured and
    # unpressured runs compare bit-exactly despite their different batch
    # compositions (composition shifts bf16 matmul rounding; see the
    # matched-composition parity note below)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    # mixed-length trace: a few long prompts among many short ones, with
    # enough decode growth to hit the block budget (preemption exercised)
    lens = [30, 6, 24, 4, 20, 8, 5, 26]
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
               for n in lens]

    def drive(layout, max_batch, kv_blocks=None):
        eng = SiPipeEngine(model, params, EngineConfig(
            pp_degree=PP, max_batch=max_batch, max_seq_len=MSL,
            n_samplers=2, prefill_chunk_tokens=16, scheduling_policy="chunked",
            kv_layout=layout, kv_block_size=BS, kv_blocks=kv_blocks))
        handles = {}
        for p in prompts:
            rid = eng.add_request(p, SamplingParams(greedy=True,
                                                    max_new_tokens=N_NEW))
            handles[rid] = eng.requests[rid].seq
        outs, max_conc, steps = {}, 0, []
        t0 = time.perf_counter()
        while eng.has_work:
            s0 = time.perf_counter()
            toks = 0
            for out in eng.step():
                toks += len(out.new_token_ids)
                if out.finished:
                    outs[out.request_id] = out.token_ids.to_list()
            steps.append((time.perf_counter() - s0, toks,
                          eng.compile_stats()["jit_executables"]))
            max_conc = max(max_conc, sum(
                1 for q in eng.scheduler.seqs.values()
                if q.status == SeqStatus.RUNNING))
        wall = time.perf_counter() - t0
        eng.shutdown()
        m = eng.metrics()
        # steady-state window: every step that paid NO compile (the
        # per-step jit-executable count is flat across it) — drain-end
        # batch-shrink compiles are excluded too, not just warmup
        final_c = steps[-1][2] if steps else 0
        tail = [s for i, s in enumerate(steps)
                if i and s[2] == steps[i - 1][2]]
        st_wall = sum(d for d, _, _ in tail)
        st_toks = sum(t for _, t, _ in tail)
        return {
            "outs": outs, "max_conc": max_conc, "wall": wall, "m": m,
            "victims": [rid for rid, q in handles.items() if q.preemptions],
            "compiles": final_c, "steady_steps": len(tail),
            "steady_tok_s": st_toks / st_wall if st_wall else 0.0,
        }

    # -- capacity story: equal budget — contiguous spends it as ROWS
    # worst-case rows; paged as SLOT_BUDGET // BS blocks.  The
    # unpressured reference (same max_batch, abundant blocks) isolates
    # what the pressure dynamics — block-deferred admission + preemption
    # — do to tokens: nothing.  (Greedy outputs across DIFFERENT
    # concurrency are not comparable even between two contiguous runs:
    # chunk composition shifts bf16 rounding enough to flip near-tie
    # argmaxes, so the cross-layout parity contract is
    # matched-composition — the policy x config matrix in
    # tests/test_paged_engine.py.)
    cap_c = drive("contiguous", max_batch=1)
    cap_p = drive("paged", max_batch=2, kv_blocks=SLOT_BUDGET // BS)
    ref_p = drive("paged", max_batch=2, kv_blocks=4 * SLOT_BUDGET // BS)
    assert ref_p["m"]["kv_preemptions"] == 0   # reference is unpressured
    match = cap_p["outs"] == ref_p["outs"]
    victims = cap_p["victims"]
    victims_match = all(cap_p["outs"][r] == ref_p["outs"][r]
                        for r in victims)
    ratio = cap_p["max_conc"] / max(cap_c["max_conc"], 1)
    emit("paged/contiguous_max_concurrent", cap_c["wall"] * 1e6,
         f"max_concurrent={cap_c['max_conc']} rows={ROWS}")
    emit("paged/paged_max_concurrent", cap_p["wall"] * 1e6,
         f"max_concurrent={cap_p['max_conc']} ratio={ratio:.2f}x "
         f"preemptions={cap_p['m']['kv_preemptions']} "
         f"outputs_match={match}")

    # -- speed story: equal composition (contiguous max_batch=2 vs the
    # ample-block paged run) — matched composition also means the token
    # streams must be bit-identical across layouts
    spd_c = drive("contiguous", max_batch=2)
    layouts_match = spd_c["outs"] == ref_p["outs"]
    steady_ratio = (spd_c["steady_tok_s"] / ref_p["steady_tok_s"]
                    if ref_p["steady_tok_s"] else float("inf"))
    emit("paged/steady_state_contiguous", 1e6 / max(
        spd_c["steady_tok_s"], 1e-9),
         f"tok_per_s={spd_c['steady_tok_s']:.2f} "
         f"compiles={spd_c['compiles']}")
    emit("paged/steady_state_paged", 1e6 / max(ref_p["steady_tok_s"], 1e-9),
         f"tok_per_s={ref_p['steady_tok_s']:.2f} "
         f"compiles={ref_p['compiles']} "
         f"wall_ratio_vs_contiguous={steady_ratio:.2f}x "
         f"table_widths={ref_p['m'].get('kv_table_widths')}")

    with open("BENCH_paged.json", "w") as f:
        json.dump({
            "workload": {"arch": ARCH, "pp": PP, "max_seq_len": MSL,
                         "block_size": BS, "kv_slot_budget": SLOT_BUDGET,
                         "prompt_lens": lens, "max_new_tokens": N_NEW,
                         "policy": "chunked"},
            "contiguous": {"max_concurrent": cap_c["max_conc"],
                           "wall_s": cap_c["wall"],
                           "throughput_tok_s": cap_c["m"]["throughput_tok_s"],
                           "jit_executables": cap_c["compiles"],
                           "rows": ROWS},
            "paged": {"max_concurrent": cap_p["max_conc"],
                      "wall_s": cap_p["wall"],
                      "throughput_tok_s": cap_p["m"]["throughput_tok_s"],
                      "jit_executables": cap_p["compiles"],
                      "blocks": SLOT_BUDGET // BS,
                      "preemptions": cap_p["m"]["kv_preemptions"],
                      "table_widths": cap_p["m"].get("kv_table_widths")},
            "concurrency_ratio": ratio,
            "wall_gain": cap_c["wall"] / cap_p["wall"],
            "outputs_match_unpressured": match,
            "preempted_requests": victims,
            "preempted_outputs_match": victims_match,
            "steady_state": {
                "definition": "tok/s over the steps that paid no XLA "
                              "compile (per-step compile_stats window)",
                "contiguous_b2": {
                    "tok_s": spd_c["steady_tok_s"],
                    "steps": spd_c["steady_steps"],
                    "jit_executables": spd_c["compiles"]},
                "paged_b2_ample": {
                    "tok_s": ref_p["steady_tok_s"],
                    "steps": ref_p["steady_steps"],
                    "jit_executables": ref_p["compiles"],
                    "table_widths": ref_p["m"].get("kv_table_widths")},
                "paged_over_contiguous_wall_ratio": steady_ratio,
                "outputs_bit_identical": layouts_match,
            },
            "note": "capacity target: concurrency ratio at equal cache "
                    "budget.  speed target: steady-state wall ratio near "
                    "1x at equal composition — the basis for the "
                    "kv_layout='auto' paged default; warmup compiles are "
                    "excluded via the per-step compile count window",
        }, f, indent=2)
    assert match, "memory pressure perturbed greedy outputs"
    # the per-victim check is the corruption canary: a preempted sequence
    # resumes by recomputing its full history, so its stream must be
    # bit-exact regardless of composition effects elsewhere
    assert victims_match, "a preempted sequence's resumed output diverged"
    assert cap_p["m"]["kv_preemptions"] > 0, "pressure never preempted"
    assert ratio >= 1.5, f"concurrency ratio {ratio:.2f} < 1.5"
    assert layouts_match, "equal-composition layouts diverged"
    emit("paged/bench_json", 0.0, "wrote BENCH_paged.json")


# ---------------------------------------------------------------------------
# Prefix caching + CoW forks (shared-prefix traffic on the real engine)
# ---------------------------------------------------------------------------

def bench_prefix() -> None:
    """Shared-prefix KV reuse priced on the real engine, recorded in
    BENCH_prefix.json.  Two stories:

    TTFT COLLAPSE: a warm request whose prompt shares its leading full
    blocks with a cached prefix prefills only the unshared tail — its
    TTFT drops to roughly tail/prompt of the cold TTFT.  Measured
    cold-vs-warm on the SAME engine after a shape-warmup run, so XLA
    compiles pollute neither number.

    SUBLINEAR BLOCKS: K concurrent requests over one shared prefix hold
    the prefix blocks ONCE (refcounted) plus per-request unique tails,
    not K full copies.  Peak live blocks are tracked per step
    (pin-only cached blocks excluded: they are reclaimable capacity,
    not working set) against the naive K * blocks_for(len) footprint.
    A parallel-sampling (n=K) request is priced the same way: one
    prompt, CoW-forked decode tails."""
    import json

    import jax

    from repro.configs import get_config
    from repro.core.engine import EngineConfig, SiPipeEngine
    from repro.core.sampling_params import SamplingParams
    from repro.models import ShardCtx, build_model

    ARCH, PP, MSL, BS, CHUNK, N_NEW = "stablelm-1.6b-smoke", 2, 64, 8, 8, 6
    BASE, TAIL, K = 48, 4, 4          # 6 shared full blocks + unique tails
    cfg = get_config(ARCH)
    model = build_model(cfg, ShardCtx.single())
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(2)

    def mk(n):
        return list(map(int, rng.integers(2, cfg.vocab_size, size=n)))

    base_a, base_b = mk(BASE), mk(BASE)
    eng = SiPipeEngine(model, params, EngineConfig(
        pp_degree=PP, max_batch=K, max_seq_len=MSL, n_samplers=2,
        prefill_chunk_tokens=CHUNK, scheduling_policy="chunked",
        kv_layout="paged", kv_block_size=BS))
    kvm = eng.kv_manager

    def drive(reqs):
        """Run to drain; returns (rids, peak live blocks)."""
        rids = [eng.add_request(p, sp) for p, sp in reqs]
        peak = 0
        while eng.has_work:
            eng.step()
            live = (kvm.n_blocks - kvm.alloc.free_blocks
                    - kvm.reclaimable_cached_blocks)
            peak = max(peak, live)
        return rids, peak

    def ttft(rid):
        return eng.metrics()["requests"][rid]["ttft_s"]

    sp = SamplingParams(greedy=True, max_new_tokens=N_NEW)
    drive([(base_a + mk(TAIL), sp)])          # shape warmup + seeds base_a
    [cold], _ = drive([(base_b + mk(TAIL), sp)])   # fresh prefix: cold
    warm_rids = []
    for _ in range(3):                        # warm: base_b is now cached
        [r], _ = drive([(base_b + mk(TAIL), sp)])
        warm_rids.append(r)
    cold_ttft = ttft(cold)
    warm_ttft = float(np.mean([ttft(r) for r in warm_rids]))
    emit("prefix/cold_ttft", cold_ttft * 1e6, f"prompt={BASE + TAIL}")
    emit("prefix/warm_ttft", warm_ttft * 1e6,
         f"ratio={warm_ttft / cold_ttft:.3f} cached_tokens={BASE}")

    # -- sublinear blocks: K concurrent shared-prefix requests
    naive = K * kvm.blocks_for(BASE + TAIL + N_NEW)
    reqs, shared_peak = drive([(base_b + mk(TAIL), sp) for _ in range(K)])
    emit("prefix/shared_blocks_peak", 0.0,
         f"peak={shared_peak} naive={naive} "
         f"ratio={shared_peak / naive:.2f}")
    # -- same shape via parallel sampling: one prompt, n=K fork tails
    [fr], fork_peak = drive([(base_a + mk(TAIL),
                              SamplingParams(greedy=True,
                                             max_new_tokens=N_NEW, n=K))])
    emit("prefix/fork_blocks_peak", 0.0,
         f"peak={fork_peak} naive={naive} ratio={fork_peak / naive:.2f}")

    m = eng.metrics()
    eng.shutdown()
    with open("BENCH_prefix.json", "w") as f:
        json.dump({
            "workload": {"arch": ARCH, "pp": PP, "max_seq_len": MSL,
                         "block_size": BS, "chunk_tokens": CHUNK,
                         "base_tokens": BASE, "tail_tokens": TAIL,
                         "max_new_tokens": N_NEW, "k": K,
                         "policy": "chunked"},
            "ttft": {"cold_s": cold_ttft, "warm_s": warm_ttft,
                     "warm_over_cold": warm_ttft / cold_ttft},
            "blocks": {"naive_k_times_full": naive,
                       "shared_prefix_peak": shared_peak,
                       "fork_n_peak": fork_peak,
                       "shared_over_naive": shared_peak / naive,
                       "fork_over_naive": fork_peak / naive},
            "counters": {k: v for k, v in m.items()
                         if k.startswith(("kv_prefix", "kv_cow",
                                          "kv_fork", "kv_blocks"))},
            "note": "warm TTFT gate < 0.5x cold: a cache-hit request "
                    "prefills only its unshared tail.  blocks gates "
                    "< 0.7x naive: K streams over one prefix hold the "
                    "shared blocks once (refcounted), unique tails per "
                    "stream — sublinear in K.",
        }, f, indent=2)
    assert m["kv_prefix_hits"] >= K + 3, "warm admissions missed the cache"
    assert warm_ttft < 0.5 * cold_ttft, \
        f"warm TTFT {warm_ttft:.4f}s not < 0.5x cold {cold_ttft:.4f}s"
    assert shared_peak < 0.7 * naive, \
        f"shared-prefix peak {shared_peak} not sublinear vs naive {naive}"
    assert fork_peak < 0.7 * naive, \
        f"fork peak {fork_peak} not sublinear vs naive {naive}"
    emit("prefix/bench_json", 0.0, "wrote BENCH_prefix.json")


# ---------------------------------------------------------------------------
# HTTP front-end: open-loop Poisson client over the fleet (docs/http.md)
# ---------------------------------------------------------------------------

def bench_http() -> None:
    """Open-loop Poisson clients against the REAL HTTP stack (server +
    admission + router + 2 engine replicas), recorded in BENCH_http.json.
    Three stories: CLIENT-side TTFT/TPOT percentiles measured over the
    wire (transport overhead included), router balance (routed counts +
    per-replica peak block occupancy stay bounded), and the 429 burst —
    a full admission queue rejects instantly with Retry-After while the
    held streams finish undisturbed."""
    import http.client
    import json
    import threading
    import time as _t

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.launch.serve import build_http_server
    from repro.models import ShardCtx, build_model

    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single())
    prebuilt = (cfg, model, model.init(jax.random.key(0)))
    rng = np.random.default_rng(0)
    N_REQ, RATE, N_NEW = 10, 4.0, 6

    def post_stream(addr, prompt, max_tokens, record=None):
        """One streamed completion; returns (status, token_count)."""
        conn = http.client.HTTPConnection(*addr, timeout=300)
        t0 = _t.monotonic()
        conn.request("POST", "/v1/completions", json.dumps(
            {"prompt": prompt, "max_tokens": max_tokens,
             "temperature": 0.0, "stream": True}),
            {"Content-Type": "application/json"})
        resp = conn.getresponse()
        if resp.status != 200:
            resp.read()
            conn.close()
            return resp.status, 0
        stamps = []
        while True:
            line = resp.readline()
            if not line:
                break
            if not line.startswith(b"data: ") or line == b"\n":
                continue
            if line.startswith(b"data: [DONE]"):
                break
            ev = json.loads(line[len(b"data: "):])
            if any(c["token_ids"] for c in ev["choices"]):
                stamps.append(_t.monotonic())
        conn.close()
        if record is not None and stamps:
            record["ttft"].append(stamps[0] - t0)
            if len(stamps) > 1:
                record["tpot"].extend(np.diff(stamps).tolist())
        return 200, len(stamps)

    # -- phase 1: Poisson open loop over 2 replicas -------------------------
    _, server = build_http_server(
        "stablelm-1.6b-smoke", replicas=2, pp=2, max_batch=2,
        max_seq_len=64, kv_layout="paged", block_size=8,
        max_queue=64, prebuilt=prebuilt)
    server.start()
    addr = server.address
    record = {"ttft": [], "tpot": []}
    rec_lock = threading.Lock()

    def client(delay, prompt):
        _t.sleep(delay)
        r = {"ttft": [], "tpot": []}
        status, n_tok = post_stream(addr, prompt, N_NEW, r)
        with rec_lock:
            record["ttft"] += r["ttft"]
            record["tpot"] += r["tpot"]
        assert status == 200 and n_tok == N_NEW, (status, n_tok)

    # warm both replicas first (jit compile) so the measured phase sees
    # steady-state service times; two concurrent requests spread by load
    warm = [threading.Thread(target=post_stream,
                             args=(addr, [5, 9, 13], 2)) for _ in range(2)]
    for t in warm:
        t.start()
    for t in warm:
        t.join()

    arrivals = np.cumsum(rng.exponential(1.0 / RATE, size=N_REQ))
    prompts = [list(map(int, rng.integers(2, cfg.vocab_size, size=n)))
               for n in rng.integers(4, 12, size=N_REQ)]
    t0 = _t.monotonic()
    threads = [threading.Thread(target=client, args=(a, p))
               for a, p in zip(arrivals, prompts)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = _t.monotonic() - t0
    routed = dict(server.router.routed)
    peaks = {r.name: r.peak_busy_blocks for r in server.router.replicas}
    adm = server.admission.snapshot()
    server.close()
    balance_routed = max(routed.values()) / max(1, min(routed.values()))
    balance_blocks = (max(peaks.values()) / max(1, min(peaks.values()))
                      if min(peaks.values()) else float("inf"))
    ttft = np.array(record["ttft"])
    tpot = np.array(record["tpot"]) if record["tpot"] else np.zeros(1)

    # -- phase 2: burst past tiny caps -> 429s, held stream undisturbed ----
    _, server = build_http_server(
        "stablelm-1.6b-smoke", replicas=1, pp=2, max_batch=2,
        max_seq_len=64, kv_layout="paged", block_size=8,
        max_queue=1, max_active=1, prebuilt=prebuilt)
    server.start()
    addr = server.address
    post_stream(addr, [5, 9, 13], 2)                  # warm the replica
    statuses = []
    st_lock = threading.Lock()

    def burst_client(prompt):
        status, n_tok = post_stream(addr, prompt, N_NEW)
        with st_lock:
            statuses.append((status, n_tok))

    burst = [threading.Thread(target=burst_client, args=(p,))
             for p in prompts[:6]]
    for t in burst:
        t.start()
    for t in burst:
        t.join()
    n_ok = sum(1 for s, _ in statuses if s == 200)
    n_429 = sum(1 for s, _ in statuses if s == 429)
    ok_complete = all(n == N_NEW for s, n in statuses if s == 200)
    server.close()

    with open("BENCH_http.json", "w") as f:
        json.dump({
            "workload": {"arch": "stablelm-1.6b-smoke", "replicas": 2,
                         "requests": N_REQ, "arrival_rate_rps": RATE,
                         "max_new_tokens": N_NEW, "pp": 2, "max_batch": 2},
            "client_latency": {
                "ttft_p50_s": float(np.percentile(ttft, 50)),
                "ttft_p99_s": float(np.percentile(ttft, 99)),
                "tpot_p50_s": float(np.percentile(tpot, 50)),
                "tpot_p99_s": float(np.percentile(tpot, 99)),
                "wall_s": wall,
            },
            "router_balance": {
                "routed": routed,
                "peak_busy_blocks": peaks,
                "routed_max_over_min": balance_routed,
                "blocks_max_over_min": balance_blocks,
            },
            "admission": {**adm, "rejected_rate":
                          adm["admission_rejected_total"]
                          / max(1, adm["admission_admitted_total"]
                                + adm["admission_rejected_total"])},
            "burst": {"clients": len(burst), "ok": n_ok, "rejected": n_429,
                      "ok_streams_complete": ok_complete},
            "note": "client-side latencies over a real socket (SSE); "
                    "routed/blocks ratios gate the router's spread; the "
                    "burst phase gates 429-on-full with live streams "
                    "finishing token-complete.",
        }, f, indent=2)
    assert all(v > 0 for v in routed.values()), \
        f"router starved a replica: {routed}"
    assert balance_routed <= 4.0, f"routed imbalance {routed}"
    assert n_429 > 0, "burst past caps produced no 429"
    assert ok_complete, "a 429 burst perturbed an admitted stream"
    emit("http/poisson_ttft_p50", float(np.percentile(ttft, 50)) * 1e6,
         f"ttft_p99_ms={float(np.percentile(ttft, 99)) * 1e3:.0f} "
         f"routed={routed} burst_429={n_429}/{len(burst)}")
    emit("http/bench_json", 0.0, "wrote BENCH_http.json")


# ---------------------------------------------------------------------------
# Hybrid online/offline serving (docs/hybrid.md)
# ---------------------------------------------------------------------------

def bench_hybrid() -> None:
    """Selling pipeline slack to an offline tier, recorded in
    BENCH_hybrid.json.  Two gates:

    SLACK SELLS: on the REAL engine (paged KV), an online Poisson trace
    with an offline backlog enqueued produces offline tokens (> 0 tok/s)
    and every request of both tiers completes — the bubbles carried paid
    work.

    ONLINE UNDISTURBED: in the deterministic virtual-time simulator
    (same real scheduler, pipeline timing model), adding a SATURATING
    offline backlog leaves the online tier's token count bit-identical
    and its virtual-time TPOT p99 within 5% of the online-only run.
    The engine-level bit-exactness of the online sub-trace itself is
    a unit property (tests/test_hybrid.py); this bench prices it.
    """
    import json

    import jax

    from benchmarks.pp_sim import simulate_mixed_workload
    from repro.configs import get_config
    from repro.launch.serve import run_online
    from repro.models import ShardCtx, build_model

    # -- deterministic virtual-time comparison (simulator) ----------------
    ONLINE_LENS = [48, 40, 12, 8, 32, 16, 24, 20]
    OFFLINE_LENS = [24] * 12          # saturating backlog
    sim = {}
    for pol, factor in (("chunked", 1), ("disaggregated", 4)):
        kw = dict(p=2, max_batch=2, token_budget=16,
                  prompt_lens=ONLINE_LENS, max_new_tokens=12,
                  # bubble-dominated regime (the paper's testbed): the
                  # per-iteration fixed cost dwarfs the marginal token
                  t_token=1e-6, t_fixed=5e-4, policy=pol)
        base = simulate_mixed_workload(**kw)
        hyb = simulate_mixed_workload(
            offline_prompt_lens=OFFLINE_LENS, offline_max_new_tokens=16,
            decode_enlarge_factor=factor, **kw)
        degr = (hyb.online_tpot_p99_s / base.online_tpot_p99_s - 1.0
                if base.online_tpot_p99_s else 0.0)
        sim[pol] = {
            "online_tokens_base": base.online_tokens,
            "online_tokens_hybrid": hyb.online_tokens,
            "offline_tokens": hyb.offline_tokens,
            "online_tpot_p99_base_s": base.online_tpot_p99_s,
            "online_tpot_p99_hybrid_s": hyb.online_tpot_p99_s,
            "online_tpot_p99_degradation": degr,
            "decode_enlarge_factor": factor,
        }
        emit(f"hybrid/sim_{pol}_tpot_p99", hyb.online_tpot_p99_s * 1e6,
             f"degradation={degr * 100:.2f}% "
             f"offline_tokens={hyb.offline_tokens}")
        assert hyb.online_tokens == base.online_tokens, \
            (pol, base.online_tokens, hyb.online_tokens)
        assert hyb.offline_tokens > 0, f"{pol}: no slack sold in sim"
        assert degr <= 0.05, \
            f"{pol}: online TPOT p99 degraded {degr * 100:.1f}% > 5%"

    # -- real engine: offline tok/s under online load ---------------------
    cfg = get_config("stablelm-1.6b-smoke")
    model = build_model(cfg, ShardCtx.single())
    prebuilt = (cfg, model, model.init(jax.random.key(0)))
    m = run_online("stablelm-1.6b", policy="chunked", pp=2, requests=8,
                   max_batch=2, max_new_tokens=8, chunk_tokens=16,
                   kv_layout="paged", arrival_rate=8.0,
                   offline_requests=4, seed=0, verbose=False,
                   prebuilt=prebuilt)
    off_tok_s = m["offline_streamed_tokens"] / m["wall_s"]
    real = {
        "wall_s": m["wall_s"],
        "online_throughput_tok_s": m["throughput_tok_s"],
        "offline_tok_s": off_tok_s,
        "offline_finished": m["offline_finished"],
        "offline_streamed_tokens": m["offline_streamed_tokens"],
        "online_tpot_p99_s": m["tpot_p99_s"],
        "slack_seats_seen": m["slack_seats_seen"],
        "slack_tokens_sold": m["slack_tokens_sold"],
        "offline_preemptions": m["offline_preemptions"],
    }
    emit("hybrid/real_offline_tok_s", 1e6 / max(off_tok_s, 1e-9),
         f"offline_tok_s={off_tok_s:.2f} "
         f"slack_sold={m['slack_tokens_sold']} "
         f"offline_preemptions={m['offline_preemptions']}")

    # -- real engine: enlarged decode batches (disaggregated + ladder) ----
    me = run_online("stablelm-1.6b", policy="disaggregated", pp=2,
                    requests=4, max_batch=2, max_new_tokens=8,
                    chunk_tokens=16, kv_layout="paged", arrival_rate=8.0,
                    offline_requests=6, decode_enlarge_factor=2,
                    seed=0, verbose=False, prebuilt=prebuilt)
    enlarged = {
        "enlarged_decode_iters": me["policy_enlarged_decode_iters"],
        "decode_enlarge_factor": me["policy_decode_enlarge_factor"],
        "jit_executables": me["jit_executables"],
        "offline_streamed_tokens": me["offline_streamed_tokens"],
        "slack_tokens_sold": me["slack_tokens_sold"],
    }
    emit("hybrid/enlarged_decode", float(me["policy_enlarged_decode_iters"]),
         f"factor={me['policy_decode_enlarge_factor']} "
         f"jit_executables={me['jit_executables']}")

    with open("BENCH_hybrid.json", "w") as f:
        json.dump({
            "workload": {"arch": "stablelm-1.6b-smoke", "pp": 2,
                         "max_batch": 2, "token_budget": 16,
                         "online_requests": 8, "offline_requests": 4,
                         "arrival_rate_rps": 8.0},
            "simulated": sim,
            "real_engine": real,
            "enlarged_decode": enlarged,
            "gates": {
                "offline_tok_s_gt_0": off_tok_s > 0,
                "online_tpot_p99_degradation_max":
                    max(s["online_tpot_p99_degradation"]
                        for s in sim.values()),
                "online_tpot_p99_degradation_limit": 0.05,
            },
            "note": "simulated degradation is the deterministic gate "
                    "(virtual time, same scheduler); the real-engine "
                    "numbers price slack sale + the enlargement ladder "
                    "at CPU scale.",
        }, f, indent=2)
    assert off_tok_s > 0, "real engine sold no offline tokens"
    assert m["offline_finished"] == 4
    assert me["offline_streamed_tokens"] > 0
    emit("hybrid/bench_json", 0.0, "wrote BENCH_hybrid.json")


# ---------------------------------------------------------------------------
# Real-engine end-to-end (CPU-scale, structural validation)
# ---------------------------------------------------------------------------

def bench_engine_e2e() -> None:
    from repro.launch.serve import run as serve_run

    for engine in ("naive", "sipipe"):
        m = serve_run("stablelm-1.6b", engine=engine, pp=2, requests=4,
                      max_batch=2, max_new_tokens=5, n_samplers=2,
                      verbose=False)
        emit(f"engine_e2e/{engine}", 1e6 / max(m["throughput_tok_s"], 1e-9),
             f"tok_per_s={m['throughput_tok_s']:.2f} "
             f"tpot_ms={m['tpot_mean_s'] * 1e3:.0f}")


# ---------------------------------------------------------------------------
# Pallas kernels (interpret-mode; TPU-target timing is out of scope here)
# ---------------------------------------------------------------------------

def bench_kernels() -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    ks = jax.random.split(jax.random.key(0), 3)
    b, s, h, kv, hd = 1, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32).astype(jnp.bfloat16)

    def krn():
        ops.flash_attention_bshd(q, k, v, q_block=128,
                                 kv_block=128).block_until_ready()

    t = _time(krn, reps=2)
    emit("kernels/flash_attention_interpret_512", t * 1e6,
         "interpret-mode; allclose-validated vs ref in tests")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    print("name,us_per_call,derived")
    measured: Dict[str, float] = {}
    if want("sampler"):
        measured.update(bench_sampler())
    if want("sat"):
        bench_sat()
    if want("tsem"):
        bench_tsem()
    if want("throughput"):
        bench_throughput(measured)
    if want("bubbles"):
        bench_bubbles(measured)
    if want("batch"):
        bench_batch_sweep(measured)
    if want("tpot"):
        bench_tpot_cdf(measured)
    if want("scalability"):
        bench_scalability(measured)
    if want("ablation"):
        bench_ablation(measured)
    if want("chunked"):
        bench_chunked_prefill()
    if want("serving"):
        bench_serving()
    if want("paged"):
        bench_paged()
    if want("prefix"):
        bench_prefix()
    if want("http"):
        bench_http()
    if want("hybrid"):
        bench_hybrid()
    if want("engine"):
        bench_engine_e2e()
    if want("kernels"):
        bench_kernels()


if __name__ == "__main__":
    main()
