"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Component latencies used by
the simulator are MEASURED from this repo's real implementations (sampler,
SAT channels, TSEM executors); the pipeline-level reproductions of the
paper's H100 figures come from the calibrated discrete-event simulator
(benchmarks/pp_sim.py) since this container exposes one CPU device.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only sampler,ablation
"""
from __future__ import annotations

import argparse
import time
from typing import Callable, Dict, List

import numpy as np

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _time(fn: Callable, *args, reps: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args)
    return (time.perf_counter() - t0) / reps


# ---------------------------------------------------------------------------
# §5.1 — column-wise CPU sampling microbenchmark (real measurement)
# ---------------------------------------------------------------------------

def bench_sampler() -> Dict[str, float]:
    """CPU sampling cost — incremental vs naive recompute at serving scale
    (V ~ 152k, B up to 256) and realistic history depth (512 generated +
    prompt tokens, where the naive path's per-iteration recompute hurts)."""
    from repro.core.sampler import ColumnWiseSampler, NaiveSampler
    from repro.core.sampling_params import SamplingParams

    out = {}
    params = SamplingParams(temperature=0.8, top_k=50,
                            frequency_penalty=0.5, presence_penalty=0.2)
    HIST = 512
    for v, b in ((151_936, 64), (151_936, 256), (32_000, 256)):
        rng = np.random.default_rng(0)
        z = rng.normal(size=(b, v)).astype(np.float32)
        cw = ColumnWiseSampler(v, b, max_len=4096)
        nv = NaiveSampler(v)
        # seed realistic histories: incremental folds them once; naive will
        # recompute them on every subsequent iteration
        hist = [rng.integers(0, v, HIST) for _ in range(b)]
        cw.seed_prompt(0, b, list(range(b)), hist)
        nv.history[0] = [h.astype(np.int64) for h in hist]
        t_cw = _time(lambda: cw.sample(z, params), reps=3)
        t_nv = _time(lambda: nv.sample(z, params), reps=3)
        emit(f"sampler/incremental_v{v}_b{b}", t_cw * 1e6,
             f"hist={HIST} speedup_vs_naive={t_nv / t_cw:.2f}x")
        emit(f"sampler/naive_recompute_v{v}_b{b}", t_nv * 1e6, f"hist={HIST}")
        # penalty-path isolation (greedy: no softmax/top-k in either path)
        g = SamplingParams(greedy=True, frequency_penalty=0.5,
                           presence_penalty=0.2)
        t_cwp = _time(lambda: cw.sample(z, g), reps=3)
        t_nvp = _time(lambda: nv.sample(z, g), reps=3)
        emit(f"sampler/penalty_only_incremental_v{v}_b{b}", t_cwp * 1e6,
             f"speedup_vs_naive={t_nvp / t_cwp:.2f}x")
        # transposed-shard ingestion path (§5.1(3))
        zt = np.ascontiguousarray(z.T)
        cw_t = ColumnWiseSampler(v, b, max_len=4096)
        t_cwt = _time(lambda: cw_t.sample(zt, params, transposed=True), reps=3)
        emit(f"sampler/transposed_shards_v{v}_b{b}", t_cwt * 1e6,
             "zero-gather TP-shard concat path")
        out[f"cw_{v}_{b}"] = t_cw
    return out


# ---------------------------------------------------------------------------
# §5.3 — SAT vs structure-unaware transmission (real channel objects)
# ---------------------------------------------------------------------------

def bench_sat() -> Dict[str, float]:
    from repro.core.sat import StructureAwareChannel, StructureUnawareChannel

    b, d = 256, 8192
    tensors = {"hidden": np.zeros((b, d), np.float16),
               "residual": np.zeros((b, d), np.float16)}
    round_lat = 0.0007  # 0.7 ms per synchronous round (RDMA-scale, §5.3)

    def unaware_iter():
        ch = StructureUnawareChannel(round_lat)
        ch.send(tensors)
        ch.recv()

    aware = StructureAwareChannel(round_lat)
    aware.send(tensors)
    aware.recv()  # capture iteration

    def aware_iter():
        aware.send(tensors)
        aware.recv()

    t_u = _time(unaware_iter, reps=3)
    t_a = _time(aware_iter, reps=3)
    emit("sat/structure_unaware_per_edge", t_u * 1e6, "rounds=4")
    emit("sat/structure_aware_per_edge", t_a * 1e6,
         f"rounds=1 speedup={t_u / t_a:.2f}x")
    return {"t_edge_unaware": t_u, "t_edge_aware": t_a}


# ---------------------------------------------------------------------------
# §5.2 — TSEM overlap (real executor threads)
# ---------------------------------------------------------------------------

def bench_tsem() -> None:
    from repro.core.scheduler import SchedulingOutput
    from repro.core.tsem import SynchronousExecutor, TokenSafeExecutor

    PREP = EXEC = 0.004
    N = 24

    def prepare(s, bufs):
        time.sleep(PREP)

    def execute(d, bufs):
        time.sleep(EXEC)
        return True

    def sched(it):
        return SchedulingOutput(it, 0, [0], np.zeros(1, np.int32),
                                np.zeros(1, np.int32), False)

    sync = SynchronousExecutor(prepare, execute)
    t0 = time.perf_counter()
    for it in range(N):
        sync.run(sched(it))
    t_sync = (time.perf_counter() - t0) / N

    ex = TokenSafeExecutor(prepare, execute)
    ex.start()
    t0 = time.perf_counter()
    for it in range(N):
        ex.submit(sched(it))
    for it in range(N):
        ex.result(it, timeout=30)
    t_tsem = (time.perf_counter() - t0) / N
    ex.stop()
    emit("tsem/synchronous_per_iter", t_sync * 1e6, "prep+exec serialized")
    emit("tsem/token_safe_per_iter", t_tsem * 1e6,
         f"overlap_gain={t_sync / t_tsem:.2f}x")


# ---------------------------------------------------------------------------
# Fig. 1 / 8 — throughput across engines and parallel configs (simulator)
# ---------------------------------------------------------------------------

PAPER_SAMPLE_S = 0.0015 * 48  # the paper's engineered samplers finish a
# microbatch in 1-2 ms *per sampler*; expressed pre-pool-division


def bench_throughput(measured: Dict[str, float]) -> None:
    """Two calibrations of the async sampling latency:
      paper  — the paper's engineered C-level samplers (1.5 ms pooled)
      meas   — this repo's numpy sampler (single-core full batch / pool)
    """
    from benchmarks.pp_sim import paper_costs, simulate

    t_meas = measured.get("cw_151936_256", 0.10)
    for model in ("qwen-2.5-72b", "llama-3.1-70b", "mixtral-8x7b",
                  "deepseek-v3", "llama-3.1-405b"):
        for p in (2, 4):
            base = simulate(paper_costs(model, p,
                                        measured_cpu_sample_s=PAPER_SAMPLE_S),
                            sipipe=False)
            emit(f"throughput/{model}_p{p}_baseline",
                 1e6 / base.tokens_per_s, f"iters_per_s={base.tokens_per_s:.1f}")
            for calib, t_s in (("paper", PAPER_SAMPLE_S), ("meas", t_meas)):
                sip = simulate(paper_costs(model, p, measured_cpu_sample_s=t_s,
                                           sipipe=True), sipipe=True)
                emit(f"throughput/{model}_p{p}_sipipe_{calib}",
                     1e6 / sip.tokens_per_s,
                     f"iters_per_s={sip.tokens_per_s:.1f} "
                     f"speedup={sip.tokens_per_s / base.tokens_per_s:.2f}x")


# ---------------------------------------------------------------------------
# Fig. 3 / 4 / 11 — per-stage bubble anatomy (simulator timelines)
# ---------------------------------------------------------------------------

def bench_bubbles(measured: Dict[str, float]) -> None:
    from benchmarks.pp_sim import paper_costs, simulate

    t_cpu = PAPER_SAMPLE_S
    for name, sip in (("baseline", False), ("sipipe", True)):
        r = simulate(paper_costs("deepseek-v3", 4,
                                 measured_cpu_sample_s=t_cpu, sipipe=sip),
                     sipipe=sip)
        fr = " ".join(f"s{i}={f:.2f}" for i, f in enumerate(r.bubble_fracs))
        emit(f"bubbles/deepseek-v3_p4_{name}", r.tpot_mean * 1e6,
             f"bubble_fracs: {fr}")


# ---------------------------------------------------------------------------
# Fig. 9 — batch size sweep  /  Fig. 10 — GPU-count scalability
# ---------------------------------------------------------------------------

def bench_batch_sweep(measured: Dict[str, float]) -> None:
    import dataclasses as dc

    from benchmarks.pp_sim import paper_costs, simulate

    t_cpu = PAPER_SAMPLE_S
    for bs_scale, tag in ((0.5, "b256"), (1.0, "b512"), (2.0, "b1024")):
        for sip in (False, True):
            c = paper_costs("qwen-2.5-72b", 4, measured_cpu_sample_s=t_cpu,
                            sipipe=sip)
            c = dc.replace(c, t_fwd=c.t_fwd * (0.6 + 0.4 * bs_scale),
                           t_sample_stage=c.t_sample_stage * bs_scale,
                           t_sample_async=c.t_sample_async * bs_scale)
            r = simulate(c, sipipe=sip)
            emit(f"batch_sweep/qwen72b_{tag}_{'sipipe' if sip else 'baseline'}",
                 1e6 / r.tokens_per_s, f"iters_per_s={r.tokens_per_s:.1f}")


def bench_scalability(measured: Dict[str, float]) -> None:
    from benchmarks.pp_sim import paper_costs, simulate

    t_cpu = PAPER_SAMPLE_S
    tput = {}
    for p in (2, 4, 8):
        for sip in (False, True):
            r = simulate(paper_costs("llama-3.1-70b", p,
                                     measured_cpu_sample_s=t_cpu, sipipe=sip),
                         sipipe=sip)
            key = "sipipe" if sip else "baseline"
            tput[(key, p)] = r.tokens_per_s
            scale = r.tokens_per_s / tput.get((key, p // 2), r.tokens_per_s)
            emit(f"scalability/llama70b_p{p}_{key}", 1e6 / r.tokens_per_s,
                 f"iters_per_s={r.tokens_per_s:.1f} scale_vs_half={scale:.2f}x")


# ---------------------------------------------------------------------------
# Fig. 12 / 13 — TPOT distribution (per-iteration latency percentiles)
# ---------------------------------------------------------------------------

def bench_tpot_cdf(measured: Dict[str, float]) -> None:
    from benchmarks.pp_sim import paper_costs, simulate

    t_cpu = PAPER_SAMPLE_S
    for model, p in (("qwen-2.5-72b", 4), ("deepseek-v3", 4)):
        for sip in (False, True):
            r = simulate(paper_costs(model, p, measured_cpu_sample_s=t_cpu,
                                     sipipe=sip), sipipe=sip, n_iters=128)
            ts = np.array(r.iteration_times)
            pct = {q: float(np.percentile(ts, q)) for q in (50, 90, 99)}
            emit(f"tpot/{model}_p{p}_{'sipipe' if sip else 'baseline'}",
                 r.tpot_mean * 1e6,
                 f"p50={pct[50]*1e3:.1f}ms p90={pct[90]*1e3:.1f}ms "
                 f"p99={pct[99]*1e3:.1f}ms")


# ---------------------------------------------------------------------------
# Fig. 16 — per-component ablation
# ---------------------------------------------------------------------------

def bench_ablation(measured: Dict[str, float]) -> None:
    """Reproduces Fig. 16's component ordering under the paper's sampler
    calibration (bench_throughput reports the measured calibration)."""
    from benchmarks.pp_sim import ablation_variants, simulate_variant

    for model in ("qwen-2.5-72b", "mixtral-8x7b", "deepseek-v3"):
        variants = ablation_variants(model, 4, PAPER_SAMPLE_S)
        base_tput = None
        for name, (costs, mode) in variants.items():
            r = simulate_variant(costs, mode)
            if base_tput is None:
                base_tput = r.tokens_per_s
            emit(f"ablation/{model}_{name}", 1e6 / r.tokens_per_s,
                 f"gain_vs_baseline={r.tokens_per_s / base_tput:.2f}x")


# ---------------------------------------------------------------------------
# Chunked prefill vs monolithic prefill on a mixed long-prompt workload
# ---------------------------------------------------------------------------

def bench_chunked_prefill() -> None:
    """Steady-state slot occupancy + bubble anatomy under a mixed
    long-prompt/decode workload, driven through the REAL scheduler
    (chunked vs monolithic whole-prompt prefill)."""
    from benchmarks.pp_sim import simulate_mixed_workload

    prompts = [200, 8, 150, 6, 180, 10, 90, 120, 5, 160, 7, 140]
    for p in (2, 4):
        results = {}
        for chunked in (False, True):
            r = simulate_mixed_workload(
                p=p, max_batch=4, token_budget=32, prompt_lens=prompts,
                max_new_tokens=24, chunked=chunked)
            results[chunked] = r
            name = "chunked" if chunked else "monolithic"
            emit(f"chunked_prefill/p{p}_{name}", r.wall_s * 1e6,
                 f"occupancy={r.occupancy:.3f} bubble_ticks={r.bubble_ticks} "
                 f"bubble_frac={max(r.bubble_fracs):.3f} "
                 f"prefill_block_ms={r.prefill_block_s * 1e3:.1f}")
        gain = results[False].wall_s / results[True].wall_s
        emit(f"chunked_prefill/p{p}_speedup", 0.0,
             f"wall_gain={gain:.2f}x occupancy "
             f"{results[False].occupancy:.3f}->{results[True].occupancy:.3f}")


# ---------------------------------------------------------------------------
# Real-engine end-to-end (CPU-scale, structural validation)
# ---------------------------------------------------------------------------

def bench_engine_e2e() -> None:
    from repro.launch.serve import run as serve_run

    for engine in ("naive", "sipipe"):
        m = serve_run("stablelm-1.6b", engine=engine, pp=2, requests=4,
                      max_batch=2, max_new_tokens=5, n_samplers=2,
                      verbose=False)
        emit(f"engine_e2e/{engine}", 1e6 / max(m["throughput_tok_s"], 1e-9),
             f"tok_per_s={m['throughput_tok_s']:.2f} "
             f"tpot_ms={m['tpot_mean_s'] * 1e3:.0f}")


# ---------------------------------------------------------------------------
# Pallas kernels (interpret-mode; TPU-target timing is out of scope here)
# ---------------------------------------------------------------------------

def bench_kernels() -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    ks = jax.random.split(jax.random.key(0), 3)
    b, s, h, kv, hd = 1, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32).astype(jnp.bfloat16)

    def krn():
        ops.flash_attention_bshd(q, k, v, q_block=128,
                                 kv_block=128).block_until_ready()

    t = _time(krn, reps=2)
    emit("kernels/flash_attention_interpret_512", t * 1e6,
         "interpret-mode; allclose-validated vs ref in tests")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    print("name,us_per_call,derived")
    measured: Dict[str, float] = {}
    if want("sampler"):
        measured.update(bench_sampler())
    if want("sat"):
        bench_sat()
    if want("tsem"):
        bench_tsem()
    if want("throughput"):
        bench_throughput(measured)
    if want("bubbles"):
        bench_bubbles(measured)
    if want("batch"):
        bench_batch_sweep(measured)
    if want("tpot"):
        bench_tpot_cdf(measured)
    if want("scalability"):
        bench_scalability(measured)
    if want("ablation"):
        bench_ablation(measured)
    if want("chunked"):
        bench_chunked_prefill()
    if want("engine"):
        bench_engine_e2e()
    if want("kernels"):
        bench_kernels()


if __name__ == "__main__":
    main()
