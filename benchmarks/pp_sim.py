"""Discrete-event pipeline simulator, calibrated with measured component
latencies from this repo's real implementations.

Why a simulator: this container has one CPU, so engine-level wall-clock
cannot exhibit H100-scale overlap.  The simulator reproduces the paper's
figures from first principles: each (stage, iteration, microbatch) event
respects the same dependencies the real engines enforce —

  stage s, microbatch m, iteration n starts when:
    (a) stage s is free,
    (b) stage s-1 finished (m, n)            [hidden-state dependency]
    (c) s == 0: sampling of (m, n-1) done    [autoregressive dependency]

Baseline (vLLM-like PP) costs, from the paper's measurements (§3.1):
  stage busy   = t_prep + t_fwd              (prep on the critical path)
  last stage  += t_sample_gpu                (in-stage sampling)
  edge latency = t_meta + t_xfer             (sync structure-unaware send)

SiPipe costs:
  stage busy   = max(t_fwd, t_prep)          (TSEM overlaps prep)
  sampling     = async on CPUs, latency t_sample_cpu, off the stage;
                 gates only dependency (c)
  edge latency = t_xfer_async                (SAT: pre-posted receives)

Calibration: t_sample_cpu is *measured* from ColumnWiseSampler (and the
baseline's t_sample_gpu share from the paper's 22–40%% last-stage excess);
t_prep is the paper's 12–19%% share; t_meta its 1.4–2.6 ms; t_xfer 1–2 ms.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class PipeCosts:
    p: int                      # pipeline stages
    t_fwd: float                # per-stage forward seconds
    t_prep: float               # input preparation seconds
    t_sample_stage: float       # in-stage sampling (baseline last stage)
    t_sample_async: float       # async CPU sampling (SiPipe)
    t_edge: float               # inter-stage transfer latency
    fwd_jitter: float = 0.0     # +- fractional per-stage variation (Obs. 3)

    def stage_time(self, s: int, overlap: bool, sampling_async: bool) -> float:
        base = max(self.t_fwd, self.t_prep) if overlap else self.t_fwd + self.t_prep
        if self.fwd_jitter:
            # deterministic alternating jitter models the 3-7% std-dev
            base *= 1.0 + self.fwd_jitter * (1 if s % 2 else -1)
        if s == self.p - 1 and not sampling_async:
            base += self.t_sample_stage   # in-stage sampling (baseline)
        return base


@dataclasses.dataclass
class SimResult:
    iters_done: int
    wall_s: float
    stage_busy: List[float]
    iteration_times: List[float]

    @property
    def tokens_per_s(self) -> float:
        return self.iters_done / self.wall_s if self.wall_s else 0.0

    @property
    def bubble_fracs(self) -> List[float]:
        return [max(0.0, 1 - b / self.wall_s) for b in self.stage_busy]

    @property
    def tpot_mean(self) -> float:
        return (sum(self.iteration_times) / len(self.iteration_times)
                if self.iteration_times else 0.0)


def simulate(costs: PipeCosts, *, sipipe: Optional[bool] = None,
             overlap: Optional[bool] = None,
             sampling_async: Optional[bool] = None,
             n_iters: int = 64, n_micro: Optional[int] = None) -> SimResult:
    """Event-driven simulation of ``n_iters`` decode iterations for each of
    ``n_micro`` (default p) in-flight microbatches.

    ``overlap``        — TSEM: prep hidden under the forward
    ``sampling_async`` — CPU sampling off the stage (gates only the next
                         iteration of the same microbatch)
    ``sipipe``         — shorthand setting both.
    """
    if sipipe is not None:
        overlap = sampling_async = sipipe
    p = costs.p
    m_count = n_micro or p
    stage_free = [0.0] * p
    stage_busy = [0.0] * p
    stage_done: List[Dict[Tuple[int, int], float]] = [dict() for _ in range(p)]
    sample_done: Dict[Tuple[int, int], float] = {}
    iter_finish: Dict[Tuple[int, int], float] = {}

    for n in range(n_iters):
        for m in range(m_count):
            t_ready = 0.0 if n == 0 else sample_done[(m, n - 1)]
            for s in range(p):
                dep = stage_done[s - 1][(m, n)] + costs.t_edge if s else t_ready
                start = max(stage_free[s], dep)
                dur = costs.stage_time(s, overlap, sampling_async)
                end = start + dur
                stage_free[s] = end
                stage_busy[s] += dur
                stage_done[s][(m, n)] = end
            last = stage_done[p - 1][(m, n)]
            sample_done[(m, n)] = last + (
                costs.t_sample_async if sampling_async else 0.0)
            iter_finish[(m, n)] = sample_done[(m, n)]

    wall = max(iter_finish.values())
    itimes = []
    for m in range(m_count):
        for n in range(1, n_iters):
            itimes.append(iter_finish[(m, n)] - iter_finish[(m, n - 1)])
    return SimResult(n_iters * m_count, wall, stage_busy, itimes)


# ---------------------------------------------------------------------------
# Paper-shaped configurations
# ---------------------------------------------------------------------------

SAMPLER_POOL = 48  # CPU sampler processes (paper testbed: 192-core hosts,
                   # ~8 cores pinned to input prep, the rest to sampling;
                   # each sampler handles a column slice of the batch)


def paper_costs(model: str, p: int, *, measured_cpu_sample_s: float,
                sipipe: bool = False) -> PipeCosts:
    """Per-model stage costs shaped on the paper's H100 measurements.

    ``measured_cpu_sample_s`` is this repo's single-core ColumnWiseSampler
    latency for the full batch; the pool of SAMPLER_POOL samplers splits
    batch columns, so effective async latency divides by the pool size.
    """
    # total forward time per iteration (all stages), H100-ish
    total_fwd = {
        "llama-3.1-70b": 0.050, "qwen-2.5-72b": 0.052, "mixtral-8x7b": 0.022,
        "deepseek-v3": 0.120, "deepseek-v2.5": 0.085, "llama-3.1-405b": 0.160,
    }[model]
    t_fwd = total_fwd / p
    t_prep = 0.16 * t_fwd / (1 - 0.16)         # 12-19% of the stage (Obs. 2)
    t_sample_stage = 0.30 * t_fwd              # 22-40% last-stage excess (Obs. 1)
    return PipeCosts(
        p=p, t_fwd=t_fwd, t_prep=t_prep,
        t_sample_stage=t_sample_stage,
        t_sample_async=measured_cpu_sample_s / SAMPLER_POOL,
        t_edge=(0.0001 if sipipe else 0.0020 + 0.0015),  # SAT vs 2-round sync
        fwd_jitter=0.05,
    )


def ablation_variants(model: str, p: int, measured_cpu_sample_s: float):
    """Incremental feature stack for the Fig.16-style ablation.  The async
    sampling latency is the pooled one (paper_costs divides by the pool)."""
    base = paper_costs(model, p, measured_cpu_sample_s=measured_cpu_sample_s)
    plus_sampling = dataclasses.replace(base, t_sample_stage=0.0)
    plus_tsem = plus_sampling  # TSEM handled by the sipipe stage_time path
    plus_sat = dataclasses.replace(plus_tsem, t_edge=0.0001)
    return {
        "baseline": (base, False),
        "+cpu-sampling": (plus_sampling, "sampling-only"),
        "+tsem": (plus_tsem, "tsem"),
        "+sat": (plus_sat, True),
    }


# ---------------------------------------------------------------------------
# Chunked-prefill vs monolithic-prefill workload simulation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MixedWorkloadResult:
    """Occupancy/bubble anatomy of a mixed long-prompt + decode workload."""

    iterations: int
    wall_s: float
    tokens_total: int
    stage_busy: List[float]
    occupancy: float          # mean fraction of the token budget carried
    bubble_ticks: int         # (stage, iteration) events where a stage idled
    prefill_block_s: float    # wall time spent in pipeline-blocking prefills
    iteration_tokens: List[int]
    # hybrid tier accounting (docs/hybrid.md): virtual-time split of the
    # token stream and the online tier's simulated inter-token latency
    online_tokens: int = 0
    offline_tokens: int = 0
    online_tpot_mean_s: float = 0.0
    online_tpot_p99_s: float = 0.0

    @property
    def bubble_fracs(self) -> List[float]:
        return [max(0.0, 1 - b / self.wall_s) for b in self.stage_busy]


def simulate_mixed_workload(*, p: int = 2, max_batch: int = 4,
                            token_budget: int = 32,
                            prompt_lens: List[int],
                            max_new_tokens: int = 16,
                            t_token: float = 1e-4,
                            t_fixed: float = 5e-4,
                            t_sample: float = 0.0,
                            overlap_sampling: bool = True,
                            fwd_jitter: float = 0.0,
                            chunked: bool = True,
                            policy: Optional[str] = None,
                            hysteresis_tokens: Optional[int] = None,
                            offline_prompt_lens: Optional[List[int]] = None,
                            offline_max_new_tokens: Optional[int] = None,
                            decode_enlarge_factor: int = 1,
                            max_iters: int = 100_000) -> MixedWorkloadResult:
    """Drive the REAL continuous-batching scheduler (repro.core.scheduler)
    through a discrete-event pipeline timing model.

    Per-iteration stage time is ``t_fixed + t_token * tokens`` — iteration
    cost scales with the token count it carries, which is what makes
    monolithic whole-prompt prefills (engine ``_admit_and_prefill``: a
    pipeline-blocking pass over every stage) stall the other p-1 slots,
    while chunked prefill keeps every slot near the token budget.

    ``fwd_jitter`` models per-stage heterogeneity (the paper's Obs. 3,
    same deterministic alternating convention as ``PipeCosts.stage_time``):
    stage ``s`` runs ``1 + fwd_jitter * (+1 if s odd else -1)`` of the
    nominal duration, so the policy comparison no longer charges every
    stage an identical cost — the slowest stage paces the pipeline and
    the fast stages' idle time shows up as bubbles.

    ``policy`` selects the scheduling policy directly ("monolithic",
    "chunked", "disaggregated"); the legacy ``chunked`` flag is kept as a
    shorthand for the first two.  All three run through the same span
    interface, so the timing model needs no per-policy branches beyond
    the monolithic ``is_prefill`` pipeline-blocking pass.

    ``t_sample`` is the per-iteration host-side sampling cost, charged
    only to iterations that SAMPLE (chunk-only spans carry none).  With
    ``overlap_sampling=False`` it sits inside the last stage's critical
    path (the engine's synchronous ``emit_logits`` dispatch); with the
    overlap on (the engine's ``SamplingWorker``), the stage is freed at
    forward-end and sampling latency gates only the same slot's next
    iteration — the engine's per-slot autoregressive gate — so other
    slots stream through the freed stage and the bubble closes.

    ``offline_prompt_lens`` adds a tier="offline" batch workload
    (docs/hybrid.md) riding in the scheduler's slack;
    ``decode_enlarge_factor`` enables the disaggregated policy's
    decode-phase batch enlargement.  The result then carries per-tier
    token totals and the online tier's virtual-time TPOT — the
    deterministic basis for the hybrid bench's "offline traffic must
    not degrade online latency" gate.
    """
    from repro.core.sampling_params import SamplingParams
    from repro.core.scheduler import Scheduler
    from repro.core.sequence import Sequence

    import numpy as np

    if policy is None:
        policy = "chunked" if chunked else "monolithic"
    off_lens = offline_prompt_lens or []
    off_new = offline_max_new_tokens or max_new_tokens
    all_lens = list(prompt_lens) + list(off_lens)
    sched = Scheduler(max_batch=max_batch, pp_degree=p,
                      max_seq_len=max(all_lens) + max(max_new_tokens,
                                                      off_new) + 4,
                      token_budget=(token_budget if policy != "monolithic"
                                    else None),
                      policy=policy, hysteresis_tokens=hysteresis_tokens,
                      decode_enlarge_factor=decode_enlarge_factor)
    for i, plen in enumerate(prompt_lens):
        sched.add_request(Sequence(i, list(range(1, plen + 1)),
                                   SamplingParams(greedy=True,
                                                  max_new_tokens=max_new_tokens)))
    online_ids = set(range(len(prompt_lens)))
    for j, plen in enumerate(off_lens):
        sched.add_request(Sequence(
            len(prompt_lens) + j, list(range(1, plen + 1)),
            SamplingParams(greedy=True, max_new_tokens=off_new,
                           tier="offline")))

    def stage_dur(s: int, tokens: int) -> float:
        d = t_fixed + t_token * tokens
        if fwd_jitter:
            d *= 1.0 + fwd_jitter * (1 if s % 2 else -1)
        return d

    stage_free = [0.0] * p
    stage_busy = [0.0] * p
    slot_prev_end: Dict[int, float] = {}
    bubble_ticks = 0
    prefill_block = 0.0
    iter_tokens: List[int] = []
    online_toks = offline_toks = 0
    online_last_t: Dict[int, float] = {}     # seq -> last sample (virtual s)
    online_tpots: List[float] = []
    wall = 0.0
    it = 0
    while it < max_iters and sched.has_work:
        out = sched.schedule(it)
        if out is None:
            it += 1
            continue
        if out.is_prefill:
            # monolithic path: _admit_and_prefill runs the new prompts
            # through ALL stages back-to-back while nothing else executes
            new = [sid for sid in out.seq_ids if not sched.seqs[sid].output_ids]
            pf_tokens = sum(sched.seqs[s].prompt_len for s in new)
            start = max(stage_free)
            t = start
            for s in range(p):
                dur = stage_dur(s, pf_tokens)
                stage_busy[s] += dur
                t += dur
            for s in range(p):
                if stage_free[s] < start:
                    bubble_ticks += 1
                stage_free[s] = t
            prefill_block += t - start
            sched.complete(it, new, np.full(len(new), 7, np.int32))
            out = sched.schedule(it)
            if out is None:
                it += 1
                continue
        tokens = out.total_tokens
        iter_tokens.append(tokens)
        for i, sid in enumerate(out.seq_ids):
            n = out.spans[i][1] if out.spans is not None else 1
            if sid in online_ids:
                online_toks += n
            else:
                offline_toks += n
        dep = slot_prev_end.get(out.slot, 0.0)
        for s in range(p):
            dur = stage_dur(s, tokens)
            start = max(stage_free[s], dep)
            if start > stage_free[s] and stage_free[s] > 0.0:
                bubble_ticks += 1
            end = start + dur
            stage_free[s] = end
            stage_busy[s] += dur
            dep = end
        cols = out.sample_indices()
        if cols:
            # autoregressive gate: only iterations that SAMPLE gate the
            # slot's next round through the full pipeline + sampler
            # round-trip (the engine's per-slot await).  Chunk-only
            # iterations (a disaggregated prefill phase's body) stream
            # back-to-back — the next chunk only needs the previous one's
            # same-stage cache write, enforced by stage_free ordering.
            if t_sample and not overlap_sampling:
                # synchronous dispatch: sampling occupies the last stage
                stage_free[p - 1] = dep + t_sample
                stage_busy[p - 1] += t_sample
            slot_prev_end[out.slot] = dep + t_sample
            dep += t_sample
        wall = max(wall, dep)
        ids = [out.seq_ids[i] for i in cols]
        for sid in ids:
            # virtual-time online inter-token latency: each sampled token
            # lands at ``dep`` (iteration end incl. the sampling gate)
            if sid in online_ids:
                if sid in online_last_t:
                    online_tpots.append(dep - online_last_t[sid])
                online_last_t[sid] = dep
        sched.complete(it, ids, np.full(len(ids), 7, np.int32))
        it += 1

    wall = max(wall, max(stage_free))
    toks = sum(iter_tokens)
    occ = (sum(min(t / token_budget, 1.0) for t in iter_tokens)
           / max(len(iter_tokens), 1))
    return MixedWorkloadResult(
        iterations=len(iter_tokens), wall_s=wall, tokens_total=toks,
        stage_busy=stage_busy, occupancy=occ, bubble_ticks=bubble_ticks,
        prefill_block_s=prefill_block, iteration_tokens=iter_tokens,
        online_tokens=online_toks, offline_tokens=offline_toks,
        online_tpot_mean_s=(float(np.mean(online_tpots))
                            if online_tpots else 0.0),
        online_tpot_p99_s=(float(np.percentile(online_tpots, 99))
                           if online_tpots else 0.0))


def simulate_disaggregated(*, p: int = 2, max_batch: int = 4,
                           token_budget: int = 32,
                           prompt_lens: List[int],
                           max_new_tokens: int = 16,
                           t_token: float = 1e-4,
                           t_fixed: float = 5e-4,
                           t_sample: float = 0.0,
                           overlap_sampling: bool = True,
                           fwd_jitter: float = 0.0,
                           hysteresis_tokens: Optional[int] = None,
                           max_iters: int = 100_000) -> MixedWorkloadResult:
    """TD-Pipe-style temporally-disaggregated phase scheduling through the
    same timing model as :func:`simulate_mixed_workload` — directly
    comparable against the chunked and monolithic policies on one trace.

    The gain over chunked comes from phase-uniform iteration durations:
    chunked interleaves budget-wide prefill-carrying iterations with
    short decode-only iterations across slots, and the pipeline's
    dependency structure makes every such pair cost ~2x the LONG
    duration; grouping iterations into prefill phases (full budget, no
    decode piggybacking) and decode phases packs the stages instead.
    """
    return simulate_mixed_workload(
        p=p, max_batch=max_batch, token_budget=token_budget,
        prompt_lens=prompt_lens, max_new_tokens=max_new_tokens,
        t_token=t_token, t_fixed=t_fixed, t_sample=t_sample,
        overlap_sampling=overlap_sampling, fwd_jitter=fwd_jitter,
        policy="disaggregated",
        hysteresis_tokens=hysteresis_tokens, max_iters=max_iters)


def simulate_variant(costs: PipeCosts, mode, n_iters: int = 64) -> SimResult:
    """mode: False=baseline, True=full sipipe, or partial-feature strings."""
    if mode is False or mode is True:
        return simulate(costs, sipipe=bool(mode), n_iters=n_iters)
    if mode == "sampling-only":
        # CPU sampling without TSEM: prep still serial, edges still sync
        return simulate(costs, overlap=False, sampling_async=True,
                        n_iters=n_iters)
    if mode == "tsem":
        # sampling off-stage + prep overlapped, edges still synchronous
        return simulate(dataclasses.replace(costs, t_edge=0.0035),
                        overlap=True, sampling_async=True, n_iters=n_iters)
    raise ValueError(mode)
