"""Fault tolerance + straggler mitigation for the serving/training runtime.

Components:
  HeartbeatMonitor — per-worker liveness with deadline detection; drives
      restart-from-checkpoint (training) or stage re-dispatch (serving).
  StragglerDetector — EWMA of per-stage step latencies; stages slower than
      ``threshold`` x the pipeline median are flagged, triggering
      microbatch rebalancing (shrink the straggler's share) — the
      pipeline-level analogue of backup tasks.
  RetryPolicy — bounded exponential backoff for transient stage failures.

All pure-Python state machines: unit-testable without devices, and driven
by the engine / train loop which feeds observations in.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class HeartbeatMonitor:
    timeout_s: float = 10.0
    _last: Dict[str, float] = dataclasses.field(default_factory=dict)

    def beat(self, worker: str, now: Optional[float] = None):
        self._last[worker] = now if now is not None else time.monotonic()

    def dead_workers(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else time.monotonic()
        return [w for w, t in self._last.items() if now - t > self.timeout_s]

    def forget(self, worker: str):
        self._last.pop(worker, None)


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.2                # EWMA smoothing
    threshold: float = 1.5            # x median -> straggler
    min_samples: int = 5
    _ewma: Dict[int, float] = dataclasses.field(default_factory=dict)
    _count: Dict[int, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))

    def observe(self, stage: int, latency_s: float):
        prev = self._ewma.get(stage)
        self._ewma[stage] = latency_s if prev is None else (
            self.alpha * latency_s + (1 - self.alpha) * prev)
        self._count[stage] += 1

    def stragglers(self) -> List[int]:
        ready = {s: v for s, v in self._ewma.items()
                 if self._count[s] >= self.min_samples}
        if len(ready) < 2:
            return []
        med = sorted(ready.values())[len(ready) // 2]
        return [s for s, v in ready.items() if v > self.threshold * med]

    def rebalance_shares(self, n_stages: int) -> List[float]:
        """Microbatch share per stage, inverse to observed latency."""
        if not self._ewma:
            return [1.0 / n_stages] * n_stages
        inv = [1.0 / self._ewma.get(s, 1.0) for s in range(n_stages)]
        tot = sum(inv)
        return [x / tot for x in inv]


@dataclasses.dataclass
class RetryPolicy:
    max_attempts: int = 3
    base_delay_s: float = 0.5
    backoff: float = 2.0

    def run(self, fn: Callable, *args, on_retry: Optional[Callable] = None):
        delay = self.base_delay_s
        last_exc: Optional[BaseException] = None
        for attempt in range(self.max_attempts):
            try:
                return fn(*args)
            except Exception as e:  # pragma: no cover - exercised in tests
                last_exc = e
                if on_retry:
                    on_retry(attempt, e)
                if attempt + 1 < self.max_attempts:
                    time.sleep(delay)
                    delay *= self.backoff
        raise RuntimeError(
            f"operation failed after {self.max_attempts} attempts") from last_exc


@dataclasses.dataclass
class BubbleAccounting:
    """Per-stage busy-interval bookkeeping -> the paper's bubble taxonomy."""

    n_stages: int
    busy: Dict[int, List] = dataclasses.field(
        default_factory=lambda: defaultdict(list))

    def record(self, stage: int, start: float, end: float):
        self.busy[stage].append((start, end))

    def report(self) -> Dict[str, float]:
        if not self.busy:
            return {"pipeline_bubble_frac": 0.0}
        t0 = min(s for iv in self.busy.values() for s, _ in iv)
        t1 = max(e for iv in self.busy.values() for _, e in iv)
        wall = max(t1 - t0, 1e-9)
        frac = {}
        for s in range(self.n_stages):
            b = sum(e - st for st, e in self.busy.get(s, []))
            frac[f"stage{s}_busy_frac"] = b / wall
        busy_avg = sum(frac.values()) / max(len(frac), 1)
        frac["pipeline_bubble_frac"] = 1.0 - busy_avg
        return frac
