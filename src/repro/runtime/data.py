"""Data pipeline: deterministic, restartable token streams.

Two sources:
  * SyntheticLM — Zipfian token stream with document boundaries (training)
  * ShareGPTLike — synthetic request generator whose prompt/output length
    distribution matches the ShareGPT workload used in the paper (§7.1):
    log-normal prompt lengths (median ~ 160 tokens) and output budgets.

Both are seeded and indexable by global step, so a restarted job resumes
the exact batch cursor from the checkpoint (fault tolerance requirement).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Tuple

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    doc_len_mean: int = 512

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """Deterministic (tokens, labels) for a given global step."""
        rng = np.random.default_rng((self.seed, step))
        shape = (self.global_batch, self.seq_len + 1)
        toks = rng.zipf(self.zipf_a, size=shape).astype(np.int64)
        toks = (toks % (self.vocab_size - 2)) + 2        # reserve 0=pad 1=eos
        # insert document boundaries
        n_docs = max(1, self.seq_len // self.doc_len_mean)
        for b in range(self.global_batch):
            cuts = rng.integers(1, self.seq_len, size=n_docs)
            toks[b, cuts] = 1
        return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class ShareGPTLike:
    """Synthetic serving workload with ShareGPT-shaped length statistics."""

    vocab_size: int
    n_requests: int = 64
    seed: int = 0
    prompt_len_median: int = 160
    prompt_len_sigma: float = 0.9
    output_len_median: int = 128
    output_len_sigma: float = 0.7
    max_prompt: int = 2048
    max_output: int = 1024

    def requests(self) -> List[Tuple[List[int], int]]:
        """[(prompt_ids, max_new_tokens)] deterministic by seed."""
        rng = np.random.default_rng(self.seed)
        out = []
        for _ in range(self.n_requests):
            pl = int(np.clip(rng.lognormal(np.log(self.prompt_len_median),
                                           self.prompt_len_sigma), 4, self.max_prompt))
            ol = int(np.clip(rng.lognormal(np.log(self.output_len_median),
                                           self.output_len_sigma), 4, self.max_output))
            prompt = rng.integers(2, self.vocab_size, size=pl).tolist()
            out.append((prompt, ol))
        return out

    def arrivals(self, rate_rps: float) -> List[Tuple[float, List[int], int]]:
        """Poisson arrival process over :meth:`requests`: exponential
        inter-arrival gaps at ``rate_rps`` requests/second, deterministic
        by seed.  Returns ``[(t_arrival_s, prompt_ids, max_new_tokens)]``
        sorted by arrival time — the online serving replay format
        (``serve.py --online``)."""
        if rate_rps <= 0:
            raise ValueError(f"arrival rate must be positive, got {rate_rps}")
        rng = np.random.default_rng((self.seed, 0xA881))
        t = 0.0
        out = []
        for prompt, budget in self.requests():
            t += float(rng.exponential(1.0 / rate_rps))
            out.append((t, prompt, budget))
        return out
