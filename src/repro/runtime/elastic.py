"""Elastic scaling: re-mesh a running job onto a different chip count.

The mechanism is checkpoint-mediated (the production-proven approach):
  1. on membership change, quiesce + save (or reuse the latest periodic
     checkpoint — losing at most ``interval`` steps on hard failures);
  2. build the new mesh from the surviving chip count;
  3. re-resolve every logical-axis sharding against the new mesh (the
     first-fit-divisible resolver degrades gracefully: axes that no longer
     divide fall back to replication);
  4. restore with the new shardings (restore() re-places full arrays).

``plan_new_mesh`` picks the largest (data x model) grid that fits the
survivors while preserving the model-parallel degree when possible —
dropping data-parallel replicas first is the cheapest contraction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro import sharding as shlib
from repro.runtime import checkpoint as ckpt_lib

PyTree = Any


def plan_new_mesh(n_available: int, *, prefer_model: int = 16,
                  multi_pod_threshold: int = 512) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest usable (data, model) or (pod, data, model) grid <= n_available."""
    model = prefer_model
    while model > 1 and n_available % model:
        model //= 2
    rest = n_available // model
    if rest >= 32 and rest % 2 == 0 and n_available >= multi_pod_threshold:
        return (2, rest // 2, model), ("pod", "data", "model")
    return (rest, model), ("data", "model")


def build_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...],
               devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    n = int(np.prod(shape))
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


@dataclasses.dataclass
class ElasticController:
    """Drives quiesce -> save -> re-mesh -> restore."""

    ckpt_dir: str
    strategy: str = "train"

    def contract(self, tree: PyTree, axes_tree: PyTree, step: int,
                 n_available: int) -> Tuple[Mesh, PyTree]:
        """Save under the old mesh, rebuild on ``n_available`` chips."""
        ckpt_lib.save(self.ckpt_dir, step, tree)
        shape, axes = plan_new_mesh(n_available)
        mesh = build_mesh(shape, axes)
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(np.asarray(x).shape, np.asarray(x).dtype),
            tree)
        shardings = shlib.tree_shardings(axes_tree, abstract, self.strategy, mesh)
        _, restored = ckpt_lib.restore(self.ckpt_dir, abstract,
                                       shardings=shardings)
        return mesh, restored
