"""Sharded checkpointing with restore-time resharding (fault tolerance).

Layout on disk (orbax-free, numpy-native, works on any filesystem):

  <dir>/step_<N>/
    MANIFEST.json      — pytree structure, per-leaf shape/dtype, step,
                         mesh shape it was saved under, integrity hashes
    <leaf-path>.npy    — one file per leaf (full array; per-host sharded
                         saving writes disjoint slices of the same file
                         via memmap, so any host count can write/read)
    COMMIT             — written last; a checkpoint without COMMIT is
                         ignored at restore (crash-safe atomicity)

Restore never requires the saving mesh: leaves are loaded as full arrays
and re-placed with whatever sharding the *current* mesh resolves to —
this is what elastic scaling (repro.runtime.elastic) builds on.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> List[Tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((name, leaf))
    return out


def save(ckpt_dir: str | Path, step: int, tree: PyTree, *,
         extra: Optional[Dict] = None, keep: int = 3) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    manifest = {"step": step, "saved_at": time.time(), "leaves": {},
                "extra": extra or {}}
    for name, leaf in _flatten(tree):
        arr = np.asarray(leaf)
        dtype_name = str(arr.dtype)
        store = arr
        if arr.dtype.kind == "V" or dtype_name not in np.sctypeDict:
            # ml_dtypes (bfloat16, fp8...) are not numpy-native: store the
            # raw bits and record the logical dtype in the manifest
            store = arr.view(np.uint8).reshape(arr.shape + (arr.dtype.itemsize,))
        fname = name.replace("/", "__") + ".npy"
        np.save(tmp / fname, store)
        manifest["leaves"][name] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": dtype_name,
            "raw_bits": store is not arr,
            "crc": hashlib.sha1(arr.tobytes()[:1 << 20]).hexdigest()[:16],
        }
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
    (tmp / "COMMIT").write_text(str(step))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _gc(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for p in ckpt_dir.iterdir():
        if p.name.startswith("step_") and (p / "COMMIT").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, tree_like: PyTree, *,
            step: Optional[int] = None, shardings: Optional[PyTree] = None,
            verify: bool = True) -> Tuple[int, PyTree]:
    """Restore into the structure of ``tree_like`` (ShapeDtypeStructs ok),
    re-placing each leaf with ``shardings`` (current-mesh layout)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "MANIFEST.json").read_text())

    names = [n for n, _ in _flatten(tree_like)]
    flat_sh = [s for _, s in _flatten(shardings)] if shardings is not None \
        else [None] * len(names)
    missing = [n for n in names if n not in manifest["leaves"]]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")

    loaded = []
    for name, sh in zip(names, flat_sh):
        meta = manifest["leaves"][name]
        arr = np.load(d / meta["file"])
        if meta.get("raw_bits"):
            import ml_dtypes

            dt = np.dtype(getattr(ml_dtypes, meta["dtype"]))
            arr = arr.reshape(-1).view(dt).reshape(tuple(meta["shape"]))
        if verify:
            crc = hashlib.sha1(arr.tobytes()[:1 << 20]).hexdigest()[:16]
            if crc != meta["crc"]:
                raise IOError(f"checkpoint corruption in {name}")
        loaded.append(jax.device_put(arr, sh))

    treedef = jax.tree_util.tree_structure(tree_like)
    return step, jax.tree_util.tree_unflatten(treedef, loaded)


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(
        p for p in ckpt_dir.iterdir()
        if p.name.startswith("step_") and (p / "COMMIT").exists())
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


@dataclasses.dataclass
class CheckpointManager:
    """Train-loop helper: periodic save + crash-restart restore.

    ``async_save=True`` snapshots leaves to host numpy on the caller
    thread (cheap: device->host copy) and writes files on a background
    thread so the train loop never blocks on the filesystem — the
    standard production pattern for large checkpoints."""

    directory: str
    interval_steps: int = 100
    keep: int = 3
    async_save: bool = False
    _last: int = -1
    _thread: Optional[object] = None

    def maybe_save(self, step: int, tree: PyTree, extra: Optional[Dict] = None):
        if step % self.interval_steps == 0 and step != self._last:
            self._last = step
            if self.async_save:
                import threading

                import jax as _jax

                snapshot = _jax.tree.map(lambda x: np.asarray(x), tree)
                self.wait()
                self._thread = threading.Thread(
                    target=save, args=(self.directory, step, snapshot),
                    kwargs=dict(extra=extra, keep=self.keep), daemon=True)
                self._thread.start()
            else:
                save(self.directory, step, tree, extra=extra, keep=self.keep)
            return True
        return False

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_or_none(self, tree_like: PyTree, shardings=None):
        try:
            return restore(self.directory, tree_like, shardings=shardings)
        except FileNotFoundError:
            return None
