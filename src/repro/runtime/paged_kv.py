"""Paged KV-cache substrate (vLLM-style block tables) for the engine.

Physical cache: [n_blocks, block_size, kv_heads, head_dim] per layer.
Each sequence owns a list of physical block ids; logical position p lives
at (block_table[p // bs], p %% bs).  Allocation is O(1) from a free list;
freeing a finished sequence returns all its blocks.  Copy-on-write
support (for beam/parallel sampling forks) refcounts blocks.

This substrate manages *placement*; attention over paged caches gathers
the block table per sequence (``gather_cache``) — on TPU the gather feeds
the decode-attention kernel directly.  The engine uses contiguous rows by
default (simpler SPMD shardings); the paged allocator is the
memory-pressure path and is covered by its own unit/property tests.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PagedAllocator:
    n_blocks: int
    block_size: int

    def __post_init__(self):
        self._free: List[int] = list(range(self.n_blocks - 1, -1, -1))
        self._tables: Dict[int, List[int]] = {}
        self._refs: Dict[int, int] = {}

    # -- allocation ---------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_needed(self, length: int) -> int:
        return (length + self.block_size - 1) // self.block_size

    def can_allocate(self, seq_len: int) -> bool:
        return self.blocks_needed(seq_len) <= self.free_blocks

    def allocate(self, seq_id: int, seq_len: int) -> List[int]:
        need = self.blocks_needed(seq_len)
        if need > self.free_blocks:
            raise MemoryError(
                f"paged KV exhausted: need {need}, free {self.free_blocks}")
        blocks = [self._free.pop() for _ in range(need)]
        for b in blocks:
            self._refs[b] = 1
        self._tables[seq_id] = blocks
        return blocks

    def append_token(self, seq_id: int, new_len: int) -> Optional[int]:
        """Grow by one token; returns a newly allocated block id or None."""
        table = self._tables[seq_id]
        if self.blocks_needed(new_len) <= len(table):
            return None
        if not self._free:
            raise MemoryError("paged KV exhausted on append")
        b = self._free.pop()
        self._refs[b] = 1
        table.append(b)
        return b

    def grow_to(self, seq_id: int, n_slots: int) -> bool:
        """All-or-nothing growth: extend ``seq_id``'s table to cover
        ``n_slots`` logical slots.  Returns False — allocating nothing —
        when the sequence is unknown or the free list cannot cover the
        whole growth (the scheduler's preempt-and-retry path)."""
        table = self._tables.get(seq_id)
        if table is None:
            return False
        grow = self.blocks_needed(n_slots) - len(table)
        if grow <= 0:
            return True
        if grow > len(self._free):
            return False
        for _ in range(grow):
            b = self._free.pop()
            self._refs[b] = 1
            table.append(b)
        return True

    def has(self, seq_id: int) -> bool:
        return seq_id in self._tables

    def free(self, seq_id: int):
        for b in self._tables.pop(seq_id, []):
            self._refs[b] -= 1
            if self._refs[b] == 0:
                del self._refs[b]
                self._free.append(b)

    # -- copy-on-write forks -------------------------------------------------
    def fork(self, src_seq: int, dst_seq: int):
        """Share all blocks (refcounted); writes must call cow() first."""
        table = self._tables[src_seq]
        for b in table:
            self._refs[b] += 1
        self._tables[dst_seq] = list(table)

    def cow(self, seq_id: int, logical_block: int) -> Tuple[int, Optional[int]]:
        """Ensure exclusive ownership of one logical block before a write.
        Returns (physical_block, copied_from or None)."""
        table = self._tables[seq_id]
        b = table[logical_block]
        if self._refs[b] == 1:
            return b, None
        if not self._free:
            raise MemoryError("paged KV exhausted on CoW")
        nb = self._free.pop()
        self._refs[b] -= 1
        self._refs[nb] = 1
        table[logical_block] = nb
        return nb, b

    def table(self, seq_id: int) -> List[int]:
        return list(self._tables[seq_id])

    # -- invariant helpers (used by property tests) -------------------------
    def check_invariants(self):
        owned = [b for t in self._tables.values() for b in t]
        assert len(set(self._free) & set(owned)) == 0, "block both free+owned"
        for b, r in self._refs.items():
            assert r == sum(1 for t in self._tables.values() for x in t if x == b)
        assert len(self._free) + len(set(owned)) == self.n_blocks


class BlockSpaceManager:
    """Block-budget accounting + placement shared by the scheduler and the
    engine's worker side (the engine memory mode ``kv_layout="paged"``).

    The scheduler consults it for admission (``can_admit``) and growth
    (``ensure``: a decode step writing position ``length-1`` may need a new
    block) and frees a preempted/finished sequence's blocks (``release``);
    the engine's CPU executors snapshot per-batch padded block tables
    (``padded_tables``) at schedule time for the device-side gather/scatter.
    Mutations come from the driver thread (schedule/admission/preemption)
    while stage CPU threads read tables concurrently — all entry points
    take the manager lock.

    ``slot_cap`` bounds the logical slots per sequence for sliding-window
    models with rolling caches (slot = pos %% W): a sequence never needs
    more than ``ceil(W / block_size)`` blocks regardless of length.

    ``max_slots``/``max_table_buckets`` shape the *ladder* of padded
    table widths ``padded_tables`` may emit.  Each distinct width is one
    XLA compile of the whole stage function, so the engine wants a
    handful of steady-state widths, not one per pow2 growth step.  The
    ladder is the powers of two strictly below the per-sequence block
    ceiling plus the exact ceiling itself (``slot_cap // block_size``
    for rolling models — the rolling kernels' stored-position modulus
    requires a wrapped row's width to be *exactly* the window), and
    ``max_table_buckets`` keeps only the largest N rungs.  With neither
    bound set the ladder is unbounded pow2s (the pre-capping behavior).
    """

    def __init__(self, n_blocks: int, block_size: int,
                 slot_cap: Optional[int] = None, *,
                 max_slots: Optional[int] = None,
                 max_table_buckets: Optional[int] = None):
        if slot_cap is not None and slot_cap % block_size:
            raise ValueError(
                f"block_size {block_size} must divide the sliding window "
                f"{slot_cap}: rolling slot arithmetic needs whole blocks")
        self.block_size = block_size
        self.slot_cap = slot_cap
        self.alloc = PagedAllocator(n_blocks, block_size)
        self._lock = threading.Lock()
        if slot_cap is not None:
            cap = slot_cap // block_size
        elif max_slots is not None:
            cap = -(-max_slots // block_size)
        else:
            cap = None
        self._ladder: Optional[List[int]] = None
        if cap is not None:
            ladder = []
            w = 1
            while w < cap:
                ladder.append(w)
                w <<= 1
            ladder.append(cap)
            if max_table_buckets is not None and max_table_buckets >= 1:
                ladder = ladder[-max_table_buckets:]
            self._ladder = ladder

    @property
    def table_widths(self) -> Optional[List[int]]:
        """The padded-table width ladder (None = unbounded pow2s)."""
        return list(self._ladder) if self._ladder is not None else None

    # -- budget arithmetic ---------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return self.alloc.n_blocks

    @property
    def pad_block(self) -> int:
        """Physical id of the trash block: the engine allocates one block
        past ``n_blocks`` that padded table entries point at — writes to it
        are discarded, reads from it are position-masked."""
        return self.alloc.n_blocks

    @property
    def free_blocks(self) -> int:
        with self._lock:
            return self.alloc.free_blocks

    def slots_for(self, length: int) -> int:
        """Logical KV slots a sequence of ``length`` tokens occupies."""
        if self.slot_cap is not None:
            return min(length, self.slot_cap)
        return length

    def blocks_for(self, length: int) -> int:
        return max(1, self.alloc.blocks_needed(self.slots_for(length)))

    # -- scheduler-side operations ------------------------------------------
    def can_admit(self, length: int) -> bool:
        with self._lock:
            return self.blocks_for(length) <= self.alloc.free_blocks

    def admit(self, seq_id: int, length: int):
        with self._lock:
            if self.alloc.has(seq_id):
                return
            self.alloc.allocate(seq_id, max(1, self.slots_for(length)))

    def ensure(self, seq_id: int, length: int) -> bool:
        """Grow ``seq_id``'s table to cover ``length`` tokens.  Returns
        False (allocating nothing) when the free list cannot cover the
        growth — the caller preempts and retries."""
        with self._lock:
            return self.alloc.grow_to(seq_id, self.slots_for(length))

    def release(self, seq_id: int):
        with self._lock:
            self.alloc.free(seq_id)          # idempotent: no-op when absent

    def has(self, seq_id: int) -> bool:
        with self._lock:
            return self.alloc.has(seq_id)

    def table(self, seq_id: int) -> Optional[List[int]]:
        with self._lock:
            return (self.alloc.table(seq_id) if self.alloc.has(seq_id)
                    else None)

    # -- engine-side snapshot ------------------------------------------------
    def padded_tables(self, seq_ids: Sequence[int]) -> np.ndarray:
        """[B, nb] int32 block tables padded with the trash block.

        ``nb`` is the smallest rung of the width ladder covering the
        batch's longest table (unbounded pow2 rounding when no ladder is
        configured), so the engine compiles one executable per
        (batch, nb) pair — and with ``max_table_buckets`` set, only a
        capped handful of nb values ever occur.  A sequence with no
        table (released between schedule and prepare — e.g. preempted
        with an iteration in flight) pads to an all-trash row: its
        writes land in the trash block and its sampled token is
        discarded by the scheduler."""
        with self._lock:
            tables = [self.alloc.table(sid) if self.alloc.has(sid) else []
                      for sid in seq_ids]
            nb = max(1, max((len(t) for t in tables), default=1))
            if self._ladder is not None:
                nbp = next((w for w in self._ladder if w >= nb),
                           self._ladder[-1])
            else:
                nbp = 1
                while nbp < nb:
                    nbp <<= 1
            nbp = max(nbp, nb)
            out = np.full((len(tables), nbp), self.pad_block, np.int32)
            for i, t in enumerate(tables):
                out[i, :len(t)] = t
            return out


def init_paged_cache(n_layers: int, n_blocks: int, block_size: int,
                     kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
    shape = (n_layers, n_blocks, block_size, kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def write_token(cache, layer: int, block: int, offset: int, k, v):
    """Write one token's K/V into its physical slot."""
    return {
        "k": cache["k"].at[layer, block, offset].set(k),
        "v": cache["v"].at[layer, block, offset].set(v),
    }


def gather_cache(cache, layer: int, block_table: np.ndarray, length: int,
                 block_size: int):
    """Materialize a contiguous [length, kv, hd] view for one sequence
    (feeds the decode-attention kernel; on TPU this is the block-table
    gather the paged kernel performs in VMEM)."""
    bt = jnp.asarray(block_table, jnp.int32)
    k = cache["k"][layer][bt].reshape(-1, *cache["k"].shape[3:])[:length]
    v = cache["v"][layer][bt].reshape(-1, *cache["v"].shape[3:])[:length]
    return k, v
