"""Paged KV-cache substrate (vLLM-style block tables) for the engine.

Physical cache: [n_blocks, block_size, kv_heads, head_dim] per layer.
Each sequence owns a list of physical block ids; logical position p lives
at (block_table[p // bs], p %% bs).  Allocation is O(1) from a free list;
freeing a finished sequence returns all its blocks.  Copy-on-write
support (for beam/parallel sampling forks) refcounts blocks.

This substrate manages *placement*; attention over paged caches gathers
the block table per sequence (``gather_cache``) — on TPU the gather feeds
the decode-attention kernel directly.  The engine uses contiguous rows by
default (simpler SPMD shardings); the paged allocator is the
memory-pressure path and is covered by its own unit/property tests.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PagedAllocator:
    n_blocks: int
    block_size: int

    def __post_init__(self):
        self._free: List[int] = list(range(self.n_blocks - 1, -1, -1))
        self._tables: Dict[int, List[int]] = {}
        self._refs: Dict[int, int] = {}
        # prefix-cache holds: block -> number of cache pins.  A pinned
        # block is refcounted like a table reference, so it survives the
        # release of every sequence that wrote it — its contents stay
        # valid for future prefix matches until the cache unpins it.
        self._pins: Dict[int, int] = {}
        # device-side CoW work queue: (src, dst) physical pairs appended by
        # cow(); the BlockSpaceManager drains them into the iteration that
        # must copy block contents on every stage before computing.
        self._pending_copies: List[Tuple[int, int]] = []

    # -- allocation ---------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_needed(self, length: int) -> int:
        return (length + self.block_size - 1) // self.block_size

    def can_allocate(self, seq_len: int) -> bool:
        return self.blocks_needed(seq_len) <= self.free_blocks

    def allocate(self, seq_id: int, seq_len: int) -> List[int]:
        need = self.blocks_needed(seq_len)
        if need > self.free_blocks:
            raise MemoryError(
                f"paged KV exhausted: need {need}, free {self.free_blocks}")
        blocks = [self._free.pop() for _ in range(need)]
        for b in blocks:
            self._refs[b] = 1
        self._tables[seq_id] = blocks
        return blocks

    def append_token(self, seq_id: int, new_len: int) -> Optional[int]:
        """Grow by one token; returns a newly allocated block id or None.

        The token lands at slot ``new_len - 1``: if that block is shared
        (a live fork or a cached prefix holds a reference), it is CoW'd
        first — writing through a shared block would corrupt every other
        holder.  The copy pair is queued in ``_pending_copies``."""
        table = self._tables[seq_id]
        created = None
        if self.blocks_needed(new_len) > len(table):
            if not self._free:
                raise MemoryError("paged KV exhausted on append")
            b = self._free.pop()
            self._refs[b] = 1
            table.append(b)
            created = b
        wb = (new_len - 1) // self.block_size
        if wb < len(table) and self._refs[table[wb]] > 1:
            nb, _ = self.cow(seq_id, wb)      # may raise on exhaustion
            if created is None:
                created = nb
        return created

    def grow_to(self, seq_id: int, n_slots: int,
                write_slot: Optional[int] = None) -> bool:
        """All-or-nothing growth: extend ``seq_id``'s table to cover
        ``n_slots`` logical slots AND guarantee the caller's next write —
        slot ``write_slot`` (default ``n_slots - 1``) — targets an
        exclusively-owned block, CoW-ing a shared one.  Returns False,
        allocating and copying nothing, when the sequence is unknown or
        the free list cannot cover growth + CoW together (the
        scheduler's preempt-and-retry path)."""
        table = self._tables.get(seq_id)
        if table is None:
            return False
        grow = self.blocks_needed(n_slots) - len(table)
        wb = (n_slots - 1 if write_slot is None else write_slot) \
            // self.block_size
        need_cow = wb < len(table) and self._refs[table[wb]] > 1
        if max(grow, 0) + (1 if need_cow else 0) > len(self._free):
            return False
        for _ in range(max(grow, 0)):
            b = self._free.pop()
            self._refs[b] = 1
            table.append(b)
        if need_cow:
            self.cow(seq_id, wb)              # free list pre-checked above
        return True

    def has(self, seq_id: int) -> bool:
        return seq_id in self._tables

    def free(self, seq_id: int):
        for b in self._tables.pop(seq_id, []):
            self._refs[b] -= 1
            if self._refs[b] == 0:
                del self._refs[b]
                self._free.append(b)

    # -- copy-on-write forks -------------------------------------------------
    def fork(self, src_seq: int, dst_seq: int):
        """Share all blocks (refcounted); writes must call cow() first."""
        table = self._tables[src_seq]
        for b in table:
            self._refs[b] += 1
        self._tables[dst_seq] = list(table)

    def cow(self, seq_id: int, logical_block: int) -> Tuple[int, Optional[int]]:
        """Ensure exclusive ownership of one logical block before a write.
        Returns (physical_block, copied_from or None); when a copy
        happened the (src, dst) pair is queued in ``_pending_copies`` for
        the device-side content copy."""
        table = self._tables[seq_id]
        b = table[logical_block]
        if self._refs[b] == 1:
            return b, None
        if not self._free:
            raise MemoryError("paged KV exhausted on CoW")
        nb = self._free.pop()
        self._refs[b] -= 1
        self._refs[nb] = 1
        table[logical_block] = nb
        self._pending_copies.append((b, nb))
        return nb, b

    def adopt(self, seq_id: int, shared: List[int], n_fresh: int):
        """Build a table from ``shared`` existing blocks (refcount + 1
        each — the prefix-cache admission path) followed by ``n_fresh``
        newly popped blocks.  All-or-nothing on the free list."""
        assert seq_id not in self._tables, f"seq {seq_id} already has a table"
        if n_fresh > len(self._free):
            raise MemoryError(
                f"paged KV exhausted: need {n_fresh}, free {len(self._free)}")
        for b in shared:
            self._refs[b] += 1
        fresh = [self._free.pop() for _ in range(n_fresh)]
        for b in fresh:
            self._refs[b] = 1
        self._tables[seq_id] = list(shared) + fresh

    # -- prefix-cache pins ---------------------------------------------------
    def pin(self, block: int):
        """Hold a block on behalf of the prefix cache: one extra ref, so
        it outlives every sequence table that contains it."""
        self._refs[block] = self._refs.get(block, 0) + 1
        self._pins[block] = self._pins.get(block, 0) + 1

    def unpin(self, block: int):
        self._pins[block] -= 1
        if not self._pins[block]:
            del self._pins[block]
        self._refs[block] -= 1
        if self._refs[block] == 0:
            del self._refs[block]
            self._free.append(block)

    def drain_copies(self) -> List[Tuple[int, int]]:
        out, self._pending_copies = self._pending_copies, []
        return out

    def table(self, seq_id: int) -> List[int]:
        return list(self._tables[seq_id])

    # -- invariant helpers (used by property tests) -------------------------
    def check_invariants(self):
        owned = [b for t in self._tables.values() for b in t]
        held = set(owned) | set(self._pins)
        assert len(set(self._free) & held) == 0, "block both free+held"
        for b, r in self._refs.items():
            occ = sum(1 for t in self._tables.values() for x in t if x == b)
            assert r == occ + self._pins.get(b, 0), \
                f"block {b}: refs {r} != tables {occ} + pins " \
                f"{self._pins.get(b, 0)}"
        assert len(self._free) + len(held) == self.n_blocks


@dataclasses.dataclass
class _PrefixEntry:
    block: int                  # physical block holding the cached K/V
    tokens: Tuple[int, ...]     # the block's token ids (collision guard)
    parent: Optional[int]       # chain key of the preceding block's entry
    tick: int                   # LRU clock


#: registration sentinel: this sequence's hash chain hit a (vanishingly
#: rare) collision — stop registering its blocks rather than corrupt the
#: chain with wrong-content entries.
_CHAIN_BROKEN = object()


class PrefixCache:
    """Hash-based block-granular prompt-prefix index (vLLM-style).

    Each FULL prompt block is keyed by the *cumulative* hash of
    ``(parent_key, block token tuple)``, so a chain of matches is
    position-aware for free: block i of one prompt can only match block i
    of an identical leading prefix.  Entries store the token tuple and
    verify it on match — a hash collision degrades to a miss, never to
    wrong K/V.  Matched/registered blocks are *pinned* in the
    :class:`PagedAllocator` (one extra refcount), so cached content
    survives the sequences that produced it; eviction is LRU over entries
    whose pin is the only remaining reference.
    """

    def __init__(self, block_size: int):
        self.block_size = block_size
        self._entries: Dict[int, _PrefixEntry] = {}
        self._by_block: Dict[int, int] = {}       # physical block -> key
        self._tick = 0
        self.hits = 0              # admissions that matched >= 1 block
        self.misses = 0            # admissions that matched none
        self.evictions = 0
        self.tokens_served = 0     # prompt tokens mapped instead of computed

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _key(parent: Optional[int], tokens: Tuple[int, ...]) -> int:
        return hash((parent, tokens))

    def match(self, token_ids: Sequence[int]) -> List[int]:
        """Physical blocks of the longest cached chain covering the
        leading full blocks of ``token_ids`` (touches LRU ticks)."""
        bs = self.block_size
        out: List[int] = []
        parent: Optional[int] = None
        for i in range(len(token_ids) // bs):
            tok = tuple(int(t) for t in token_ids[i * bs:(i + 1) * bs])
            key = self._key(parent, tok)
            e = self._entries.get(key)
            if e is None or e.tokens != tok:
                break
            self._tick += 1
            e.tick = self._tick
            out.append(e.block)
            parent = key
        return out

    def register(self, parent: Optional[int], tokens: Tuple[int, ...],
                 block: int) -> Tuple[Optional[int], bool]:
        """Insert one block into the chain.  Returns ``(chain_key,
        created)``; ``(None, False)`` on a content-mismatched hash
        collision (the caller stops chaining this sequence)."""
        key = self._key(parent, tokens)
        e = self._entries.get(key)
        if e is not None:
            if e.tokens != tokens:
                return None, False
            return key, False      # identical content already cached
        self._tick += 1
        self._entries[key] = _PrefixEntry(block, tokens, parent, self._tick)
        self._by_block[block] = key
        return key, True

    def key_of(self, block: int) -> Optional[int]:
        return self._by_block.get(block)

    def pop(self, key: int) -> _PrefixEntry:
        e = self._entries.pop(key)
        self._by_block.pop(e.block, None)
        self.evictions += 1
        return e


class BlockSpaceManager:
    """Block-budget accounting + placement shared by the scheduler and the
    engine's worker side (the engine memory mode ``kv_layout="paged"``).

    The scheduler consults it for admission (``can_admit``) and growth
    (``ensure``: a decode step writing position ``length-1`` may need a new
    block) and frees a preempted/finished sequence's blocks (``release``);
    the engine's CPU executors snapshot per-batch padded block tables
    (``padded_tables``) at schedule time for the device-side gather/scatter.
    Mutations come from the driver thread (schedule/admission/preemption)
    while stage CPU threads read tables concurrently — all entry points
    take the manager lock.

    ``slot_cap`` bounds the logical slots per sequence for sliding-window
    models with rolling caches (slot = pos %% W): a sequence never needs
    more than ``ceil(W / block_size)`` blocks regardless of length.

    ``max_slots``/``max_table_buckets`` shape the *ladder* of padded
    table widths ``padded_tables`` may emit.  Each distinct width is one
    XLA compile of the whole stage function, so the engine wants a
    handful of steady-state widths, not one per pow2 growth step.  The
    ladder is the powers of two strictly below the per-sequence block
    ceiling plus the exact ceiling itself (``slot_cap // block_size``
    for rolling models — the rolling kernels' stored-position modulus
    requires a wrapped row's width to be *exactly* the window), and
    ``max_table_buckets`` keeps only the largest N rungs.  With neither
    bound set the ladder is unbounded pow2s (the pre-capping behavior).
    """

    def __init__(self, n_blocks: int, block_size: int,
                 slot_cap: Optional[int] = None, *,
                 max_slots: Optional[int] = None,
                 max_table_buckets: Optional[int] = None,
                 prefix_cache: bool = False):
        if slot_cap is not None and slot_cap % block_size:
            raise ValueError(
                f"block_size {block_size} must divide the sliding window "
                f"{slot_cap}: rolling slot arithmetic needs whole blocks")
        if prefix_cache and slot_cap is not None:
            raise ValueError(
                "prefix caching requires a non-rolling cache: with "
                "slot = pos % window a block's content is position-"
                "dependent and cannot be shared across prompts")
        self.block_size = block_size
        self.slot_cap = slot_cap
        self.alloc = PagedAllocator(n_blocks, block_size)
        self._lock = threading.Lock()
        self._prefix = PrefixCache(block_size) if prefix_cache else None
        # per-seq registration watermark: (full blocks registered, chain
        # key of the last one) — registration resumes from here; dropped
        # (NOT the cached entries, which are pinned) on release
        self._reg: Dict[int, Tuple[int, Optional[int]]] = {}
        self.ladder_extensions = 0
        self.cow_copies = 0
        self.forks = 0
        if slot_cap is not None:
            cap = slot_cap // block_size
        elif max_slots is not None:
            cap = -(-max_slots // block_size)
        else:
            cap = None
        self._ladder: Optional[List[int]] = None
        if cap is not None:
            ladder = []
            w = 1
            while w < cap:
                ladder.append(w)
                w <<= 1
            ladder.append(cap)
            if max_table_buckets is not None and max_table_buckets >= 1:
                ladder = ladder[-max_table_buckets:]
            self._ladder = ladder

    @property
    def table_widths(self) -> Optional[List[int]]:
        """The padded-table width ladder (None = unbounded pow2s)."""
        return list(self._ladder) if self._ladder is not None else None

    # -- budget arithmetic ---------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return self.alloc.n_blocks

    @property
    def pad_block(self) -> int:
        """Physical id of the trash block: the engine allocates one block
        past ``n_blocks`` that padded table entries point at — writes to it
        are discarded, reads from it are position-masked."""
        return self.alloc.n_blocks

    @property
    def free_blocks(self) -> int:
        with self._lock:
            return self.alloc.free_blocks

    def slots_for(self, length: int) -> int:
        """Logical KV slots a sequence of ``length`` tokens occupies."""
        if self.slot_cap is not None:
            return min(length, self.slot_cap)
        return length

    def blocks_for(self, length: int) -> int:
        return max(1, self.alloc.blocks_needed(self.slots_for(length)))

    # -- prefix cache ---------------------------------------------------------
    @property
    def prefix_enabled(self) -> bool:
        return self._prefix is not None

    def _matchable(self, length: int, chain: List[int]) -> List[int]:
        """Cap a matched chain so at least one prompt token is always
        computed — the admitted sequence needs logits at its last
        position, which only a real prefill/chunk produces."""
        return chain[:min(len(chain), (length - 1) // self.block_size)]

    def _evict_cached(self, need: int, exclude=()) -> int:
        """Evict up to ``need`` LRU cache entries whose pin is the only
        remaining reference; their blocks return to the free list.
        Returns the number of blocks freed.  (Caller holds the lock.)"""
        if self._prefix is None or need <= 0:
            return 0
        skip = set(exclude)
        cands = sorted(
            (e.tick, k) for k, e in self._prefix._entries.items()
            if self.alloc._refs.get(e.block, 0) == 1 and e.block not in skip)
        freed = 0
        for _, key in cands:
            if freed >= need:
                break
            e = self._prefix.pop(key)
            self.alloc.unpin(e.block)
            freed += 1
        return freed

    def register_prefix(self, seq_id: int, token_ids: Sequence[int],
                        upto: int):
        """Register ``seq_id``'s full prompt blocks below token ``upto``
        (its K/V-written watermark) into the prefix index, pinning each
        newly cached block.  Idempotent and incremental per sequence."""
        if self._prefix is None:
            return
        bs = self.block_size
        with self._lock:
            if not self.alloc.has(seq_id):
                return
            table = self.alloc._tables[seq_id]
            done, parent = self._reg.get(seq_id, (0, None))
            if parent is _CHAIN_BROKEN:
                return
            nfull = min(min(upto, len(token_ids)) // bs, len(table))
            for i in range(done, nfull):
                tok = tuple(int(t) for t in token_ids[i * bs:(i + 1) * bs])
                key, created = self._prefix.register(parent, tok, table[i])
                if key is None:            # hash collision: stop chaining
                    self._reg[seq_id] = (i, _CHAIN_BROKEN)
                    return
                if created:
                    self.alloc.pin(table[i])
                parent = key
            if nfull > done:
                self._reg[seq_id] = (nfull, parent)

    def prefix_stats(self) -> Dict[str, int]:
        with self._lock:
            out = {
                "cow_copies": self.cow_copies,
                "ladder_extensions": self.ladder_extensions,
                "forks": self.forks,
            }
            if self._prefix is not None:
                px = self._prefix
                out.update(
                    prefix_hits=px.hits, prefix_misses=px.misses,
                    prefix_evictions=px.evictions,
                    prefix_cached_blocks=len(px),
                    prefix_tokens_served=px.tokens_served)
            return out

    @property
    def reclaimable_cached_blocks(self) -> int:
        """Cached blocks held ONLY by their pin — reclaimed on demand by
        admission/growth eviction, so they count as available capacity."""
        with self._lock:
            if self._prefix is None:
                return 0
            return sum(1 for e in self._prefix._entries.values()
                       if self.alloc._refs.get(e.block, 0) == 1)

    # -- scheduler-side operations ------------------------------------------
    def can_admit(self, length: int, token_ids=None,
                  evict_cached: bool = True) -> bool:
        """``evict_cached=False`` counts only genuinely free blocks as
        supply (no cached-prefix reclamation): admission that passes this
        stricter gate is guaranteed not to evict anything from the prefix
        cache — used for offline-tier admission and for the scheduler's
        baseline-equivalence reclaim loop (docs/hybrid.md)."""
        with self._lock:
            need = self.blocks_for(length)
            supply = self.alloc.free_blocks
            if self._prefix is not None:
                matched = []
                if token_ids is not None:
                    matched = self._matchable(
                        length, self._prefix.match(token_ids))
                ms = set(matched)
                need -= len(matched)
                if evict_cached:
                    supply += sum(
                        1 for e in self._prefix._entries.values()
                        if self.alloc._refs.get(e.block, 0) == 1
                        and e.block not in ms)
            return need <= supply

    def admit(self, seq_id: int, length: int, token_ids=None) -> int:
        """Reserve blocks for an admitted sequence.  With the prefix
        cache enabled and ``token_ids`` given, leading full blocks whose
        hash chain is cached are *shared* (refcount + 1) instead of
        allocated — the return value is the number of leading tokens
        whose K/V is already in cache (0 on a miss / cache off), i.e.
        where the sequence's prefill may start."""
        with self._lock:
            if self.alloc.has(seq_id):
                return 0
            need = max(1, self.blocks_for(length))
            shared: List[int] = []
            if self._prefix is not None and token_ids is not None:
                shared = self._matchable(
                    length, self._prefix.match(token_ids))
                if shared:
                    self._prefix.hits += 1
                    self._prefix.tokens_served += len(shared) * self.block_size
                else:
                    self._prefix.misses += 1
            fresh = need - len(shared)
            if fresh > self.alloc.free_blocks:
                self._evict_cached(fresh - self.alloc.free_blocks,
                                   exclude=shared)
            self.alloc.adopt(seq_id, shared, fresh)   # raises when short
            if shared:
                # the shared prefix is already registered: resume the
                # chain from its last cached block
                self._reg[seq_id] = (len(shared),
                                     self._prefix.key_of(shared[-1]))
            return len(shared) * self.block_size

    def ensure(self, seq_id: int, length: int,
               evict_cached: bool = True) -> bool:
        """Grow ``seq_id``'s table to cover ``length`` tokens and make
        the write-target block (the decode writes slot ``length - 1``)
        exclusively owned, CoW-ing a fork-shared tail.  Cached prefix
        blocks are evicted under pressure before giving up; returns
        False (allocating nothing) only when growth + CoW still cannot
        be covered — the caller preempts and retries.

        ``evict_cached=False`` grows from genuinely free blocks only,
        failing instead of touching the prefix cache — used for
        offline-tier growth and the scheduler's baseline-equivalence
        path (docs/hybrid.md)."""
        with self._lock:
            if not self.alloc.has(seq_id):
                return False
            slots = self.slots_for(length)
            ws = ((length - 1) % self.slot_cap if self.slot_cap is not None
                  else length - 1)
            while not self.alloc.grow_to(seq_id, slots, write_slot=ws):
                if not evict_cached or self._evict_cached(1) == 0:
                    return False
            return True

    def fork(self, src_seq: int, dst_seq: int) -> bool:
        """Share all of ``src_seq``'s blocks with ``dst_seq`` (refcounted
        CoW fork).  Returns False when the source holds no table."""
        with self._lock:
            if not self.alloc.has(src_seq) or self.alloc.has(dst_seq):
                return False
            self.alloc.fork(src_seq, dst_seq)
            self.forks += 1
            return True

    def drain_copies(self) -> Optional[np.ndarray]:
        """Pop the pending CoW (src, dst) block pairs as an [K, 2] int32
        array (None when empty).  The scheduler attaches them to the next
        SchedulingOutput; every stage copies block contents device-side
        before computing that iteration."""
        with self._lock:
            pc = self.alloc.drain_copies()
            if not pc:
                return None
            self.cow_copies += len(pc)
            return np.asarray(pc, np.int32)

    def release(self, seq_id: int):
        with self._lock:
            self.alloc.free(seq_id)          # idempotent: no-op when absent
            self._reg.pop(seq_id, None)

    def has(self, seq_id: int) -> bool:
        with self._lock:
            return self.alloc.has(seq_id)

    def table(self, seq_id: int) -> Optional[List[int]]:
        with self._lock:
            return (self.alloc.table(seq_id) if self.alloc.has(seq_id)
                    else None)

    # -- engine-side snapshot ------------------------------------------------
    def padded_tables(self, seq_ids: Sequence[int],
                      mask_shared: bool = False) -> np.ndarray:
        """[B, nb] int32 block tables padded with the trash block.

        ``nb`` is the smallest rung of the width ladder covering the
        batch's longest table (unbounded pow2 rounding when no ladder is
        configured), so the engine compiles one executable per
        (batch, nb) pair — and with ``max_table_buckets`` set, only a
        capped handful of nb values ever occur.  A table that outgrows
        the capped ladder EXTENDS it deterministically with the next
        power-of-two rung (recorded in ``table_widths`` /
        ``metrics()["kv_table_widths"]``) instead of emitting a one-off
        off-ladder width — each distinct width is an XLA compile, so a
        silent ``max(nbp, nb)`` escape would compile once per growth
        step.  A sequence with no table (released between schedule and
        prepare — e.g. preempted with an iteration in flight) pads to an
        all-trash row: its writes land in the trash block and its
        sampled token is discarded by the scheduler.

        ``mask_shared`` replaces every block with refcount > 1 (prefix-
        shared or fork-shared) by the trash block: the *write-masked*
        view ``run_prefill`` scatters through, so a monolithic prefill
        recomputing a shared prompt never writes a block other holders
        read (the recomputed values are bit-identical anyway; masking
        removes the write hazard entirely)."""
        with self._lock:
            tables = [self.alloc.table(sid) if self.alloc.has(sid) else []
                      for sid in seq_ids]
            nb = max(1, max((len(t) for t in tables), default=1))
            if self._ladder is not None:
                if nb > self._ladder[-1]:
                    w = 1
                    while w < nb:
                        w <<= 1
                    self._ladder.append(w)
                    self.ladder_extensions += 1
                nbp = next(w for w in self._ladder if w >= nb)
            else:
                nbp = 1
                while nbp < nb:
                    nbp <<= 1
            out = np.full((len(tables), nbp), self.pad_block, np.int32)
            for i, t in enumerate(tables):
                if mask_shared:
                    t = [b if self.alloc._refs.get(b, 0) == 1
                         else self.pad_block for b in t]
                out[i, :len(t)] = t
            return out


def init_paged_cache(n_layers: int, n_blocks: int, block_size: int,
                     kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
    shape = (n_layers, n_blocks, block_size, kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def write_token(cache, layer: int, block: int, offset: int, k, v):
    """Write one token's K/V into its physical slot."""
    return {
        "k": cache["k"].at[layer, block, offset].set(k),
        "v": cache["v"].at[layer, block, offset].set(v),
    }


def gather_cache(cache, layer: int, block_table: np.ndarray, length: int,
                 block_size: int):
    """Materialize a contiguous [length, kv, hd] view for one sequence
    (feeds the decode-attention kernel; on TPU this is the block-table
    gather the paged kernel performs in VMEM)."""
    bt = jnp.asarray(block_table, jnp.int32)
    k = cache["k"][layer][bt].reshape(-1, *cache["k"].shape[3:])[:length]
    v = cache["v"][layer][bt].reshape(-1, *cache["v"].shape[3:])[:length]
    return k, v
