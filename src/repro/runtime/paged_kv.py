"""Paged KV-cache substrate (vLLM-style block tables) for the engine.

Physical cache: [n_blocks, block_size, kv_heads, head_dim] per layer.
Each sequence owns a list of physical block ids; logical position p lives
at (block_table[p // bs], p %% bs).  Allocation is O(1) from a free list;
freeing a finished sequence returns all its blocks.  Copy-on-write
support (for beam/parallel sampling forks) refcounts blocks.

This substrate manages *placement*; attention over paged caches gathers
the block table per sequence (``gather_cache``) — on TPU the gather feeds
the decode-attention kernel directly.  The engine uses contiguous rows by
default (simpler SPMD shardings); the paged allocator is the
memory-pressure path and is covered by its own unit/property tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PagedAllocator:
    n_blocks: int
    block_size: int

    def __post_init__(self):
        self._free: List[int] = list(range(self.n_blocks - 1, -1, -1))
        self._tables: Dict[int, List[int]] = {}
        self._refs: Dict[int, int] = {}

    # -- allocation ---------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_needed(self, length: int) -> int:
        return (length + self.block_size - 1) // self.block_size

    def can_allocate(self, seq_len: int) -> bool:
        return self.blocks_needed(seq_len) <= self.free_blocks

    def allocate(self, seq_id: int, seq_len: int) -> List[int]:
        need = self.blocks_needed(seq_len)
        if need > self.free_blocks:
            raise MemoryError(
                f"paged KV exhausted: need {need}, free {self.free_blocks}")
        blocks = [self._free.pop() for _ in range(need)]
        for b in blocks:
            self._refs[b] = 1
        self._tables[seq_id] = blocks
        return blocks

    def append_token(self, seq_id: int, new_len: int) -> Optional[int]:
        """Grow by one token; returns a newly allocated block id or None."""
        table = self._tables[seq_id]
        if self.blocks_needed(new_len) <= len(table):
            return None
        if not self._free:
            raise MemoryError("paged KV exhausted on append")
        b = self._free.pop()
        self._refs[b] = 1
        table.append(b)
        return b

    def free(self, seq_id: int):
        for b in self._tables.pop(seq_id, []):
            self._refs[b] -= 1
            if self._refs[b] == 0:
                del self._refs[b]
                self._free.append(b)

    # -- copy-on-write forks -------------------------------------------------
    def fork(self, src_seq: int, dst_seq: int):
        """Share all blocks (refcounted); writes must call cow() first."""
        table = self._tables[src_seq]
        for b in table:
            self._refs[b] += 1
        self._tables[dst_seq] = list(table)

    def cow(self, seq_id: int, logical_block: int) -> Tuple[int, Optional[int]]:
        """Ensure exclusive ownership of one logical block before a write.
        Returns (physical_block, copied_from or None)."""
        table = self._tables[seq_id]
        b = table[logical_block]
        if self._refs[b] == 1:
            return b, None
        if not self._free:
            raise MemoryError("paged KV exhausted on CoW")
        nb = self._free.pop()
        self._refs[b] -= 1
        self._refs[nb] = 1
        table[logical_block] = nb
        return nb, b

    def table(self, seq_id: int) -> List[int]:
        return list(self._tables[seq_id])

    # -- invariant helpers (used by property tests) -------------------------
    def check_invariants(self):
        owned = [b for t in self._tables.values() for b in t]
        assert len(set(self._free) & set(owned)) == 0, "block both free+owned"
        for b, r in self._refs.items():
            assert r == sum(1 for t in self._tables.values() for x in t if x == b)
        assert len(self._free) + len(set(owned)) == self.n_blocks


def init_paged_cache(n_layers: int, n_blocks: int, block_size: int,
                     kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
    shape = (n_layers, n_blocks, block_size, kv_heads, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def write_token(cache, layer: int, block: int, offset: int, k, v):
    """Write one token's K/V into its physical slot."""
    return {
        "k": cache["k"].at[layer, block, offset].set(k),
        "v": cache["v"].at[layer, block, offset].set(v),
    }


def gather_cache(cache, layer: int, block_table: np.ndarray, length: int,
                 block_size: int):
    """Materialize a contiguous [length, kv, hd] view for one sequence
    (feeds the decode-attention kernel; on TPU this is the block-table
    gather the paged kernel performs in VMEM)."""
    bt = jnp.asarray(block_table, jnp.int32)
    k = cache["k"][layer][bt].reshape(-1, *cache["k"].shape[3:])[:length]
    v = cache["v"][layer][bt].reshape(-1, *cache["v"].shape[3:])[:length]
    return k, v
