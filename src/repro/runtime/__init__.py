from repro.runtime import checkpoint, data, elastic, fault_tolerance  # noqa: F401
