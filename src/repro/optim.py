"""AdamW + LR schedules (cosine, WSD) with sharding-preserving state.

Moment dtype is configurable: fp32 by default, bf16 for very large MoE
models where fp32 moments alone would exceed HBM (llama4-400b on a single
pod; see EXPERIMENTS.md §Dry-run).  Optional gradient compression hooks
(int8 quantize + error feedback) live here too — applied to the DP
all-reduce in the train step when enabled.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32
    grad_clip: float = 1.0


def abstract_opt_state(params_abstract: PyTree, cfg: AdamWConfig) -> PyTree:
    mk = lambda p: jax.ShapeDtypeStruct(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(mk, params_abstract),
        "v": jax.tree.map(mk, params_abstract),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_opt_state(params: PyTree, cfg: AdamWConfig) -> PyTree:
    mk = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {"m": jax.tree.map(mk, params), "v": jax.tree.map(mk, params),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_axes(param_axes: PyTree) -> PyTree:
    """Moments shard exactly like their parameters."""
    return {"m": param_axes, "v": param_axes, "step": ()}


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params: PyTree,
    grads: PyTree,
    state: PyTree,
    cfg: AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
) -> Tuple[PyTree, PyTree]:
    step = state["step"] + 1
    if cfg.grad_clip:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m1 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v1 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mh, vh = m1 / c1, v1 / c2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * step_).astype(p.dtype),
                m1.astype(m.dtype), v1.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(warmup: int, total: int, min_frac: float = 0.1) -> Callable:
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos

    return fn


def wsd_schedule(warmup: int, stable: int, decay: int, min_frac: float = 0.1) -> Callable:
    """Warmup-Stable-Decay (MiniCPM's schedule)."""

    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
        in_decay = jnp.clip((s - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
        return warm * (1.0 - (1.0 - min_frac) * in_decay)

    return fn


# ---------------------------------------------------------------------------
# Gradient compression (int8 quantize + error feedback)
# ---------------------------------------------------------------------------

def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_with_feedback(grads: PyTree, error: Optional[PyTree]):
    """Returns (quantized-dequantized grads, new error feedback state).

    Communicating int8 grads cuts DP all-reduce volume 4x (bf16) with the
    quantization error carried into the next step."""
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_int8(gf)
        dq = dequantize_int8(q, s)
        return dq.astype(g.dtype), gf - dq

    out = jax.tree.map(one, grads, error)
    newg = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newe = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return newg, newe
