"""llama4-maverick-400b-a17b [moe] — MoE, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    head_dim=128,
    moe=MoEConfig(num_experts=128, top_k=1, capacity_factor=1.25, expert_d_ff=8192,
                  every=2, shared=True),
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
