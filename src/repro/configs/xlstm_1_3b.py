"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks.

48L d_model=2048 4H (kv=4) d_ff=0 (no separate FFN; blocks carry their own
up/down projections) vocab=50304.  Grouping: 48 = 6 groups x (1 sLSTM + 7
mLSTM), matching the paper's mostly-mLSTM [7:1] configuration.
[arXiv:2405.04517; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    head_dim=512,
    xlstm_group=8,
    xlstm_slstm_per_group=1,
    source="arXiv:2405.04517; unverified",
)
