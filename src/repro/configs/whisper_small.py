"""whisper-small [audio] — encoder-decoder, conv frontend (stub).

12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865.  The conv frontend is a
STUB: input_specs() provides precomputed frame embeddings [B, S, d].
Encoder and decoder both use 12 layers (whisper-small).
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    head_dim=64,
    encoder_layers=12,
    rope_theta=10_000.0,  # whisper uses learned/sinusoidal pos; we use RoPE-free sinusoid
    source="arXiv:2212.04356; unverified",
)
