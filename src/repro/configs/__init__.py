"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

Arch ids use the assignment's spelling (e.g. ``mixtral-8x7b``); module
names are the pythonized versions.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exports)
    ArchConfig,
    InputShape,
    MoEConfig,
    SHAPES,
    SHAPES_BY_NAME,
    cell_is_runnable,
)

_ARCH_MODULES: Dict[str, str] = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "mixtral-8x7b": "mixtral_8x7b",
    "stablelm-1.6b": "stablelm_1_6b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "minicpm-2b": "minicpm_2b",
    "glm4-9b": "glm4_9b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "xlstm-1.3b": "xlstm_1_3b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "whisper-small": "whisper_small",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    smoke = arch_id.endswith("-smoke")
    base_id = arch_id[: -len("-smoke")] if smoke else arch_id
    if base_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[base_id]}")
    cfg: ArchConfig = mod.CONFIG
    return cfg.reduced() if smoke else cfg
