"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 ratio.

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000.
Pattern: repeating (rglru, rglru, local-attn) superblocks (Griffin),
38 = 12x3 + 2 trailing recurrent layers.
[arXiv:2402.19427; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256_000,
    head_dim=256,
    window=2048,  # local attention window -> bounded cache, sub-quadratic
    block_pattern=("rglru", "rglru", "attn"),
    tail_pattern=("rglru", "rglru"),
    rope_theta=10_000.0,
    source="arXiv:2402.19427; unverified",
)
