"""glm4-9b [dense] — RoPE, aggressive GQA (kv=2).

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
[hf:THUDM/glm-4-9b; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151_552,
    head_dim=128,
    rope_theta=10_000.0,
    source="hf:THUDM/glm-4-9b; hf",
)
