"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2.
[arXiv:2401.04088; hf]
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32_000,
    head_dim=128,
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25, expert_d_ff=14336),
    window=4096,  # SWA: bounds the decode KV cache -> sub-quadratic
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088; hf",
)
