"""minicpm-2b [dense] — llama-like arch trained with the WSD schedule.

40L d_model=2304 36H (GQA kv=36) d_ff=5760 vocab=122753.
[arXiv:2404.06395; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    head_dim=64,
    rope_theta=10_000.0,
    source="arXiv:2404.06395; hf",
)
