"""llama-3.2-vision-90b [vlm] — cross-attention image layers.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Backbone only; the vision tower is a STUB (input_specs() supplies
precomputed patch embeddings).  One cross-attention layer per 4
self-attention layers: 100 = 20 x (4 self + 1 cross).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128_256,
    head_dim=128,
    cross_attn_every=4,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
