"""Architecture + input-shape configuration for the SiPipe reproduction.

Every assigned architecture gets one module in this package exporting a
``CONFIG`` built from :class:`ArchConfig`.  Configs are pure data — model
construction lives in :mod:`repro.models`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (sparse FFN)."""

    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # d_ff of each expert (falls back to ArchConfig.d_ff when 0)
    expert_d_ff: int = 0
    # MoE every Nth layer (llama4 maverick alternates dense/MoE: every=2)
    every: int = 1
    # llama4-style shared expert computed alongside the routed ones
    shared: bool = False


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """A single architecture's exact published configuration.

    ``family`` selects the model builder:
      dense   — standard decoder-only transformer (GQA, SwiGLU)
      moe     — decoder-only transformer with sparse-MoE FFN
      hybrid  — RG-LRU recurrent blocks + local attention (RecurrentGemma)
      ssm     — xLSTM (sLSTM + mLSTM blocks)
      vlm     — decoder-only text backbone with interleaved cross-attention
                to (stubbed) image patch embeddings
      audio   — encoder-decoder (Whisper) with stubbed conv frontend
    """

    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                      # 0 -> d_model // num_heads
    moe: Optional[MoEConfig] = None
    # Sliding/local attention window (0 = full attention).  Mixtral uses a
    # sliding window; RecurrentGemma uses local attention in its hybrid mix.
    window: int = 0
    # hybrid: per-superblock layer pattern, e.g. ("rglru", "rglru", "attn").
    block_pattern: Tuple[str, ...] = ()
    # number of trailing layers appended after the scanned superblocks
    # (for layer counts not divisible by the pattern length)
    tail_pattern: Tuple[str, ...] = ()
    # vlm: one cross-attention layer every `cross_attn_every` self-attn layers
    cross_attn_every: int = 0
    # audio: encoder depth (decoder uses num_layers)
    encoder_layers: int = 0
    # ssm (xLSTM): index pattern of sLSTM blocks within a group of
    # ``xlstm_group`` blocks; remaining blocks are mLSTM.
    xlstm_group: int = 0
    xlstm_slstm_per_group: int = 0

    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_position: int = 1 << 20

    # provenance (public-literature source + verification tier)
    source: str = ""

    # --- derived ---------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.family == "audio"

    @property
    def sub_quadratic(self) -> bool:
        """True when a 500k-token decode is feasible (bounded state)."""
        if self.family in ("hybrid", "ssm"):
            return True
        return self.window > 0  # sliding-window attention bounds the cache

    def padded_heads(self, tp: int) -> int:
        """Q-heads padded up so attention heads shard over ``tp`` devices.

        Padding adds zero-weight heads (documented compute overhead for
        archs whose head count does not divide the TP degree).
        """
        return int(math.ceil(self.num_heads / tp) * tp)

    def padded_kv_heads(self, tp: int) -> int:
        """KV heads shard only when divisible; otherwise replicate (GQA
        replication, the standard choice when tp > n_kv)."""
        if self.num_kv_heads % tp == 0:
            return self.num_kv_heads
        return self.num_kv_heads  # replicated, never padded

    def param_count(self) -> int:
        """Exact parameter count of the backbone (used for MODEL_FLOPS)."""
        from repro.models.registry import count_params

        return count_params(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        from repro.models.registry import count_params

        return count_params(self, active_only=True)

    def reduced(self) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        moe = kw.pop("moe")
        kw.update(
            num_layers=max(4, len(self.block_pattern) + len(self.tail_pattern)),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16,
            window=min(self.window, 32) if self.window else 0,
            max_position=4096,
        )
        if self.family == "hybrid":
            kw["num_layers"] = len(self.block_pattern) * 2 + len(self.tail_pattern)
        if self.family == "ssm":
            kw["num_layers"] = self.xlstm_group or 4
            kw["num_heads"] = 2
            kw["num_kv_heads"] = 2
            kw["head_dim"] = 32
        if self.family == "vlm":
            kw["num_layers"] = (self.cross_attn_every + 1) * 2
        if self.family == "audio":
            kw["encoder_layers"] = 2
        if moe is not None:
            kw["moe"] = MoEConfig(num_experts=4, top_k=moe["top_k"], capacity_factor=2.0,
                                  expert_d_ff=kw["d_ff"], every=moe["every"],
                                  shared=moe["shared"])
            kw["num_layers"] = 2 * moe["every"]
        kw["name"] = self.name + "-smoke"
        return ArchConfig(**kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) workload cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four assigned LM shapes (identical across all ten architectures).
SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cell_is_runnable(cfg: ArchConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and why not when skipped.

    Per assignment: ``long_500k`` requires sub-quadratic attention; pure
    full-attention archs skip it (noted in DESIGN.md).
    """
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k KV cache is quadratic-cost/unbounded; skipped per assignment"
    return True, ""
