"""Packed ragged span attention over a KV cache as Pallas TPU kernels.

The serving engine's chunked-prefill iterations carry a *packed* token
layout: all valid span tokens of a mixed batch concatenated into flat
[T] vectors (``docs/scheduling.md``).  These kernels generalize
:mod:`repro.kernels.decode_attention` — one grid row per packed token
instead of per sequence — streaming the KV cache in [kv_block] tiles
through VMEM with a flash-style running softmax in scratch.  The cache
row each token reads is data-dependent (``seq_idx``), so the row index
is scalar-prefetched (``PrefetchScalarGridSpec``) and consumed by the
BlockSpec index maps before the body runs.

Three variants, matching the pure-jnp oracles in
:mod:`repro.models.attention` (validated in interpret mode):

  span_attention          full-length cache; per-token position masking
                          with early termination past the filled prefix,
                          plus an optional sliding window whose lower
                          bound also skips whole kv blocks (the
                          ``_triangular_attention`` trick).
  span_attention_quant    int8 cache: both contractions are s8 x s8 ->
                          s32 MXU dots with the K/V scales folded
                          outside them (q and the probability rows are
                          quantized on the fly, per block).
  span_attention_rolling  sliding-window models with rolling caches
                          (slot = pos %% W): the old cache and the
                          span's fresh K/V feed one running softmax
                          (attend-then-scatter — see the jnp oracle's
                          docstring for why scatter-first is wrong).
  span_attention_rolling_quant
                          the int8 + sliding-window combination: the
                          old-cache source runs s8 x s8 -> s32 dots with
                          folded scales; the span's own fresh K/V is
                          still bf16, so the intra-span source keeps
                          full-precision dots — both into one running
                          softmax.

Every variant also has a *paged* twin (``paged_span_attention`` etc.) for
the engine's block-paged KV substrate (docs/memory.md): the physical
cache is [n_blocks, bs, Kv, hd] and each sequence's slots live at
``(block_table[p // bs], p %% bs)``.  The twins reuse the same kernel
bodies — the only change is the BlockSpec index maps, which look the
*physical* block id up in a scalar-prefetched flattened block table
(``tbl[seq[t] * nb + i]``) instead of indexing a per-sequence row, with
the kv tile pinned to the page size.  Padded table entries point at the
trash block; its garbage is never read live thanks to the same position
masks (and early-termination guards) the contiguous kernels use.

Layouts: q [T, H, hd]; caches [B, S, Kv, hd] (contiguous) or
[n_blocks, bs, Kv, hd] + block_tables [B, nb] (paged);
positions/seq_idx [T].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pick_block(s: int, kv_block: int) -> int:
    kv_block = min(kv_block, s)
    while s % kv_block:
        kv_block //= 2
    return kv_block


# ---------------------------------------------------------------------------
# Full-length cache (optionally windowed)
# ---------------------------------------------------------------------------

def _kernel(seq_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, kv_block: int, g: int, scale: float,
            ns: int, window: int):
    i_t = pl.program_id(0)
    i_s = pl.program_id(1)

    @pl.when(i_s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[i_t]
    start = i_s * kv_block
    # early termination past the filled prefix; with a window, also skip
    # blocks that lie entirely below the window's lower bound
    live = start <= pos
    if window:
        live &= start + kv_block > pos - window + 1

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # [H, hd]
        k = k_ref[0].astype(jnp.float32)               # [kb, Kv, hd]
        v = v_ref[0].astype(jnp.float32)
        h, hd = q.shape
        kv = k.shape[1]
        qg = q.reshape(kv, g, hd)
        s = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0),                  # [Kv, hd, kb]
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale
        kpos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        valid = kpos <= pos
        if window:
            valid &= kpos > pos - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_scr[...]                            # [Kv, G]
        m_new = jnp.maximum(m_prev, s.max(axis=2))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=2)
        acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
            p, v.transpose(1, 0, 2),                   # [Kv, kb, hd]
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(i_s == ns - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        out = acc_scr[...] / denom
        o_ref[0] = out.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


def span_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                   positions: jax.Array, seq_idx: jax.Array, *,
                   window: int = 0, kv_block: int = 512,
                   scale: float = 0.0, interpret: bool = True) -> jax.Array:
    """q [T,H,hd]; caches [B,S,Kv,hd]; positions/seq_idx [T] -> [T, H*hd]."""
    t, h, hd = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    kv_block = _pick_block(s, kv_block)
    ns = s // kv_block
    scale = scale or hd ** -0.5

    kernel = functools.partial(_kernel, kv_block=kv_block, g=g, scale=scale,
                               ns=ns, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(t, ns),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda t_, i, seq, pos: (t_, 0, 0)),
            pl.BlockSpec((1, kv_block, kv, hd),
                         lambda t_, i, seq, pos: (seq[t_], i, 0, 0)),
            pl.BlockSpec((1, kv_block, kv, hd),
                         lambda t_, i, seq, pos: (seq[t_], i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda t_, i, seq, pos: (t_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, h, hd), q.dtype),
        interpret=interpret,
    )(seq_idx, positions, q, k_cache, v_cache)
    return out.reshape(t, h * hd)


# ---------------------------------------------------------------------------
# int8 cache
# ---------------------------------------------------------------------------

def _quantize(x: jax.Array):
    """Per-row symmetric int8 quantization along the last axis (fp32 in)."""
    s = jnp.max(jnp.abs(x), axis=-1) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x / s[..., None]), -127, 127)
    return q.astype(jnp.int8), s


def _quant_kernel(seq_ref, pos_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
                  o_ref, m_scr, l_scr, acc_scr, *, kv_block: int, g: int,
                  scale: float, ns: int):
    i_t = pl.program_id(0)
    i_s = pl.program_id(1)

    @pl.when(i_s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[i_t]
    start = i_s * kv_block

    @pl.when(start <= pos)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # [H, hd]
        k8 = k_ref[0]                                  # [kb, Kv, hd] int8
        v8 = v_ref[0]
        ks = ks_ref[0].astype(jnp.float32)             # [kb, Kv]
        vs = vs_ref[0].astype(jnp.float32)
        h, hd = q.shape
        kv = k8.shape[1]
        q8, qs = _quantize(q.reshape(kv, g, hd))       # s8, [Kv, G]
        s32 = jax.lax.dot_general(
            q8, k8.transpose(1, 2, 0),                 # [Kv, hd, kb] s8
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32)          # [Kv, G, kb]
        s = s32.astype(jnp.float32) * qs[..., None] \
            * ks.T[:, None, :] * scale
        kpos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(kpos <= pos, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=2))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=2)
        pv = p * vs.T[:, None, :]                      # fold V scales
        p8, ps = _quantize(pv)
        o32 = jax.lax.dot_general(
            p8, v8.transpose(1, 0, 2),                 # [Kv, kb, hd] s8
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32)
        acc_scr[...] = acc_scr[...] * corr[..., None] + \
            o32.astype(jnp.float32) * ps[..., None]
        m_scr[...] = m_new

    @pl.when(i_s == ns - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        out = acc_scr[...] / denom
        o_ref[0] = out.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


def span_attention_quant(q: jax.Array, k8: jax.Array, ks: jax.Array,
                         v8: jax.Array, vs: jax.Array, positions: jax.Array,
                         seq_idx: jax.Array, *, kv_block: int = 512,
                         scale: float = 0.0, interpret: bool = True) -> jax.Array:
    """q [T,H,hd] bf16; k8/v8 [B,S,Kv,hd] int8; ks/vs [B,S,Kv] -> [T, H*hd]."""
    t, h, hd = q.shape
    s, kv = k8.shape[1], k8.shape[2]
    g = h // kv
    kv_block = _pick_block(s, kv_block)
    ns = s // kv_block
    scale = scale or hd ** -0.5

    kernel = functools.partial(_quant_kernel, kv_block=kv_block, g=g,
                               scale=scale, ns=ns)
    cache_spec = pl.BlockSpec((1, kv_block, kv, hd),
                              lambda t_, i, seq, pos: (seq[t_], i, 0, 0))
    scale_spec = pl.BlockSpec((1, kv_block, kv),
                              lambda t_, i, seq, pos: (seq[t_], i, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(t, ns),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda t_, i, seq, pos: (t_, 0, 0)),
            cache_spec, scale_spec, cache_spec, scale_spec,
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda t_, i, seq, pos: (t_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, h, hd), q.dtype),
        interpret=interpret,
    )(seq_idx, positions, q, k8, ks, v8, vs)
    return out.reshape(t, h * hd)


# ---------------------------------------------------------------------------
# Rolling cache (sliding-window models)
# ---------------------------------------------------------------------------

def _rolling_kernel(seq_ref, pos_ref, off_ref, nv_ref, q_ref, k_ref, v_ref,
                    ksp_ref, vsp_ref, posv_ref, seqv_ref, o_ref,
                    m_scr, l_scr, acc_scr, *, kv_block: int, g: int,
                    scale: float, ns: int, window: int, w_slots: int):
    i_t = pl.program_id(0)
    i_s = pl.program_id(1)

    @pl.when(i_s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[i_t]
    off = off_ref[i_t]

    def _accumulate(s, v_t):
        """One running-softmax step; s [Kv, G, n], v_t [Kv, n, hd] fp32."""
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=2))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=2)
        acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
            p, v_t, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    # old-cache source: reconstruct the position stored in each slot
    # (largest m < off with m % W == slot) to mask age and window
    @pl.when((i_s < ns) & (off >= 1))
    def _cache_block():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)               # [kb, Kv, hd]
        v = v_ref[0].astype(jnp.float32)
        h, hd = q.shape
        kv = k.shape[1]
        qg = q.reshape(kv, g, hd)
        s = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0),
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale
        slot = i_s * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        stored = off - 1 - ((off - 1 - slot) % w_slots)
        valid = (stored >= 0) & (stored > pos - window)
        _accumulate(jnp.where(valid, s, NEG_INF), v.transpose(1, 0, 2))

    # intra-span source: the packed chunk's own fresh K/V
    @pl.when(i_s == ns)
    def _span_block():
        q = q_ref[0].astype(jnp.float32)
        k = ksp_ref[...].astype(jnp.float32)           # [T, Kv, hd]
        v = vsp_ref[...].astype(jnp.float32)
        h, hd = q.shape
        kv = k.shape[1]
        qg = q.reshape(kv, g, hd)
        s = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0),
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale
        u = jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        upos = posv_ref[...][None, None, :]            # [1, 1, T]
        useq = seqv_ref[...][None, None, :]
        valid = (useq == seq_ref[i_t]) & (upos <= pos) \
            & (upos > pos - window) & (u < nv_ref[0])
        _accumulate(jnp.where(valid, s, NEG_INF), v.transpose(1, 0, 2))

    @pl.when(i_s == ns)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        out = acc_scr[...] / denom
        o_ref[0] = out.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


def _rolling_quant_kernel(seq_ref, pos_ref, off_ref, nv_ref, q_ref, k_ref,
                          ks_ref, v_ref, vs_ref, ksp_ref, vsp_ref, posv_ref,
                          seqv_ref, o_ref, m_scr, l_scr, acc_scr, *,
                          kv_block: int, g: int, scale: float, ns: int,
                          window: int, w_slots: int):
    i_t = pl.program_id(0)
    i_s = pl.program_id(1)

    @pl.when(i_s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[i_t]
    off = off_ref[i_t]

    def _accumulate(s, update_acc):
        """One running-softmax step; ``update_acc(p, corr)`` folds the AV
        contraction (int8 cache blocks requantize p, the fp span doesn't)."""
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=2))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=2)
        update_acc(p, corr)
        m_scr[...] = m_new

    # old-cache source: s8 x s8 -> s32 dots with folded scales, masked by
    # the reconstructed stored position (age + window)
    @pl.when((i_s < ns) & (off >= 1))
    def _cache_block():
        q = q_ref[0].astype(jnp.float32)               # [H, hd]
        k8 = k_ref[0]                                  # [kb, Kv, hd] int8
        v8 = v_ref[0]
        ks = ks_ref[0].astype(jnp.float32)             # [kb, Kv]
        vs = vs_ref[0].astype(jnp.float32)
        h, hd = q.shape
        kv = k8.shape[1]
        q8, qs = _quantize(q.reshape(kv, g, hd))       # s8, [Kv, G]
        s32 = jax.lax.dot_general(
            q8, k8.transpose(1, 2, 0),                 # [Kv, hd, kb] s8
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32)
        s = s32.astype(jnp.float32) * qs[..., None] \
            * ks.T[:, None, :] * scale
        slot = i_s * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        stored = off - 1 - ((off - 1 - slot) % w_slots)
        valid = (stored >= 0) & (stored > pos - window)
        s = jnp.where(valid, s, NEG_INF)

        def update_acc(p, corr):
            pv = p * vs.T[:, None, :]                  # fold V scales
            p8, ps = _quantize(pv)
            o32 = jax.lax.dot_general(
                p8, v8.transpose(1, 0, 2),             # [Kv, kb, hd] s8
                (((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.int32)
            acc_scr[...] = acc_scr[...] * corr[..., None] + \
                o32.astype(jnp.float32) * ps[..., None]

        _accumulate(s, update_acc)

    # intra-span source: the packed chunk's own fresh bf16 K/V
    @pl.when(i_s == ns)
    def _span_block():
        q = q_ref[0].astype(jnp.float32)
        k = ksp_ref[...].astype(jnp.float32)           # [T, Kv, hd]
        v = vsp_ref[...].astype(jnp.float32)
        h, hd = q.shape
        kv = k.shape[1]
        qg = q.reshape(kv, g, hd)
        s = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0),
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale
        u = jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        upos = posv_ref[...][None, None, :]            # [1, 1, T]
        useq = seqv_ref[...][None, None, :]
        valid = (useq == seq_ref[i_t]) & (upos <= pos) \
            & (upos > pos - window) & (u < nv_ref[0])
        s = jnp.where(valid, s, NEG_INF)

        def update_acc(p, corr):
            acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
                p, v.transpose(1, 0, 2),
                (((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32)

        _accumulate(s, update_acc)

    @pl.when(i_s == ns)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        out = acc_scr[...] / denom
        o_ref[0] = out.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


def span_attention_rolling_quant(q: jax.Array, k8: jax.Array, ks: jax.Array,
                                 v8: jax.Array, vs: jax.Array,
                                 k_span: jax.Array, v_span: jax.Array,
                                 positions: jax.Array, seq_idx: jax.Array,
                                 offsets: jax.Array, n_valid: jax.Array, *,
                                 window: int, kv_block: int = 512,
                                 scale: float = 0.0,
                                 interpret: bool = True) -> jax.Array:
    """Two-source windowed span attention over an int8 rolling cache.

    q [T,H,hd]; k8/v8 [B,W,Kv,hd] int8 (pre-scatter); ks/vs [B,W,Kv];
    k_span/v_span [T,Kv,hd] bf16; positions/seq_idx/offsets [T];
    n_valid [1] -> [T, H*hd].  Matches
    :func:`repro.models.attention.packed_span_attention_rolling_quant`.
    """
    t, h, hd = q.shape
    w_slots, kv = k8.shape[1], k8.shape[2]
    g = h // kv
    kv_block = _pick_block(w_slots, kv_block)
    ns = w_slots // kv_block
    scale = scale or hd ** -0.5

    kernel = functools.partial(_rolling_quant_kernel, kv_block=kv_block, g=g,
                               scale=scale, ns=ns, window=window,
                               w_slots=w_slots)

    def cache_idx(t_, i, seq, pos, off, nv):
        return (seq[t_], jnp.minimum(i, ns - 1), 0, 0)

    def scale_idx(t_, i, seq, pos, off, nv):
        return (seq[t_], jnp.minimum(i, ns - 1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,        # seq_idx, positions, offsets, n_valid
        grid=(t, ns + 1),             # ns cache blocks + 1 span block
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda t_, i, *_: (t_, 0, 0)),
            pl.BlockSpec((1, kv_block, kv, hd), cache_idx),
            pl.BlockSpec((1, kv_block, kv), scale_idx),
            pl.BlockSpec((1, kv_block, kv, hd), cache_idx),
            pl.BlockSpec((1, kv_block, kv), scale_idx),
            pl.BlockSpec((t, kv, hd), lambda t_, i, *_: (0, 0, 0)),
            pl.BlockSpec((t, kv, hd), lambda t_, i, *_: (0, 0, 0)),
            pl.BlockSpec((t,), lambda t_, i, *_: (0,)),
            pl.BlockSpec((t,), lambda t_, i, *_: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda t_, i, *_: (t_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, h, hd), q.dtype),
        interpret=interpret,
    )(seq_idx, positions, offsets, n_valid, q, k8, ks, v8, vs,
      k_span, v_span, positions, seq_idx)
    return out.reshape(t, h * hd)


def span_attention_rolling(q: jax.Array, k_cache: jax.Array,
                           v_cache: jax.Array, k_span: jax.Array,
                           v_span: jax.Array, positions: jax.Array,
                           seq_idx: jax.Array, offsets: jax.Array,
                           n_valid: jax.Array, *, window: int,
                           kv_block: int = 512, scale: float = 0.0,
                           interpret: bool = True) -> jax.Array:
    """Two-source windowed span attention over a rolling cache.

    q [T,H,hd]; caches [B,W,Kv,hd] (pre-scatter); k_span/v_span [T,Kv,hd];
    positions/seq_idx/offsets [T]; n_valid [1] -> [T, H*hd].
    """
    t, h, hd = q.shape
    w_slots, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    kv_block = _pick_block(w_slots, kv_block)
    ns = w_slots // kv_block
    scale = scale or hd ** -0.5

    kernel = functools.partial(_rolling_kernel, kv_block=kv_block, g=g,
                               scale=scale, ns=ns, window=window,
                               w_slots=w_slots)

    def cache_idx(t_, i, seq, pos, off, nv):
        return (seq[t_], jnp.minimum(i, ns - 1), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,        # seq_idx, positions, offsets, n_valid
        grid=(t, ns + 1),             # ns cache blocks + 1 span block
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda t_, i, *_: (t_, 0, 0)),
            pl.BlockSpec((1, kv_block, kv, hd), cache_idx),
            pl.BlockSpec((1, kv_block, kv, hd), cache_idx),
            pl.BlockSpec((t, kv, hd), lambda t_, i, *_: (0, 0, 0)),
            pl.BlockSpec((t, kv, hd), lambda t_, i, *_: (0, 0, 0)),
            pl.BlockSpec((t,), lambda t_, i, *_: (0,)),
            pl.BlockSpec((t,), lambda t_, i, *_: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda t_, i, *_: (t_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, h, hd), q.dtype),
        interpret=interpret,
    )(seq_idx, positions, offsets, n_valid, q, k_cache, v_cache,
      k_span, v_span, positions, seq_idx)
    return out.reshape(t, h * hd)


# ---------------------------------------------------------------------------
# Paged twins: block-table scalar prefetch over [n_blocks, bs, Kv, hd]
# ---------------------------------------------------------------------------
# The kernel bodies are the contiguous ones verbatim — a thin wrapper
# drops the extra block-table scalar ref (only the index maps consume it)
# and the kv tile is the page block size, so logical block i of token t's
# sequence is fetched from physical block ``tbl[seq[t] * nb + i]``.

def _resolve_interpret(interpret: bool | None) -> bool:
    """``None`` = auto: compiled on TPU, interpret-mode elsewhere.

    The paged twins are the engine's execution path (attention.py routes
    through them on TPU backends), so their default must not silently pin
    interpret mode the way the contiguous validation wrappers do."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _paged_kernel(seq_ref, pos_ref, tbl_ref, *rest, **kw):
    _kernel(seq_ref, pos_ref, *rest, **kw)


def _paged_quant_kernel(seq_ref, pos_ref, tbl_ref, *rest, **kw):
    _quant_kernel(seq_ref, pos_ref, *rest, **kw)


def _paged_rolling_kernel(seq_ref, pos_ref, off_ref, nv_ref, tbl_ref,
                          *rest, **kw):
    _rolling_kernel(seq_ref, pos_ref, off_ref, nv_ref, *rest, **kw)


def _paged_rolling_quant_kernel(seq_ref, pos_ref, off_ref, nv_ref, tbl_ref,
                                *rest, **kw):
    _rolling_quant_kernel(seq_ref, pos_ref, off_ref, nv_ref, *rest, **kw)


def paged_span_attention(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, positions: jax.Array,
                         seq_idx: jax.Array, block_tables: jax.Array, *,
                         window: int = 0, scale: float = 0.0,
                         interpret: bool | None = None) -> jax.Array:
    """q [T,H,hd]; caches [n_blocks,bs,Kv,hd]; block_tables [B,nb];
    positions/seq_idx [T] -> [T, H*hd].  Matches
    :func:`repro.models.attention.paged_span_attention`."""
    interpret = _resolve_interpret(interpret)
    t, h, hd = q.shape
    bs, kv = k_cache.shape[1], k_cache.shape[2]
    nb = block_tables.shape[1]
    g = h // kv
    scale = scale or hd ** -0.5

    kernel = functools.partial(_paged_kernel, kv_block=bs, g=g, scale=scale,
                               ns=nb, window=window)
    tbl = block_tables.reshape(-1).astype(jnp.int32)
    cache_spec = pl.BlockSpec(
        (1, bs, kv, hd),
        lambda t_, i, seq, pos, tb: (tb[seq[t_] * nb + i], 0, 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,        # seq_idx, positions, block table
        grid=(t, nb),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda t_, i, *_: (t_, 0, 0)),
            cache_spec,
            cache_spec,
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda t_, i, *_: (t_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, h, hd), q.dtype),
        interpret=interpret,
    )(seq_idx, positions, tbl, q, k_cache, v_cache)
    return out.reshape(t, h * hd)


def paged_span_attention_quant(q: jax.Array, k8: jax.Array, ks: jax.Array,
                               v8: jax.Array, vs: jax.Array,
                               positions: jax.Array, seq_idx: jax.Array,
                               block_tables: jax.Array, *,
                               scale: float = 0.0,
                               interpret: bool | None = None) -> jax.Array:
    """q [T,H,hd] bf16; k8/v8 [n_blocks,bs,Kv,hd] int8; ks/vs
    [n_blocks,bs,Kv]; block_tables [B,nb] -> [T, H*hd]."""
    interpret = _resolve_interpret(interpret)
    t, h, hd = q.shape
    bs, kv = k8.shape[1], k8.shape[2]
    nb = block_tables.shape[1]
    g = h // kv
    scale = scale or hd ** -0.5

    kernel = functools.partial(_paged_quant_kernel, kv_block=bs, g=g,
                               scale=scale, ns=nb)
    tbl = block_tables.reshape(-1).astype(jnp.int32)
    cache_spec = pl.BlockSpec(
        (1, bs, kv, hd),
        lambda t_, i, seq, pos, tb: (tb[seq[t_] * nb + i], 0, 0, 0))
    scale_spec = pl.BlockSpec(
        (1, bs, kv),
        lambda t_, i, seq, pos, tb: (tb[seq[t_] * nb + i], 0, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(t, nb),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda t_, i, *_: (t_, 0, 0)),
            cache_spec, scale_spec, cache_spec, scale_spec,
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda t_, i, *_: (t_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, h, hd), q.dtype),
        interpret=interpret,
    )(seq_idx, positions, tbl, q, k8, ks, v8, vs)
    return out.reshape(t, h * hd)


def paged_span_attention_rolling(q: jax.Array, k_cache: jax.Array,
                                 v_cache: jax.Array, k_span: jax.Array,
                                 v_span: jax.Array, positions: jax.Array,
                                 seq_idx: jax.Array, offsets: jax.Array,
                                 n_valid: jax.Array,
                                 block_tables: jax.Array, *, window: int,
                                 scale: float = 0.0,
                                 interpret: bool | None = None) -> jax.Array:
    """Two-source windowed span attention over a block-paged rolling cache.

    caches [n_blocks,bs,Kv,hd] (pre-scatter); block_tables [B,nb] with the
    gathered view width ``nb * bs`` playing the stored-position modulus
    (== W once a row's table covers the full window).  Matches
    :func:`repro.models.attention.paged_span_attention_rolling`."""
    interpret = _resolve_interpret(interpret)
    t, h, hd = q.shape
    bs, kv = k_cache.shape[1], k_cache.shape[2]
    nb = block_tables.shape[1]
    g = h // kv
    scale = scale or hd ** -0.5

    kernel = functools.partial(_paged_rolling_kernel, kv_block=bs, g=g,
                               scale=scale, ns=nb, window=window,
                               w_slots=nb * bs)
    tbl = block_tables.reshape(-1).astype(jnp.int32)

    def cache_idx(t_, i, seq, pos, off, nv, tb):
        return (tb[seq[t_] * nb + jnp.minimum(i, nb - 1)], 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,    # seq_idx, positions, offsets, n_valid, tbl
        grid=(t, nb + 1),         # nb cache blocks + 1 span block
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda t_, i, *_: (t_, 0, 0)),
            pl.BlockSpec((1, bs, kv, hd), cache_idx),
            pl.BlockSpec((1, bs, kv, hd), cache_idx),
            pl.BlockSpec((t, kv, hd), lambda t_, i, *_: (0, 0, 0)),
            pl.BlockSpec((t, kv, hd), lambda t_, i, *_: (0, 0, 0)),
            pl.BlockSpec((t,), lambda t_, i, *_: (0,)),
            pl.BlockSpec((t,), lambda t_, i, *_: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda t_, i, *_: (t_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, h, hd), q.dtype),
        interpret=interpret,
    )(seq_idx, positions, offsets, n_valid, tbl, q, k_cache, v_cache,
      k_span, v_span, positions, seq_idx)
    return out.reshape(t, h * hd)


def paged_span_attention_rolling_quant(q: jax.Array, k8: jax.Array,
                                       ks: jax.Array, v8: jax.Array,
                                       vs: jax.Array, k_span: jax.Array,
                                       v_span: jax.Array,
                                       positions: jax.Array,
                                       seq_idx: jax.Array,
                                       offsets: jax.Array,
                                       n_valid: jax.Array,
                                       block_tables: jax.Array, *,
                                       window: int, scale: float = 0.0,
                                       interpret: bool | None = None,
                                       ) -> jax.Array:
    """The int8 + sliding-window + paged combination: s8 x s8 -> s32
    old-cache dots with folded scales, bf16 intra-span source, block-table
    scalar prefetch — one running softmax."""
    interpret = _resolve_interpret(interpret)
    t, h, hd = q.shape
    bs, kv = k8.shape[1], k8.shape[2]
    nb = block_tables.shape[1]
    g = h // kv
    scale = scale or hd ** -0.5

    kernel = functools.partial(_paged_rolling_quant_kernel, kv_block=bs,
                               g=g, scale=scale, ns=nb, window=window,
                               w_slots=nb * bs)
    tbl = block_tables.reshape(-1).astype(jnp.int32)

    def cache_idx(t_, i, seq, pos, off, nv, tb):
        return (tb[seq[t_] * nb + jnp.minimum(i, nb - 1)], 0, 0, 0)

    def scale_idx(t_, i, seq, pos, off, nv, tb):
        return (tb[seq[t_] * nb + jnp.minimum(i, nb - 1)], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(t, nb + 1),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda t_, i, *_: (t_, 0, 0)),
            pl.BlockSpec((1, bs, kv, hd), cache_idx),
            pl.BlockSpec((1, bs, kv), scale_idx),
            pl.BlockSpec((1, bs, kv, hd), cache_idx),
            pl.BlockSpec((1, bs, kv), scale_idx),
            pl.BlockSpec((t, kv, hd), lambda t_, i, *_: (0, 0, 0)),
            pl.BlockSpec((t, kv, hd), lambda t_, i, *_: (0, 0, 0)),
            pl.BlockSpec((t,), lambda t_, i, *_: (0,)),
            pl.BlockSpec((t,), lambda t_, i, *_: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda t_, i, *_: (t_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, h, hd), q.dtype),
        interpret=interpret,
    )(seq_idx, positions, offsets, n_valid, tbl, q, k8, ks, v8, vs,
      k_span, v_span, positions, seq_idx)
    return out.reshape(t, h * hd)
