"""Single-token (decode) attention over a KV cache as a Pallas TPU kernel.

The decode step is memory-bound: the kernel streams the cache once from
HBM through VMEM in [kv_block x Kv x hd] tiles while all H query heads of
one sequence stay resident, accumulating flash-style running softmax per
head in VMEM scratch.  Length masking comes from a per-sequence ``lengths``
vector (valid cache prefix), which is how the serving engine expresses
ragged batches.

Layouts: q [B, H, hd]; k_cache/v_cache [B, S, Kv, hd]; lengths [B] int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            kv_block: int, g: int, scale: float, ns: int):
    i_s = pl.program_id(1)

    @pl.when(i_s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    start = i_s * kv_block

    @pl.when(start < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # [H, hd]
        k = k_ref[0].astype(jnp.float32)               # [kb, Kv, hd]
        v = v_ref[0].astype(jnp.float32)
        h, hd = q.shape
        kv = k.shape[1]
        qg = q.reshape(kv, g, hd)
        # scores [Kv, G, kb]
        s = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0),                  # [Kv, hd, kb]
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale
        kpos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(kpos < length, s, NEG_INF)

        m_prev = m_scr[...]                            # [Kv, G]
        m_new = jnp.maximum(m_prev, s.max(axis=2))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=2)
        # acc [Kv, G, hd] += p @ v
        acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
            p, v.transpose(1, 0, 2),                   # [Kv, kb, hd]
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(i_s == ns - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        out = acc_scr[...] / denom                     # [Kv, G, hd]
        o_ref[0] = out.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *, kv_block: int = 512,
                     scale: float = 0.0, interpret: bool = True) -> jax.Array:
    """q [B,H,hd]; caches [B,S,Kv,hd]; lengths [B] -> [B, H*hd]."""
    b, h, hd = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    kv_block = min(kv_block, s)
    while s % kv_block:
        kv_block //= 2
    ns = s // kv_block
    scale = scale or hd ** -0.5

    kernel = functools.partial(_kernel, kv_block=kv_block, g=g, scale=scale,
                               ns=ns)
    out = pl.pallas_call(
        kernel,
        grid=(b, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, i: (b_,)),
            pl.BlockSpec((1, h, hd), lambda b_, i: (b_, 0, 0)),
            pl.BlockSpec((1, kv_block, kv, hd), lambda b_, i: (b_, i, 0, 0)),
            pl.BlockSpec((1, kv_block, kv, hd), lambda b_, i: (b_, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda b_, i: (b_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g), jnp.float32),
            pltpu.VMEM((kv, g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k_cache, v_cache)
    return out.reshape(b, h * hd)
