"""Fused SwiGLU MLP (silu(x@w1) * (x@w3)) @ w2 as a Pallas TPU kernel.

Fusing the three matmuls keeps the [T, ff] intermediate inside VMEM tiles
instead of round-tripping it through HBM: the grid iterates ff blocks in
the minor dimension and accumulates partial products of the down
projection into a VMEM scratch accumulator — HBM traffic drops from
2*T*ff (+weights) to weights-only.

Layouts: x [T, d]; w1, w3 [d, ff]; w2 [ff, d]; out [T, d].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref, acc_scr, *, nf: int):
    i_f = pl.program_id(1)

    @pl.when(i_f == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...]
    a = jax.lax.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    b = jax.lax.dot(x, w3_ref[...], preferred_element_type=jnp.float32)
    h = (jax.nn.silu(a) * b).astype(x.dtype)
    acc_scr[...] += jax.lax.dot(h, w2_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(i_f == nf - 1)
    def _finalize():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array, *,
           t_block: int = 256, f_block: int = 512,
           interpret: bool = True) -> jax.Array:
    t, d = x.shape
    ff = w1.shape[1]
    t_block = min(t_block, t)
    while t % t_block:
        t_block //= 2
    f_block = min(f_block, ff)
    while ff % f_block:
        f_block //= 2
    nt, nf = t // t_block, ff // f_block

    kernel = functools.partial(_kernel, nf=nf)
    return pl.pallas_call(
        kernel,
        grid=(nt, nf),
        in_specs=[
            pl.BlockSpec((t_block, d), lambda it, if_: (it, 0)),
            pl.BlockSpec((d, f_block), lambda it, if_: (0, if_)),
            pl.BlockSpec((d, f_block), lambda it, if_: (0, if_)),
            pl.BlockSpec((f_block, d), lambda it, if_: (if_, 0)),
        ],
        out_specs=pl.BlockSpec((t_block, d), lambda it, if_: (it, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((t_block, d), jnp.float32)],
        interpret=interpret,
    )(x, w1, w3, w2)
