"""Flash attention (prefill) as a Pallas TPU kernel.

TPU adaptation notes (vs. the CUDA flash-attention formulation):
  * the grid's minor dimension iterates KV blocks sequentially; running
    softmax statistics (m, l) and the output accumulator live in VMEM
    scratch that persists across grid steps — the TPU analogue of keeping
    them in registers/SMEM on GPU;
  * blocks are (q_block x head_dim) / (kv_block x head_dim) with head_dim
    a multiple of 128 so the MXU sees aligned matmuls;
  * GQA is expressed in the BlockSpec index_map (kv head = q head // G) —
    no materialized key/value repetition;
  * fully-masked causal blocks are skipped with pl.when (block-level
    triangular schedule — compute proportional to the causal half).

Layouts: q [B, H, Sq, hd];  k, v [B, Kv, Skv, hd];  out [B, H, Sq, hd].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, q_block: int, kv_block: int,
            scale: float, nk: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * q_block
    k_start = ik * kv_block
    live = True
    if causal:
        live = k_start <= q_start + q_block - 1        # block intersects causal
    if window:
        live = jnp.logical_and(live, k_start + kv_block > q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [qb, hd]
        k = k_ref[0, 0].astype(jnp.float32)            # [kb, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_block: int = 256, kv_block: int = 256,
                    scale: float = 0.0, interpret: bool = True) -> jax.Array:
    """q [B,H,Sq,hd]; k,v [B,Kv,Skv,hd] -> [B,H,Sq,hd]."""
    b, h, sq, hd = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    q_block = min(q_block, sq)
    while sq % q_block:
        q_block //= 2
    kv_block = min(kv_block, skv)
    while skv % kv_block:
        kv_block //= 2
    nq, nk = sq // q_block, skv // kv_block
    scale = scale or hd ** -0.5

    kernel = functools.partial(
        _kernel, causal=causal, window=window, q_block=q_block,
        kv_block=kv_block, scale=scale, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, q_block, hd), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, kv_block, hd), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, kv_block, hd), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, hd), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),   # running max m
            pltpu.VMEM((q_block,), jnp.float32),   # running sum l
            pltpu.VMEM((q_block, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
