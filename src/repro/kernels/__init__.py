"""Pallas TPU kernels for the serving hot paths.

Each kernel follows the <name>.py (pl.pallas_call + BlockSpec) / ops.py
(jit'd wrappers) / ref.py (pure-jnp oracle) convention; tests sweep
shapes/dtypes and assert_allclose against the oracles in interpret mode.
"""
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.decode_attention import decode_attention  # noqa: F401
from repro.kernels.span_attention import (  # noqa: F401
    span_attention,
    span_attention_quant,
    span_attention_rolling,
)
from repro.kernels.swiglu import swiglu  # noqa: F401
