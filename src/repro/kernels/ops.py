"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and should be False
on real TPUs; the layout adapters here translate between the model's
[B, S, H, hd] convention and the kernels' head-major tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.span_attention import span_attention as _span
from repro.kernels.swiglu import swiglu as _swiglu
from repro.kernels.rmsnorm_matmul import rmsnorm_matmul as _rmsnorm_mm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "kv_block"))
def flash_attention_bshd(q, k, v, *, causal: bool = True, window: int = 0,
                         q_block: int = 256, kv_block: int = 256):
    """Model-layout adapter: q [B,S,H,hd], k/v [B,S,Kv,hd] -> [B,S,H*hd]."""
    b, s, h, hd = q.shape
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = _flash(qt, kt, vt, causal=causal, window=window, q_block=q_block,
               kv_block=kv_block, interpret=not _on_tpu())
    return o.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


@functools.partial(jax.jit, static_argnames=("kv_block",))
def decode_attention_cached(q, k_cache, v_cache, lengths, *, kv_block: int = 512):
    """q [B,H,hd]; caches [B,S,Kv,hd]; lengths [B] -> [B, H*hd]."""
    return _decode(q, k_cache, v_cache, lengths, kv_block=kv_block,
                   interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("window", "kv_block"))
def span_attention_packed(q, k_cache, v_cache, positions, seq_idx, *,
                          window: int = 0, kv_block: int = 512):
    """Packed ragged chunk attention: q [T,H,hd]; caches [B,S,Kv,hd];
    positions/seq_idx [T] -> [T, H*hd]."""
    return _span(q, k_cache, v_cache, positions, seq_idx, window=window,
                 kv_block=kv_block, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("t_block", "f_block"))
def swiglu_fused(x, w1, w3, w2, *, t_block: int = 256, f_block: int = 512):
    """x [..., d] -> [..., d] fused gated MLP."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _swiglu(x2, w1, w3, w2, t_block=t_block, f_block=f_block,
                interpret=not _on_tpu())
    return y.reshape(*lead, -1)


@functools.partial(jax.jit, static_argnames=("eps", "t_block", "f_block"))
def rmsnorm_matmul_fused(x, w_norm, w_proj, *, eps: float = 1e-5,
                         t_block: int = 256, f_block: int = 512):
    """Fused block-entry norm + projection: x [..., d] -> [..., F]."""
    lead = x.shape[:-1]
    y = _rmsnorm_mm(x.reshape(-1, x.shape[-1]), w_norm, w_proj, eps=eps,
                    t_block=t_block, f_block=f_block,
                    interpret=not _on_tpu())
    return y.reshape(*lead, -1)
