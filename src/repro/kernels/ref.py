"""Pure-jnp oracles for every Pallas kernel (the ground truth the
interpret-mode kernels are validated against in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float = 0.0):
    """Naive full-materialization attention.  q [B,Sq,H,hd]; k,v [B,Skv,Kv,hd]."""
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    sc = scale or hd ** -0.5
    s = jnp.einsum("bsgqd,btgd->bgqst", qg, k).astype(jnp.float32) * sc
    if causal or window:
        qpos = jnp.arange(sq)[:, None]
        kpos = jnp.arange(skv)[None, :]
        mask = jnp.ones((sq, skv), bool)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqst,btgd->bsgqd", p.astype(q.dtype), v)
    return o.reshape(b, sq, h * hd)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q [B,H,hd]; caches [B,S,Kv,hd]; lengths [B] = #valid positions."""
    b, h, hd = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, hd)
    sc = hd ** -0.5
    scores = jnp.einsum("bgqd,bsgd->bgqs", qg, k_cache).astype(jnp.float32) * sc
    valid = jnp.arange(s)[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bgqs,bsgd->bgqd", p.astype(q.dtype), v_cache)
    return o.reshape(b, h * hd)


def rmsnorm_matmul_ref(x, w_norm, w_proj, eps: float = 1e-5):
    """Fused RMSNorm + projection oracle.  x [T, d]; w_proj [d, f]."""
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    h = (xf * inv).astype(x.dtype) * w_norm
    return h @ w_proj


def swiglu_ref(x, w1, w3, w2):
    """Gated-SiLU MLP oracle.  x [T, d]; w1/w3 [d, f]; w2 [f, d]."""
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2
