"""Fused RMSNorm + projection as a Pallas TPU kernel.

Every transformer block enters its matmuls through an RMSNorm; fusing the
normalization into the projection's LHS load avoids materializing the
normalized activations in HBM (a [T, d] round-trip per block entry).
The row statistics are recomputed per (t-block, f-block) tile — an
elementwise cost that is negligible next to the matmul and the saved
bandwidth (the standard TPU trade: recompute in VMEM over HBM traffic).

Layouts: x [T, d]; w_norm [d]; w_proj [d, F]; out [T, F].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wn_ref, wp_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                  # [tb, d]
    inv = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    h = (x * inv).astype(o_ref.dtype) * wn_ref[...]
    o_ref[...] = jax.lax.dot(
        h, wp_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def rmsnorm_matmul(x: jax.Array, w_norm: jax.Array, w_proj: jax.Array, *,
                   eps: float = 1e-5, t_block: int = 256, f_block: int = 512,
                   interpret: bool = True) -> jax.Array:
    t, d = x.shape
    f = w_proj.shape[1]
    t_block = min(t_block, t)
    while t % t_block:
        t_block //= 2
    f_block = min(f_block, f)
    while f % f_block:
        f_block //= 2

    kernel = functools.partial(_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(t // t_block, f // f_block),
        in_specs=[
            pl.BlockSpec((t_block, d), lambda it, if_: (it, 0)),
            pl.BlockSpec((d,), lambda it, if_: (0,)),
            pl.BlockSpec((d, f_block), lambda it, if_: (0, if_)),
        ],
        out_specs=pl.BlockSpec((t_block, f_block), lambda it, if_: (it, if_)),
        out_shape=jax.ShapeDtypeStruct((t, f), x.dtype),
        interpret=interpret,
    )(x, w_norm, w_proj)
