"""Logical-axis sharding: rules mapping logical tensor axes to mesh axes.

Every parameter / activation / cache tensor carries a tuple of *logical*
axis names.  A strategy (serve / train / pp) supplies an ordered rule list
per logical axis; the resolver picks the first candidate whose mesh axes
are free on this tensor and divide the dimension.  Non-divisible dims fall
back to replication (e.g. glm4's kv=2 heads under tp=16), in which case a
later logical axis (e.g. the cache's ``kv_seq``) can claim the mesh axis
instead — that is how sequence-parallel KV caches appear automatically.
"""
from __future__ import annotations

import math
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Rule tables.  Each entry: logical axis -> tuple of candidates; a candidate
# is a tuple of mesh axis names (sharded jointly, in order).
# ---------------------------------------------------------------------------

Rules = Mapping[str, Sequence[Tuple[str, ...]]]

# Inference: Megatron-style TP on "model", batch data-parallel over
# ("pod", "data").  KV caches prefer head sharding, then sequence sharding.
SERVE_RULES: Rules = {
    "batch": (("pod", "data"), ("data",), ("pod",)),
    "vocab": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "ff": (("model",),),
    "experts": (("model",),),
    "expert_ff": (("model",),),
    "kv_seq": (("model",),),      # claimed only when kv_heads replicated
    "rnn": (("model",),),         # RG-LRU / xLSTM inner width
    "embed": (),                  # replicated at serve time
    "layers": (),
    "seq": (),
    "head_dim": (),
    "patches": (),
}

# Training: TP on "model" + FSDP-style weight sharding over "data" on the
# non-TP dim ("embed"), batch over ("pod", "data").
TRAIN_RULES: Rules = {
    "batch": (("pod", "data"), ("data",), ("pod",)),
    "vocab": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "ff": (("model",),),
    "experts": (("model",),),
    "expert_ff": (("model",),),
    "embed": (("data",),),        # ZeRO-3/FSDP over the data axis
    "rnn": (("model",),),
    "kv_seq": (),
    "layers": (),
    "seq": (),
    # Megatron-SP residual stream (enabled by ModelOptions.seq_shard)
    "seq_sp": (("model",),),
    "head_dim": (),
    "patches": (),
}

# Pipeline-parallel (the paper's regime): derived mesh ("pipe","data","model").
# Stage ("layers"-stacked) weights shard over "pipe"; otherwise as serve.
PP_RULES: Rules = {
    **SERVE_RULES,
    "stage": (("pipe",),),
    "batch": (("data",), ("pod", "data")),
}

RULESETS: Dict[str, Rules] = {
    "serve": SERVE_RULES,
    "train": TRAIN_RULES,
    "pp": PP_RULES,
}


def resolve_pspec(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    rules: Rules,
    mesh: Mesh,
) -> P:
    """First-fit-divisible mapping of one tensor's logical axes to a PartitionSpec."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    out = []
    for dim, lax in zip(shape, logical_axes):
        assigned: Optional[Tuple[str, ...]] = None
        if lax is not None:
            for cand in rules.get(lax, ()):  # ordered candidates
                axes = tuple(a for a in cand if a in mesh_shape)
                if not axes or any(a in used for a in axes):
                    continue
                size = int(np.prod([mesh_shape[a] for a in axes]))
                if size > 1 and dim % size == 0:
                    assigned = axes
                    used.update(axes)
                    break
        if assigned is None:
            out.append(None)
        elif len(assigned) == 1:
            out.append(assigned[0])
        else:
            out.append(assigned)
    # trim trailing Nones (canonical form)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    strategy: str,
    mesh: Mesh,
) -> NamedSharding:
    return NamedSharding(mesh, resolve_pspec(logical_axes, shape, RULESETS[strategy], mesh))


def tree_pspecs(axes_tree, shape_tree, strategy: str, mesh: Mesh):
    """Map pytrees of logical-axes tuples + ShapeDtypeStructs -> PartitionSpecs."""
    rules = RULESETS[strategy]
    return jax.tree.map(
        lambda ax, sd: resolve_pspec(ax, sd.shape, rules, mesh),
        axes_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def tree_shardings(axes_tree, shape_tree, strategy: str, mesh: Mesh):
    specs = tree_pspecs(axes_tree, shape_tree, strategy, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, logical_axes: Sequence[Optional[str]], strategy: str, mesh: Optional[Mesh]):
    """with_sharding_constraint by logical axes (no-op when mesh is None/1-dev)."""
    if mesh is None or math.prod(mesh.devices.shape) == 1:
        return x
    spec = resolve_pspec(logical_axes, x.shape, RULESETS[strategy], mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
