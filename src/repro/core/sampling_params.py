"""Per-request sampling parameters (vLLM-compatible subset)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 1.0
    top_k: int = 0            # 0 = disabled
    top_p: float = 1.0        # 1.0 = disabled
    min_p: float = 0.0        # 0.0 = disabled
    frequency_penalty: float = 0.0
    presence_penalty: float = 0.0
    repetition_penalty: float = 1.0   # 1.0 = disabled (multiplicative)
    max_new_tokens: int = 64
    eos_token_id: int = -1    # -1 = never stop on EOS
    greedy: bool = False
    # parallel sampling: n completions from one prompt prefill.  n-1
    # children are CoW-forked off the parent's KV when its first token
    # lands (docs/memory.md "Prefix caching & CoW forks"); paged KV only.
    n: int = 1
    # request priority (docs/http.md): higher values are served first.
    # Threaded through Sequence into the scheduler — admission orders the
    # waiting queue priority-then-FIFO, and the paged preemption victim
    # choice is lowest-priority-then-latest-arrival, so under block
    # pressure low-priority requests are evicted before high-priority
    # ones.  0 is the neutral default; negative values mark best-effort
    # background work (e.g. offline batch traffic).
    priority: int = 0
    # workload tier (docs/hybrid.md): "online" requests are foreground
    # latency-SLO traffic; "offline" requests (evals, synthetic data,
    # backfills) queue separately, are admitted only into measured
    # pipeline slack, and are ALWAYS the first preemption victims — an
    # offline sequence ranks below every online priority, including
    # negative ones.  Priority still orders requests WITHIN a tier.
    tier: str = "online"

    def __post_init__(self):
        if self.tier not in ("online", "offline"):
            raise ValueError(
                f"tier must be 'online' or 'offline', got {self.tier!r}")

    def needs_penalties(self) -> bool:
        return (
            self.frequency_penalty != 0.0
            or self.presence_penalty != 0.0
            or self.repetition_penalty != 1.0
        )
