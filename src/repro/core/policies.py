"""Pluggable scheduling policies behind the SchedulingOutput span interface.

The continuous-batching scheduler (repro.core.scheduler) owns the durable
state — sequences, the waiting queue, per-slot membership — and delegates
each iteration's admission + span construction to a ``SchedulingPolicy``:

  monolithic     whole-prompt prefills dispatched as pipeline-blocking
                 ``is_prefill`` batches (the seed behavior; the engine's
                 ``_admit_and_prefill`` runs them through every stage).
  chunked        SARATHI-style chunked prefill: decode members always carry
                 their 1 token, the remaining per-iteration token budget is
                 handed to prefilling members as prompt chunks (PR 1-2).
  disaggregated  TD-Pipe-style temporal disaggregation: the pipeline
                 alternates *prefill phases* (iterations carry only prompt
                 chunks at the full token budget, zero decode piggybacking;
                 admission happens here) and *decode phases* (pure 1-token
                 iterations that keep the TSEM incremental n/n+p fast path),
                 switched by a hysteresis threshold on pending-prefill
                 tokens vs. the in-flight decode slots being paused.
  adaptive       chunked scheduling with a latency-SLO adaptive token
                 budget: shrinks the chunk budget when the live TPOT
                 (Scheduler.tpot_samples, fed by the request layer's
                 completion path) breaches the SLO, grows it back under
                 headroom.

Every policy emits the same per-seq ``(offset, n_tokens)`` spans, so TSEM
staging, the packed [T] chunk execution path, SAT transmission and the
sampler pool need no wire changes; a new policy is a subclass here, not
an engine fork.  See docs/scheduling.md §Scheduling policies and
docs/serving.md for the request lifecycle feeding the adaptive budget.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Optional, Tuple

import numpy as np

from repro.core.sequence import SeqStatus, Sequence

if TYPE_CHECKING:  # avoid the runtime cycle scheduler <-> policies
    from repro.core.scheduler import Scheduler, SchedulingOutput


def _span_output(s: "Scheduler", it: int, slot: int, batch_ids: List[int],
                 spans: List[Tuple[int, int]], span_tokens: List[List[int]],
                 needs_sample: List[bool], recomposed: bool) -> "SchedulingOutput":
    """Assemble a span-carrying SchedulingOutput (shared by span policies)."""
    from repro.core.scheduler import SchedulingOutput

    return SchedulingOutput(
        iteration=it,
        slot=slot,
        seq_ids=batch_ids,
        positions=np.array([off for off, _ in spans], np.int32),
        tokens=np.array([t[0] for t in span_tokens], np.int32),
        is_prefill=False,          # no monolithic pipeline-blocking pass
        # span-relevant prefill length: the prompt, or — for a sequence
        # resuming from preemption — its full recomputed token history
        prompt_lens=[s.seqs[q].prefill_len for q in batch_ids],
        batch_recomposed=recomposed,
        spans=spans,
        span_tokens=span_tokens,
        needs_sample=needs_sample,
    )


class SchedulingPolicy:
    """Builds one iteration's SchedulingOutput from scheduler state.

    ``uses_spans`` declares the execution contract: span policies emit
    per-seq ``(offset, n_tokens)`` spans executed through the packed-[T]
    chunk path (and require a token budget); the monolithic policy emits
    flat decode batches plus ``is_prefill`` admission batches.
    """

    name: str = "?"
    uses_spans: bool = False

    def schedule(self, s: "Scheduler", it: int) -> Optional["SchedulingOutput"]:
        raise NotImplementedError

    def metrics(self) -> Dict[str, int]:
        """Policy-specific counters, merged into engine metrics."""
        return {}

    @staticmethod
    def _alive_members(s: "Scheduler", slot: int) -> Tuple[List[int], bool]:
        """Slot membership minus finished sequences; True if it shrank."""
        members = [sid for sid in s.slot_members[slot]
                   if s.seqs[sid].status == SeqStatus.RUNNING]
        return members, len(members) != len(s.slot_members[slot])

    @staticmethod
    def _tier_split(s: "Scheduler",
                    members: List[int]) -> Tuple[List[int], List[int]]:
        """Partition slot members by tier, preserving order.  Policies
        schedule the online sublist FIRST and exactly as an online-only
        run would (docs/hybrid.md): offline members ride behind it in
        batch order, so the online sub-trace of every iteration is
        bit-identical with or without offline traffic."""
        online = [sid for sid in members if s.seqs[sid].is_online]
        offline = [sid for sid in members if not s.seqs[sid].is_online]
        return online, offline

    @staticmethod
    def _prune_running(s: "Scheduler", ids: List[int]) -> List[int]:
        """Drop members preempted mid-schedule (the online admission
        gate reclaims offline holdings as a side effect)."""
        return [sid for sid in ids
                if s.seqs[sid].status == SeqStatus.RUNNING]


class MonolithicPolicy(SchedulingPolicy):
    """Seed behavior: admit waiters as whole-prompt ``is_prefill`` batches
    (the engine prefills them through every stage, pipeline-blocking), then
    run flat 1-token decode iterations."""

    name = "monolithic"
    uses_spans = False

    def schedule(self, s: "Scheduler", it: int) -> Optional["SchedulingOutput"]:
        from repro.core.scheduler import SchedulingOutput

        slot = it % s.p
        members, recomposed = self._alive_members(s, slot)
        online, offline = self._tier_split(s, members)
        new_prefill: List[int] = []

        def admit(seq: Sequence, into: List[int]):
            # a fork child admits with its prefill already satisfied (its
            # prompt KV lives in the shared blocks) — it joins as a pure
            # decode member, no is_prefill pass.  A prefix-cache-hit seq
            # still runs the full monolithic prefill (prefill_fn is pure
            # self-attention, it cannot resume mid-prompt from cache); its
            # recompute is write-masked so shared blocks are never touched
            # (engine passes mask_shared tables) — memory sharing only.
            needs_prefill = not seq.prefill_done
            seq.prefilled = seq.prefill_len       # monolithic: all at once
            into.append(seq.seq_id)
            if needs_prefill:
                new_prefill.append(seq.seq_id)

        while s.waiting and len(online) < s.max_batch and s.can_admit_next():
            offline = self._prune_running(s, offline)
            # online always gets its seat: an offline member occupying
            # the last one is preempted-by-recompute (docs/hybrid.md)
            if (len(online) + len(offline) >= s.max_batch
                    and not s.preempt_offline_seat(offline)):
                break
            admit(s.admit_next(), online)         # paged: reserves blocks
            recomposed = True
        # ---- offline tier: only seats the online tier left unclaimed ----
        offline = self._prune_running(s, offline)
        s.slack.see(s.max_batch - len(online))
        while (not s.waiting and s.waiting_offline
               and len(online) + len(offline) < s.max_batch
               and s.can_admit_next_offline()):
            admit(s.admit_next_offline(), offline)
            recomposed = True
        new_members = online + offline
        recomposed = recomposed or new_members != members
        members = new_members
        s.slot_members[slot] = members
        if not members:
            return None
        s.slack.sell(len(offline))    # one decode token per offline member

        tokens = np.array([s.seqs[sid].last_token for sid in members], np.int32)
        positions = np.array([s.seqs[sid].length - 1 for sid in members], np.int32)
        return SchedulingOutput(
            iteration=it,
            slot=slot,
            seq_ids=list(members),
            positions=positions,
            tokens=tokens,
            is_prefill=bool(new_prefill),
            prompt_lens=[len(s.seqs[q].prompt_ids) for q in members],
            batch_recomposed=recomposed,
        )


class ChunkedPolicy(SchedulingPolicy):
    """SARATHI-style chunked prefill piggybacked on decodes (PR 1-2).

    Decode members are always carried (1 token each); prefill chunks share
    whatever budget remains, in slot-membership order; admission continues
    while the slot has space and budget."""

    name = "chunked"
    uses_spans = True

    def schedule(self, s: "Scheduler", it: int) -> Optional["SchedulingOutput"]:
        slot = it % s.p
        members, recomposed = self._alive_members(s, slot)
        online, offline = self._tier_split(s, members)

        # online decodes are entitled to their token; offline members get
        # no entitlement — they draw only from the leftover budget below
        n_decode = sum(1 for sid in online if s.seqs[sid].prefill_done)
        budget_left = s.token_budget - n_decode

        batch_ids: List[int] = []
        spans: List[Tuple[int, int]] = []
        span_tokens: List[List[int]] = []
        needs_sample: List[bool] = []

        def emit(seq: Sequence):
            nonlocal budget_left
            if seq.prefill_done:
                off = seq.length - 1
                spans.append((off, 1))
                span_tokens.append([seq.last_token])
                needs_sample.append(True)
                batch_ids.append(seq.seq_id)
                return True
            c = min(seq.prefill_len - seq.prefilled, budget_left)
            if c <= 0:
                return False          # deferred: stays a slot member
            off = seq.prefilled
            spans.append((off, c))
            span_tokens.append(seq.prefill_slice(off, c))
            needs_sample.append(off + c >= seq.prefill_len)
            batch_ids.append(seq.seq_id)
            seq.prefilled = off + c   # chunk issued: next schedule continues
            budget_left -= c
            return True

        deferred = False
        for sid in online:
            if not emit(s.seqs[sid]):
                deferred = True
        # fork children and prefix-cache hits need no special casing here:
        # kv_admit leaves them prefill_done (fork) or with ``prefilled``
        # advanced past the cached blocks (hit), and ``emit`` naturally
        # produces a decode span or a tail-only chunk starting at the
        # first unshared (block-aligned) token
        while (s.waiting and len(online) < s.max_batch
               and budget_left > 0 and s.can_admit_next()):
            offline = self._prune_running(s, offline)
            if (len(online) + len(offline) >= s.max_batch
                    and not s.preempt_offline_seat(offline)):
                break
            seq = s.admit_next()
            online.append(seq.seq_id)
            recomposed = True
            emit(seq)

        # ---- offline tier (docs/hybrid.md): whatever budget and seats
        # the online tier left this iteration.  Offline decodes are
        # deferrable (unlike online ones) — an iteration whose online
        # members ate the budget simply pauses them.
        offline = self._prune_running(s, offline)
        s.slack.see(s.max_batch - len(online))
        sold = 0

        def emit_offline(seq: Sequence) -> bool:
            nonlocal budget_left, sold
            if seq.prefill_done:
                if budget_left < 1:
                    return False
                spans.append((seq.length - 1, 1))
                span_tokens.append([seq.last_token])
                needs_sample.append(True)
                batch_ids.append(seq.seq_id)
                budget_left -= 1
                sold += 1
                return True
            c = min(seq.prefill_len - seq.prefilled, budget_left)
            if c <= 0:
                return False
            off = seq.prefilled
            spans.append((off, c))
            span_tokens.append(seq.prefill_slice(off, c))
            needs_sample.append(off + c >= seq.prefill_len)
            batch_ids.append(seq.seq_id)
            seq.prefilled = off + c
            budget_left -= c
            sold += c
            return True

        for sid in offline:
            if not emit_offline(s.seqs[sid]):
                deferred = True
        # admit offline only when no online waiter wants the seat (an
        # online head blocked on KV blocks would thrash: its admission
        # gate reclaims offline holdings on its next attempt)
        while (not s.waiting and s.waiting_offline
               and len(online) + len(offline) < s.max_batch
               and budget_left > 0 and s.can_admit_next_offline()):
            seq = s.admit_next_offline()
            offline.append(seq.seq_id)
            recomposed = True
            emit_offline(seq)
        s.slack.sell(sold)

        new_members = online + offline
        recomposed = recomposed or new_members != members
        s.slot_members[slot] = new_members
        if not batch_ids:
            return None
        # any chunked batch (or deferral gap) recomposes vs. pure decode
        recomposed = recomposed or deferred or any(c > 1 for _, c in spans)
        return _span_output(s, it, slot, batch_ids, spans, span_tokens,
                            needs_sample, recomposed)


class DisaggregatedPolicy(SchedulingPolicy):
    """TD-Pipe-style temporally-disaggregated phase scheduling.

    The whole pipeline (all p slots) is either in a *prefill phase* or a
    *decode phase*:

      prefill phase  iterations carry only prompt chunks, each slot using
                     the FULL token budget (zero decode piggybacking);
                     waiting sequences are admitted here.  Decode-ready
                     members are deferred (stay slot members, excluded from
                     the batch).
      decode phase   pure 1-token decode iterations — ``max_span == 1``, so
                     the engine runs the flat decode fast path and TSEM's
                     incremental n/n+p metadata update applies.  Prefilling
                     is never interleaved; no admission happens here.

    Phase machine (re-evaluated before every schedule call; the switch is
    global, so iteration durations stay uniform within a phase — the
    load-imbalance bubble TD-Pipe targets):

      PREFILL -> DECODE  when no prefill work is schedulable: every running
                         sequence finished its prefill and no waiter can be
                         admitted (queue empty or slots full).  Entering
                         decode therefore never strands a half-prefilled
                         sequence.
      DECODE  -> PREFILL when the pending prefill backlog justifies pausing
                         the in-flight decodes:
                           pending_tokens >= hysteresis_tokens * n_decode_slots
                         where ``pending_tokens`` counts only ADMISSIBLE
                         waiting prompts (the first ``free-seat-count``
                         queue entries — a deep queue behind one free seat
                         must not thrash the phase), ``n_decode_slots`` is
                         the number of slots currently carrying decode work
                         (the slots a prefill phase would pause), and
                         ``hysteresis_tokens`` defaults to the token budget
                         (one full prefill iteration per paused slot).
                         Forced immediately when no decode work remains, so
                         waiters never starve.

    TPOT-aware phase-length cap (``tpot_slo_s``): a prefill phase pauses
    every in-flight decode for its whole duration, so its length directly
    bounds the worst inter-token gap.  With an SLO set, the policy
    estimates the wall cost per prefill token from the live
    ``Scheduler.tpot_samples`` feed (median decode-iteration latency /
    token budget) and caps the tokens one phase may issue at
    ``PAUSE_FACTOR * tpot_slo_s`` worth of work: past the cap the phase
    stops ADMITTING new waiters and switches to decode as soon as every
    running prefill completes — the cap can end a phase early but never
    strands a half-prefilled sequence (the PREFILL->DECODE entry condition
    keeps requiring ``run_prefill == 0``).  The cap never drops below one
    full prefill iteration, so every phase makes progress — and it only
    binds while decode work is actually being paused (``n_decode > 0``):
    a phase with nothing to pause resets its token count and admits
    freely, which is also what keeps a capped phase whose members all
    FINISH from blocking admission forever.

    On a static workload (everything admitted, empty queue) the phase
    switches at most once, PREFILL -> DECODE; the threshold cannot re-fire
    because pending prefill stays zero — the no-oscillation property
    (tests/test_policies.py).
    """

    name = "disaggregated"
    uses_spans = True

    PREFILL = "prefill"
    DECODE = "decode"

    PAUSE_FACTOR = 4.0     # max decode pause per prefill phase, in SLO units
    MIN_TPOT_SAMPLES = 8   # live samples needed before the cap engages

    def __init__(self, hysteresis_tokens: Optional[int] = None,
                 tpot_slo_s: Optional[float] = None,
                 decode_enlarge_factor: int = 1):
        self.hysteresis_tokens = hysteresis_tokens   # None -> token budget
        self.tpot_slo_s = tpot_slo_s                 # None -> no phase cap
        # TD-Pipe-style decode-phase batch enlargement (docs/hybrid.md):
        # during pure-decode phases, offline decodes may widen the batch
        # beyond max_batch up to max_batch * factor, but only at pow2
        # rung totals (2*mb, 4*mb, ...) so each rung is ONE extra XLA
        # compile shape — the same capping discipline as table widths
        self.decode_enlarge_factor = max(1, int(decode_enlarge_factor))
        self.phase = self.PREFILL
        self.phase_switches = 0
        self.prefill_iters = 0
        self.decode_iters = 0
        self.enlarged_decode_iters = 0   # decode batches widened past mb
        self._phase_tokens = 0      # prefill tokens issued this phase
        self._phase_cap = 0         # 0 = uncapped
        self.capped_phases = 0

    def metrics(self) -> Dict[str, int]:
        return {
            "phase": self.phase,
            "phase_switches": self.phase_switches,
            "prefill_iters": self.prefill_iters,
            "decode_iters": self.decode_iters,
            "enlarged_decode_iters": self.enlarged_decode_iters,
            "decode_enlarge_factor": self.decode_enlarge_factor,
            "phase_token_cap": self._phase_cap,
            "capped_phases": self.capped_phases,
        }

    # -- phase machine ------------------------------------------------------
    def _switch(self, phase: str):
        self.phase = phase
        self.phase_switches += 1
        if phase == self.PREFILL:
            self._phase_tokens = 0

    def _refresh_cap(self, s: "Scheduler"):
        """Recompute the per-phase token cap from the live TPOT feed."""
        if self.tpot_slo_s is None or \
                len(s.tpot_samples) < self.MIN_TPOT_SAMPLES:
            self._phase_cap = 0
            return
        # one decode iteration ~ one sample gap; a prefill iteration does
        # ~token_budget tokens of the same stage work, so the wall cost of
        # a prefill token ~ median_gap / budget
        s_per_token = float(np.median(list(s.tpot_samples))) / s.token_budget
        cap = int((self.PAUSE_FACTOR * self.tpot_slo_s)
                  / max(s_per_token, 1e-9))
        self._phase_cap = max(cap, s.token_budget)   # >= one full iteration

    def _capped(self) -> bool:
        return bool(self._phase_cap) and self._phase_tokens >= self._phase_cap

    def _evaluate_phase(self, s: "Scheduler"):
        # Phase decisions are a pure function of ONLINE state: offline
        # members or backlog flipping a phase would change online
        # scheduling vs an online-only run (docs/hybrid.md).  Only when
        # there is no online work anywhere — nothing running, nothing
        # queued (incl. preempted resumes) — does the offline tier drive
        # the machine: an online-only run schedules nothing in that
        # state, so there is no online trace to disturb.
        tier_online = bool(s.waiting) or any(
            q.status == SeqStatus.RUNNING and q.is_online
            for q in s.seqs.values())
        queue = s.waiting if tier_online else s.waiting_offline
        running = [q for q in s.seqs.values()
                   if q.status == SeqStatus.RUNNING
                   and q.is_online == tier_online]
        n_decode = sum(1 for q in running if q.prefill_done)
        run_prefill = sum(q.prefill_len - q.prefilled for q in running
                          if not q.prefill_done)
        slot_alive = [sum(1 for sid in m
                          if s.seqs[sid].status == SeqStatus.RUNNING
                          and s.seqs[sid].is_online == tier_online)
                      for m in s.slot_members]
        # offline-driven: seats extend to the enlargement headroom, so a
        # backlog keeps prefilling until decode phases can run enlarged
        per_slot = (s.max_batch if tier_online
                    else s.max_batch * self.decode_enlarge_factor)
        space = sum(max(0, per_slot - a) for a in slot_alive)
        # only the ADMISSIBLE backlog counts: the first `space` waiting
        # prompts (FIFO admission) — a deep queue behind one free seat
        # must not fire the threshold, pause every decode slot, and then
        # flip straight back (phase thrash)
        # remaining (not total) prefill tokens: a prefix-cache hit's shared
        # prefix and a fork child's whole prompt cost no prefill compute,
        # so they must not inflate the pause-the-decodes threshold
        waiting_tokens = sum(max(0, q.prefill_len - q.prefilled)
                             for q, _ in zip(queue, range(space)))

        if self.phase == self.PREFILL:
            self._refresh_cap(s)
            # the cap bounds how long PAUSED DECODES wait; with no decode
            # work in flight it has nothing to protect — reset it so the
            # backlog keeps admitting (otherwise a phase whose members all
            # FINISH while capped would block admission forever: no
            # decodes to switch to, no admission to make progress with)
            if self._capped() and n_decode == 0:
                self._phase_tokens = 0
            # leave only when nothing is prefillable: running prefills done
            # AND no admission possible — so decode never strands a
            # half-prefilled sequence.  A capped phase treats its remaining
            # backlog as non-admissible (it paused decodes long enough).
            backlog = 0 if self._capped() else waiting_tokens
            if run_prefill == 0 and backlog == 0 and n_decode > 0:
                if self._capped() and waiting_tokens > 0:
                    self.capped_phases += 1    # the cap ended this phase
                self._switch(self.DECODE)
            return
        # DECODE phase: running sequences are all prefill_done (the entry
        # condition), so pending prefill is exactly the admissible backlog
        if waiting_tokens == 0:
            return
        if n_decode == 0:
            self._switch(self.PREFILL)   # forced: no decode work at all
            return
        n_decode_slots = sum(
            1 for m in s.slot_members
            if any(s.seqs[sid].status == SeqStatus.RUNNING
                   and s.seqs[sid].is_online == tier_online
                   and s.seqs[sid].prefill_done for sid in m))
        h = (self.hysteresis_tokens if self.hysteresis_tokens is not None
             else s.token_budget)
        if waiting_tokens >= h * max(1, n_decode_slots):
            self._switch(self.PREFILL)

    # -- per-slot dispatch --------------------------------------------------
    def schedule(self, s: "Scheduler", it: int) -> Optional["SchedulingOutput"]:
        self._evaluate_phase(s)
        slot = it % s.p
        members, recomposed = self._alive_members(s, slot)
        online, offline = self._tier_split(s, members)
        # offline membership may run up to max_batch * factor (the
        # enlargement headroom); online always fits in max_batch
        cap_members = s.max_batch * self.decode_enlarge_factor

        if self.phase == self.DECODE:
            # fork children carry zero prefill tokens — admitting them
            # mid-decode-phase keeps the pure-1-token invariant (they join
            # as decode members) and lets parallel-sampling children start
            # without waiting for the next prefill phase
            while (s.waiting and s.waiting[0].forked
                   and len(online) < s.max_batch and s.can_admit_next()):
                offline = self._prune_running(s, offline)
                if (len(online) + len(offline) >= cap_members
                        and not s.preempt_offline_seat(offline)):
                    break
                seq = s.admit_next()
                online.append(seq.seq_id)
                recomposed = True
            # offline fork children are likewise decode-ready; fresh
            # offline prompts wait for a prefill phase
            offline = self._prune_running(s, offline)
            s.slack.see(s.max_batch - len(online))
            while (s.waiting_offline and s.waiting_offline[0].forked
                   and len(online) + len(offline) < cap_members
                   and s.can_admit_next_offline()):
                seq = s.admit_next_offline()
                offline.append(seq.seq_id)
                recomposed = True
            new_members = online + offline
            recomposed = recomposed or new_members != members
            s.slot_members[slot] = new_members
            on_ids = [sid for sid in online if s.seqs[sid].prefill_done]
            off_ids = [sid for sid in offline if s.seqs[sid].prefill_done]
            # enlargement ladder: batch totals beyond max_batch only at
            # pow2 rungs (2*mb, 4*mb, ... <= mb*factor) — each rung is
            # one extra compile shape.  Between rungs, offline decodes
            # share the <= max_batch seats round-robin (rotation by
            # decode_iters) so none of them starves.
            total = len(on_ids) + len(off_ids)
            if total > s.max_batch:
                rung = s.max_batch
                r = 2 * s.max_batch
                while r <= cap_members:
                    if r <= total:
                        rung = r
                    r *= 2
                total = rung
            n_off = max(0, total - len(on_ids))
            if off_ids and n_off < len(off_ids):
                start = self.decode_iters % len(off_ids)
                off_ids = [off_ids[(start + i) % len(off_ids)]
                           for i in range(n_off)]
            else:
                off_ids = off_ids[:n_off]
            batch_ids = on_ids + off_ids
            if not batch_ids:
                return None
            spans = []
            span_tokens = []
            for sid in batch_ids:
                seq = s.seqs[sid]
                spans.append((seq.length - 1, 1))
                span_tokens.append([seq.last_token])
            recomposed = recomposed or len(batch_ids) != len(new_members)
            self.decode_iters += 1
            if len(batch_ids) > s.max_batch:
                self.enlarged_decode_iters += 1
            s.slack.sell(len(off_ids))
            return _span_output(s, it, slot, batch_ids, spans, span_tokens,
                                [True] * len(batch_ids), recomposed)

        # PREFILL phase: full budget to prompt chunks, decodes deferred
        budget_left = s.token_budget
        batch_ids, spans, span_tokens, needs_sample = [], [], [], []
        deferred = False

        def emit_chunk(seq: Sequence) -> bool:
            nonlocal budget_left
            c = min(seq.prefill_len - seq.prefilled, budget_left)
            if c <= 0:
                return False
            off = seq.prefilled
            spans.append((off, c))
            span_tokens.append(seq.prefill_slice(off, c))
            needs_sample.append(off + c >= seq.prefill_len)
            batch_ids.append(seq.seq_id)
            seq.prefilled = off + c
            budget_left -= c
            return True

        def emit_online_chunk(seq: Sequence) -> bool:
            ok = emit_chunk(seq)
            if ok:
                # only ONLINE tokens advance the TPOT phase cap: offline
                # tokens riding leftover budget must not end a phase
                # earlier than an online-only run would (docs/hybrid.md)
                self._phase_tokens += spans[-1][1]
            return ok

        for sid in online:
            seq = s.seqs[sid]
            if seq.prefill_done or not emit_online_chunk(seq):
                deferred = True       # decode members pause during prefill
        # a TPOT-capped phase stops admitting: in-progress prefills finish,
        # the backlog waits for the next phase (decodes get their turn)
        while (s.waiting and len(online) < s.max_batch
               and budget_left > 0 and not self._capped()
               and s.can_admit_next()):
            offline = self._prune_running(s, offline)
            if (len(online) + len(offline) >= cap_members
                    and not s.preempt_offline_seat(offline)):
                break
            seq = s.admit_next()
            online.append(seq.seq_id)
            recomposed = True
            emit_online_chunk(seq)

        # ---- offline tier: leftover prefill budget (docs/hybrid.md).
        # The phase's iteration count is a function of online state
        # alone, and each iteration stays <= token_budget tokens, so
        # filling the leftover costs at most what a full online prefill
        # iteration already costs.  Batch width stays <= max_batch (no
        # new compile shapes on the packed path).
        offline = self._prune_running(s, offline)
        s.slack.see(s.max_batch - len(online))
        sold0 = budget_left
        for sid in offline:
            seq = s.seqs[sid]
            if seq.prefill_done or len(batch_ids) >= s.max_batch \
                    or not emit_chunk(seq):
                deferred = True       # offline decodes pause during prefill
        while (not s.waiting and s.waiting_offline
               and len(online) + len(offline) < cap_members
               and len(batch_ids) < s.max_batch
               and budget_left > 0 and s.can_admit_next_offline()):
            seq = s.admit_next_offline()
            offline.append(seq.seq_id)
            recomposed = True
            if not seq.prefill_done:      # forked child: already decode-ready
                emit_chunk(seq)
        s.slack.sell(sold0 - budget_left)

        new_members = online + offline
        recomposed = recomposed or new_members != members
        s.slot_members[slot] = new_members
        if not batch_ids:
            return None
        self.prefill_iters += 1
        recomposed = recomposed or deferred or any(c > 1 for _, c in spans)
        return _span_output(s, it, slot, batch_ids, spans, span_tokens,
                            needs_sample, recomposed)


class AdaptivePolicy(ChunkedPolicy):
    """Latency-SLO adaptive token budget (ROADMAP item).

    Chunked scheduling whose per-iteration budget tracks the LIVE TPOT
    the request layer exposes.  Every chunk-carrying iteration inflates
    the inter-token latency of each co-scheduled decode (iteration cost
    ~ t_fixed + t_token * budget), so:

      * when the recent mean inter-token gap (``Scheduler.tpot_samples``,
        fed by ``complete()``) breaches the SLO, the chunk budget shrinks
        multiplicatively — decodes win back latency;
      * when there is headroom (< ``GROW_AT`` x SLO), the budget grows
        back toward the configured maximum — prefill wins back TTFT.

    The budget stays within ``[max_batch + 1, initial budget]``: the
    lower bound preserves prefill progress (the scheduler's own clamp),
    the upper bound preserves the engine's budget-fits-sliding-window
    validation done against the initial value.  ``tpot_slo_s=None``
    self-calibrates: the SLO becomes ``SLO_CALIB`` x the median of the
    first full sample window (useful on hardware whose absolute decode
    latency is unknown up front, e.g. this CPU container).
    """

    name = "adaptive"

    WINDOW = 16        # iterations between budget re-evaluations
    MIN_SAMPLES = 8    # gaps needed before adapting / self-calibrating
    SHRINK = 0.5       # multiplicative decrease on SLO breach
    GROW = 1.5         # multiplicative increase under headroom
    GROW_AT = 0.6      # grow when tpot < GROW_AT * SLO
    SLO_CALIB = 1.5    # self-calibrated SLO = SLO_CALIB * median(window)

    def __init__(self, tpot_slo_s: Optional[float] = None):
        self.tpot_slo_s = tpot_slo_s
        self._budget: Optional[int] = None
        self._min_budget = 0
        self._max_budget = 0
        self._next_eval = self.WINDOW
        self.budget_adjustments = 0

    def metrics(self) -> Dict[str, int]:
        return {
            "budget": self._budget or 0,
            "budget_max": self._max_budget,
            "budget_adjustments": self.budget_adjustments,
            "tpot_slo_us": int((self.tpot_slo_s or 0.0) * 1e6),
        }

    def _adapt(self, s: "Scheduler", it: int):
        if self._budget is None:           # first call: bind to the scheduler
            self._max_budget = s.token_budget
            self._min_budget = min(s.max_batch + 1, s.token_budget)
            self._budget = s.token_budget
        if it < self._next_eval or len(s.tpot_samples) < self.MIN_SAMPLES:
            return
        self._next_eval = it + self.WINDOW
        window = list(s.tpot_samples)
        if self.tpot_slo_s is None:
            self.tpot_slo_s = self.SLO_CALIB * float(np.median(window))
            return
        tpot = float(np.mean(window[-self.WINDOW:]))
        if tpot > self.tpot_slo_s and self._budget > self._min_budget:
            self._budget = max(self._min_budget,
                               int(self._budget * self.SHRINK))
            self.budget_adjustments += 1
        elif tpot < self.GROW_AT * self.tpot_slo_s \
                and self._budget < self._max_budget:
            self._budget = min(self._max_budget,
                               max(self._budget + 1,
                                   int(self._budget * self.GROW)))
            self.budget_adjustments += 1

    def schedule(self, s: "Scheduler", it: int) -> Optional["SchedulingOutput"]:
        self._adapt(s, it)
        s.token_budget = self._budget      # ChunkedPolicy reads it live
        return super().schedule(s, it)


POLICIES = {
    "monolithic": MonolithicPolicy,
    "chunked": ChunkedPolicy,
    "disaggregated": DisaggregatedPolicy,
    "adaptive": AdaptivePolicy,
}


def make_policy(name: Optional[str], *, token_budget: Optional[int] = None,
                hysteresis_tokens: Optional[int] = None,
                tpot_slo_s: Optional[float] = None,
                decode_enlarge_factor: int = 1) -> SchedulingPolicy:
    """Resolve a policy name against the token budget.

    ``None``/``"auto"`` keeps the historical contract: a token budget means
    chunked, no budget means monolithic.  Span policies require a budget;
    the monolithic policy rejects one (it would be silently ignored).
    """
    if name is None or name == "auto":
        name = "chunked" if token_budget is not None else "monolithic"
    if name not in POLICIES:
        raise ValueError(
            f"unknown scheduling policy {name!r}; choose from "
            f"{sorted(POLICIES)}")
    if hysteresis_tokens is not None and name != "disaggregated":
        raise ValueError(
            "phase_hysteresis_tokens / --hysteresis-tokens applies only "
            f"to the disaggregated policy (got policy {name!r})")
    if tpot_slo_s is not None and name not in ("adaptive", "disaggregated"):
        raise ValueError(
            "tpot_slo_s / --tpot-slo-ms applies only to the adaptive "
            "(budget adaptation) and disaggregated (prefill-phase length "
            f"cap) policies (got policy {name!r})")
    if decode_enlarge_factor < 1:
        raise ValueError(
            f"decode_enlarge_factor must be >= 1, got {decode_enlarge_factor}")
    if decode_enlarge_factor > 1 and name != "disaggregated":
        raise ValueError(
            "decode_enlarge_factor > 1 applies only to the disaggregated "
            "policy (decode-phase batch enlargement, docs/hybrid.md; got "
            f"policy {name!r})")
    if name == "monolithic":
        if token_budget is not None:
            raise ValueError(
                "monolithic policy takes no token budget "
                "(prefill_chunk_tokens / --chunk-tokens must be unset)")
        return MonolithicPolicy()
    if token_budget is None:
        raise ValueError(
            f"{name} policy requires a per-iteration token budget "
            "(set prefill_chunk_tokens / --chunk-tokens)")
    if name == "disaggregated":
        return DisaggregatedPolicy(hysteresis_tokens=hysteresis_tokens,
                                   tpot_slo_s=tpot_slo_s,
                                   decode_enlarge_factor=decode_enlarge_factor)
    if name == "adaptive":
        return AdaptivePolicy(tpot_slo_s=tpot_slo_s)
    return ChunkedPolicy()
