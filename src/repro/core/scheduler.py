"""Continuous-batching scheduler (SiPipe §4.2) with pluggable policies.

Keeps p microbatches in flight (one per pipeline stage).  On receiving
iteration n's sampling output it immediately dispatches iteration n+p with
the same sequence set minus finished ones plus admitted waiters — which is
exactly the stability property the column-wise sampler and the TSEM
BatchMetadata replicas rely on (batches n and n+p are near-identical).

The scheduler owns the durable state (sequences, waiting queue, slot
membership, completion bookkeeping); WHAT each iteration carries is
delegated to a :class:`repro.core.policies.SchedulingPolicy`:

  monolithic     whole-prompt ``is_prefill`` batches + flat decodes (the
                 seed behavior; selected when ``token_budget`` is None).
  chunked        SARATHI-style chunked prefill (opt-in via
                 ``token_budget``): long prompts are split into
                 fixed-token-budget chunks piggybacked on the slot's
                 in-flight decode tokens.
  disaggregated  TD-Pipe-style temporal disaggregation: the pipeline
                 alternates prefill-only and decode-only phases under a
                 hysteresis threshold (opt-in via ``policy=``).

Span-policy contract (chunked + disaggregated):

  * each scheduled iteration emits per-seq *spans* ``(offset, n_tokens)``
    — a decode step is the degenerate span ``(length-1, 1)``;
  * sampling fires only for sequences whose span reaches the last prompt
    token (``needs_sample``) — earlier chunks produce no token;
  * total tokens per iteration never exceed ``token_budget`` (the budget
    is clamped to ``max_batch + 1`` so prefill always makes progress).

Chunk-carrying iterations are executed over a *packed ragged* layout —
the batch's valid span tokens concatenated into flat [T] vectors and
bucketed to a small set of power-of-two widths (``packed_layout()`` /
``packed_width``) — see docs/scheduling.md.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.sequence import SeqStatus, Sequence


BUCKET_FLOOR = 8


def bucket_width(n_tokens: int) -> int:
    """Packed execution width for ``n_tokens`` valid span tokens: the
    smallest power of two >= n_tokens (floor 8).  Bucketing the ragged
    total to a small set of widths means XLA compiles one chunk step per
    (bucket, batch) pair instead of one per distinct token count."""
    b = BUCKET_FLOOR
    while b < n_tokens:
        b <<= 1
    return b


class SlackAccount:
    """Measured pipeline slack and the offline tokens sold into it
    (docs/hybrid.md).

    Every policy feeds this at schedule time: free decode seats left
    after online admission, the leftover token budget of a prefill
    phase, whole drain-tail iterations once online work runs out.  The
    counters are the engine's bubble accounting — how much slack the
    scheduler SAW (``seats_seen``) versus how much it actually SOLD to
    offline-tier sequences (``tokens_sold``)."""

    def __init__(self):
        self.seats_seen = 0      # free online seats observed at schedule time
        self.tokens_sold = 0     # span tokens issued to offline sequences
        self.offers = 0          # schedule calls that observed any slack

    def see(self, seats: int):
        if seats > 0:
            self.seats_seen += seats
            self.offers += 1

    def sell(self, tokens: int):
        if tokens > 0:
            self.tokens_sold += tokens


@dataclasses.dataclass
class SchedulingOutput:
    """Broadcast to every worker + sampler via BIC-I."""

    iteration: int
    slot: int                      # iteration %% p — the TSEM replica index
    seq_ids: List[int]
    # per-seq state the CPU executor needs to build model inputs
    positions: np.ndarray          # [B] span start (decode: next-token position)
    tokens: np.ndarray             # [B] first input token of each span
    is_prefill: bool               # True -> monolithic-prefill the batch first
    prompt_lens: Optional[List[int]] = None
    batch_recomposed: bool = False
    # ---- chunked-prefill extensions (None on pure monolithic/decode paths) --
    spans: Optional[List[Tuple[int, int]]] = None   # per-seq (offset, n_tokens)
    span_tokens: Optional[List[List[int]]] = None   # input ids for each span
    needs_sample: Optional[List[bool]] = None       # span reaches a sampling point
    # ---- paged KV layout (None under contiguous rows) -----------------------
    # [B, nb] int32 physical block table per batch row, padded with the
    # trash block — snapshotted at schedule time by the scheduler (the
    # placement this iteration's in-kernel gather / dirty-slot write-back
    # must see), staged verbatim by every stage's CPU executor.  ``nb`` is
    # a rung of the BlockSpaceManager's capped width ladder, so only a
    # handful of (batch, nb) stage-fn shapes ever compile (docs/memory.md)
    block_tables: Optional[np.ndarray] = None
    # [K, 2] int32 (src, dst) device-side block copies queued by CoW since
    # the previous schedule (fork tail-block copies, growth-time CoW of a
    # shared block).  Every stage applies them to its physical cache
    # BEFORE executing this iteration: per-stage FIFO puts the copy after
    # all in-flight writes to ``src`` (shared blocks are never written, so
    # src content is stable) and before any reader of ``dst``
    block_copies: Optional[np.ndarray] = None
    # per-seq preemption generation at schedule time: ``complete`` drops a
    # sampled token whose sequence was preempted (and possibly already
    # re-admitted) after this iteration was scheduled — the resumed
    # prefill recomputes that token itself, and accepting the stale one
    # would duplicate it
    epochs: Optional[List[int]] = None

    @property
    def max_span(self) -> int:
        """Widest span in the batch; 1 for pure-decode iterations."""
        if not self.spans:
            return 1
        return max(c for _, c in self.spans)

    @property
    def total_tokens(self) -> int:
        if not self.spans:
            return len(self.seq_ids)
        return sum(c for _, c in self.spans)

    @property
    def packed_width(self) -> int:
        """Execution width of the packed ragged token layout: 1 for pure
        decode (the flat [B] fast path), else the power-of-two bucket that
        ``total_tokens`` rounds up to (see :func:`bucket_width`)."""
        if self.max_span == 1:
            return 1
        return bucket_width(self.total_tokens)

    def packed_layout(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                     np.ndarray]:
        """The packed [T] token layout (T = total_tokens, unpadded).

        Returns ``(tokens, positions, seq_idx, last_index)`` int32 arrays:
        every valid span token exactly once, batch columns concatenated in
        order, positions monotone within each column; ``last_index[i]`` is
        the packed index of column i's final (sampling) token.
        """
        toks: List[int] = []
        pos: List[int] = []
        seq: List[int] = []
        last = np.zeros(len(self.seq_ids), np.int32)
        for i, ((off, n), ids) in enumerate(zip(self.spans, self.span_tokens)):
            toks.extend(ids)
            pos.extend(range(off, off + n))
            seq.extend([i] * n)
            last[i] = len(toks) - 1
        return (np.asarray(toks, np.int32), np.asarray(pos, np.int32),
                np.asarray(seq, np.int32), last)

    def sample_indices(self) -> List[int]:
        """Batch columns whose logits must be sampled this iteration."""
        if self.needs_sample is None:
            return list(range(len(self.seq_ids)))
        return [i for i, ns in enumerate(self.needs_sample) if ns]


class Scheduler:
    def __init__(self, *, max_batch: int, pp_degree: int = 1,
                 max_seq_len: int = 4096,
                 token_budget: Optional[int] = None,
                 policy: Optional[str] = None,
                 hysteresis_tokens: Optional[int] = None,
                 tpot_slo_s: Optional[float] = None,
                 decode_enlarge_factor: int = 1,
                 keep_finished: int = 1024,
                 kv_manager=None,
                 seq_id_fn=None):
        from repro.core.policies import make_policy

        self.max_batch = max_batch
        self.p = pp_degree
        self.max_seq_len = max_seq_len
        # span policies need a budget; decode members take 1 token each,
        # so budget > max_batch guarantees prefill progress
        self.token_budget = (max(token_budget, max_batch + 1)
                             if token_budget is not None else None)
        self.policy = make_policy(policy, token_budget=self.token_budget,
                                  hysteresis_tokens=hysteresis_tokens,
                                  tpot_slo_s=tpot_slo_s,
                                  decode_enlarge_factor=decode_enlarge_factor)
        # paged KV layout (docs/memory.md): admission switches from seat
        # counting to block-budget accounting against this
        # BlockSpaceManager, and decode growth under memory pressure
        # preempts the lowest-priority running sequence (None = the
        # contiguous row layout, no block accounting)
        self.kv = kv_manager
        self.n_preemptions = 0
        # parallel sampling (SamplingParams.n > 1): fresh seq ids for fork
        # children come from the engine's RequestIdAllocator so they can
        # never collide with future requests; the fallback counter only
        # serves schedulers constructed without an engine (unit tests)
        self._seq_id_fn = seq_id_fn
        self._fallback_id = 1 << 20
        self.n_forks = 0
        self.n_fork_demotions = 0
        self._spawned_forks: List[Sequence] = []  # for the engine to adopt
        self._preempted_pending: List[int] = []   # for the engine to reap
        self._preempt_hold: set = set()   # no re-admission within the call
        self.waiting: Deque[Sequence] = deque()
        # hybrid serving (docs/hybrid.md): offline-tier requests queue
        # separately so every online code path — admission loops, the
        # disaggregated phase machine, block-budget gates — sees state
        # IDENTICAL to an online-only run.  Policies admit from this
        # queue only into measured slack, accounted here.
        self.waiting_offline: Deque[Sequence] = deque()
        self.slack = SlackAccount()
        self.n_offline_preemptions = 0
        self.seqs: Dict[int, Sequence] = {}
        self.slot_members: List[List[int]] = [[] for _ in range(pp_degree)]
        self.iteration = 0
        # long-run memory bound: FINISHED/ABORTED sequences are released
        # from ``seqs`` once their slot membership clears; only a capped
        # window of recently finished sequences is retained here
        self.finished: Deque[Sequence] = deque(maxlen=keep_finished)
        self._retired: set = set()       # finished/aborted, pending release
        # live inter-token gaps across all sequences (seconds); feeds the
        # adaptive token-budget policy
        self.tpot_samples: Deque[float] = deque(maxlen=128)
        # serializes status transitions between complete() (runs on the
        # engine's device thread) and abort() (caller thread): without it
        # an abort landing between complete's RUNNING check and
        # Sequence.append could be overwritten to FINISHED
        self._mutex = threading.Lock()

    @property
    def chunked(self) -> bool:
        """True when the active policy emits spans (packed-[T] execution)."""
        return self.policy.uses_spans

    # -- request ingestion --------------------------------------------------
    def add_request(self, seq: Sequence):
        if len(seq.prompt_ids) >= self.max_seq_len:
            # fail loudly up front: the chunked path would otherwise issue
            # chunks past the KV cache and silently produce garbage
            raise ValueError(
                f"prompt of {len(seq.prompt_ids)} tokens does not fit "
                f"max_seq_len={self.max_seq_len} (need >= 1 output slot)")
        seq.arrival_t = seq.arrival_t or time.monotonic()
        self.seqs[seq.seq_id] = seq
        self._enqueue_waiting(seq)

    def _queue_for(self, seq: Sequence) -> Deque[Sequence]:
        """The waiting queue a sequence belongs to (by tier)."""
        return self.waiting if seq.is_online else self.waiting_offline

    def _enqueue_waiting(self, seq: Sequence):
        """Insert a NEW request into its tier's waiting queue in admission
        order: priority first, FIFO within a priority (monotonic ids =
        arrival order).  Resume entries at the queue FRONT — PREEMPTED
        sequences awaiting re-admission and spawned fork children — are
        never jumped: they already hold tokens/blocks and resume first
        regardless of a newcomer's priority (docs/http.md)."""
        w = self._queue_for(seq)
        if not w or w[-1].priority >= seq.priority:
            w.append(seq)                      # fast path: uniform priority
            return
        i = 0
        while i < len(w) and (w[i].status == SeqStatus.PREEMPTED
                              or w[i].forked):
            i += 1
        while i < len(w) and w[i].priority >= seq.priority:
            i += 1
        w.insert(i, seq)

    def admit_next(self) -> Sequence:
        """Pop the waiting-queue head and admit it: WAITING -> RUNNING plus
        paged block reservation.  Policies call this inside their admission
        loops (gated on :meth:`can_admit_next`), so every policy shares one
        admission order — priority, then FIFO (the queue's insertion
        order); per-tenant fair share is enforced a layer up, by
        ``serving.admission`` (docs/http.md)."""
        seq = self.waiting.popleft()
        seq.mark_running()
        self.kv_admit(seq)
        return seq

    @property
    def has_work(self) -> bool:
        return (bool(self.waiting) or bool(self.waiting_offline)
                or any(self.slot_members))

    # -- paged-KV admission / growth / preemption ----------------------------
    def can_admit_next(self) -> bool:
        """Block-budget admission gate for the ONLINE waiting-queue head
        (FIFO: a head that does not fit blocks the queue rather than
        being skipped).  Always True under the contiguous layout.

        Offline-tier sequences never stand between online traffic and
        the block pool: when the head does not fit, RUNNING offline
        sequences are preempted-by-recompute (cheapest relief first:
        their released blocks — including any cached blocks they pinned —
        return to the pool at once) until the head fits or no offline
        victim remains.  An online-only run has no offline victims, so
        its admission decisions are untouched."""
        if self.kv is None or not self.waiting:
            return True
        head = self.waiting[0]
        if head.seq_id in self._preempt_hold:
            return False       # never re-admit within the evicting call
        if head.forked and self.kv.has(head.seq_id):
            return True        # fork child: blocks materialized at spawn
        token_ids = head.prompt_ids + head.output_ids
        while not self.kv.can_admit(head.length, token_ids=token_ids,
                                    evict_cached=False):
            if self._demote_waiting_fork(offline_only=True):
                continue
            victim = self._preemption_victim(offline_only=True)
            if victim is None:
                # the offline tier holds nothing: the free list equals
                # the online-only baseline, so the ordinary gate (which
                # may reclaim cached prefix blocks at admit time) makes
                # exactly the decision an online-only run would make
                return self.kv.can_admit(head.length, token_ids=token_ids)
            self._preempt(victim)
        return True

    def can_admit_next_offline(self) -> bool:
        """Block-budget gate for the OFFLINE queue head.  Unlike the
        online gate this never reclaims anything — offline work is
        admitted only into blocks that are genuinely free right now
        (``evict_cached=False``, no prefix matching), so admitting it
        cannot disturb the prefix cache or any online sequence."""
        if not self.waiting_offline:
            return False
        head = self.waiting_offline[0]
        if head.seq_id in self._preempt_hold:
            return False
        if self.kv is None:
            return True
        if head.forked and self.kv.has(head.seq_id):
            return True
        return self.kv.can_admit(head.length, token_ids=None,
                                 evict_cached=False)

    def admit_next_offline(self) -> Sequence:
        """Pop and admit the offline-queue head (policies call this only
        after online admission has taken everything it can use)."""
        seq = self.waiting_offline.popleft()
        seq.mark_running()
        self.kv_admit(seq)
        return seq

    def kv_admit(self, seq: Sequence):
        """Reserve KV blocks for an admitted sequence (covers its full
        prefill target — prompt, or post-preemption token history).

        Prefix caching (docs/memory.md): the manager maps the sequence's
        leading full blocks onto cached physical blocks when their token
        hashes match — those tokens need no prefill compute, so
        ``prefilled`` starts past them and span policies chunk only the
        unshared tail.  A fork child whose blocks were materialized at
        spawn skips block reservation entirely (its prompt KV already
        lives in the shared blocks)."""
        if self.kv is None:
            return
        if seq.forked and self.kv.has(seq.seq_id):
            seq.prefilled = seq.prefill_len
            return
        # offline sequences bypass the prefix index entirely (no matches,
        # no registrations): sharing or evicting cached blocks on behalf
        # of best-effort work would perturb the online trace
        token_ids = (seq.prompt_ids + seq.output_ids) if seq.is_online \
            else None
        cached = self.kv.admit(seq.seq_id, seq.length, token_ids=token_ids)
        seq.cached_prefix = cached
        if cached > seq.prefilled:
            seq.prefilled = cached

    def _preemption_victim(self, offline_only: bool = False) -> Optional[int]:
        """Preemption victim: the lowest-priority RUNNING sequence that
        still holds blocks; latest arrival breaks priority ties (monotonic
        ids make arrival order = id order, so ``-sid`` prefers the newest).
        Offline-tier sequences are ALWAYS chosen before any online one,
        regardless of priority (docs/hybrid.md).  ``offline_only``
        restricts candidates to the offline tier — used when the
        beneficiary is itself offline (growth) or when reclaiming slack
        for online admission, so those paths can never touch online
        state.  Candidates are sorted first so the choice is a pure
        function of the candidate set — never of ``seqs`` dict insertion
        order."""
        cands = sorted(sid for sid, q in self.seqs.items()
                       if q.status == SeqStatus.RUNNING and self.kv.has(sid)
                       and not (offline_only and q.is_online))
        if not cands:
            return None
        return min(cands, key=lambda sid: (self.seqs[sid].is_online,
                                           self.seqs[sid].priority, -sid))

    def _preempt(self, victim: int):
        """Evict a RUNNING sequence under memory pressure: free its blocks,
        mark it PREEMPTED and push it to the FRONT of the waiting queue so
        it is re-admitted (as a fresh prefill of its full token history) as
        soon as blocks free up.  In-flight iterations still referencing it
        execute harmlessly — their sampled tokens are discarded by
        ``complete`` (status != RUNNING) and recomputed bit-exactly after
        the resume under greedy sampling."""
        seq = self.seqs[victim]
        seq.status = SeqStatus.PREEMPTED
        seq.prefilled = 0
        seq.prefill_target = seq.length
        seq.preemptions += 1
        # losing the blocks voids any shared placement: the resume is a
        # plain recompute (re-admission may still prefix-cache-hit)
        seq.forked = False
        seq.cached_prefix = 0
        if self.kv is not None:     # seat-only mode has no blocks to free
            self.kv.release(victim)
        for m in self.slot_members:
            if victim in m:
                m.remove(victim)
        self._queue_for(seq).appendleft(seq)
        self._preempted_pending.append(victim)
        self._preempt_hold.add(victim)
        self.n_preemptions += 1
        if not seq.is_online:
            self.n_offline_preemptions += 1

    def preempt_offline_seat(self, members: List[int]) -> bool:
        """Free one SEAT for online admission: preempt the lowest-priority
        (then newest) RUNNING offline member of ``members`` (the list is
        mutated in place).  Works in both seat-only mode (no KV manager,
        e.g. pp_sim) and paged mode; returns False when no offline member
        remains — online admission then proceeds exactly as it would in
        an online-only run."""
        offline = [sid for sid in members
                   if self.seqs[sid].status == SeqStatus.RUNNING
                   and not self.seqs[sid].is_online]
        if not offline:
            return False
        victim = min(offline,
                     key=lambda sid: (self.seqs[sid].priority, -sid))
        self._preempt(victim)
        if victim in members:
            members.remove(victim)
        return True

    def _ensure_block_capacity(self, slot: int):
        """Pre-schedule growth reservation: every RUNNING member of the
        slot about to be scheduled gets blocks covering its current length
        (a decode span writes KV at position ``length - 1``).  When the
        free list cannot cover a growth, the lowest-priority RUNNING
        sequence is preempted and the growth retried; the grower preempts
        itself when it IS the lowest priority."""
        members = sorted(sid for sid in self.slot_members[slot]
                         if self.seqs[sid].status == SeqStatus.RUNNING)
        for sid in members:
            seq = self.seqs[sid]
            if seq.status != SeqStatus.RUNNING:
                continue       # evicted as a victim earlier in this loop
            if seq.is_online:
                # Baseline-equivalent growth (docs/hybrid.md): while any
                # offline work still holds blocks, grow from genuinely
                # free blocks only, reclaiming offline holdings (waiting
                # offline fork CoW tails, then RUNNING offline members)
                # when short.  Only once the offline tier holds nothing —
                # i.e. the free list equals what an online-only run would
                # see — fall through to the ordinary relief chain (evict
                # cached prefix blocks, demote online forks, preempt
                # online victims), so hybrid traffic can never change
                # WHICH cached blocks or online sequences get evicted.
                while not self.kv.ensure(sid, seq.length,
                                         evict_cached=False):
                    if self._demote_waiting_fork(offline_only=True):
                        continue
                    victim = self._preemption_victim(offline_only=True)
                    if victim is None:
                        break
                    self._preempt(victim)
                else:
                    continue   # strict growth succeeded
                while not self.kv.ensure(sid, seq.length):
                    # cheapest relief first: demote a not-yet-admitted
                    # fork child back to recompute (frees its CoW tail
                    # block and drops shared refs) before evicting a
                    # RUNNING sequence
                    if self._demote_waiting_fork():
                        continue
                    victim = self._preemption_victim()
                    if victim is None:
                        break
                    self._preempt(victim)
                    if victim == sid:
                        break
            else:
                # offline grower: relief strictly within its own tier —
                # never evict cached prefix blocks, demote online forks,
                # or preempt online sequences for best-effort growth
                # (self-preemption when it is the only offline holder)
                while not self.kv.ensure(sid, seq.length,
                                         evict_cached=False):
                    if self._demote_waiting_fork(offline_only=True):
                        continue
                    victim = self._preemption_victim(offline_only=True)
                    if victim is None:
                        break
                    self._preempt(victim)
                    if victim == sid:
                        break

    def _demote_fork(self, seq: Sequence):
        """Un-fork a child: release its (mostly shared) block table and
        fall back to the preemption-style recompute path — on admission it
        prefills its full history (prompt + first token) from scratch,
        bit-exact under greedy.  Keeps its queue position."""
        if self.kv is not None:
            self.kv.release(seq.seq_id)
        seq.forked = False
        seq.cached_prefix = 0
        seq.prefilled = 0
        seq.prefill_target = seq.length
        self.n_fork_demotions += 1

    def _demote_waiting_fork(self, offline_only: bool = False) -> bool:
        """Demote the most recently spawned WAITING fork child, if any.
        Offline forks go first (their CoW tails are offline holdings —
        reclaiming them can never perturb the online trace); with
        ``offline_only`` the online queue is not touched at all."""
        for seq in reversed(self.waiting_offline):
            if seq.forked and seq.status == SeqStatus.WAITING:
                self._demote_fork(seq)
                return True
        if offline_only:
            return False
        for seq in reversed(self.waiting):
            if seq.forked and seq.status == SeqStatus.WAITING:
                self._demote_fork(seq)
                return True
        return False

    def drain_preempted(self) -> List[int]:
        """Hand the engine the sequences preempted since the last drain
        (it drops their worker-side handles; blocks are already free)."""
        out, self._preempted_pending = self._preempted_pending, []
        return out

    # -- parallel sampling (SamplingParams.n > 1) ----------------------------
    def _spawn_forks(self, parent: Sequence, tok: int, now: float):
        """Materialize ``n - 1`` CoW fork children off the parent's prompt
        KV (called under ``_mutex`` from ``complete`` when the parent's
        first token lands).  Each child adopts the parent's block table by
        refcount (``kv.fork``) and immediately CoWs its tail block
        (``kv.ensure`` — the child's first decode writes slot
        ``prompt_len``, which lives in a shared block): after spawn no
        decode ever writes a block another sequence reads.  When even the
        one CoW block cannot be found, the child is demoted to
        resume-by-recompute instead of failing.  Children enter the FRONT
        of the waiting queue; a child whose single sampled token already
        finishes it (``max_new_tokens == 1`` or instant EOS) never touches
        the allocator at all."""
        parent.forks_spawned = True
        for _ in range(parent.params.n - 1):
            if self._seq_id_fn is not None:
                cid = self._seq_id_fn()
            else:
                self._fallback_id = max(self._fallback_id,
                                        max(self.seqs, default=0) + 1)
                cid = self._fallback_id
                self._fallback_id += 1
            child = Sequence(seq_id=cid,
                             prompt_ids=list(parent.prompt_ids),
                             params=parent.params,
                             arrival_t=parent.arrival_t,
                             fork_parent=parent.seq_id)
            child.first_sched_t = parent.first_sched_t
            self.n_forks += 1
            if child.append(tok, now):       # finished on its first token
                self.finished.append(child)
                self._spawned_forks.append(child)
                continue
            child.prefilled = parent.prompt_len
            if self.kv is not None and self.kv.fork(parent.seq_id, cid):
                child.forked = True
                child.cached_prefix = parent.prompt_len
                # an offline child's CoW tail may not evict cached prefix
                # blocks (best-effort work must not perturb online state)
                if not self.kv.ensure(cid, child.length,
                                      evict_cached=parent.is_online):
                    self._demote_fork(child)
            else:
                # contiguous layout / parent blocks already gone: full
                # recompute of the (prompt + first token) history
                child.prefilled = 0
                child.prefill_target = child.length
            self.seqs[cid] = child
            self._queue_for(child).appendleft(child)
            self._spawned_forks.append(child)

    def drain_spawned_forks(self) -> List[Sequence]:
        """Hand the engine the fork children spawned since the last drain
        (it attaches them to the parent's Request for per-fork streams)."""
        with self._mutex:
            out, self._spawned_forks = self._spawned_forks, []
            return out

    def fork_children_of(self, parent_id: int) -> List[Sequence]:
        """Live fork children of ``parent_id`` known to the scheduler —
        including ones spawned by ``complete`` that the engine has not yet
        attached to the parent Request.  ``engine.abort`` folds these into
        its target set so a request aborted inside the spawn→attach window
        cannot leave orphaned children decoding against freed parents."""
        with self._mutex:
            return [q for q in self.seqs.values()
                    if q.fork_parent == parent_id
                    and q.status in (SeqStatus.WAITING, SeqStatus.RUNNING,
                                     SeqStatus.PREEMPTED)]

    # -- iteration dispatch ---------------------------------------------------
    def schedule(self, iteration: Optional[int] = None) -> Optional[SchedulingOutput]:
        """Build the scheduling output for the next iteration of slot
        ``iteration %% p``, delegating admission + span construction to the
        active :class:`~repro.core.policies.SchedulingPolicy`."""
        it = self.iteration if iteration is None else iteration
        if self.kv is not None:
            self._preempt_hold.clear()
            with self._mutex:      # vs complete() appending on device threads
                if self.kv.prefix_enabled:
                    # publish full prompt blocks whose KV writes were
                    # issued in STRICTLY EARLIER iterations into the
                    # prefix index: per-stage FIFO means those writes
                    # execute on every stage before any iteration
                    # scheduled from here on can read the shared blocks
                    # offline sequences never feed the prefix index: a
                    # cache entry that exists only because best-effort
                    # work ran would change online hit patterns
                    for sid, q in self.seqs.items():
                        if (q.status == SeqStatus.RUNNING and not q.forked
                                and q.is_online):
                            self.kv.register_prefix(
                                sid, q.prompt_ids,
                                min(q.prefilled, q.prompt_len))
                self._ensure_block_capacity(it % self.p)
        out = self.policy.schedule(self, it)
        if out is not None:
            self.iteration = max(self.iteration, it + 1)
            if self.kv is not None:
                # snapshot the batch's physical placement NOW: the padded
                # block tables every stage's CPU executor stages verbatim
                # (tables only grow between iterations; growth for THIS
                # iteration's members was ensured above) — plus each
                # member's preemption generation, so completions of
                # iterations scheduled before an eviction are dropped
                out.block_tables = self.kv.padded_tables(out.seq_ids)
                out.block_copies = self.kv.drain_copies()
                out.epochs = [self.seqs[sid].preemptions
                              for sid in out.seq_ids]
        self._purge_retired()
        return out

    def _purge_retired(self):
        """Release FINISHED/ABORTED sequences whose slot membership has
        cleared (the slot's own next ``schedule`` filters them out, which
        only happens after every in-flight iteration referencing them has
        completed — so nothing downstream can still need ``seqs[sid]``)."""
        if not self._retired:
            return
        live = set()
        for m in self.slot_members:
            live.update(m)
        for sid in [s for s in self._retired if s not in live]:
            self.seqs.pop(sid, None)
            self._retired.discard(sid)

    # -- request cancellation ------------------------------------------------
    def abort(self, seq_id: int) -> Optional[Sequence]:
        """Mark a sequence ABORTED; returns it (or None if unknown/done).

        A WAITING sequence is removed from the queue and released at
        once; a RUNNING one keeps its scheduler record until its slot's
        next ``schedule`` call drops it from membership (in-flight
        iterations may still reference it) — worker-side resources (KV
        row, sampler columns) are the engine's to reclaim."""
        with self._mutex:
            seq = self.seqs.get(seq_id)
            if seq is None or seq.status in (SeqStatus.FINISHED,
                                             SeqStatus.ABORTED):
                return None
            now = time.monotonic()
            # PREEMPTED sequences sit in the waiting queue awaiting resume
            # — an abort must pull them out before a policy re-admits them
            queued = seq.status in (SeqStatus.WAITING, SeqStatus.PREEMPTED)
            seq.status = SeqStatus.ABORTED
            seq.finish_t = now
            seq.finish_reason = "abort"
            if queued:
                try:
                    self._queue_for(seq).remove(seq)
                except ValueError:
                    pass
                self.seqs.pop(seq_id, None)
                if self.kv is not None:
                    self.kv.release(seq_id)
            else:
                self._retired.add(seq_id)
            return seq

    # -- sampling-output ingestion ----------------------------------------
    def complete(self, iteration: int, seq_ids: List[int],
                 token_ids: np.ndarray,
                 epochs: Optional[List[int]] = None) -> List[int]:
        """Append sampled tokens; returns finished seq ids.

        ``epochs`` (paged layout) is each sequence's preemption
        generation at the time this iteration was SCHEDULED: a token from
        an iteration that predates the sequence's eviction is dropped
        even if the sequence has already been re-admitted — the resumed
        prefill recomputes that very token (bit-exact under greedy), so
        accepting the stale one would duplicate it."""
        now = time.monotonic()
        done = []
        epochs = epochs if epochs is not None else [None] * len(seq_ids)
        with self._mutex:
            for sid, tok, epoch in zip(seq_ids, token_ids, epochs):
                seq = self.seqs.get(sid)
                if seq is None or seq.status != SeqStatus.RUNNING:
                    continue   # finished/aborted while this batch was in flight
                if epoch is not None and seq.preemptions != epoch:
                    continue   # scheduled before an eviction: stale token
                if seq.last_token_t is not None and seq.is_online:
                    # TPOT-SLO feedback (adaptive budget, disaggregated
                    # phase cap) tracks ONLINE latency only — offline
                    # tokens steering it would alter online decisions
                    self.tpot_samples.append(now - seq.last_token_t)
                finished_now = (seq.append(int(tok), now)
                                or seq.length >= self.max_seq_len)
                # parallel sampling: the parent's FIRST token is the
                # moment every stage provably holds its full prompt KV
                # (the token only exists because the prefill traversed
                # the whole pipeline) — fork the n-1 children here,
                # BEFORE any finish-time block release below
                if (seq.params.n > 1 and not seq.forks_spawned
                        and seq.fork_parent is None):
                    self._spawn_forks(seq, int(tok), now)
                if finished_now:
                    seq.status = SeqStatus.FINISHED
                    seq.finish_t = seq.finish_t or now
                    seq.finish_reason = seq.finish_reason or "length"
                    self.finished.append(seq)
                    self._retired.add(sid)
                    if self.kv is not None:
                        # block-budget accounting: a finished sequence's
                        # blocks return to the pool at once (the engine's
                        # own release is idempotent with this)
                        self.kv.release(sid)
                    done.append(sid)
        return done
