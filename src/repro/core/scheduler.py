"""Continuous-batching scheduler (SiPipe §4.2) with chunked prefill.

Keeps p microbatches in flight (one per pipeline stage).  On receiving
iteration n's sampling output it immediately dispatches iteration n+p with
the same sequence set minus finished ones plus admitted waiters — which is
exactly the stability property the column-wise sampler and the TSEM
BatchMetadata replicas rely on (batches n and n+p are near-identical).

Chunked prefill (SARATHI-style, opt-in via ``token_budget``): instead of
dispatching whole-prompt prefills as monolithic pipeline-blocking batches,
long prompts are split into fixed-token-budget chunks that piggyback on
the slot's in-flight decode tokens, so every iteration of every slot
carries a near-constant token count:

  * each scheduled iteration emits per-seq *spans* ``(offset, n_tokens)``
    — a decode step is the degenerate span ``(length-1, 1)``;
  * decode tokens are always scheduled; the remaining budget is handed to
    prefilling members (admission order) as chunks;
  * sampling fires only for sequences whose span reaches the last prompt
    token (``needs_sample``) — earlier chunks produce no token;
  * total tokens per iteration never exceed ``token_budget`` (the budget
    is clamped to ``max_batch + 1`` so prefill always makes progress).

With ``token_budget=None`` the scheduler behaves exactly like the seed
monolithic path (``is_prefill`` batches handled by the engine's
``_admit_and_prefill``).

Chunk-carrying iterations are executed over a *packed ragged* layout —
the batch's valid span tokens concatenated into flat [T] vectors and
bucketed to a small set of power-of-two widths (``packed_layout()`` /
``packed_width``) — see docs/scheduling.md.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.sampling_params import SamplingParams
from repro.core.sequence import SeqStatus, Sequence


BUCKET_FLOOR = 8


def bucket_width(n_tokens: int) -> int:
    """Packed execution width for ``n_tokens`` valid span tokens: the
    smallest power of two >= n_tokens (floor 8).  Bucketing the ragged
    total to a small set of widths means XLA compiles one chunk step per
    (bucket, batch) pair instead of one per distinct token count."""
    b = BUCKET_FLOOR
    while b < n_tokens:
        b <<= 1
    return b


@dataclasses.dataclass
class SchedulingOutput:
    """Broadcast to every worker + sampler via BIC-I."""

    iteration: int
    slot: int                      # iteration %% p — the TSEM replica index
    seq_ids: List[int]
    # per-seq state the CPU executor needs to build model inputs
    positions: np.ndarray          # [B] span start (decode: next-token position)
    tokens: np.ndarray             # [B] first input token of each span
    is_prefill: bool               # True -> monolithic-prefill the batch first
    prompt_lens: Optional[List[int]] = None
    batch_recomposed: bool = False
    # ---- chunked-prefill extensions (None on pure monolithic/decode paths) --
    spans: Optional[List[Tuple[int, int]]] = None   # per-seq (offset, n_tokens)
    span_tokens: Optional[List[List[int]]] = None   # input ids for each span
    needs_sample: Optional[List[bool]] = None       # span reaches a sampling point

    @property
    def max_span(self) -> int:
        """Widest span in the batch; 1 for pure-decode iterations."""
        if not self.spans:
            return 1
        return max(c for _, c in self.spans)

    @property
    def total_tokens(self) -> int:
        if not self.spans:
            return len(self.seq_ids)
        return sum(c for _, c in self.spans)

    @property
    def packed_width(self) -> int:
        """Execution width of the packed ragged token layout: 1 for pure
        decode (the flat [B] fast path), else the power-of-two bucket that
        ``total_tokens`` rounds up to (see :func:`bucket_width`)."""
        if self.max_span == 1:
            return 1
        return bucket_width(self.total_tokens)

    def packed_layout(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                     np.ndarray]:
        """The packed [T] token layout (T = total_tokens, unpadded).

        Returns ``(tokens, positions, seq_idx, last_index)`` int32 arrays:
        every valid span token exactly once, batch columns concatenated in
        order, positions monotone within each column; ``last_index[i]`` is
        the packed index of column i's final (sampling) token.
        """
        toks: List[int] = []
        pos: List[int] = []
        seq: List[int] = []
        last = np.zeros(len(self.seq_ids), np.int32)
        for i, ((off, n), ids) in enumerate(zip(self.spans, self.span_tokens)):
            toks.extend(ids)
            pos.extend(range(off, off + n))
            seq.extend([i] * n)
            last[i] = len(toks) - 1
        return (np.asarray(toks, np.int32), np.asarray(pos, np.int32),
                np.asarray(seq, np.int32), last)

    def sample_indices(self) -> List[int]:
        """Batch columns whose logits must be sampled this iteration."""
        if self.needs_sample is None:
            return list(range(len(self.seq_ids)))
        return [i for i, ns in enumerate(self.needs_sample) if ns]


class Scheduler:
    def __init__(self, *, max_batch: int, pp_degree: int = 1,
                 max_seq_len: int = 4096,
                 token_budget: Optional[int] = None):
        self.max_batch = max_batch
        self.p = pp_degree
        self.max_seq_len = max_seq_len
        # chunked prefill is enabled iff a budget is given; decode members
        # take 1 token each, so budget > max_batch guarantees progress
        self.token_budget = (max(token_budget, max_batch + 1)
                             if token_budget is not None else None)
        self.waiting: Deque[Sequence] = deque()
        self.seqs: Dict[int, Sequence] = {}
        self.slot_members: List[List[int]] = [[] for _ in range(pp_degree)]
        self.iteration = 0
        self.finished: List[Sequence] = []

    @property
    def chunked(self) -> bool:
        return self.token_budget is not None

    # -- request ingestion --------------------------------------------------
    def add_request(self, seq: Sequence):
        if len(seq.prompt_ids) >= self.max_seq_len:
            # fail loudly up front: the chunked path would otherwise issue
            # chunks past the KV cache and silently produce garbage
            raise ValueError(
                f"prompt of {len(seq.prompt_ids)} tokens does not fit "
                f"max_seq_len={self.max_seq_len} (need >= 1 output slot)")
        seq.arrival_t = seq.arrival_t or time.monotonic()
        self.seqs[seq.seq_id] = seq
        self.waiting.append(seq)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(self.slot_members)

    # -- iteration dispatch ---------------------------------------------------
    def schedule(self, iteration: Optional[int] = None) -> Optional[SchedulingOutput]:
        """Build the scheduling output for the next iteration of slot
        ``iteration %% p``, topping the slot up from the waiting queue."""
        it = self.iteration if iteration is None else iteration
        if self.chunked:
            return self._schedule_chunked(it)
        slot = it % self.p
        members = [sid for sid in self.slot_members[slot]
                   if self.seqs[sid].status == SeqStatus.RUNNING]
        recomposed = len(members) != len(self.slot_members[slot])
        new_prefill: List[int] = []
        while self.waiting and len(members) < self.max_batch:
            seq = self.waiting.popleft()
            seq.status = SeqStatus.RUNNING
            seq.prefilled = len(seq.prompt_ids)   # monolithic: all at once
            members.append(seq.seq_id)
            new_prefill.append(seq.seq_id)
            recomposed = True
        self.slot_members[slot] = members
        if not members:
            return None

        tokens = np.array([self.seqs[sid].last_token for sid in members], np.int32)
        positions = np.array([self.seqs[sid].length - 1 for sid in members], np.int32)
        out = SchedulingOutput(
            iteration=it,
            slot=slot,
            seq_ids=list(members),
            positions=positions,
            tokens=tokens,
            is_prefill=bool(new_prefill),
            prompt_lens=[len(self.seqs[s].prompt_ids) for s in members],
            batch_recomposed=recomposed,
        )
        self.iteration = max(self.iteration, it + 1)
        return out

    # -- chunked-prefill dispatch ------------------------------------------
    def _schedule_chunked(self, it: int) -> Optional[SchedulingOutput]:
        slot = it % self.p
        members = [sid for sid in self.slot_members[slot]
                   if self.seqs[sid].status == SeqStatus.RUNNING]
        recomposed = len(members) != len(self.slot_members[slot])

        # decode members are always carried (1 token each); prefill chunks
        # share whatever budget remains, in slot-membership order
        n_decode = sum(1 for sid in members if self.seqs[sid].prefill_done)
        budget_left = self.token_budget - n_decode

        batch_ids: List[int] = []
        spans: List[Tuple[int, int]] = []
        span_tokens: List[List[int]] = []
        needs_sample: List[bool] = []

        def emit(seq: Sequence):
            nonlocal budget_left
            if seq.prefill_done:
                off = seq.length - 1
                spans.append((off, 1))
                span_tokens.append([seq.last_token])
                needs_sample.append(True)
                batch_ids.append(seq.seq_id)
                return True
            c = min(seq.prompt_len - seq.prefilled, budget_left)
            if c <= 0:
                return False          # deferred: stays a slot member
            off = seq.prefilled
            spans.append((off, c))
            span_tokens.append(list(seq.prompt_ids[off:off + c]))
            needs_sample.append(off + c >= seq.prompt_len)
            batch_ids.append(seq.seq_id)
            seq.prefilled = off + c   # chunk issued: next schedule continues
            budget_left -= c
            return True

        deferred = False
        for sid in members:
            if not emit(self.seqs[sid]):
                deferred = True
        while (self.waiting and len(members) < self.max_batch
               and budget_left > 0):
            seq = self.waiting.popleft()
            seq.status = SeqStatus.RUNNING
            members.append(seq.seq_id)
            recomposed = True
            emit(seq)

        self.slot_members[slot] = members
        if not batch_ids:
            return None
        # any chunked batch (or deferral gap) recomposes vs. pure decode
        recomposed = recomposed or deferred or any(c > 1 for _, c in spans)

        tokens = np.array([t[0] for t in span_tokens], np.int32)
        positions = np.array([off for off, _ in spans], np.int32)
        out = SchedulingOutput(
            iteration=it,
            slot=slot,
            seq_ids=batch_ids,
            positions=positions,
            tokens=tokens,
            is_prefill=False,          # no monolithic pipeline-blocking pass
            prompt_lens=[self.seqs[s].prompt_len for s in batch_ids],
            batch_recomposed=recomposed,
            spans=spans,
            span_tokens=span_tokens,
            needs_sample=needs_sample,
        )
        self.iteration = max(self.iteration, it + 1)
        return out

    # -- sampling-output ingestion ----------------------------------------
    def complete(self, iteration: int, seq_ids: List[int],
                 token_ids: np.ndarray) -> List[int]:
        """Append sampled tokens; returns finished seq ids."""
        now = time.monotonic()
        done = []
        for sid, tok in zip(seq_ids, token_ids):
            seq = self.seqs[sid]
            if seq.status != SeqStatus.RUNNING:
                continue
            if seq.append(int(tok), now) or seq.length >= self.max_seq_len:
                seq.status = SeqStatus.FINISHED
                seq.finish_t = seq.finish_t or now
                self.finished.append(seq)
                done.append(sid)
        return done
