"""Continuous-batching scheduler (SiPipe §4.2).

Keeps p microbatches in flight (one per pipeline stage).  On receiving
iteration n's sampling output it immediately dispatches iteration n+p with
the same sequence set minus finished ones plus admitted waiters — which is
exactly the stability property the column-wise sampler and the TSEM
BatchMetadata replicas rely on (batches n and n+p are near-identical).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.core.sampling_params import SamplingParams
from repro.core.sequence import SeqStatus, Sequence


@dataclasses.dataclass
class SchedulingOutput:
    """Broadcast to every worker + sampler via BIC-I."""

    iteration: int
    slot: int                      # iteration %% p — the TSEM replica index
    seq_ids: List[int]
    # per-seq state the CPU executor needs to build model inputs
    positions: np.ndarray          # [B] next-token positions
    tokens: np.ndarray             # [B] last sampled token ids (input tokens)
    is_prefill: bool               # True -> prefill the batch first
    prompt_lens: Optional[List[int]] = None
    batch_recomposed: bool = False


class Scheduler:
    def __init__(self, *, max_batch: int, pp_degree: int = 1,
                 max_seq_len: int = 4096):
        self.max_batch = max_batch
        self.p = pp_degree
        self.max_seq_len = max_seq_len
        self.waiting: Deque[Sequence] = deque()
        self.seqs: Dict[int, Sequence] = {}
        self.slot_members: List[List[int]] = [[] for _ in range(pp_degree)]
        self.iteration = 0
        self.finished: List[Sequence] = []

    # -- request ingestion --------------------------------------------------
    def add_request(self, seq: Sequence):
        seq.arrival_t = seq.arrival_t or time.monotonic()
        self.seqs[seq.seq_id] = seq
        self.waiting.append(seq)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(self.slot_members)

    # -- iteration dispatch ---------------------------------------------------
    def schedule(self, iteration: Optional[int] = None) -> Optional[SchedulingOutput]:
        """Build the scheduling output for the next iteration of slot
        ``iteration %% p``, topping the slot up from the waiting queue."""
        it = self.iteration if iteration is None else iteration
        slot = it % self.p
        members = [sid for sid in self.slot_members[slot]
                   if self.seqs[sid].status == SeqStatus.RUNNING]
        recomposed = len(members) != len(self.slot_members[slot])
        new_prefill: List[int] = []
        while self.waiting and len(members) < self.max_batch:
            seq = self.waiting.popleft()
            seq.status = SeqStatus.RUNNING
            members.append(seq.seq_id)
            new_prefill.append(seq.seq_id)
            recomposed = True
        self.slot_members[slot] = members
        if not members:
            return None

        tokens = np.array([self.seqs[sid].last_token for sid in members], np.int32)
        positions = np.array([self.seqs[sid].length - 1 for sid in members], np.int32)
        out = SchedulingOutput(
            iteration=it,
            slot=slot,
            seq_ids=list(members),
            positions=positions,
            tokens=tokens,
            is_prefill=bool(new_prefill),
            prompt_lens=[len(self.seqs[s].prompt_ids) for s in members],
            batch_recomposed=recomposed,
        )
        self.iteration = max(self.iteration, it + 1)
        return out

    # -- sampling-output ingestion ----------------------------------------
    def complete(self, iteration: int, seq_ids: List[int],
                 token_ids: np.ndarray) -> List[int]:
        """Append sampled tokens; returns finished seq ids."""
        now = time.monotonic()
        done = []
        for sid, tok in zip(seq_ids, token_ids):
            seq = self.seqs[sid]
            if seq.status != SeqStatus.RUNNING:
                continue
            if seq.append(int(tok), now) or seq.length >= self.max_seq_len:
                seq.status = SeqStatus.FINISHED
                seq.finish_t = seq.finish_t or now
                self.finished.append(seq)
                done.append(sid)
        return done
