"""Column-wise CPU sampling with incremental metadata reuse (SiPipe §5.1).

The sampler runs on host CPUs, decoupled from the accelerator: the final
pipeline stage ships logits and goes straight to its next microbatch,
eliminating the paper's *load-imbalance bubble*.

Key mechanics reproduced from the paper:
  * incremental penalty construction: each iteration touches exactly the B
    entries of each penalty buffer addressed by the new token ids, instead
    of recomputing dense penalty tensors from the output history Y (the
    naive baseline below recomputes — cost grows with sequence length);
  * preallocated max-length output buffer Y: new token ids are appended in
    place — no reshape/reallocation per iteration;
  * column-wise (transposed) layout on the *shard ingestion* path: TP
    workers produce [B, V/t] logits shards; transposed to [V/t, B] they
    concatenate along rows into Z^T [V, B] with zero gathers (§5.1(3)).
    ``sample(..., transposed=True)`` consumes that layout directly;
  * p distinct replicas (pipeline degree) — microbatch n and n+p are the
    same sequence set, so each replica's buffers stay valid under PP.

Per-request sampling parameters: ``sample()`` accepts either one
``SamplingParams`` (the whole batch shares it) or a per-column sequence
of them — the serving API contract that mixed continuous-batching
batches carry each request's own temperature/penalties.  Penalty
application is vectorized over per-column coefficient arrays against the
shared replica buffers; the draw stage partitions columns into groups of
identical params (mixed batches are recompositions of a few distinct
request configs, so groups are few).  A uniform batch takes the exact
pre-existing scalar path, bit-for-bit.

Hardware adaptation (DESIGN.md §sampler-layout): on this numpy substrate
the compute-heavy steps (softmax/top-k) are fastest along contiguous
vocab rows, so when logits arrive row-major [B, V] the penalty buffers are
kept row-major too — the *incremental O(B) update* (the paper's actual
saving) is layout-independent; the column-wise layout is used exactly
where it pays: zero-copy transposed shard ingestion.

``NaiveSampler`` implements the recompute-from-scratch baseline used for
the ablation benchmark (paper Fig. 16).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.sampling_params import SamplingParams

ParamsLike = Union[SamplingParams, Sequence[SamplingParams]]


def _softmax(z: np.ndarray, axis: int) -> np.ndarray:
    m = z.max(axis=axis, keepdims=True)
    e = np.exp(z - m, dtype=np.float32)
    return e / e.sum(axis=axis, keepdims=True)


def _normalize_params(params: ParamsLike, b: int) -> List[SamplingParams]:
    """Broadcast a single SamplingParams to the batch; validate lengths."""
    if isinstance(params, SamplingParams):
        return [params] * b
    plist = list(params)
    if len(plist) != b:
        raise ValueError(
            f"per-column sampling params length {len(plist)} != batch {b}")
    return plist


def _uniform(plist: List[SamplingParams]) -> Optional[SamplingParams]:
    """The shared params when every column agrees, else None."""
    first = plist[0]
    return first if all(q == first for q in plist) else None


def _coef(plist: List[SamplingParams], attr: str, axis: int) -> np.ndarray:
    """Per-column coefficient array shaped to broadcast along ``axis``."""
    a = np.array([getattr(q, attr) for q in plist], np.float32)
    return a[:, None] if axis == 1 else a[None, :]


def _apply_penalties(z: np.ndarray, plist: List[SamplingParams],
                     freq: np.ndarray, pres: np.ndarray,
                     axis: int) -> np.ndarray:
    """(1) logits adjustment — fused vector ops on the penalty buffers
    (a sampler replica's persistent buffers, or NaiveSampler's recomputed
    ones).  Uniform batches keep the scalar expressions; mixed batches
    use per-column coefficient arrays broadcast against the same buffers.
    Shared by both samplers so penalty semantics cannot diverge."""
    u = _uniform(plist)
    if u is not None:
        if u.frequency_penalty:
            z -= u.frequency_penalty * freq
        if u.presence_penalty:
            z -= u.presence_penalty * pres
        if u.repetition_penalty != 1.0:
            seen = pres > 0
            pen = np.where(z > 0, z / u.repetition_penalty,
                           z * u.repetition_penalty)
            z = np.where(seen, pen, z)
        return z
    fp = _coef(plist, "frequency_penalty", axis)
    if fp.any():
        z -= fp * freq
    pp = _coef(plist, "presence_penalty", axis)
    if pp.any():
        z -= pp * pres
    rp = _coef(plist, "repetition_penalty", axis)
    if (rp != 1.0).any():
        seen = (pres > 0) & (rp != 1.0)
        pen = np.where(z > 0, z / rp, z * rp)
        z = np.where(seen, pen, z)
    return z


def _draw_grouped(z: np.ndarray, plist: List[SamplingParams], axis: int,
                  draw_one) -> np.ndarray:
    """Token draw honoring per-column params: columns sharing params form
    one group and draw together via ``draw_one(z_group, params)`` (a
    uniform batch == one group == the original whole-batch path)."""
    u = _uniform(plist)
    if u is not None:
        if u.greedy or u.temperature == 0.0:
            return z.argmax(axis=axis).astype(np.int32)
        return draw_one(z, u)
    out = np.zeros(len(plist), np.int32)
    groups: Dict[SamplingParams, List[int]] = {}
    for i, q in enumerate(plist):
        groups.setdefault(q, []).append(i)
    for q, cols in groups.items():
        idx = np.asarray(cols, np.int64)
        zz = z[idx] if axis == 1 else z[:, idx]   # fancy-index copy
        if q.greedy or q.temperature == 0.0:
            ids = zz.argmax(axis=axis).astype(np.int32)
        else:
            ids = draw_one(zz, q)
        out[idx] = ids
    return out


@dataclasses.dataclass
class _Replica:
    """Per-pipeline-slot penalty state.  ``layout`` is "rm" (row-major
    [B, V]) or "cw" (column-wise [V, B], transposed-shard ingestion)."""

    layout: str
    freq: np.ndarray
    pres: np.ndarray
    out: np.ndarray         # [L_max, B] int32 output ids (row-appended)
    out_len: np.ndarray     # [B] int32
    seq_ids: List[int]


class ColumnWiseSampler:
    """The SiPipe CPU sampler (see module docstring)."""

    def __init__(self, vocab_size: int, max_batch: int, *, pp_degree: int = 1,
                 max_len: int = 4096, seed: int = 0):
        self.v = vocab_size
        self.max_batch = max_batch
        self.p = pp_degree
        self.max_len = max_len
        self.rng = np.random.default_rng(seed)
        self._replicas: Dict[int, _Replica] = {}
        # serializes replica get-rebuild-update: sample() runs on the
        # engine's pool threads while drop_seq() (request retire/abort)
        # runs on the driver thread — an unsynchronized concurrent rebuild
        # of the same slot replica would drop the pool thread's penalty
        # update for surviving sequences
        self._lock = threading.Lock()

    # ---- replica management ---------------------------------------------
    def _replica(self, slot: int, batch: int, seq_ids: Sequence[int],
                 layout: str) -> _Replica:
        """Fetch (or rebuild) the slot's penalty replica.

        Rebuilds carry per-sequence state over: when the sequence set
        shrinks, grows or is reordered (mixed-batch evictions, chunked
        prefill phases), every sequence still present keeps its freq /
        pres / output columns — only departed sequences are dropped and
        new ones start from zero.  This is what makes chunked prefill
        compose exactly with frequency/presence penalties.
        """
        r = self._replicas.get(slot)
        ids = list(seq_ids)
        if (r is not None and r.out_len.shape[0] == batch
                and r.seq_ids == ids and r.layout == layout):
            return r
        shape = (self.v, batch) if layout == "cw" else (batch, self.v)
        new = _Replica(
            layout=layout,
            freq=np.zeros(shape, np.float32),
            pres=np.zeros(shape, np.float32),
            out=np.zeros((self.max_len, batch), np.int32),
            out_len=np.zeros(batch, np.int32),
            seq_ids=ids,
        )
        if r is not None:
            old_col = {sid: j for j, sid in enumerate(r.seq_ids)}
            for col, sid in enumerate(ids):
                j = old_col.get(sid)
                if j is None:
                    continue
                src_f = r.freq[:, j] if r.layout == "cw" else r.freq[j]
                src_p = r.pres[:, j] if r.layout == "cw" else r.pres[j]
                if layout == "cw":
                    new.freq[:, col] = src_f
                    new.pres[:, col] = src_p
                else:
                    new.freq[col] = src_f
                    new.pres[col] = src_p
                new.out[:, col] = r.out[:, j]
                new.out_len[col] = r.out_len[j]
        self._replicas[slot] = new
        return new

    def reset(self):
        with self._lock:
            self._replicas.clear()

    def evict(self, slot: int):
        with self._lock:
            self._replicas.pop(slot, None)

    def drop_seq(self, seq_id: int):
        """Strip a released sequence's penalty column from every replica
        (request retired or aborted — its state must not linger)."""
        with self._lock:
            for slot, r in list(self._replicas.items()):
                if seq_id not in r.seq_ids:
                    continue
                ids = [s for s in r.seq_ids if s != seq_id]
                if not ids:
                    del self._replicas[slot]
                else:
                    self._replica(slot, len(ids), ids, r.layout)

    def tracked_seq_ids(self) -> set:
        """Sequence ids with live penalty columns (leak assertions)."""
        with self._lock:
            out = set()
            for r in self._replicas.values():
                out.update(r.seq_ids)
            return out

    # ---- the sampling pipeline -------------------------------------------
    def sample(
        self,
        logits: np.ndarray,
        params: ParamsLike,
        *,
        slot: int = 0,
        seq_ids: Optional[Sequence[int]] = None,
        transposed: bool = False,
    ) -> np.ndarray:
        """logits: [B, V] row-major, or [V, B] when ``transposed`` (the
        zero-gather concatenation of per-worker [V/t, B] shards).
        ``params``: one SamplingParams for the whole batch, or one per
        column (per-request sampling parameters in mixed batches)."""
        if transposed:
            return self._sample_cw(np.asarray(logits, np.float32), params,
                                   slot, seq_ids)
        z = np.array(logits, np.float32, copy=True)          # [B, V]
        b = z.shape[0]
        plist = _normalize_params(params, b)
        with self._lock:
            r = self._replica(slot % self.p, b, seq_ids or list(range(b)),
                              "rm")
            z = _apply_penalties(z, plist, r.freq, r.pres, axis=1)
            ids = _draw_grouped(z, plist, 1,
                                lambda zz, q: self._draw(zz, q, 1))
            self._update(r, ids)
        return ids

    def _sample_cw(self, zt, params, slot, seq_ids):
        # np.asarray does NOT copy an already-float32 input, and both the
        # penalty ops below and _draw mutate in place — copy so the
        # caller's logits buffer (shipped over BIC-L) survives intact
        zt = np.array(zt, np.float32, copy=True)
        v, b = zt.shape
        assert v == self.v, (v, self.v)
        plist = _normalize_params(params, b)
        with self._lock:
            r = self._replica(slot % self.p, b, seq_ids or list(range(b)),
                              "cw")
            zt = _apply_penalties(zt, plist, r.freq, r.pres, axis=0)
            ids = _draw_grouped(zt, plist, 0,
                                lambda zz, q: self._draw(zz, q, 0))
            self._update(r, ids)
        return ids

    # ---- shared probability pipeline --------------------------------------
    def _draw(self, z: np.ndarray, params: SamplingParams, axis: int) -> np.ndarray:
        if params.greedy or params.temperature == 0.0:
            return z.argmax(axis=axis).astype(np.int32)
        if params.temperature != 1.0:
            z /= params.temperature
        if params.top_k:
            if axis == 1:
                kth = np.partition(z, -params.top_k, axis=1)[:, -params.top_k]
                z[z < kth[:, None]] = -np.inf
            else:
                kth = np.partition(z, -params.top_k, axis=0)[-params.top_k]
                z[z < kth[None, :]] = -np.inf
        probs = _softmax(z, axis)
        if params.min_p:
            cap = probs.max(axis=axis, keepdims=True) * params.min_p
            probs[probs < cap] = 0.0
        if params.top_p < 1.0:
            probs = self._top_p_filter(probs, params.top_p, axis)
        probs /= probs.sum(axis=axis, keepdims=True)
        b = probs.shape[1 - axis]
        u = self.rng.random(b, dtype=np.float32)
        cdf = np.cumsum(probs, axis=axis)
        if axis == 1:
            ids = (cdf < u[:, None]).sum(axis=1)
        else:
            ids = (cdf < u[None, :]).sum(axis=0)
        return ids.clip(0, self.v - 1).astype(np.int32)

    @staticmethod
    def _top_p_filter(probs: np.ndarray, top_p: float, axis: int) -> np.ndarray:
        order = np.argsort(-probs, axis=axis)
        sp = np.take_along_axis(probs, order, axis=axis)
        csum = np.cumsum(sp, axis=axis)
        keep_sorted = (csum - sp) <= top_p   # keep until mass exceeds p
        keep = np.zeros_like(keep_sorted)
        np.put_along_axis(keep, order, keep_sorted, axis=axis)
        return np.where(keep, probs, 0.0)

    # ---- incremental metadata update: O(B) scattered writes ----------------
    def _update(self, r: _Replica, ids: np.ndarray):
        b = ids.shape[0]
        cols = np.arange(b)
        if r.layout == "cw":
            r.freq[ids, cols] += 1.0
            r.pres[ids, cols] = 1.0
        else:
            r.freq[cols, ids] += 1.0
            r.pres[cols, ids] = 1.0
        r.out[r.out_len.clip(max=self.max_len - 1), cols] = ids
        np.minimum(r.out_len + 1, self.max_len, out=r.out_len)

    def seed_prompt(self, slot: int, batch: int, seq_ids: Sequence[int],
                    prompt_ids: List[np.ndarray], layout: str = "rm"):
        """Fold prompt tokens into the penalty state (vLLM semantics:
        repetition/presence penalties consider the prompt)."""
        with self._lock:
            r = self._replica(slot % self.p, batch, seq_ids, layout)
            for col, ids in enumerate(prompt_ids):
                ids = np.asarray(ids, np.int64)
                if layout == "cw":
                    np.add.at(r.freq[:, col], ids, 1.0)
                    r.pres[ids, col] = 1.0
                else:
                    np.add.at(r.freq[col], ids, 1.0)
                    r.pres[col, ids] = 1.0


class NaiveSampler:
    """Recompute-from-scratch baseline (what pipeline-agnostic engines do):
    rebuilds [B, V] penalty tensors from the full output history every
    iteration — cost grows with generated length.  Accepts the same
    per-column params contract as ColumnWiseSampler.

    When ``seq_ids`` is passed (the engine always does), output history
    is keyed per sequence, so batch recomposition under continuous
    serving cannot hand a successor request its predecessor's penalty
    history; without ``seq_ids`` the legacy per-slot positional history
    applies (microbenchmarks seed it directly)."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.v = vocab_size
        self.rng = np.random.default_rng(seed)
        self.history: Dict[int, List[np.ndarray]] = {}      # slot -> columns
        self.seq_history: Dict[int, np.ndarray] = {}        # seq_id -> ids

    def drop_seq(self, seq_id: int):
        """Release a retired/aborted sequence's output history."""
        self.seq_history.pop(seq_id, None)

    def tracked_seq_ids(self) -> set:
        return set(self.seq_history)

    def sample(self, logits: np.ndarray, params: ParamsLike, *,
               slot: int = 0, seq_ids: Optional[Sequence[int]] = None,
               **_) -> np.ndarray:
        z = np.array(logits, np.float32, copy=True)   # [B, V]
        b = z.shape[0]
        plist = _normalize_params(params, b)
        if seq_ids is not None:
            hist = [self.seq_history.get(sid, np.zeros(0, np.int64))
                    for sid in seq_ids]
        else:
            hist = self.history.setdefault(
                slot, [np.zeros(0, np.int64) for _ in range(b)])
            if len(hist) != b:
                hist = self.history[slot] = [np.zeros(0, np.int64)
                                             for _ in range(b)]

        if any(q.needs_penalties() for q in plist):
            freq = np.zeros((b, self.v), np.float32)  # fresh allocation
            for i, h in enumerate(hist):              # full recompute over Y
                np.add.at(freq[i], h, 1.0)
            pres = (freq > 0).astype(np.float32)
            z = _apply_penalties(z, plist, freq, pres, axis=1)

        ids = _draw_grouped(z, plist, 1, self._draw)

        if seq_ids is not None:
            for sid, t in zip(seq_ids, ids):
                self.seq_history[sid] = np.append(
                    self.seq_history.get(sid, np.zeros(0, np.int64)), t)
        else:
            for i, t in enumerate(ids):
                hist[i] = np.append(hist[i], t)
        return ids

    def _draw(self, z: np.ndarray, params: SamplingParams) -> np.ndarray:
        b = z.shape[0]
        if params.greedy or params.temperature == 0.0:
            return z.argmax(axis=1).astype(np.int32)
        if params.temperature != 1.0:
            z /= params.temperature
        if params.top_k:
            kth = np.partition(z, -params.top_k, axis=1)[:, -params.top_k]
            z[z < kth[:, None]] = -np.inf
        probs = _softmax(z, 1)
        if params.min_p:
            cap = probs.max(axis=1, keepdims=True) * params.min_p
            probs[probs < cap] = 0.0
        if params.top_p < 1.0:
            probs = ColumnWiseSampler._top_p_filter(probs, params.top_p, 1)
        probs /= probs.sum(axis=1, keepdims=True)
        u = self.rng.random((b, 1), dtype=np.float32)
        cdf = np.cumsum(probs, axis=1)
        return (cdf < u).sum(axis=1).clip(0, self.v - 1).astype(np.int32)


class SamplingWorker:
    """Host-side sampling thread that overlaps iteration *n*'s sampling
    with the device's execution of iteration *n+1* (the SiPipe design
    point: sampling leaves the critical path of the stage loop).

    A single daemon thread drains a FIFO queue, so dispatch order equals
    submission order equals iteration order — token streams are
    *identical* to synchronous sampling (the sampler replicas' penalty
    state is mutated in exactly the same sequence, and the engine's
    per-slot autoregressive gate still makes a slot's next iteration
    await its sampled token).  The worker only moves *where* the wall
    time of ``dispatch_fn`` is spent: off the thread that launches
    device work.

    ``dispatch_fn(sched, logits)`` is the engine's synchronous sampling
    entry (sample + publish + iter-done bookkeeping).  Exceptions are
    captured and re-raised on the driver thread via ``check()`` — the
    engine polls it from its await loop, so a sampler crash surfaces
    instead of deadlocking the per-slot gate.
    """

    def __init__(self, dispatch_fn: Callable, name: str = "sampling-worker"):
        self.dispatch_fn = dispatch_fn
        self._q: "queue.Queue" = queue.Queue()
        self._exc: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._loop, name=name,
                                        daemon=True)
        self._thread.start()

    def submit(self, sched, logits):
        self._q.put((sched, logits))

    def _loop(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            if self._exc is not None:
                continue                       # drain; check() will raise
            sched, logits = item
            try:
                self.dispatch_fn(sched, logits)
            except BaseException as e:         # noqa: BLE001
                self._exc = e

    def check(self):
        """Re-raise (once per poll) any exception from the worker thread."""
        if self._exc is not None:
            raise RuntimeError("sampling worker failed") from self._exc

    def stop(self, timeout: float = 5.0):
        self._q.put(None)
        self._thread.join(timeout=timeout)
