"""The SiPipe serving engine (§4): scheduler + p stage workers + CPU
sampler pool + BIC channels, running a real JAX model end-to-end.

Two engines share all components:

  SiPipeEngine  — CPU column-wise sampling (decoupled from the last stage),
                  TSEM double-buffered CPU/device executors per stage, SAT
                  structure-aware stage channels.
  NaivePPEngine — the pipeline-agnostic baseline: in-stage sampling on the
                  final stage's critical path, synchronous prepare-then-
                  execute, structure-unaware stage transmission.

On this container everything runs on one CPU device, so stage compute
serializes physically; the engines still exercise the full concurrency
structure (threads, channels, FSMs) and *measure* the bubble anatomy:
per-stage busy intervals, prep/stall times, sampler latency.  The paper's
H100-scale headline numbers are reproduced by the calibrated discrete-
event simulator in benchmarks/pp_sim.py, fed with latencies measured here.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, Iterator, List, \
    Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.bic import LocalRing, SubSlotRing
from repro.core.request import (
    Request,
    RequestIdAllocator,
    RequestMetrics,
    RequestOutput,
    RequestState,
    TokenStream,
)
from repro.core.sampler import ColumnWiseSampler, NaiveSampler
from repro.core.sampling_params import SamplingParams
from repro.core.sat import StructureAwareChannel, StructureUnawareChannel
from repro.core.scheduler import Scheduler, SchedulingOutput
from repro.core.sequence import SeqStatus, Sequence, SequenceCache
from repro.core.tsem import (
    BatchMetadataCache,
    ModelInputDescriptor,
    SynchronousExecutor,
    TokenSafeExecutor,
)
from repro.models.registry import Model
from repro.models.stacked import run_stack
from repro.models.common import rmsnorm


# ---------------------------------------------------------------------------
# Stage splitting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PPStage:
    index: int
    n_stages: int
    groups: Tuple[int, int]              # [lo, hi) of the blocks stack
    params: Any
    prefill_fn: Callable                 # (params, x_or_tokens, pos0) -> (x|logits, cache)
    decode_fn: Callable                  # (params, cache, x_or_tokens, positions) -> (x|logits, cache)
    chunk_fn: Callable                   # (params, cache, x_or_tokens[T], positions[T], seq_idx[T], span_starts[B], last_idx[B], n_valid) -> (x|logits, cache)
    init_cache: Callable                 # (rows, s_max) -> cache tree

    @property
    def is_first(self) -> bool:
        return self.index == 0

    @property
    def is_last(self) -> bool:
        return self.index == self.n_stages - 1


def split_for_pp(model: Model, params: Any, p: int, *,
                 paged: bool = False) -> List[PPStage]:
    """Partition a decoder LM into p contiguous stages (layer groups).

    ``paged`` builds decode/chunk stage functions that take the [B, nb]
    block table as a trailing argument and run attention *through* it
    (block-major physical cache in, dirty-slot write-back out) — the
    paged-native execution path (docs/memory.md)."""
    assert set(model.stacks) == {"blocks"}, (
        "engine PP supports single-stack decoder families (dense/moe)")
    st = model.stacks["blocks"]
    assert st.n >= p, f"{st.n} groups < {p} stages"
    bounds = [round(i * st.n / p) for i in range(p + 1)]
    stages = []
    for i in range(p):
        lo, hi = bounds[i], bounds[i + 1]
        sp: Dict[str, Any] = {
            "blocks": jax.tree.map(lambda x: x[lo:hi], params["stacks"]["blocks"])}
        if i == 0:
            sp["embed"] = params["embed"]
        if i == p - 1:
            sp["lnf"], sp["head"] = params["lnf"], params["head"]
        stages.append(_make_stage(model, i, p, (lo, hi), sp, paged=paged))
    return stages


def _make_stage(model: Model, idx: int, p: int, bounds, sp, *,
                paged: bool = False) -> PPStage:
    st = model.stacks["blocks"]
    lo, hi = bounds
    n_groups = hi - lo
    sub = dataclasses.replace(st, n=n_groups)
    first, last = idx == 0, idx == p - 1

    def prefill_fn(params, x_or_tokens, pos0, last_idx):
        """last_idx [B]: each sequence's final real position (ragged
        batches are right-padded; logits must come from the true last
        token, not the pad tail — and windowed models also need the real
        lengths so pad-tail K/V stays out of the rolling cache)."""
        s = x_or_tokens.shape[1]
        ctx = model.make_ctx("prefill", pos0 + jnp.arange(s),
                             seq_lens=last_idx + 1)
        x = model.embed_tokens({"embed": params["embed"]}, x_or_tokens) if first \
            else x_or_tokens
        x, cache = run_stack(sub, params["blocks"], x, ctx, remat=False)
        if last:
            b = x.shape[0]
            x_last = x[jnp.arange(b), last_idx]
            return model.lm_head(params, x_last), cache
        return x, cache

    def decode_fn(params, cache, x_or_tokens, positions, tables=None):
        """``tables`` (paged layout only): [B, nb] physical block table.
        When set, ``cache`` leaves are block-major [n_blocks, bs, ...] and
        the attention blocks read/write through the table — the returned
        cache differs from the input in exactly the dirty slots."""
        ctx = model.make_ctx("decode", positions, block_tables=tables)
        x = model.embed_tokens({"embed": params["embed"]}, x_or_tokens) if first \
            else x_or_tokens
        x, cache = run_stack(sub, params["blocks"], x, ctx, cache_stacked=cache,
                             remat=False)
        if last:
            return model.lm_head(params, x), cache
        return x, cache

    def chunk_fn(params, cache, x_or_tokens, positions, seq_idx, span_starts,
                 last_idx, n_valid, tables=None):
        """Mixed chunked-prefill/decode step over the packed ragged layout:
        the batch's valid span tokens concatenated into flat [T] vectors
        (T = the power-of-two bucket; padding duplicates the last valid
        token).  ``seq_idx`` [T] maps each token to its batch row,
        ``span_starts`` [B] are the per-row span offsets (rolling-window
        attention), ``last_idx`` [B] the packed index of each row's final
        token whose logits feed the sampler, and ``n_valid`` the unpadded
        token count.  Embedding, RoPE, attention, cache scatter and the
        FFN all run at [T] — no padded [B, C] compute anywhere.
        ``tables`` (paged layout): as in ``decode_fn`` — the span's
        tokens scatter into exactly the physical blocks they touch."""
        ctx = model.make_ctx("chunk", positions, seq_idx=seq_idx,
                             span_starts=span_starts, n_valid=n_valid,
                             block_tables=tables)
        x = model.embed_tokens({"embed": params["embed"]}, x_or_tokens) if first \
            else x_or_tokens
        x, cache = run_stack(sub, params["blocks"], x, ctx, cache_stacked=cache,
                             remat=False)
        if last:
            return model.lm_head(params, x[last_idx]), cache
        return x, cache

    def init_cache(rows, s_max):
        import repro.models.stacked as stacked

        abstract = stacked.abstract_cache_tree(
            dataclasses.replace(sub, n=n_groups), rows, s_max)
        return stacked.zeros_cache(abstract)

    if paged:
        # the paged engine owns exactly one reference to the physical
        # cache and replaces it with the step's output, so the input
        # buffer is donated — the dirty-slot write-back updates in place
        # instead of copying the whole pool every iteration
        decode_jit = jax.jit(decode_fn, donate_argnums=(1,))
        chunk_jit = jax.jit(chunk_fn, donate_argnums=(1,))
    else:
        decode_jit, chunk_jit = jax.jit(decode_fn), jax.jit(chunk_fn)
    return PPStage(idx, p, bounds, sp, jax.jit(prefill_fn), decode_jit,
                   chunk_jit, init_cache)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EngineConfig:
    pp_degree: int = 2
    max_batch: int = 4              # per microbatch
    max_seq_len: int = 128
    n_samplers: int = 2
    cpu_sampling: bool = True       # False -> in-stage sampling (baseline)
    tsem: bool = True               # False -> synchronous prepare+execute
    sat: bool = True                # False -> structure-unaware transmission
    channel_round_latency_s: float = 0.0   # inject per-round cost for benches
    # per-iteration token budget for span scheduling policies (None =
    # monolithic whole-prompt prefill, the seed behavior)
    prefill_chunk_tokens: Optional[int] = None
    # scheduling policy: "auto" (budget -> chunked, else monolithic),
    # "monolithic", "chunked", "disaggregated" (TD-Pipe-style phase
    # scheduling), or "adaptive" (TPOT-SLO adaptive budget); see
    # docs/scheduling.md §Scheduling policies
    scheduling_policy: str = "auto"
    # disaggregated decode->prefill switch threshold in pending prefill
    # tokens per paused decode slot (None = the token budget)
    phase_hysteresis_tokens: Optional[int] = None
    # adaptive policy: target mean inter-token latency (None = the policy
    # self-calibrates from the first observed window)
    tpot_slo_s: Optional[float] = None
    # hybrid serving (docs/hybrid.md): in the disaggregated policy's
    # DECODE phase, offline-tier decodes may enlarge the batch beyond
    # max_batch up to max_batch * factor, but only at pow2 rungs (2x, 4x,
    # ...) so each rung is exactly one extra XLA compile shape — the same
    # discipline max_table_buckets applies to block-table widths.  1
    # (default) disables enlargement; > 1 requires the paged KV layout
    # and the disaggregated policy.
    decode_enlarge_factor: int = 1
    # bound on retained per-request latency records (the window online
    # metrics percentiles are computed over)
    keep_recent_requests: int = 2048
    # ---- KV memory substrate (docs/memory.md) ----------------------------
    # "paged": vLLM-style block tables over a [n_blocks, block_size, ...]
    # physical cache; admission is block-budget accounting, decode growth
    # under pressure preempts (and later recomputes) the lowest-priority
    # sequence.  Attention runs through the block table (paged-native
    # path) and is bit-exact with contiguous rows.
    # "contiguous": one dense [max_seq_len] cache row per sequence (the
    # seed layout — concurrency capped at max_batch * pp rows); the
    # escape hatch for families/configs the paged path doesn't cover.
    # "auto" (default): paged where supported (dense/moe families whose
    # sliding window, if any, is a block-size multiple), else contiguous.
    kv_layout: str = "auto"
    kv_block_size: int = 16
    # total physical blocks (None = the same slot budget contiguous rows
    # would reserve: max_batch * pp * max_seq_len / block_size — or the
    # sliding window in place of max_seq_len for rolling-cache models)
    kv_blocks: Optional[int] = None
    # cap on distinct padded block-table widths padded_tables may emit
    # (each width is one XLA compile of the stage step — see
    # BlockSpaceManager's ladder); None = unbounded pow2 widths
    max_table_buckets: Optional[int] = 2
    # hash-based prompt-prefix caching (paged layout, non-rolling caches
    # only — silently off otherwise): new requests whose leading full
    # prompt blocks hash-match cached blocks share them by refcount and
    # prefill only the unshared tail; see docs/memory.md "Prefix caching
    # & CoW forks"
    enable_prefix_caching: bool = True
    # sample iteration n on a host-side worker thread while the device
    # runs n+1 (SiPipe: sampling off the critical path); token streams
    # are identical to synchronous sampling (single FIFO worker + the
    # per-slot autoregressive gate)
    overlap_sampling: bool = True
    seed: int = 0


@dataclasses.dataclass
class StageMetrics:
    busy: List[Tuple[float, float]] = dataclasses.field(default_factory=list)
    prep_s: float = 0.0
    exec_s: float = 0.0
    sample_s: float = 0.0


class _StageWorker:
    """One pipeline stage: communicator + CPU executor + device executor."""

    def __init__(self, stage: PPStage, engine: "PPEngineBase"):
        self.stage = stage
        self.engine = engine
        self.metrics = StageMetrics()
        cfg = engine.cfg
        rows = cfg.max_batch * cfg.pp_degree
        if engine.paged:
            # physical cache [groups, n_blocks + 1, block_size, ...] per
            # leaf: logical slot p of a sequence lives at
            # (block_table[p // bs], p %% bs); the extra final block is the
            # trash block padded table entries point at (writes discarded,
            # reads position-masked) — docs/memory.md
            template = stage.init_cache(1, 1)
            nb = engine.kv_manager.n_blocks + 1
            bs = cfg.kv_block_size
            self.cache = jax.tree.map(
                lambda c: jnp.zeros((c.shape[0], nb, bs) + c.shape[3:],
                                    c.dtype), template)
        else:
            self.cache = stage.init_cache(rows, cfg.max_seq_len)
        self.meta_cache = BatchMetadataCache(cfg.pp_degree)
        ch = StructureAwareChannel if cfg.sat else StructureUnawareChannel
        self.out_channel = ch(cfg.channel_round_latency_s) if not stage.is_last else None
        # device step used by the executor
        if cfg.tsem:
            self.executor = TokenSafeExecutor(self._prepare, self._execute,
                                              name=f"stage{stage.index}")
            self.executor.start()
        else:
            self.executor = SynchronousExecutor(self._prepare, self._execute,
                                                name=f"stage{stage.index}")

    # -- CPU executor side ---------------------------------------------------
    def _prepare(self, sched: SchedulingOutput, bufs: Dict[str, np.ndarray]):
        eng = self.engine
        if eng.paged:
            # placement is the scheduler's block-table snapshot; rows are
            # meaningless (the batch dim is positional) and the dirty-slot
            # write-back mapping is derived inside the jitted stage from
            # the table + positions — nothing else to stage
            rows = np.zeros(len(sched.seq_ids), np.int32)
        else:
            rows = np.array([eng.seq_cache.lookup(s).cache_row
                             for s in sched.seq_ids], np.int32)
        meta = self.meta_cache.update(sched, rows)
        np.copyto(bufs["tokens"], meta.tokens)
        np.copyto(bufs["positions"], meta.positions)
        np.copyto(bufs["rows"], meta.rows)
        if meta.n_blocks:
            np.copyto(bufs["block_tables"], meta.block_tables)
        if meta.width > 1:
            np.copyto(bufs["pack_tokens"], meta.pack_tokens)
            np.copyto(bufs["pack_positions"], meta.pack_positions)
            np.copyto(bufs["pack_seq"], meta.pack_seq)
            np.copyto(bufs["last_index"], meta.last_index)
            bufs["n_valid"][0] = meta.n_valid
        # SAT: pre-post this stage's incoming receive while the producer is
        # still in its forward — the leading dim (packed bucket or batch
        # size) is known from the scheduling output alone (§5.3)
        if not self.stage.is_first:
            ch = self.engine.stages[self.stage.index - 1].out_channel
            if isinstance(ch, StructureAwareChannel):
                ch.post_recv(meta.width if meta.width > 1
                             else len(sched.seq_ids))

    # -- device executor side -----------------------------------------------
    def apply_copies(self, copies: np.ndarray):
        """Apply queued CoW block copies [K, 2] (src, dst) to this stage's
        physical cache.  Runs on the stage's device thread immediately
        before the iteration that drained them: per-stage FIFO puts it
        after every in-flight write to ``src`` (shared blocks are never
        written, so src content is stable) and before any reader of
        ``dst``.  CoW is rare (fork divergence, growth into a shared
        tail), so the un-jitted gather/scatter is fine here."""
        src = jnp.asarray(copies[:, 0])
        dst = jnp.asarray(copies[:, 1])
        self.cache = jax.tree.map(lambda c: c.at[:, dst].set(c[:, src]),
                                  self.cache)

    def _execute(self, desc: ModelInputDescriptor, bufs: Dict[str, np.ndarray]):
        t0 = time.monotonic()
        stage, eng = self.stage, self.engine
        if eng.paged and desc.sched.block_copies is not None:
            self.apply_copies(desc.sched.block_copies)
        x_in = ((jnp.asarray(bufs["pack_tokens"]) if desc.width > 1
                 else jnp.asarray(bufs["tokens"])) if stage.is_first
                else eng.recv_hidden(stage.index, desc.iteration))
        if eng.paged:
            # paged-native path: the physical block-major cache and the
            # [B, nb] table go straight into the jitted stage — attention
            # reads K/V *through* the table (on TPU, inside the paged
            # span-attention kernels' scalar-prefetched BlockSpecs; no
            # materialized [B, nb * bs] view anywhere) and the returned
            # cache differs in exactly the slots this iteration's tokens
            # dirtied.  The input cache buffer is donated (one owner).
            tables = jnp.asarray(bufs["block_tables"])
            if desc.width > 1:
                out, new_cache = stage.chunk_fn(
                    stage.params, self.cache, x_in,
                    jnp.asarray(bufs["pack_positions"]),
                    jnp.asarray(bufs["pack_seq"]),
                    jnp.asarray(bufs["positions"]),
                    jnp.asarray(bufs["last_index"]),
                    jnp.asarray(bufs["n_valid"])[0],
                    tables)
            else:
                out, new_cache = stage.decode_fn(
                    stage.params, self.cache, x_in,
                    jnp.asarray(bufs["positions"]), tables)
            self.cache = new_cache
        else:
            rows = jnp.asarray(bufs["rows"])
            cache_rows = jax.tree.map(lambda c: c[:, rows], self.cache)
            if desc.width > 1:
                out, new_cache = stage.chunk_fn(
                    stage.params, cache_rows, x_in,
                    jnp.asarray(bufs["pack_positions"]),
                    jnp.asarray(bufs["pack_seq"]),
                    jnp.asarray(bufs["positions"]),
                    jnp.asarray(bufs["last_index"]),
                    jnp.asarray(bufs["n_valid"])[0])
            else:
                out, new_cache = stage.decode_fn(
                    stage.params, cache_rows, x_in,
                    jnp.asarray(bufs["positions"]))
            self.cache = jax.tree.map(lambda c, n: c.at[:, rows].set(n),
                                      self.cache, new_cache)
        out = jax.block_until_ready(out)
        self.metrics.busy.append((t0, time.monotonic()))
        if stage.is_last:
            eng.emit_logits(desc, np.asarray(out, np.float32))
        else:
            eng.send_hidden(stage.index, desc.iteration,
                            np.asarray(out, np.float32))
        return True

    def run_prefill(self, seq_batch: List[Sequence], x_or_tokens, pos0: int,
                    rows: np.ndarray, last_idx: np.ndarray,
                    tables: Optional[np.ndarray] = None):
        """Pipeline prefill pass for newly admitted sequences.  ``tables``
        is the paged layout's [B, nb] block-table snapshot (None under
        contiguous rows)."""
        stage = self.stage
        eng = self.engine
        t0 = time.monotonic()
        out, cache = stage.prefill_fn(stage.params, x_or_tokens, pos0,
                                      jnp.asarray(last_idx))
        if eng.paged:
            bs = eng.cfg.kv_block_size
            pad = eng.kv_manager.pad_block

            def write(c_all, c_new):
                # c_new [n, B, Sp, ...] -> blocks of bs slots scattered via
                # (table[p // bs], p %% bs); slots past a row's table (the
                # ragged pad tail, or zeroed short-window slots) land in
                # the trash block
                n, b, sp = c_new.shape[:3]
                spb = -(-sp // bs)
                if spb * bs > sp:
                    widths = [(0, 0), (0, 0), (0, spb * bs - sp)] + \
                        [(0, 0)] * (c_new.ndim - 3)
                    c_new = jnp.pad(c_new, widths)
                blocks = c_new.reshape(n, b, spb, bs, *c_new.shape[3:])
                st = np.full((b, spb), pad, np.int32)
                k = min(spb, tables.shape[1])
                st[:, :k] = tables[:, :k]
                return c_all.at[:, jnp.asarray(st)].set(blocks)
        else:
            # write the prefilled cache into assigned rows, padding length
            def write(c_all, c_new):
                # c_all [n, rows, S_max, ...]; c_new [n, B, Sp, ...]
                sp = c_new.shape[2]
                return c_all.at[:, rows, :sp].set(c_new)
        self.cache = jax.tree.map(write, self.cache, cache)
        out = jax.block_until_ready(out)
        self.metrics.busy.append((t0, time.monotonic()))
        return np.asarray(out, np.float32)

    def stop(self):
        if isinstance(self.executor, TokenSafeExecutor):
            self.executor.stop()
        self.metrics.prep_s = self.executor.prep_time
        self.metrics.exec_s = self.executor.exec_time


class PPEngineBase:
    """Shared orchestration for both engines."""

    def __init__(self, model: Model, params, cfg: EngineConfig):
        self.model = model
        self.arch: ArchConfig = model.cfg
        if cfg.kv_layout not in ("auto", "contiguous", "paged"):
            raise ValueError(
                f"unknown kv_layout {cfg.kv_layout!r}; choose from "
                "('auto', 'contiguous', 'paged')")
        if cfg.kv_layout == "auto":
            # paged wherever the paged-native path covers the family;
            # rolling caches additionally need whole-block windows
            # (explicit kv_layout='paged' raises on both instead)
            w = self.arch.window or 0
            supported = (self.arch.family in ("dense", "moe")
                         and not (w and w % cfg.kv_block_size))
            cfg = dataclasses.replace(
                cfg, kv_layout="paged" if supported else "contiguous")
        self.cfg = cfg
        self.paged = cfg.kv_layout == "paged"
        self.kv_manager = None
        if self.paged:
            if self.arch.family not in ("dense", "moe"):
                raise NotImplementedError(
                    "kv_layout='paged' requires a self-attention KV cache "
                    "tree ([groups, rows, slots, ...] leaves); family "
                    f"{self.arch.family!r} is not supported yet")
            if cfg.kv_block_size < 1:
                raise ValueError(f"kv_block_size must be >= 1, "
                                 f"got {cfg.kv_block_size}")
            from repro.runtime.paged_kv import BlockSpaceManager

            window = self.arch.window or None
            per_seq_slots = window or cfg.max_seq_len
            n_blocks = cfg.kv_blocks
            if n_blocks is None:
                # equal budget to the contiguous rows: rows x the blocks
                # ONE worst-case sequence needs (ceil per sequence — a
                # floor over the pooled slots would under-provision when
                # the block size does not divide the per-seq slot count)
                n_blocks = (cfg.max_batch * cfg.pp_degree *
                            -(-per_seq_slots // cfg.kv_block_size))
            self.kv_manager = BlockSpaceManager(
                n_blocks, cfg.kv_block_size, slot_cap=window,
                max_slots=cfg.max_seq_len,
                max_table_buckets=cfg.max_table_buckets,
                # rolling caches index slots by pos % window, so a block's
                # content is position-dependent — not shareable
                prefix_cache=cfg.enable_prefix_caching and window is None)
            if n_blocks < self.kv_manager.blocks_for(cfg.max_seq_len):
                raise ValueError(
                    f"kv_blocks={n_blocks} x block_size={cfg.kv_block_size}"
                    " cannot hold even one max_seq_len sequence — "
                    "preemption could never free enough")
        # the id allocator doubles as the scheduler's fork-child id source
        # (SamplingParams.n > 1): child seq ids draw from the same
        # monotonic space as request ids, so they can never collide with
        # a future request's worker-side state
        self._alloc = RequestIdAllocator()
        if cfg.decode_enlarge_factor > 1 and not self.paged:
            # enlargement admits offline members beyond max_batch whose
            # eviction must free KV capacity on demand — only the paged
            # layout's preemption-by-recompute supports that (contiguous
            # SequenceCache rows leak on drop_entry)
            raise ValueError(
                "decode_enlarge_factor > 1 requires the paged KV layout")
        self.scheduler = Scheduler(max_batch=cfg.max_batch, pp_degree=cfg.pp_degree,
                                   max_seq_len=cfg.max_seq_len,
                                   token_budget=cfg.prefill_chunk_tokens,
                                   policy=cfg.scheduling_policy,
                                   hysteresis_tokens=cfg.phase_hysteresis_tokens,
                                   tpot_slo_s=cfg.tpot_slo_s,
                                   kv_manager=self.kv_manager,
                                   decode_enlarge_factor=cfg.decode_enlarge_factor,
                                   seq_id_fn=self._alloc.next)
        if self.scheduler.chunked and self.arch.family not in ("dense", "moe"):
            raise NotImplementedError(
                "span scheduling policies (chunked/disaggregated) require "
                "the dense/moe 'chunk' model mode; "
                f"family {self.arch.family!r} is not supported yet")
        if self.scheduler.chunked and self.arch.window and \
                self.scheduler.token_budget > self.arch.window:
            # rolling caches scatter one slot per span token (slot = pos % W);
            # a chunk wider than the window would write conflicting values
            # into the same slot (and its head would be outside the window
            # anyway), so the clamped per-iteration budget must fit the window
            raise ValueError(
                f"prefill_chunk_tokens budget {self.scheduler.token_budget} "
                f"exceeds the sliding window {self.arch.window}; chunks "
                "must fit the rolling KV cache")
        self.seq_cache = SequenceCache(cfg.max_batch * cfg.pp_degree,
                                       kv=self.kv_manager)
        self.stages = [
            _StageWorker(s, self)
            for s in split_for_pp(model, params, cfg.pp_degree,
                                  paged=self.paged)
        ]
        self.bic_i = LocalRing(max(8, 2 * cfg.pp_degree), "BIC-I")
        self.bic_o = SubSlotRing(cfg.n_samplers, max(8, 2 * cfg.pp_degree))
        self._hidden: Dict[Tuple[int, int], Any] = {}
        self._hcv = threading.Condition()
        self._logits: Dict[int, np.ndarray] = {}
        self.samplers = [
            ColumnWiseSampler(self.arch.vocab_size, cfg.max_batch,
                              pp_degree=cfg.pp_degree,
                              max_len=cfg.max_seq_len, seed=cfg.seed + i)
            if cfg.cpu_sampling else
            NaiveSampler(self.arch.vocab_size, seed=cfg.seed + i)
            for i in range(cfg.n_samplers)
        ]
        self.sample_time = 0.0
        # SiPipe overlapped CPU sampling: the last stage hands logits to
        # this FIFO worker and launches the next iteration immediately;
        # the worker mutates sampler state in submission (= iteration)
        # order, so streams are token-identical to synchronous sampling
        from repro.core.sampler import SamplingWorker
        self.sampling_worker = (SamplingWorker(self._dispatch_sampling)
                                if cfg.overlap_sampling else None)
        # completion times of iterations still (possibly) being awaited;
        # pruned each step once older than every in-flight iteration —
        # the running max survives in _t_last_done (long-run memory bound)
        self.iter_done_t: Dict[int, float] = {}
        self._t_last_done = 0.0
        self.t_start = 0.0
        # -- continuous-serving request layer (docs/serving.md) ------------
        self.requests: Dict[int, Request] = {}        # active only
        self._request_stats: Deque[RequestMetrics] = deque(
            maxlen=cfg.keep_recent_requests)
        self._n_submitted = 0
        self._n_finished = 0
        self._n_aborted = 0
        self._tokens_finished = 0
        # step-driven loop state (run() is a thin wrapper over step())
        self._it = 0
        self._inflight: List[SchedulingOutput] = []
        # aborted-but-in-flight sequences: KV rows / sampler columns are
        # reclaimed only after every referencing iteration has retired
        self._pending_release: set = set()
        self._stopped = False

    # -- inter-stage hidden-state transport ------------------------------------
    def send_hidden(self, from_stage: int, iteration: int, h: np.ndarray):
        ch = self.stages[from_stage].out_channel
        ch.send({"hidden": h})
        with self._hcv:
            self._hidden[(from_stage + 1, iteration)] = ch
            self._hcv.notify_all()

    def recv_hidden(self, stage: int, iteration: int):
        deadline = time.monotonic() + 60
        with self._hcv:
            while (stage, iteration) not in self._hidden:
                if time.monotonic() > deadline:
                    raise TimeoutError(f"hidden for stage {stage} iter {iteration}")
                self._hcv.wait(1.0)
            ch = self._hidden.pop((stage, iteration))
        return jnp.asarray(ch.recv()["hidden"], jnp.bfloat16)

    # -- sampling ----------------------------------------------------------------
    def emit_logits(self, desc: ModelInputDescriptor, logits: np.ndarray):
        """Final stage output; SiPipe ships via BIC-L to the sampler pool.
        With overlapped sampling the hand-off is a queue put — the last
        stage's device thread goes straight to its next microbatch while
        the sampling worker processes this one (intra-stage bubble
        closed); otherwise sampling runs inline on this thread."""
        if self.sampling_worker is not None:
            self.sampling_worker.submit(desc.sched, logits)
        else:
            self._dispatch_sampling(desc.sched, logits)

    def _dispatch_sampling(self, sched: SchedulingOutput, logits: np.ndarray):
        t0 = time.monotonic()
        # drop in-progress prefill columns up front: their samples would be
        # discarded anyway, and vocab-wide sampling is the expensive part
        eligible = sched.sample_indices()
        if len(eligible) != logits.shape[0]:
            logits = logits[eligible]
        if logits.shape[0] == 0:       # nothing to sample this iteration
            self._on_sampled(sched, np.zeros(0, np.int32))
            return
        eligible_ids = [sched.seq_ids[i] for i in eligible]
        # per-request sampling params are an API contract: each column
        # samples with ITS OWN request's params, even in mixed batches
        # (the pre-redesign engine applied seq_ids[0]'s params batch-wide)
        params = [self.scheduler.seqs[sid].params for sid in eligible_ids]
        out = self._pool_sample(sched.iteration, sched.slot, eligible_ids,
                                logits, params)
        self.sample_time += time.monotonic() - t0
        self._on_sampled(sched, out)

    def _pool_sample(self, iteration: int, slot: int, seq_ids: List[int],
                     logits: np.ndarray,
                     params: List[SamplingParams]) -> np.ndarray:
        """Fan a batch's logits out over the sampler pool.

        ``params`` is per-sequence, aligned with ``seq_ids``; each pool
        member receives the param slice of its own columns.  Columns are
        partitioned by ``seq_id % n_samplers`` — a pure function of the
        sequence, not its batch column — so a sequence's incremental
        penalty state (freq/pres/output history) always lives in the same
        sampler instance, surviving batch recomposition and
        chunked-prefill phase changes (the per-sequence carryover in
        ColumnWiseSampler._replica is per instance).
        """
        k = self.cfg.n_samplers
        b = logits.shape[0]

        def run(j):
            cols = np.array([i for i, sid in enumerate(seq_ids)
                             if sid % k == j], np.int64)
            if cols.size:
                ids = self.samplers[j].sample(
                    logits[cols], [params[c] for c in cols], slot=slot,
                    seq_ids=[seq_ids[c] for c in cols])
            else:
                ids = np.zeros(0, np.int32)
            self.bic_o.put(iteration, j, (cols, ids))

        threads = [threading.Thread(target=run, args=(j,)) for j in range(k)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        out = np.zeros(b, np.int32)
        for cols, ids in self.bic_o.get(iteration):
            out[cols] = ids
        return out

    def _on_sampled(self, sched: SchedulingOutput, token_ids: np.ndarray):
        now = time.monotonic()
        # chunked prefill: only sequences whose span reached a sampling
        # point (decode steps + prompt-completing chunks) take a token;
        # ``token_ids`` is already aligned to sample_indices()
        sampled_ids = [sched.seq_ids[i] for i in sched.sample_indices()]
        epochs = ([sched.epochs[i] for i in sched.sample_indices()]
                  if sched.epochs is not None else None)
        finished = self.scheduler.complete(
            sched.iteration, sampled_ids, token_ids, epochs)
        for sid in finished:
            self.seq_cache.release(sid)
        # batch recomposition (finishes, chunk phases) needs no sampler
        # eviction: ColumnWiseSampler carries per-sequence penalty columns
        # across replica rebuilds, keyed by seq id (§5.1 + chunked prefill)
        for sid in sampled_ids:
            if sid not in finished:
                self.seq_cache.advance(sid)
        # publish completion LAST: _await_iteration releases the driver to
        # schedule n+p, which must see this iteration's sequence updates
        self.iter_done_t[sched.iteration] = now

    # -- public API ------------------------------------------------------------
    def add_request(self, prompt_ids: List[int], params: SamplingParams,
                    arrival_t: Optional[float] = None) -> int:
        """Admit a request; returns its monotonic request id.  Callable at
        any point of the serving loop — between ``step()`` calls new
        arrivals join the waiting queue and are scheduled continuously.

        ``arrival_t`` (time.monotonic clock) backdates the request's
        arrival for latency accounting — trace replays pass the nominal
        arrival time so TTFT/queue-delay include time spent waiting
        outside the engine (e.g. behind a long blocking step)."""
        if params.n < 1:
            raise ValueError(f"SamplingParams.n must be >= 1, got {params.n}")
        if params.n > 1 and not self.paged:
            raise ValueError(
                "SamplingParams.n > 1 (parallel sampling) forks the prompt "
                "KV copy-on-write, which requires kv_layout='paged'")
        if params.tier == "offline" and not self.paged:
            # offline sequences are preempted-by-recompute the moment
            # online traffic needs their seats; contiguous SequenceCache
            # rows have no recompute path (drop_entry leaks the row)
            raise ValueError(
                "tier='offline' (hybrid serving, docs/hybrid.md) relies on "
                "preemption-by-recompute, which requires kv_layout='paged'")
        rid = self._alloc.next()
        seq = Sequence(rid, list(prompt_ids), params,
                       arrival_t=arrival_t or 0.0)
        self.scheduler.add_request(seq)      # validates; may raise
        self.requests[rid] = Request(rid, seq)
        self._n_submitted += 1
        return rid

    def abort(self, request_id: int, fork: Optional[int] = None) -> bool:
        """Cancel a request.  QUEUED requests are dropped immediately;
        RUNNING ones stop decoding at once (in-flight iterations discard
        their sampled column) and their KV row + sampler penalty columns
        are reclaimed as soon as the last referencing iteration retires —
        surviving sequences' tokens are never perturbed.  The final
        ABORTED RequestOutput (with any tokens produced so far) is
        delivered by the next ``step()``.  Returns False when the id is
        unknown or already finished.

        With parallel sampling the abort covers the primary AND every
        fork child; ``fork=i`` (1-based completion index) instead aborts
        only that one fork — its refcounted blocks are released (shared
        ones by refcount decrement only) while siblings keep decoding
        undisturbed."""
        req = self.requests.get(request_id)
        if req is None:
            return False
        if fork is not None:
            if fork < 1 or fork > len(req.forks):
                return False
            targets = [req.forks[fork - 1]]
        else:
            targets = list(req.all_seqs)
            # children spawned by the scheduler (first token landed) but
            # not yet adopted by _attach_forks live only in scheduler
            # state — an abort in that window must cover them too, or
            # they keep decoding as orphans holding blocks the request
            # believes it released (tests/test_http.py regression)
            known = {s.seq_id for s in targets}
            for child in self.scheduler.fork_children_of(request_id):
                if child.seq_id not in known:
                    targets.append(child)
        any_aborted = False
        for seq in targets:
            if self.scheduler.abort(seq.seq_id) is None:
                continue      # already finished (or never entered: a
            any_aborted = True  # finished-at-spawn fork child)
            sid = seq.seq_id
            if any(sid in d.seq_ids for d in self._inflight):
                self._pending_release.add(sid)
            else:
                self._release_worker_state(sid)
        self._reap_aborted()
        return any_aborted

    @property
    def has_work(self) -> bool:
        """True while any request is queued, scheduled, in flight, or has
        a final output not yet delivered by ``step()`` (e.g. a request
        aborted straight out of the queue)."""
        return (self.scheduler.has_work or bool(self._inflight)
                or bool(self._pending_release) or bool(self.requests))

    def _drop_sampler_state(self, sid: int):
        for smp in self.samplers:
            drop = getattr(smp, "drop_seq", None)
            if drop is not None:
                drop(sid)

    def _release_worker_state(self, sid: int):
        """Reclaim worker-side resources of a retired sequence: the KV
        cache row and every sampler's penalty columns."""
        self.seq_cache.release(sid)
        self._drop_sampler_state(sid)

    def _reap_preempted(self):
        """Drop the worker-side handles of sequences the scheduler just
        preempted (paged layout).  Their blocks are already back on the
        free list; in-flight iterations still referencing them stage
        all-trash tables and their sampled tokens are discarded.  Sampler
        penalty state is deliberately KEPT — the sequence resumes under
        the same id and its recomputed tokens continue the same stream
        (see docs/memory.md for the penalties caveat)."""
        for sid in self.scheduler.drain_preempted():
            self.seq_cache.drop_entry(sid)

    def _reap_aborted(self):
        """Release aborted sequences no longer referenced by any
        in-flight iteration."""
        if not self._pending_release:
            return
        live: set = set()
        for d in self._inflight:
            live.update(d.seq_ids)
        for sid in [s for s in self._pending_release if s not in live]:
            self._release_worker_state(sid)
            self._pending_release.discard(sid)

    def _admit_and_prefill(self, sched: SchedulingOutput):
        """Prefill newly admitted sequences through all stages."""
        if self.paged and sched.block_copies is not None:
            # CoW copies ride the admitting sched; the monolithic path
            # drained every in-flight iteration before this call, so the
            # inline application cannot race the device threads
            for w in self.stages:
                w.apply_copies(sched.block_copies)
        # fork children skip the prefill pass entirely: their prompt KV
        # already lives in the shared blocks (the lazy seq-cache admission
        # in step() registers their worker-side handles)
        new = [sid for sid in sched.seq_ids
               if self.seq_cache.lookup(sid) is None
               and not self.scheduler.seqs[sid].forked]
        if not new:
            return
        seqs = [self.scheduler.seqs[s] for s in new]
        rows = np.array([self.seq_cache.admit(s.seq_id, len(s.prompt_ids)).cache_row
                         for s in seqs], np.int32)
        # mask_shared: the monolithic prefill recomputes the WHOLE prompt
        # (prefill_fn cannot resume mid-prompt from cache), so a
        # prefix-cache hit's shared blocks — and any fork-shared block —
        # are write-masked to the trash block; the recomputed values are
        # bit-identical to the cached ones, only the write is suppressed
        tables = (self.kv_manager.padded_tables(new, mask_shared=True)
                  if self.paged else None)
        max_len = max(s.length for s in seqs)
        toks = np.zeros((len(seqs), max_len), np.int32)
        for i, s in enumerate(seqs):
            ids = s.prompt_ids + s.output_ids
            toks[i, :len(ids)] = ids  # right-pad (positions mask the tail)
        last_idx = np.array([s.length - 1 for s in seqs], np.int32)
        x = jnp.asarray(toks)
        for w in self.stages:
            x_np = w.run_prefill(seqs, x, 0, rows, last_idx, tables)
            if not w.stage.is_last:
                x = jnp.asarray(x_np, jnp.bfloat16)  # inter-stage hidden
        # last stage output = logits at each sequence's final position;
        # sample through the pool partition so each sequence's penalty
        # state starts in (and stays with) its own sampler instance
        logits = np.asarray(x_np, np.float32)
        ids = self._pool_sample(sched.iteration, sched.slot, new, logits,
                                [s.params for s in seqs])
        # same-thread with the admitting schedule call: epochs are current
        finished = self.scheduler.complete(
            sched.iteration, new, ids,
            [s.preemptions for s in seqs] if self.paged else None)
        for sid in finished:
            self.seq_cache.release(sid)
        for sid in new:
            if sid not in finished:
                self.seq_cache.advance(sid)

    def step(self) -> List[RequestOutput]:
        """One scheduler iteration: gate, schedule, submit, retire.

        Re-entrant core of the serving loop — callers interleave
        ``add_request``/``abort`` with ``step()`` and receive the
        incremental :class:`RequestOutput` stream of every request that
        progressed (new tokens, finishes, aborts).  The iteration logic
        is policy-agnostic thanks to the span interface: monolithic
        admission (``is_prefill``) drains in-flight iterations and runs
        the pipeline-blocking prefill; span policies admit KV rows lazily
        on a sequence's first chunk.  Disaggregated phase boundaries need
        no special casing: prefill phases emit chunk-only spans at the
        full token budget, decode phases emit pure 1-token spans
        (``max_span == 1``) that take the flat ``decode_fn`` path and
        TSEM's incremental n/n+p metadata fast path; a slot with no
        schedulable work in the current phase yields ``sched is None``
        and simply idles.
        """
        if self._stopped:
            raise RuntimeError("engine is shut down; build a new one")
        if self.t_start == 0.0:
            self.t_start = time.monotonic()
        it = self._it
        inflight = self._inflight
        # opportunistically retire chunk-only iterations that already
        # completed: they carry no sampling to gate on, and an abort can
        # orphan them (a mid-prefill sequence that will never reach its
        # sampling chunk) — without this they'd pin the in-flight list
        # (and their members' KV rows) until full drain
        for d in [d for d in inflight
                  if not d.sample_indices() and d.iteration in self.iter_done_t]:
            inflight.remove(d)
        # autoregressive gate: this slot's prior SAMPLING iterations
        # must land before building its next batch (their tokens and
        # finishes feed the spans); chunk-only iterations (empty
        # sample set — the body of a disaggregated prefill phase)
        # don't gate, so phase chunks stream through the pipeline
        # back-to-back like training microbatches
        for d in [d for d in inflight
                  if d.slot == it % self.cfg.pp_degree
                  and d.sample_indices()]:
            self._await_iteration(d)
            inflight.remove(d)
        sched = self.scheduler.schedule(it)
        self._reap_preempted()
        if sched is not None:
            while sched is not None and sched.is_prefill:
                # monolithic path (chunking off): drain in-flight
                # iterations first — run_prefill writes stage caches on
                # this thread and must not race the device threads' cache
                # read-modify-writes.  Loop: the rebuild may admit again
                # (capacity freed by finishes during the prefill).
                while inflight:
                    self._await_iteration(inflight.pop(0))
                self._admit_and_prefill(sched)
                sched = self.scheduler.schedule(it)  # rebuilt after prefill
                self._reap_preempted()
            if sched is not None:
                # span policies admit KV rows lazily, on first chunk.  An
                # admission may need the row of a just-aborted sequence
                # whose release is still deferred behind in-flight
                # iterations — retire those first (oldest-first) until the
                # reap frees a row; the KV pool has exactly max_batch * p
                # rows, so scheduler admission implies one will free
                self._reap_aborted()
                for sid in sched.seq_ids:
                    if self.seq_cache.lookup(sid) is None:
                        while (self.seq_cache.free_rows == 0
                                and self._pending_release and inflight):
                            self._await_iteration(inflight.pop(0))
                            self._reap_aborted()
                        self.seq_cache.admit(
                            sid, self.scheduler.seqs[sid].prompt_len)
                self.bic_i.put(sched)
                self._submit(sched)
                inflight.append(sched)
        # retire in order once the pipeline depth is reached; a
        # chunk-only head (no sampled columns) streams instead of
        # gating, bounded at 4p so the executor queues stay shallow.
        # Streaming holds even when THIS slot yielded no work (a
        # prefill phase routinely idles decode-deferred slots): a
        # chunk-only iteration in flight implies a mid-prefill slot
        # member, so its slot keeps producing output and the loop
        # cannot spin — only sampling heads must gate on completion
        while len(inflight) >= (self.cfg.pp_degree if sched is not None else 1):
            if (inflight[0].spans
                    and not inflight[0].sample_indices()
                    and len(inflight) < 4 * self.cfg.pp_degree):
                break
            done = inflight.pop(0)
            self._await_iteration(done)
        self._reap_aborted()
        # prune completion stamps of fully retired iterations (nothing can
        # await them anymore); keep the running max for metrics' wall time
        if self.iter_done_t:
            floor = min((d.iteration for d in inflight), default=it + 1)
            # snapshot keys first: device threads insert stamps concurrently
            for k in [k for k in list(self.iter_done_t) if k < floor]:
                self._t_last_done = max(self._t_last_done,
                                        self.iter_done_t.pop(k))
        self._it = it + 1
        return self._drain_outputs()

    def _attach_forks(self):
        """Adopt the fork children the scheduler spawned since the last
        step into their parent requests (per-fork output streams)."""
        for child in self.scheduler.drain_spawned_forks():
            req = self.requests.get(child.fork_parent)
            if req is None:
                # parent request already retired — defensive: abort the
                # orphan and reclaim whatever it holds
                if child.status not in (SeqStatus.FINISHED,
                                        SeqStatus.ABORTED):
                    self.scheduler.abort(child.seq_id)
                self._release_worker_state(child.seq_id)
                continue
            req.forks.append(child)
            req.fork_streamed.append(0)

    def _drain_outputs(self) -> List[RequestOutput]:
        """Emit the incremental output of every request that progressed;
        retire requests whose final increment is being delivered."""
        from repro.core.request import ForkOutput

        self._attach_forks()
        outs: List[RequestOutput] = []
        for rid in list(self.requests):
            req = self.requests[rid]
            seq = req.seq
            status = seq.status
            primary_done = status in (SeqStatus.FINISHED, SeqStatus.ABORTED)
            # the request closes when the primary AND every fork are done
            # — and, for n > 1, only once the spawned children have been
            # attached (the spawn happens with the primary's first token;
            # a pre-first-token abort legitimately closes fork-less)
            if primary_done and seq.forks_spawned \
                    and len(req.forks) < seq.params.n - 1:
                closed = False           # spawned, not yet drained
            else:
                closed = primary_done and all(
                    f.status in (SeqStatus.FINISHED, SeqStatus.ABORTED)
                    for f in req.forks)
            if closed and any(s.seq_id in self._pending_release
                              for s in req.all_seqs):
                continue     # aborted but still in flight; emit post-reap
            n = len(seq.output_ids)
            fns = [len(f.output_ids) for f in req.forks]
            progressed = (n > req.streamed
                          or any(fn > st for fn, st
                                 in zip(fns, req.fork_streamed)))
            if not progressed and not closed:
                continue
            # delta-only emission: copy just the new tokens; the
            # cumulative stream is a zero-copy TokenStream view bounded at
            # n (output_ids only ever grows, so the view is a stable
            # snapshot — no O(len) slice per increment)
            new = seq.output_ids[req.streamed:n]
            cum = TokenStream(seq.output_ids, n)
            req.streamed = n
            forks = None
            if req.forks:
                forks = []
                for i, (f, fn) in enumerate(zip(req.forks, fns)):
                    forks.append(ForkOutput(
                        i + 1, f.output_ids[req.fork_streamed[i]:fn],
                        TokenStream(f.output_ids, fn),
                        f.status in (SeqStatus.FINISHED, SeqStatus.ABORTED),
                        f.finish_reason, f))
                    req.fork_streamed[i] = fn
            if not closed:
                outs.append(RequestOutput(
                    rid, new, cum, False, RequestState.of(seq),
                    None, None, seq, forks=forks))
                continue
            rm = RequestMetrics.of(seq)
            outs.append(RequestOutput(
                rid, new, cum, True, rm.state, seq.finish_reason, rm, seq,
                forks=forks))
            self._retire(rid, req, rm)
        return outs

    def _retire(self, rid: int, req: Request, rm: RequestMetrics):
        """Final bookkeeping once a request's last output is delivered."""
        self.requests.pop(rid, None)
        self._request_stats.append(rm)
        for s in req.all_seqs:
            if s.status == SeqStatus.FINISHED:
                self._tokens_finished += len(s.output_ids)
            # finished sequences released their KV in _on_sampled; strip
            # sampler penalty columns too so long-run state stays bounded
            # by the live batch (idempotent with the abort-path release)
            self._drop_sampler_state(s.seq_id)
        if req.seq.status == SeqStatus.FINISHED:
            self._n_finished += 1
        else:
            self._n_aborted += 1

    def generate(self, prompts: List[List[int]],
                 params: Union[SamplingParams, List[SamplingParams]],
                 ) -> Iterator[RequestOutput]:
        """Streaming entry point: admit ``prompts`` (one SamplingParams
        shared, or one per prompt) and yield their RequestOutput
        increments as tokens land, until all of them finish.  Outputs of
        OTHER concurrent requests are not consumed — drive ``step()``
        directly for a multi-consumer serving loop."""
        if isinstance(params, SamplingParams):
            params = [params] * len(prompts)
        if len(params) != len(prompts):
            raise ValueError(
                f"{len(prompts)} prompts but {len(params)} sampling params")
        want = {self.add_request(p, sp)
                for p, sp in zip(prompts, params)}
        while want:
            for out in self.step():
                if out.request_id in want:
                    if out.finished:
                        want.discard(out.request_id)
                    yield out

    def run(self, max_iterations: int = 10_000) -> List[Sequence]:
        """Offline-batch compatibility wrapper: drive ``step()`` until
        every admitted request finishes, then shut the stage workers
        down.  Token-identical to the pre-redesign blocking ``run()``
        under greedy sampling — the step loop is the same loop."""
        self.t_start = time.monotonic()
        done: List[Sequence] = []
        start_it = self._it      # cap counts THIS call's iterations
        while self._it - start_it < max_iterations:
            for out in self.step():
                if out.finished and out.state == RequestState.FINISHED:
                    done.append(out.seq)
            if not self.has_work:
                break
        self.shutdown()
        return done

    def shutdown(self):
        """Stop the stage executors (terminal — engines are not
        restartable; finish or abort outstanding requests first)."""
        if self._stopped:
            return
        self._stopped = True
        for w in self.stages:
            w.stop()
        if self.sampling_worker is not None:
            # the FIFO drains before the sentinel, so every emitted
            # iteration's sampling lands before the worker exits
            self.sampling_worker.stop()

    # engine-specific:
    def _submit(self, sched: SchedulingOutput):
        raise NotImplementedError

    def _await_iteration(self, sched: SchedulingOutput):
        deadline = time.monotonic() + 120
        while sched.iteration not in self.iter_done_t:
            if self.sampling_worker is not None:
                self.sampling_worker.check()   # surface sampler crashes
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"iteration {sched.iteration} never completed")
            time.sleep(0.0005)

    def compile_stats(self) -> Dict[str, int]:
        """Total jit executables across the stage step functions — the
        compile count benchmarks report (each distinct (batch, width,
        table-bucket) shape is one entry; bucket capping bounds it)."""
        total = 0
        for w in self.stages:
            for fn in (w.stage.prefill_fn, w.stage.decode_fn,
                       w.stage.chunk_fn):
                try:
                    total += fn._cache_size()
                except Exception:          # API moved; report what we can
                    pass
        return {"jit_executables": total}

    def load(self) -> Dict[str, int]:
        """Cheap load snapshot for routing decisions (serving/router.py):
        live request count, waiting-queue depth, and KV block occupancy.
        Unlike :meth:`metrics` this allocates nothing proportional to
        history — safe to poll per-request."""
        if self.paged:
            total = self.kv_manager.n_blocks
            free = (self.kv_manager.free_blocks
                    + self.kv_manager.reclaimable_cached_blocks)
        else:
            total = self.seq_cache.max_rows
            free = self.seq_cache.free_rows
        return {
            "active_requests": len(self.requests),
            # online waiting only — the router balances SLO traffic; the
            # offline backlog is reported separately so it never repels
            # online placements from an engine with deep batch work
            "queue_depth": len(self.scheduler.waiting),
            "offline_queue_depth": len(self.scheduler.waiting_offline),
            "kv_blocks_total": total,
            "kv_blocks_free": free,
        }

    # -- metrics ----------------------------------------------------------------
    def metrics(self) -> Dict[str, Any]:
        t_end = max([self._t_last_done, *list(self.iter_done_t.values())]) \
            or self.t_start
        wall = max(t_end - self.t_start, 1e-9)
        toks = self._tokens_finished + sum(
            len(r.seq.output_ids) for r in self.requests.values()
            if r.seq.status == SeqStatus.FINISHED)   # finished, not yet drained
        per_stage = []
        for w in self.stages:
            busy = sum(e - s for s, e in w.metrics.busy)
            per_stage.append({
                "busy_s": busy,
                "prep_s": w.executor.prep_time,
                "exec_s": w.executor.exec_time,
                "bubble_frac": max(0.0, 1.0 - busy / wall),
            })
        stats = list(self._request_stats)
        # latency percentiles are ONLINE-tier only (docs/hybrid.md):
        # offline rows would drag the SLO metrics the admission layer and
        # the adaptive policy steer by; they get their own offline_* keys
        online = [r for r in stats if r.tier != "offline"]
        offline = [r for r in stats if r.tier == "offline"]
        tpots = [r.tpot_s for r in online if r.tpot_s is not None]
        ttfts = [r.ttft_s for r in online if r.ttft_s is not None]
        queues = [r.queue_s for r in online if r.queue_s is not None]
        off_tpots = [r.tpot_s for r in offline if r.tpot_s is not None]
        off_ttfts = [r.ttft_s for r in offline if r.ttft_s is not None]

        def pct(vals, q):
            return float(np.percentile(vals, q)) if vals else 0.0

        out = {
            "wall_s": wall,
            "tokens": toks,
            "throughput_tok_s": toks / wall,
            "tpot_mean_s": float(np.mean(tpots)) if tpots else 0.0,
            "tpot_p50_s": pct(tpots, 50),
            "tpot_p99_s": pct(tpots, 99),
            "ttft_mean_s": float(np.mean(ttfts)) if ttfts else 0.0,
            "ttft_p50_s": pct(ttfts, 50),
            "ttft_p99_s": pct(ttfts, 99),
            "queue_mean_s": float(np.mean(queues)) if queues else 0.0,
            "queue_p99_s": pct(queues, 99),
            # hybrid tier (docs/hybrid.md): offline latency tracked apart
            # from the online SLO percentiles above, plus the slack ledger
            # (bubble seats offered / sold) and offline preemption count
            "offline_tpot_mean_s": float(np.mean(off_tpots)) if off_tpots else 0.0,
            "offline_tpot_p99_s": pct(off_tpots, 99),
            "offline_ttft_mean_s": float(np.mean(off_ttfts)) if off_ttfts else 0.0,
            "offline_ttft_p99_s": pct(off_ttfts, 99),
            "offline_requests_seen": len(offline),
            "slack_seats_seen": self.scheduler.slack.seats_seen,
            "slack_tokens_sold": self.scheduler.slack.tokens_sold,
            "slack_offers": self.scheduler.slack.offers,
            "offline_preemptions": self.scheduler.n_offline_preemptions,
            "requests_submitted": self._n_submitted,
            "requests_finished": self._n_finished,
            "requests_aborted": self._n_aborted,
            "requests_active": len(self.requests),
            "queue_depth": len(self.scheduler.waiting),
            "offline_queue_depth": len(self.scheduler.waiting_offline),
            # per-request latency records over the retained window
            "requests": {r.request_id: r.as_dict() for r in stats},
            "sample_s": self.sample_time,
            "stages": per_stage,
            "incremental_hits": sum(w.meta_cache.incremental_hits for w in self.stages),
            "meta_rebuilds": sum(w.meta_cache.rebuilds for w in self.stages),
            "policy": self.scheduler.policy.name,
            "kv_layout": self.cfg.kv_layout,
        }
        if self.paged:
            out["kv_block_size"] = self.cfg.kv_block_size
            out["kv_blocks_total"] = self.kv_manager.n_blocks
            # "free" counts reclaimable capacity: the free list PLUS
            # cached prefix blocks held only by their pin (admission and
            # growth evict those on demand) — so an idle engine with a
            # warm prefix cache still reports blocks_free == blocks_total
            cached = self.kv_manager.reclaimable_cached_blocks
            out["kv_blocks_free"] = self.kv_manager.free_blocks + cached
            out["kv_blocks_cached"] = cached
            out["kv_preemptions"] = self.scheduler.n_preemptions
            out["kv_fork_children"] = self.scheduler.n_forks
            out["kv_fork_demotions"] = self.scheduler.n_fork_demotions
            out["kv_table_widths"] = self.kv_manager.table_widths
            for k, v in self.kv_manager.prefix_stats().items():
                out[f"kv_{k}"] = v
        out.update(self.compile_stats())
        for k, v in self.scheduler.policy.metrics().items():
            out[f"policy_{k}"] = v
        return out


class SiPipeEngine(PPEngineBase):
    """TSEM executors run stages asynchronously; sampling on CPU pool."""

    def _submit(self, sched: SchedulingOutput):
        for w in self.stages:
            if isinstance(w.executor, TokenSafeExecutor):
                w.executor.submit(sched)
            else:
                threading.Thread(target=w.executor.run, args=(sched,),
                                 daemon=True).start()


class NaivePPEngine(PPEngineBase):
    """Synchronous baseline: stages run in order on the caller thread; the
    final stage performs sampling *inside* its critical path (overlapped
    sampling is forced off — it's the SiPipe technique being ablated)."""

    def __init__(self, model: Model, params, cfg: EngineConfig):
        cfg = dataclasses.replace(cfg, tsem=False, sat=False,
                                  cpu_sampling=False,
                                  overlap_sampling=False)
        super().__init__(model, params, cfg)

    def _submit(self, sched: SchedulingOutput):
        for w in self.stages:
            w.executor.run(sched)
