"""Buffered IPC Channels (SiPipe §6).

Three channel kinds, mirroring the paper:
  BIC-I  scheduling outputs, scheduler -> workers/samplers (dispatch)
  BIC-L  logits, final stage -> sampler pool (dispatch)
  BIC-O  sampling outputs, samplers -> scheduler (combine, sub-slots)

The shared-memory implementation (``ShmRing``) uses an N-slot ring with a
*lock-ahead* protocol: in iteration n the producer pre-acquires slot
(n+1) %% N, writes slot n %% N, then releases it — consumers poll slots in
order under shared locks, so steady-state progress never contends.  A
lighter ``LocalRing`` (threading) backs the in-process engine; both expose
the same interface so the engine is transport-agnostic.
"""
from __future__ import annotations

import mmap
import os
import pickle
import struct
import tempfile
import threading
import time
from typing import Any, List, Optional

import numpy as np

_HDR = struct.Struct("<QQ")  # (seq, payload_len)


class LocalRing:
    """In-process N-slot ring with per-slot condition variables."""

    def __init__(self, n_slots: int = 8, name: str = ""):
        self.n = n_slots
        self.name = name
        self._slots: List[Optional[Any]] = [None] * n_slots
        self._seq = [-1] * n_slots
        self._cv = threading.Condition()
        self._head = 0  # next sequence number to write

    def put(self, item: Any, timeout: float = 30.0) -> int:
        with self._cv:
            seq = self._head
            slot = seq % self.n
            # lock-ahead analogue: ensure the *next* slot's consumer lag is
            # bounded by N (writer never laps readers by a full ring)
            self._slots[slot] = item
            self._seq[slot] = seq
            self._head += 1
            self._cv.notify_all()
            return seq

    def get(self, seq: int, timeout: float = 30.0) -> Any:
        deadline = time.monotonic() + timeout
        slot = seq % self.n
        with self._cv:
            while self._seq[slot] < seq:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(f"BIC {self.name}: seq {seq} not produced")
                self._cv.wait(remaining)
            if self._seq[slot] != seq:
                raise RuntimeError(
                    f"BIC {self.name}: slot overwritten (seq {seq} -> {self._seq[slot]}); "
                    f"ring too small for consumer lag")
            return self._slots[slot]


class ShmRing:
    """Cross-process shared-memory ring (file-backed mmap + fcntl locks).

    Slot layout: [lock byte area | header (seq, len) | payload bytes].
    The producer lock-ahead acquires slot n+1 before publishing slot n.
    """

    def __init__(self, slot_bytes: int, n_slots: int = 8, path: str = "",
                 create: bool = True):
        self.n = n_slots
        self.slot_bytes = slot_bytes
        self.stride = _HDR.size + slot_bytes
        self.path = path or tempfile.mktemp(prefix="sipipe_bic_")
        total = self.stride * n_slots
        flags = os.O_RDWR | (os.O_CREAT if create else 0)
        self._fd = os.open(self.path, flags, 0o600)
        if create:
            os.ftruncate(self._fd, total)
            # initialize headers to seq = -1
            with mmap.mmap(self._fd, total) as mm:
                for s in range(n_slots):
                    mm[s * self.stride : s * self.stride + _HDR.size] = _HDR.pack(
                        2**64 - 1, 0)
        self._mm = mmap.mmap(self._fd, total)
        self._head = 0

    # -- fcntl slot locks ---------------------------------------------------
    def _lock(self, slot: int, exclusive: bool):
        import fcntl

        fcntl.lockf(self._fd, fcntl.LOCK_EX if exclusive else fcntl.LOCK_SH,
                    1, slot, os.SEEK_SET)

    def _unlock(self, slot: int):
        import fcntl

        fcntl.lockf(self._fd, fcntl.LOCK_UN, 1, slot, os.SEEK_SET)

    def put(self, item: Any, seq: Optional[int] = None) -> int:
        if seq is None:
            seq = self._head
        payload = item if isinstance(item, (bytes, bytearray)) else pickle.dumps(
            item, protocol=pickle.HIGHEST_PROTOCOL)
        assert len(payload) <= self.slot_bytes, (len(payload), self.slot_bytes)
        slot = seq % self.n
        nxt = (seq + 1) % self.n
        self._lock(nxt, exclusive=True)      # lock-ahead
        try:
            self._lock(slot, exclusive=True)
            try:
                off = slot * self.stride
                self._mm[off + _HDR.size : off + _HDR.size + len(payload)] = payload
                self._mm[off : off + _HDR.size] = _HDR.pack(seq, len(payload))
            finally:
                self._unlock(slot)
        finally:
            self._unlock(nxt)
        self._head = seq + 1
        return seq

    def get(self, seq: int, timeout: float = 30.0, raw: bool = False) -> Any:
        slot = seq % self.n
        off = slot * self.stride
        deadline = time.monotonic() + timeout
        while True:
            self._lock(slot, exclusive=False)
            try:
                got_seq, ln = _HDR.unpack(self._mm[off : off + _HDR.size])
                if got_seq == seq:
                    data = bytes(self._mm[off + _HDR.size : off + _HDR.size + ln])
                    return data if raw else pickle.loads(data)
                if got_seq != 2**64 - 1 and got_seq > seq:
                    raise RuntimeError(f"slot overwritten: want {seq} have {got_seq}")
            finally:
                self._unlock(slot)
            if time.monotonic() > deadline:
                raise TimeoutError(f"seq {seq} not available")
            time.sleep(0.0002)

    def close(self, unlink: bool = False):
        self._mm.close()
        os.close(self._fd)
        if unlink:
            try:
                os.unlink(self.path)
            except OSError:
                pass


class SubSlotRing:
    """BIC-O: multi-producer combine ring.  Slot n has one sub-slot per
    sampler; the consumer sees iteration n complete when all sub-slots are
    filled (each sub-slot is typically just token ids)."""

    def __init__(self, n_producers: int, n_slots: int = 8):
        self.k = n_producers
        self.n = n_slots
        self._cv = threading.Condition()
        self._data: List[List[Optional[Any]]] = [
            [None] * n_producers for _ in range(n_slots)]
        self._seq = [[-1] * n_producers for _ in range(n_slots)]

    def put(self, seq: int, producer: int, item: Any):
        slot = seq % self.n
        with self._cv:
            self._data[slot][producer] = item
            self._seq[slot][producer] = seq
            self._cv.notify_all()

    def get(self, seq: int, timeout: float = 30.0) -> List[Any]:
        slot = seq % self.n
        deadline = time.monotonic() + timeout
        with self._cv:
            while any(s < seq for s in self._seq[slot]):
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(f"combine seq {seq} incomplete")
                self._cv.wait(remaining)
            if any(s != seq for s in self._seq[slot]):
                raise RuntimeError("combine slot overwritten")
            return list(self._data[slot])
