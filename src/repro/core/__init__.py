"""SiPipe core: the paper's contribution as composable modules.

sampler    — column-wise incremental CPU sampling (§5.1)
tsem       — token-safe execution model: decoupled CPU/device FSMs (§5.2)
sat        — structure-aware stage transmission (§5.3)
bic        — buffered IPC channels (§6)
scheduler  — continuous batching, p in-flight microbatches (§4.2)
engine     — SiPipeEngine / NaivePPEngine end-to-end serving (§4)
pipeline   — shard_map pipeline-parallel step builders (dry-run regime)
"""
from repro.core.sampling_params import SamplingParams  # noqa: F401
from repro.core.sampler import ColumnWiseSampler, NaiveSampler  # noqa: F401
from repro.core.scheduler import Scheduler, SchedulingOutput  # noqa: F401
from repro.core.sequence import Sequence, SequenceCache  # noqa: F401
