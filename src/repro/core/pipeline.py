"""Pipeline-parallel step builders (shard_map, manual over the "pipe" axis).

This is the paper's deployment regime expressed as a single SPMD program
for the multi-pod dry-run: p pipeline stages x t-way tensor parallelism
x data parallelism, on a ("pipe", "data", "model") view of the production
device set (mesh.make_pipeline_mesh).

Decode runs as a *steady-state round*: one jitted call advances all p
in-flight microbatches by one full iteration.  Each of the p ticks inside
the round, stage s processes microbatch (t - s) mod p and ppermutes its
activation to stage s+1 — all stages stay busy every tick, which is the
zero-bubble steady state SiPipe's host-side machinery sustains (the
engine-level techniques keep the gaps BETWEEN these device steps empty;
this module is the device-side program those steps execute).

Embedding and LM head run OUTSIDE the manual region under plain GSPMD
(vocab-sharded over "model"), so their FLOPs are not replicated p times.

The stage body itself stays under GSPMD "auto" for the data/model axes —
TP sharding inside a stage is inherited from the operand shardings, which
is exactly the hybrid PP+TP deployment (p stages x t-way TP) of the paper.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.registry import Model
from repro.models.stacked import run_stack

PyTree = Any


@dataclasses.dataclass
class PPPlan:
    p: int                         # pipeline degree
    microbatch: int                # sequences per microbatch
    mesh: Mesh                     # ("pipe", "data", "model")
    groups_per_stage: int


def plan_pp(model: Model, mesh: Mesh, global_batch: int) -> PPPlan:
    p = dict(zip(mesh.axis_names, mesh.devices.shape))["pipe"]
    st = model.stacks["blocks"]
    assert st.n % p == 0, f"{st.n} scan groups not divisible by pipe={p}"
    assert global_batch % p == 0, (global_batch, p)
    return PPPlan(p, global_batch // p, mesh, st.n // p)


def _restack(params_blocks: PyTree, p: int, gps: int) -> PyTree:
    """[n_groups, ...] -> [p, groups_per_stage, ...] for pipe sharding."""
    return jax.tree.map(lambda x: x.reshape((p, gps) + x.shape[1:]), params_blocks)


def restack_abstract(model: Model, plan: PPPlan):
    import repro.models.common as mc

    abs_p = mc.abstract_params(model.specs)
    blocks = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((plan.p, plan.groups_per_stage) + s.shape[1:],
                                       s.dtype),
        abs_p["stacks"]["blocks"])
    return {**abs_p, "stacks": {"blocks": blocks}}


def pp_decode_round(model: Model, plan: PPPlan) -> Callable:
    """Returns step(params, caches, inflight, tokens, positions) ->
    (logits [p, B_m, V], caches, inflight).

    params["stacks"]["blocks"] must be re-stacked [p, gps, ...].
    caches: model cache trees with leading [p_stage, p_micro, ...].
    inflight: [p, B_m, d] cross-round activations (zeros initially; the
    first p rounds are warmup).
    tokens/positions: [p, B_m] per microbatch.
    """
    p = plan.p
    st = model.stacks["blocks"]
    sub = dataclasses.replace(st, n=plan.groups_per_stage)
    d = model.cfg.d_model
    perm = [(i, (i + 1) % p) for i in range(p)]
    # jax<0.6 has no partial-auto shard_map; run the region fully manual
    # and neutralize in-region sharding constraints (they only *guide*
    # GSPMD placement — the math is identical without them)
    legacy_manual = not hasattr(jax, "shard_map")

    def stage_body(stage_l, blocks_l, caches_l, inflight_l, embeds, positions):
        # stage_l [1]; blocks_l [1, gps, ...]; caches_l [1, p, gps, ...];
        # inflight_l [1, B_m, d].  The stage index arrives as a pipe-sharded
        # operand rather than lax.axis_index: partition-id does not lower
        # under partial-auto SPMD on older XLA versions.
        s = stage_l[0]
        blocks_l = jax.tree.map(lambda x: x[0], blocks_l)
        caches_l = jax.tree.map(lambda x: x[0], caches_l)
        x0 = inflight_l[0]

        def tick(carry, t):
            x, caches = carry
            m = (t - s) % p
            x_in = jnp.where(s == 0, embeds[m].astype(x.dtype), x)
            cache_m = jax.tree.map(lambda c: c[m], caches)
            ctx = model.make_ctx("decode", positions[m])
            if legacy_manual:
                from repro.models.common import ShardCtx

                ctx = dataclasses.replace(ctx, shard=ShardCtx.single())
            x_out, cache_m = run_stack(sub, blocks_l, x_in, ctx,
                                       cache_stacked=cache_m, remat=False)
            caches = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(c, n, m, 0),
                caches, cache_m)
            emit = jnp.where(s == p - 1, x_out, jnp.zeros_like(x_out))
            x_next = jax.lax.ppermute(x_out, "pipe", perm)
            return (x_next, caches), emit

        (x_fin, caches_l), emits = jax.lax.scan(tick, (x0, caches_l),
                                                jnp.arange(p))
        pack = lambda t: jax.tree.map(lambda a: a[None], t)
        return pack(caches_l), x_fin[None], emits[None]

    specs = dict(
        in_specs=(P("pipe"), P("pipe"), P("pipe"), P("pipe"), P(None), P(None)),
        out_specs=(P("pipe"), P("pipe"), P("pipe")),
    )
    if hasattr(jax, "shard_map"):
        smapped = jax.shard_map(stage_body, mesh=plan.mesh,
                                axis_names={"pipe"}, check_vma=False, **specs)
    else:  # jax<0.6 compat: experimental namespace, fully-manual region
        from jax.experimental.shard_map import shard_map as _shard_map

        smapped = _shard_map(stage_body, mesh=plan.mesh, check_rep=False,
                             **specs)

    def step(params, caches, inflight, tokens, positions):
        # embed all p microbatches under plain GSPMD (vocab-sharded gather)
        embeds = model.embed_tokens(params, tokens)          # [p, B_m, d]
        caches, inflight, emits = smapped(
            jnp.arange(p, dtype=jnp.int32),
            params["stacks"]["blocks"], caches, inflight, embeds, positions)
        # emits[p_stage, tick, B_m, d]: only the last stage's row is live.
        hidden = emits[-1]                                   # [ticks, B_m, d]
        # tick t emitted microbatch (t - (p-1)) mod p -> reorder to m-order
        order = jnp.array([(m + p - 1) % p for m in range(p)])
        hidden = jnp.take(hidden, order, axis=0)
        logits = model.lm_head(params, hidden)               # [p, B_m, V]
        return logits, caches, inflight

    return step


def pp_shardings(model: Model, plan: PPPlan, batch_shape: Tuple[int, int]):
    """NamedShardings for (params, caches, inflight, tokens, positions)."""
    from repro import sharding as shlib
    import repro.models.common as mc

    mesh = plan.mesh
    abs_p = restack_abstract(model, plan)
    ax_p = mc.logical_axes(model.specs)
    ax_blocks = jax.tree.map(
        lambda ax: ("stage",) + ax,
        ax_p["stacks"]["blocks"],
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x),
    )
    ax_p = {**ax_p, "stacks": {"blocks": ax_blocks}}
    p_sh = shlib.tree_shardings(ax_p, abs_p, "pp", mesh)

    def cache_sh(abs_cache, ax_cache):
        # per-tensor axes ("layers", *t) -> ("stage", micro, gps, *t)
        ax = jax.tree.map(
            lambda a: ("stage", None, None) + a[1:],
            ax_cache,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(aa, (str, type(None))) for aa in x),
        )
        return shlib.tree_shardings(ax, abs_cache, "pp", mesh)

    ns = lambda *spec: NamedSharding(mesh, P(*spec))
    v = model.cfg.vocab_size
    logits_sh = shlib.named_sharding((None, "batch", "vocab"),
                                     (plan.p, plan.microbatch, v), "pp", mesh)
    return {
        "params": p_sh,
        "params_abstract": abs_p,
        "cache_sharding_fn": cache_sh,
        "inflight": ns("pipe", "data"),
        "tokens": ns(None, "data"),
        "positions": ns(None, "data"),
        "logits": logits_sh,
    }


def pp_abstract_cache(model: Model, plan: PPPlan, cache_len: int):
    """Cache tree with leading [p_stage, p_micro, gps, B_m, ...]."""
    base = model.abstract_cache(plan.microbatch, cache_len)["blocks"]

    def expand(sd):
        gps = plan.groups_per_stage
        # base leading dim is n_groups = p * gps -> [p, micro(p), gps, ...]
        return jax.ShapeDtypeStruct((plan.p, plan.p, gps) + sd.shape[1:], sd.dtype)

    return jax.tree.map(expand, base)
