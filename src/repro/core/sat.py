"""Structure-aware transmission (SiPipe §5.3).

Hidden-state dictionaries crossing pipeline stages have a stable structure
(same keys, dtypes, trailing dims); only the leading batch dim varies.
SAT captures that structure on the first iteration, after which the
receiver pre-allocates buffers and posts asynchronous receives *before*
the producer finishes its forward — eliminating metadata rounds and
communication stalls.

Two transports implement a common interface so benchmarks can compare:

  StructureUnawareChannel — the baseline 5-round protocol from Fig. 7(a):
      (1) recv metadata-size, (2) recv metadata blob, (3..) recv each
      tensor after allocating from deserialized metadata.
  StructureAwareChannel   — Fig. 7(b): first iteration uses the unaware
      path + captures structure; steady state is a single async payload
      copy into a pre-posted buffer keyed by (iteration, batch size).

The in-process transport models each communication round as a queue
hand-off (+ optional simulated per-round latency for the benchmark
harness, mirroring the paper's 1.4–2.6 ms metadata overhead on RDMA).
"""
from __future__ import annotations

import dataclasses
import pickle
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TensorMeta:
    key: str
    shape: Tuple[int, ...]
    dtype: str

    def nbytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n * np.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class StructureSignature:
    """The invariant part: keys, dtypes, trailing dims (batch dim varies)."""

    keys: Tuple[str, ...]
    dtypes: Tuple[str, ...]
    trailing: Tuple[Tuple[int, ...], ...]

    @staticmethod
    def of(tensors: Dict[str, np.ndarray]) -> "StructureSignature":
        keys = tuple(sorted(tensors))
        return StructureSignature(
            keys=keys,
            dtypes=tuple(str(tensors[k].dtype) for k in keys),
            trailing=tuple(tuple(tensors[k].shape[1:]) for k in keys),
        )


class _Wire:
    """One directional in-process 'link'; each put/get pair is a round."""

    def __init__(self, round_latency_s: float = 0.0):
        self.q: "queue.Queue[bytes]" = queue.Queue()
        self.round_latency_s = round_latency_s
        self.rounds = 0
        self.bytes_moved = 0

    def send(self, payload: bytes):
        self.rounds += 1
        self.bytes_moved += len(payload)
        if self.round_latency_s:
            time.sleep(self.round_latency_s)
        self.q.put(payload)

    def recv(self, timeout: float = 30.0) -> bytes:
        return self.q.get(timeout=timeout)


class StructureUnawareChannel:
    """Baseline: metadata size -> metadata blob -> per-tensor payloads."""

    def __init__(self, round_latency_s: float = 0.0):
        self.wire = _Wire(round_latency_s)

    def send(self, tensors: Dict[str, np.ndarray]):
        metas = [TensorMeta(k, tuple(v.shape), str(v.dtype))
                 for k, v in sorted(tensors.items())]
        blob = pickle.dumps(metas)
        self.wire.send(len(blob).to_bytes(8, "little"))       # round 1
        self.wire.send(blob)                                  # round 2
        for m in metas:                                       # rounds 3..
            self.wire.send(np.ascontiguousarray(tensors[m.key]).tobytes())

    def recv(self, timeout: float = 30.0) -> Dict[str, np.ndarray]:
        self.wire.recv(timeout)                               # size header
        return self._recv_body(timeout)

    def _recv_body(self, timeout: float) -> Dict[str, np.ndarray]:
        """Rounds after the size header: metadata blob + per-tensor
        payloads (shared with StructureAwareChannel's capture path)."""
        metas: List[TensorMeta] = pickle.loads(self.wire.recv(timeout))
        out = {}
        for m in metas:
            buf = bytearray(m.nbytes())                       # late allocation
            payload = self.wire.recv(timeout)
            buf[:] = payload
            out[m.key] = np.frombuffer(bytes(buf), m.dtype).reshape(m.shape)
        return out


class StructureAwareChannel:
    """SAT: capture structure once; steady-state sends one fused payload
    into a receiver-preallocated buffer (the async-irecv analogue).

    Capture (fallback-protocol) rounds and steady payloads share ONE wire:
    a producer may run a full iteration ahead of the consumer, so putting
    them on separate queues would let a recapture (e.g. a chunked-prefill
    span-width change) be consumed out of order.  The receiver tells them
    apart by length — the fallback's first round is exactly the 8-byte
    metadata-size header, while steady payloads are 8 + fused bytes."""

    def __init__(self, round_latency_s: float = 0.0):
        self.wire = _Wire(round_latency_s)
        self._sig: Optional[StructureSignature] = None
        self._fallback = StructureUnawareChannel(round_latency_s)
        self._fallback.wire = self.wire     # single FIFO for both protocols
        self._prealloc: Dict[Tuple[int, ...], List[np.ndarray]] = {}
        self.captures = 0

    # -- sender --------------------------------------------------------------
    def send(self, tensors: Dict[str, np.ndarray]):
        sig = StructureSignature.of(tensors)
        if self._sig != sig:
            # first iteration (or structure change): full protocol
            self._fallback.send(tensors)
            self._sig = sig
            self.captures += 1
            return
        batch = next(iter(tensors.values())).shape[0]
        fused = b"".join(
            np.ascontiguousarray(tensors[k]).tobytes() for k in sig.keys)
        self.wire.send(batch.to_bytes(8, "little") + fused)   # single round

    # -- receiver --------------------------------------------------------------
    def post_recv(self, batch: int):
        """Pre-allocate target buffers from the captured structure + the
        payload's leading dim, the only dynamic factor: the batch size for
        decode hiddens [B, d], the packed bucket width for chunk hiddens
        [T, d].  Buffers are kept per leading-dim key, so revisiting a
        (batch, bucket) allocates nothing and span-width changes never
        cost a recapture round — the engine's stage workers call this
        during input preparation, before the producer finishes its
        forward (the async-irecv analogue)."""
        if self._sig is None:
            return
        key = (batch,)
        if key not in self._prealloc:
            self._prealloc[key] = [
                np.empty((batch,) + t, d)
                for t, d in zip(self._sig.trailing, self._sig.dtypes)
            ]

    def recv(self, timeout: float = 30.0) -> Dict[str, np.ndarray]:
        payload = self.wire.recv(timeout)
        if len(payload) == 8:  # metadata-size header: a capture iteration
            out = self._fallback._recv_body(timeout)
            self._sig = StructureSignature.of(out)
            self._prealloc.clear()   # trailing dims changed: buffers stale
            return out
        batch = int.from_bytes(payload[:8], "little")
        self.post_recv(batch)
        bufs = self._prealloc[(batch,)]
        out = {}
        off = 8
        for k, buf in zip(self._sig.keys, bufs):
            n = buf.nbytes
            flat = np.frombuffer(payload[off : off + n], buf.dtype)
            buf[...] = flat.reshape(buf.shape)
            out[k] = buf
            off += n
        return out
