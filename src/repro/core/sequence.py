"""Sequence state + the worker-side SequenceCache (TSEM §5.2)."""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.sampling_params import SamplingParams


class SeqStatus(enum.Enum):
    WAITING = 0
    RUNNING = 1
    FINISHED = 2
    PREEMPTED = 3
    ABORTED = 4


@dataclasses.dataclass
class Sequence:
    seq_id: int
    prompt_ids: List[int]
    params: SamplingParams
    output_ids: List[int] = dataclasses.field(default_factory=list)
    status: SeqStatus = SeqStatus.WAITING
    arrival_t: float = 0.0
    first_sched_t: Optional[float] = None   # WAITING -> RUNNING transition
    first_token_t: Optional[float] = None
    last_token_t: Optional[float] = None    # feeds live TPOT (adaptive policy)
    finish_t: Optional[float] = None
    finish_reason: Optional[str] = None     # "stop" | "length" | "abort"
    # chunked-prefill progress: prompt tokens whose KV is (or is being)
    # written into the cache.  Advanced by the scheduler at chunk-issue
    # time; the monolithic path sets it to the full prompt on admission.
    prefilled: int = 0

    @property
    def length(self) -> int:
        return len(self.prompt_ids) + len(self.output_ids)

    @property
    def prompt_len(self) -> int:
        return len(self.prompt_ids)

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= len(self.prompt_ids)

    @property
    def last_token(self) -> int:
        return self.output_ids[-1] if self.output_ids else self.prompt_ids[-1]

    def mark_running(self, now: Optional[float] = None):
        """WAITING -> RUNNING (admission); records the queue-exit time the
        per-request queue-delay metric is computed from."""
        self.status = SeqStatus.RUNNING
        if self.first_sched_t is None:
            self.first_sched_t = time.monotonic() if now is None else now

    def append(self, token_id: int, now: float) -> bool:
        """Returns True when the sequence finishes."""
        self.output_ids.append(int(token_id))
        if self.first_token_t is None:
            self.first_token_t = now
        self.last_token_t = now
        if len(self.output_ids) >= self.params.max_new_tokens:
            done, reason = True, "length"
        elif (self.params.eos_token_id >= 0
                and token_id == self.params.eos_token_id):
            done, reason = True, "stop"
        else:
            done = False
        if done:
            self.status = SeqStatus.FINISHED
            self.finish_t = now
            self.finish_reason = self.finish_reason or reason
        return done


@dataclasses.dataclass
class CachedSeqState:
    """Worker-local cached metadata for a sequence (avoids re-shipping
    prompt/output ids every iteration — the paper's SequenceCache)."""

    seq_id: int
    prompt_len: int
    out_len: int
    cache_row: int            # row in the device KV cache this seq occupies


class SequenceCache:
    """Maps seq_id -> cached state; assigns/releases KV-cache rows."""

    def __init__(self, max_rows: int):
        self.max_rows = max_rows
        self._by_id: Dict[int, CachedSeqState] = {}
        self._free_rows = list(range(max_rows - 1, -1, -1))

    def lookup(self, seq_id: int) -> Optional[CachedSeqState]:
        return self._by_id.get(seq_id)

    def admit(self, seq_id: int, prompt_len: int) -> CachedSeqState:
        st = self._by_id.get(seq_id)
        if st is None:
            if not self._free_rows:
                raise RuntimeError("KV cache rows exhausted")
            st = CachedSeqState(seq_id, prompt_len, 0, self._free_rows.pop())
            self._by_id[seq_id] = st
        return st

    def release(self, seq_id: int):
        st = self._by_id.pop(seq_id, None)
        if st is not None:
            self._free_rows.append(st.cache_row)

    def advance(self, seq_id: int):
        self._by_id[seq_id].out_len += 1

    @property
    def free_rows(self) -> int:
        return len(self._free_rows)
