"""Sequence state + the worker-side SequenceCache (TSEM §5.2)."""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.sampling_params import SamplingParams


class SeqStatus(enum.Enum):
    WAITING = 0
    RUNNING = 1
    FINISHED = 2
    PREEMPTED = 3
    ABORTED = 4


@dataclasses.dataclass
class Sequence:
    seq_id: int
    prompt_ids: List[int]
    params: SamplingParams
    output_ids: List[int] = dataclasses.field(default_factory=list)
    status: SeqStatus = SeqStatus.WAITING
    arrival_t: float = 0.0
    first_sched_t: Optional[float] = None   # WAITING -> RUNNING transition
    first_token_t: Optional[float] = None
    last_token_t: Optional[float] = None    # feeds live TPOT (adaptive policy)
    finish_t: Optional[float] = None
    finish_reason: Optional[str] = None     # "stop" | "length" | "abort"
    # chunked-prefill progress: prompt tokens whose KV is (or is being)
    # written into the cache.  Advanced by the scheduler at chunk-issue
    # time; the monolithic path sets it to the full prompt on admission.
    prefilled: int = 0
    # preemption-by-recompute (paged KV, docs/memory.md): a preempted
    # sequence loses its KV blocks and is re-admitted as a fresh prefill
    # of its FULL token history (prompt + outputs so far).  The target
    # records how many leading tokens that resume-prefill must cover;
    # None = an ordinary sequence, prefill covers the prompt only.
    prefill_target: Optional[int] = None
    preemptions: int = 0
    # parallel sampling (SamplingParams.n > 1, docs/memory.md): a fork
    # child shares its parent's prompt KV via refcounted block tables.
    # ``forked`` marks a child whose KV is already materialized (no
    # prefill compute needed — admission is bookkeeping only); it is
    # cleared on preemption/demotion, falling back to recompute.
    fork_parent: Optional[int] = None
    forked: bool = False
    forks_spawned: bool = False       # parent: children already created
    # prompt-prefix caching: leading tokens whose KV was mapped onto
    # cached blocks at admission (prefill may start past them).
    cached_prefix: int = 0

    @property
    def length(self) -> int:
        return len(self.prompt_ids) + len(self.output_ids)

    @property
    def prompt_len(self) -> int:
        return len(self.prompt_ids)

    @property
    def priority(self) -> int:
        """Scheduling priority (from SamplingParams): higher serves first,
        lower preempts first under KV block pressure."""
        return self.params.priority

    @property
    def tier(self) -> str:
        """Workload tier (docs/hybrid.md): "online" or "offline"."""
        return self.params.tier

    @property
    def is_online(self) -> bool:
        """False for best-effort offline-tier work: queued separately,
        admitted only into scheduler slack, preempted before any online
        sequence regardless of priority."""
        return self.params.tier != "offline"

    @property
    def prefill_len(self) -> int:
        """Tokens the prefill phase must cover before sampling resumes:
        the prompt, or — after a preemption — the full token history at
        eviction time (the last history token's logits produce the next
        output, exactly the decode step the eviction interrupted)."""
        if self.prefill_target is not None:
            return self.prefill_target
        return len(self.prompt_ids)

    @property
    def prefill_done(self) -> bool:
        return self.prefilled >= self.prefill_len

    def prefill_slice(self, off: int, n: int) -> List[int]:
        """Input ids for the prefill span [off, off+n) over the prefill
        token stream (prompt, extended by outputs after a preemption)."""
        if off + n <= len(self.prompt_ids):
            return list(self.prompt_ids[off:off + n])
        return list((self.prompt_ids + self.output_ids)[off:off + n])

    @property
    def last_token(self) -> int:
        return self.output_ids[-1] if self.output_ids else self.prompt_ids[-1]

    def mark_running(self, now: Optional[float] = None):
        """WAITING -> RUNNING (admission); records the queue-exit time the
        per-request queue-delay metric is computed from."""
        self.status = SeqStatus.RUNNING
        if self.first_sched_t is None:
            self.first_sched_t = time.monotonic() if now is None else now

    def append(self, token_id: int, now: float) -> bool:
        """Returns True when the sequence finishes."""
        self.output_ids.append(int(token_id))
        if self.first_token_t is None:
            self.first_token_t = now
        self.last_token_t = now
        if len(self.output_ids) >= self.params.max_new_tokens:
            done, reason = True, "length"
        elif (self.params.eos_token_id >= 0
                and token_id == self.params.eos_token_id):
            done, reason = True, "stop"
        else:
            done = False
        if done:
            self.status = SeqStatus.FINISHED
            self.finish_t = now
            self.finish_reason = self.finish_reason or reason
        return done


@dataclasses.dataclass
class CachedSeqState:
    """Worker-local cached metadata for a sequence (avoids re-shipping
    prompt/output ids every iteration — the paper's SequenceCache)."""

    seq_id: int
    prompt_len: int
    out_len: int
    cache_row: int            # contiguous layout: KV-cache row; paged: -1
    # paged layout: physical placement lives in the shared
    # BlockSpaceManager (read live at staging time — tables grow between
    # iterations); this handle only marks the sequence as admitted


class SequenceCache:
    """Maps seq_id -> cached state; assigns/releases KV placement.

    Two memory modes (``EngineConfig.kv_layout``, docs/memory.md):

      contiguous  each sequence owns one dense ``[max_seq_len]`` cache row
                  from a fixed pool — admission fails when rows run out.
      paged       placement is a block table in the shared
                  :class:`~repro.runtime.paged_kv.BlockSpaceManager`
                  (``kv``); rows are not assigned, capacity is governed by
                  block-budget admission + preemption in the scheduler.
    """

    def __init__(self, max_rows: int, kv=None):
        self.max_rows = max_rows
        self.kv = kv                       # BlockSpaceManager in paged mode
        self._by_id: Dict[int, CachedSeqState] = {}
        self._free_rows = list(range(max_rows - 1, -1, -1))

    @property
    def paged(self) -> bool:
        return self.kv is not None

    def lookup(self, seq_id: int) -> Optional[CachedSeqState]:
        return self._by_id.get(seq_id)

    def admit(self, seq_id: int, prompt_len: int) -> CachedSeqState:
        st = self._by_id.get(seq_id)
        if st is None:
            if self.paged:
                # blocks were reserved by the scheduler's block-budget
                # admission; this only registers the worker-side handle
                st = CachedSeqState(seq_id, prompt_len, 0, -1)
            else:
                if not self._free_rows:
                    raise RuntimeError("KV cache rows exhausted")
                st = CachedSeqState(seq_id, prompt_len, 0,
                                    self._free_rows.pop())
            self._by_id[seq_id] = st
        return st

    def release(self, seq_id: int):
        st = self._by_id.pop(seq_id, None)
        if st is None:
            return
        if self.paged:
            self.kv.release(seq_id)        # idempotent (preempt frees first)
        else:
            self._free_rows.append(st.cache_row)

    def drop_entry(self, seq_id: int):
        """Forget the worker-side handle WITHOUT touching placement —
        preemption already freed the blocks scheduler-side, and the
        sequence keeps its id (and sampler state) for the resume."""
        self._by_id.pop(seq_id, None)

    def advance(self, seq_id: int):
        st = self._by_id.get(seq_id)
        if st is not None:     # may be gone: aborted/preempted mid-flight
            st.out_len += 1

    @property
    def free_rows(self) -> int:
        return len(self._free_rows)
