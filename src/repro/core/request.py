"""The continuous-serving request layer over :class:`~repro.core.sequence.
Sequence`.

The engine's public surface speaks *requests*, not sequences: a request
is admitted with its own :class:`SamplingParams`, carries a monotonic id
from :class:`RequestIdAllocator` (ids never collide even after the
scheduler releases finished sequence state), moves through the

    QUEUED -> RUNNING -> FINISHED | ABORTED

lifecycle, and streams :class:`RequestOutput` increments from
``engine.step()`` / ``engine.generate()``.  The underlying ``Sequence``
remains the unit the scheduler, KV cache and sampler operate on; the
request's *primary* sequence shares its id (``request_id == seq_id``),
and parallel sampling (``SamplingParams.n > 1``) attaches ``n - 1``
CoW-forked sibling sequences whose streams ride along as
:class:`ForkOutput` entries on every increment (docs/memory.md "Prefix
caching & CoW forks").
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from collections.abc import Sequence as SequenceABC
from typing import List, Optional, Union

from repro.core.sequence import SeqStatus, Sequence


class TokenStream(SequenceABC):
    """Zero-copy snapshot of the first ``n`` tokens of a request's growable
    output list.

    Streaming used to hand every :class:`RequestOutput` a fresh cumulative
    list — an O(len) slice per increment, quadratic per request end to
    end.  A ``TokenStream`` shares the request's backing ``output_ids``
    list instead (O(1) to construct); the bound ``n`` freezes the view at
    emit time, so tokens appended later never leak into an older output.
    It behaves like a read-only list (len / index / slice / iterate /
    ``==`` against lists and tuples); call :meth:`to_list` for a real copy.
    """

    __slots__ = ("_backing", "_n")

    def __init__(self, backing: List[int], n: int):
        self._backing = backing
        self._n = n

    @property
    def backing(self) -> List[int]:
        return self._backing

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, i: Union[int, slice]):
        if isinstance(i, slice):
            return self._backing[:self._n][i]
        if i < -self._n or i >= self._n:
            raise IndexError(i)
        return self._backing[i if i >= 0 else self._n + i]

    def __iter__(self):
        return iter(self._backing[:self._n])

    def to_list(self) -> List[int]:
        return self._backing[:self._n]

    def __add__(self, other) -> List[int]:
        return self.to_list() + list(other)

    def __radd__(self, other) -> List[int]:
        return list(other) + self.to_list()

    def __eq__(self, other) -> bool:
        if isinstance(other, TokenStream):
            other = other.to_list()
        if isinstance(other, tuple):
            other = list(other)
        return self.to_list() == other

    def __repr__(self) -> str:
        return f"TokenStream({self.to_list()!r})"


class RequestState(enum.Enum):
    QUEUED = 0      # admitted to the waiting queue, not yet scheduled
    RUNNING = 1     # scheduled at least once (prefilling or decoding)
    FINISHED = 2    # completed normally ("stop" / "length")
    ABORTED = 3     # cancelled via engine.abort(); resources reclaimed
    PREEMPTED = 4   # evicted under KV memory pressure (paged layout);
    #                 queued for resume-by-recompute, tokens so far retained

    @staticmethod
    def of(seq: Sequence) -> "RequestState":
        return {
            SeqStatus.WAITING: RequestState.QUEUED,
            SeqStatus.RUNNING: RequestState.RUNNING,
            SeqStatus.FINISHED: RequestState.FINISHED,
            SeqStatus.ABORTED: RequestState.ABORTED,
            SeqStatus.PREEMPTED: RequestState.PREEMPTED,
        }.get(seq.status, RequestState.RUNNING)


class RequestIdAllocator:
    """Monotonic request/sequence ids.  Never reuses an id, so releasing
    finished sequences from ``Scheduler.seqs`` (long-run memory bound)
    cannot cause a later request to collide with live worker-side state
    (KV rows, sampler penalty columns, TSEM metadata are all keyed by
    sequence id)."""

    def __init__(self, start: int = 0):
        self._counter = itertools.count(start)

    def next(self) -> int:
        return next(self._counter)


@dataclasses.dataclass
class RequestMetrics:
    """Per-request latency accounting (all times in seconds)."""

    request_id: int
    prompt_tokens: int
    output_tokens: int
    queue_s: Optional[float]    # arrival -> first scheduled
    ttft_s: Optional[float]     # arrival -> first output token
    tpot_s: Optional[float]     # mean inter-token time after the first
    e2e_s: Optional[float]      # arrival -> finish
    finish_reason: Optional[str]
    state: RequestState
    tier: str = "online"        # workload tier (docs/hybrid.md): online
    #                             latency percentiles exclude offline rows

    @staticmethod
    def of(seq: Sequence) -> "RequestMetrics":
        n = len(seq.output_ids)
        ttft = (seq.first_token_t - seq.arrival_t
                if seq.first_token_t is not None else None)
        queue = (seq.first_sched_t - seq.arrival_t
                 if seq.first_sched_t is not None else None)
        tpot = None
        if seq.first_token_t is not None and seq.last_token_t is not None \
                and n > 1:
            tpot = (seq.last_token_t - seq.first_token_t) / (n - 1)
        e2e = (seq.finish_t - seq.arrival_t
               if seq.finish_t is not None else None)
        return RequestMetrics(
            request_id=seq.seq_id, prompt_tokens=seq.prompt_len,
            output_tokens=n, queue_s=queue, ttft_s=ttft, tpot_s=tpot,
            e2e_s=e2e, finish_reason=seq.finish_reason,
            state=RequestState.of(seq), tier=seq.params.tier)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["state"] = self.state.name
        return d


@dataclasses.dataclass
class Request:
    """Engine-side bookkeeping for one in-flight request."""

    request_id: int
    seq: Sequence
    streamed: int = 0       # output tokens already emitted via RequestOutput
    # parallel sampling: the n-1 fork children (scheduler-spawned when the
    # primary's first token lands) and their per-fork streamed watermarks
    forks: List[Sequence] = dataclasses.field(default_factory=list)
    fork_streamed: List[int] = dataclasses.field(default_factory=list)

    @property
    def state(self) -> RequestState:
        return RequestState.of(self.seq)

    @property
    def priority(self) -> int:
        """Scheduling priority (from SamplingParams, docs/http.md)."""
        return self.seq.params.priority

    @property
    def all_seqs(self) -> List[Sequence]:
        return [self.seq] + self.forks


@dataclasses.dataclass
class RequestOutput:
    """One streaming increment for a request, returned by ``engine.step()``.

    ``new_token_ids`` are the tokens generated since the previous output
    for this request (the delta — the only per-emit copy); ``token_ids``
    is the cumulative output so far as a zero-copy :class:`TokenStream`
    view over the request's growable output list (list-like; call
    ``.to_list()`` for an owned copy).  The final increment has
    ``finished=True`` and carries the request's latency metrics; after
    it, the engine holds no per-request state (the ``seq`` handle stays
    valid for the caller)."""

    request_id: int
    new_token_ids: List[int]
    token_ids: Union[List[int], "TokenStream"]
    finished: bool
    state: RequestState
    finish_reason: Optional[str] = None
    metrics: Optional[RequestMetrics] = None
    seq: Optional[Sequence] = None      # underlying sequence (offline compat)
    # parallel sampling (SamplingParams.n > 1): one entry per fork child,
    # in spawn order — index 0 is the SECOND completion (the primary
    # sequence's stream stays in the top-level fields, so n == 1 callers
    # see no change).  ``finished`` above flips only when the primary AND
    # every fork are done.
    forks: Optional[List["ForkOutput"]] = None


@dataclasses.dataclass
class ForkOutput:
    """One fork child's slice of a :class:`RequestOutput` increment."""

    index: int                          # 1-based completion index
    new_token_ids: List[int]
    token_ids: Union[List[int], "TokenStream"]
    finished: bool
    finish_reason: Optional[str] = None
    seq: Optional[Sequence] = None
