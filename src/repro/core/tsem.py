"""Token-Safe Execution Model (SiPipe §5.2), adapted to JAX.

The paper's mechanism targets CUDA graphs: static kernel sequences bound
to fixed device buffers, where asynchronous CPU input preparation causes
write-after-read hazards.  The JAX/TPU analogue (see DESIGN.md
§Hardware-adaptation):

  CUDA graph              ->  AOT-compiled executable (jit().lower().compile())
                              with donated inputs (stable buffer bindings)
  two captured graphs     ->  two *versioned host staging buffer sets* per
  per batch size              batch size; the executable is shape-keyed
  WAR hazard              ->  CPU executor writes staging version i % 2
                              while the device consumes version (i-1) % 2

The FSM with CPU/GPU indicators (CI/GI) is reproduced literally: the CPU
executor may run ahead by exactly one iteration (CI == GI gate), which is
what makes the double buffer sufficient.

``BatchMetadataCache`` keeps p replica versions (pipeline degree) and
updates them *incrementally* when the batch composition is unchanged
between iterations n and n+p — only positions advance and last tokens
swap, no reallocation (§5.2 + §5.1 inter-batch similarity).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.scheduler import SchedulingOutput


@dataclasses.dataclass
class BatchMetadata:
    """Preprocessed CPU tensors for one microbatch (one TSEM replica).

    Pure-decode batches use the flat [B] layout (``width == 1``).  Mixed
    chunked-prefill batches carry the *packed ragged* layout instead of
    padded [B, C] matrices: flat [W] token/position/seq-index vectors
    (W = the power-of-two bucket ``SchedulingOutput.packed_width``), so
    a mostly-decode batch with one chunk does sum(T_i) work, not B x C.
    Padding entries duplicate the last valid packed element (same token,
    position AND batch row), so downstream cache scatters write identical
    values at duplicate indices and stay deterministic without a mask.
    """

    seq_ids: List[int]
    rows: np.ndarray           # [B] cache-row assignment (contiguous layout)
    tokens: np.ndarray         # [B] first input token of each span
    positions: np.ndarray      # [B] span start positions
    iteration: int = -1
    width: int = 1             # packed bucket width (1 = pure decode)
    n_valid: int = 0           # valid packed tokens (T <= width)
    pack_tokens: Optional[np.ndarray] = None     # [W] int32
    pack_positions: Optional[np.ndarray] = None  # [W] int32
    pack_seq: Optional[np.ndarray] = None        # [W] batch column per token
    last_index: Optional[np.ndarray] = None      # [B] packed idx of last valid
    # paged KV layout: [B, nb] physical block table (trash-padded).  The
    # dirty-slot write-back mapping (which physical block a row's new
    # token lands in) is derived *inside* the jitted stage function from
    # the table + positions — no host-side slot staging.
    n_blocks: int = 0          # nb (0 = contiguous layout)
    block_tables: Optional[np.ndarray] = None    # [B, nb] int32

    def advance_inplace(self, sched: SchedulingOutput, rows: np.ndarray):
        """Incremental update: same sequence set, next iteration.  Under
        the paged layout a table may have gained a block between n and
        n+p, so the (same-shaped) table snapshot is refreshed in place."""
        np.copyto(self.tokens, sched.tokens)
        np.copyto(self.positions, sched.positions)
        np.copyto(self.rows, rows)
        if self.block_tables is not None:
            np.copyto(self.block_tables, sched.block_tables)
        self.iteration = sched.iteration


def _build_packed(sched: SchedulingOutput):
    """Packed [W] vectors, padded to the bucket with last-valid duplicates."""
    tok, pos, seq, last = sched.packed_layout()
    t = tok.shape[0]
    w = sched.packed_width

    def pad(a):
        out = np.empty(w, np.int32)
        out[:t] = a
        out[t:] = a[-1]
        return out

    return pad(tok), pad(pos), pad(seq), last, t


class BatchMetadataCache:
    """p versions of BatchMetadata, indexed by iteration %% p.

    The incremental-update fast path applies only when both the cached
    replica and the incoming batch are pure decode (width 1) with the same
    sequence set; iterations carrying prefill chunks rebuild, since their
    per-seq token spans change between n and n+p as prefill progresses.
    """

    def __init__(self, pp_degree: int):
        self.p = pp_degree
        self._meta: List[Optional[BatchMetadata]] = [None] * pp_degree
        self.incremental_hits = 0
        self.rebuilds = 0

    def update(self, sched: SchedulingOutput,
               rows: np.ndarray) -> BatchMetadata:
        slot = sched.iteration % self.p
        meta = self._meta[slot]
        width = sched.packed_width
        nb = 0 if sched.block_tables is None else sched.block_tables.shape[1]
        if (meta is not None and meta.seq_ids == sched.seq_ids
                and meta.width == 1 and width == 1
                and meta.n_blocks == nb):
            meta.advance_inplace(sched, rows)
            self.incremental_hits += 1
            return meta
        meta = BatchMetadata(
            seq_ids=list(sched.seq_ids),
            rows=np.array(rows, np.int32),
            tokens=np.array(sched.tokens, np.int32),
            positions=np.array(sched.positions, np.int32),
            iteration=sched.iteration,
            width=width,
            n_blocks=nb,
        )
        if width > 1:
            (meta.pack_tokens, meta.pack_positions, meta.pack_seq,
             meta.last_index, meta.n_valid) = _build_packed(sched)
        if nb:
            meta.block_tables = np.array(sched.block_tables, np.int32)
        self._meta[slot] = meta
        self.rebuilds += 1
        return meta


class VersionedStaging:
    """Two host-side staging buffer sets per batch shape (v0 / v1).

    Pure-decode iterations stage flat [B] arrays; chunked iterations are
    keyed additionally by the packed bucket width W and stage flat [W]
    token/position/seq-index vectors plus the [B] last-valid indices.
    Under the paged KV layout the key gains the padded block-table width
    nb, and the set stages the [B, nb] physical block table (the jitted
    stage derives the dirty-slot write-back mapping from it on device).
    """

    def __init__(self):
        self._bufs: Dict[Tuple[int, int, int, int],
                         Dict[str, np.ndarray]] = {}

    def buffers(self, version: int, batch: int, width: int = 1,
                n_blocks: int = 0) -> Dict[str, np.ndarray]:
        key = (version & 1, batch, width, n_blocks)
        if key not in self._bufs:
            bufs = {
                "tokens": np.zeros(batch, np.int32),
                "positions": np.zeros(batch, np.int32),
                "rows": np.zeros(batch, np.int32),
            }
            if width > 1:
                bufs["pack_tokens"] = np.zeros(width, np.int32)
                bufs["pack_positions"] = np.zeros(width, np.int32)
                bufs["pack_seq"] = np.zeros(width, np.int32)
                bufs["last_index"] = np.zeros(batch, np.int32)
                bufs["n_valid"] = np.zeros(1, np.int32)
            if n_blocks:
                bufs["block_tables"] = np.zeros((batch, n_blocks), np.int32)
            self._bufs[key] = bufs
        return self._bufs[key]


@dataclasses.dataclass
class ModelInputDescriptor:
    """Lightweight descriptor enqueued to the device executor (the heavy
    tensors live in the staging buffers it points at)."""

    iteration: int
    version: int
    batch: int
    is_prefill: bool
    sched: SchedulingOutput
    width: int = 1             # packed bucket width (1 = flat decode)
    n_blocks: int = 0          # padded block-table width (0 = contiguous)


class TokenSafeExecutor:
    """Decoupled CPU-prepare / device-execute with the paper's FSM.

    ``prepare_fn(sched, staging_bufs) -> None`` fills staging in place.
    ``execute_fn(desc, staging_bufs) -> Any`` runs the AOT step.
    """

    def __init__(self, prepare_fn: Callable, execute_fn: Callable,
                 *, max_ahead: int = 1, name: str = "stage"):
        self.prepare_fn = prepare_fn
        self.execute_fn = execute_fn
        self.staging = VersionedStaging()
        self.name = name
        self.ci = -1                      # CPU indicator
        self.gi = -1                      # GPU indicator
        self.max_ahead = max_ahead
        self._sched_q: List[SchedulingOutput] = []
        self._input_q: List[ModelInputDescriptor] = []
        self._cv = threading.Condition()
        self._stop = False
        self._results: Dict[int, Any] = {}
        self.prep_time = 0.0
        self.exec_time = 0.0
        self.stall_time = 0.0
        self._threads: List[threading.Thread] = []

    # -- communicator API ----------------------------------------------------
    def submit(self, sched: SchedulingOutput):
        with self._cv:
            self._sched_q.append(sched)
            self._cv.notify_all()

    def result(self, iteration: int, timeout: float = 60.0) -> Any:
        deadline = time.monotonic() + timeout
        with self._cv:
            while iteration not in self._results:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(f"{self.name}: iter {iteration}")
                self._cv.wait(remaining)
            return self._results.pop(iteration)

    # -- FSM loops -------------------------------------------------------------
    def _cpu_loop(self):
        while True:
            with self._cv:
                # W -> R when all generated inputs are consumed (CI - GI gate)
                while not self._stop and (
                    not self._sched_q or self.ci - self.gi >= self.max_ahead
                ):
                    self._cv.wait(0.05)
                if self._stop:
                    return
                sched = self._sched_q.pop(0)
                version = (self.ci + 1) & 1
            t0 = time.monotonic()
            width = sched.packed_width
            nb = (0 if sched.block_tables is None
                  else sched.block_tables.shape[1])
            bufs = self.staging.buffers(version, len(sched.seq_ids), width,
                                        nb)
            self.prepare_fn(sched, bufs)
            self.prep_time += time.monotonic() - t0
            with self._cv:
                self.ci += 1
                self._input_q.append(ModelInputDescriptor(
                    sched.iteration, version, len(sched.seq_ids),
                    sched.is_prefill, sched, width, nb))
                self._cv.notify_all()

    def _device_loop(self):
        while True:
            t_wait = time.monotonic()
            with self._cv:
                while not self._stop and not self._input_q:
                    self._cv.wait(0.05)
                if self._stop:
                    return
                desc = self._input_q.pop(0)
                self.gi += 1        # increment on entering R: frees the CPU
                self._cv.notify_all()
            self.stall_time += time.monotonic() - t_wait
            t0 = time.monotonic()
            bufs = self.staging.buffers(desc.version, desc.batch, desc.width,
                                        desc.n_blocks)
            out = self.execute_fn(desc, bufs)
            self.exec_time += time.monotonic() - t0
            with self._cv:
                self._results[desc.iteration] = out
                self._cv.notify_all()

    def start(self):
        for fn, nm in ((self._cpu_loop, "cpu"), (self._device_loop, "dev")):
            t = threading.Thread(target=fn, name=f"{self.name}-{nm}", daemon=True)
            t.start()
            self._threads.append(t)

    def stop(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=5)


class SynchronousExecutor:
    """Baseline (no TSEM): prepare-then-execute serially, like engines that
    defer input preparation until the previous forward completes."""

    def __init__(self, prepare_fn: Callable, execute_fn: Callable, name: str = "stage"):
        self.prepare_fn = prepare_fn
        self.execute_fn = execute_fn
        self.staging = VersionedStaging()
        self.name = name
        self.prep_time = 0.0
        self.exec_time = 0.0
        self.stall_time = 0.0

    def run(self, sched: SchedulingOutput) -> Any:
        width = sched.packed_width
        nb = 0 if sched.block_tables is None else sched.block_tables.shape[1]
        bufs = self.staging.buffers(0, len(sched.seq_ids), width, nb)
        t0 = time.monotonic()
        self.prepare_fn(sched, bufs)
        t1 = time.monotonic()
        out = self.execute_fn(
            ModelInputDescriptor(sched.iteration, 0, len(sched.seq_ids),
                                 sched.is_prefill, sched, width, nb), bufs)
        t2 = time.monotonic()
        self.prep_time += t1 - t0
        self.exec_time += t2 - t1
        return out
