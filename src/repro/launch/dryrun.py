import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production mesh and record roofline inputs.

The two lines above MUST run before any other import (jax locks the device
count on first init) — do not move them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape decode_32k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
Writes one JSON per cell under results/dryrun/.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES_BY_NAME, cell_is_runnable, get_config, list_archs
from repro.launch import hlo_analysis
from repro.launch.mesh import (
    CHIP_HBM_BW,
    CHIP_PEAK_FLOPS,
    ICI_LINK_BW,
    make_production_mesh,
    mesh_chips,
)
from repro import sharding as shlib
from repro.models import ModelOptions, ShardCtx, build_model
from repro.models.common import abstract_params, logical_axes
from repro.models.flops import model_flops
from repro import optim


def build_step(model, shape, mesh, strategy):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    cfg = model.cfg
    batch_sds, batch_ax = model.input_specs(shape)
    batch_sh = {k: shlib.named_sharding(batch_ax[k], batch_sds[k].shape, strategy, mesh)
                for k in batch_sds}
    p_abs = abstract_params(model.specs)
    p_ax = logical_axes(model.specs)
    p_sh = shlib.tree_shardings(p_ax, p_abs, strategy, mesh)

    if shape.kind == "train":
        ocfg = optim.AdamWConfig(
            moment_dtype=jnp.bfloat16 if model.cfg.name.startswith("llama4") else jnp.float32)
        o_abs = optim.abstract_opt_state(p_abs, ocfg)
        o_ax = optim.opt_state_axes(p_ax)
        o_sh = shlib.tree_shardings(o_ax, o_abs, strategy, mesh)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                logits = model.forward_train(p, batch)
                lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
                ll = jnp.take_along_axis(lp, batch["labels"][..., None], -1)
                return -jnp.mean(ll)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            new_params, new_state = optim.adamw_update(params, grads, opt_state, ocfg)
            return loss, new_params, new_state

        fn = jax.jit(
            train_step,
            in_shardings=(p_sh, o_sh, batch_sh),
            out_shardings=(jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                           p_sh, o_sh),
            donate_argnums=(0, 1),
        )
        return fn, (p_abs, o_abs, batch_sds)

    if shape.kind == "prefill":
        logits_sh = shlib.named_sharding(("batch", "vocab"),
                                         (shape.global_batch, cfg.vocab_size),
                                         strategy, mesh)
        c_abs = model.abstract_cache(shape.global_batch, shape.seq_len)
        c_ax = model.cache_axes()
        c_sh = shlib.tree_shardings(c_ax, c_abs, strategy, mesh)
        fn = jax.jit(model.prefill, in_shardings=(p_sh, batch_sh),
                     out_shardings=(logits_sh, c_sh))
        return fn, (p_abs, batch_sds)

    # decode
    c_abs = model.abstract_cache(shape.global_batch, shape.seq_len)
    c_ax = model.cache_axes()
    c_sh = shlib.tree_shardings(c_ax, c_abs, strategy, mesh)
    logits_sh = shlib.named_sharding(("batch", "vocab"),
                                     (shape.global_batch, cfg.vocab_size),
                                     strategy, mesh)
    fn = jax.jit(model.decode, in_shardings=(p_sh, c_sh, batch_sh),
                 out_shardings=(logits_sh, c_sh), donate_argnums=(1,))
    return fn, (p_abs, c_abs, batch_sds)


# Paper-regime PP degrees per arch (scan-group divisibility; see DESIGN.md)
PP_DEGREE = {
    "stablelm-1.6b": 8, "codeqwen1.5-7b": 8, "glm4-9b": 8,
    # minicpm-2b: vocab 122753 indivisible by tp -> XLA SPMD check-failure
    # in the partial-manual region; see results/dryrun pp skip record.
    "mixtral-8x7b": 8, "llama4-maverick-400b-a17b": 8,
    "llama-3.2-vision-90b": 4, "xlstm-1.3b": 2,
}


def run_pp_cell(arch: str, shape_name: str, multi_pod: bool,
                options: ModelOptions = ModelOptions(), tag: str = "pp",
                pp: int = 0) -> dict:
    """Dry-run the paper's PP regime: pp stages x tp=16 x dp."""
    from repro.core import pipeline as pl
    from repro.launch.mesh import make_pipeline_mesh

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = ("pod2x16x16" if multi_pod else "pod16x16")
    p = pp or PP_DEGREE.get(arch, 0)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "tag": f"{tag}-p{p}", "ok": False, "pp": p}
    if p == 0 or shape.kind != "decode":
        rec.update(skipped=True, ok=True,
                   reason="PP dry-run covers decode shapes of single-stack archs")
        return rec
    runnable, why = cell_is_runnable(cfg, shape)
    if not runnable:
        rec.update(skipped=True, reason=why, ok=True)
        return rec
    if shape.global_batch % p:
        rec.update(skipped=True, ok=True,
                   reason=f"global_batch {shape.global_batch} % pp {p} != 0")
        return rec

    t0 = time.time()
    mesh = make_pipeline_mesh(p, multi_pod=multi_pod)
    shard = ShardCtx.from_mesh(mesh, "pp")
    model = build_model(cfg, shard, options, enc_len=shape.seq_len)
    plan = pl.plan_pp(model, mesh, shape.global_batch)
    step = pp_step = pl.pp_decode_round(model, plan)
    sh = pl.pp_shardings(model, plan, (p, plan.microbatch))
    c_abs = pl.pp_abstract_cache(model, plan, shape.seq_len)
    c_ax = model.cache_axes()["blocks"]
    c_sh = sh["cache_sharding_fn"](c_abs, c_ax)
    i32 = jax.ShapeDtypeStruct((p, plan.microbatch), jnp.int32)
    inflight = jax.ShapeDtypeStruct((p, plan.microbatch, cfg.d_model), jnp.bfloat16)
    fn = jax.jit(step,
                 in_shardings=(sh["params"], c_sh, sh["inflight"],
                               sh["tokens"], sh["positions"]),
                 out_shardings=(sh["logits"], c_sh, sh["inflight"]),
                 donate_argnums=(1,))
    t1 = time.time()
    lowered = fn.lower(sh["params_abstract"], c_abs, inflight, i32, i32)
    t2 = time.time()
    compiled = lowered.compile()
    t3 = time.time()
    ma = compiled.memory_analysis()
    summary = hlo_analysis.analyze(compiled.as_text())
    fr = model_flops(cfg, shape, tp=shard.tp, triangular=options.triangular)
    chips = mesh_chips(mesh)
    terms = {"compute_s": summary.flops / CHIP_PEAK_FLOPS,
             "memory_s": summary.bytes_accessed / CHIP_HBM_BW,
             "collective_s": summary.total_collective_bytes / ICI_LINK_BW}
    rec.update(
        ok=True, chips=chips, strategy="pp",
        build_s=round(t1 - t0, 2), lower_s=round(t2 - t1, 2),
        compile_s=round(t3 - t2, 2),
        memory=dict(argument_bytes=ma.argument_size_in_bytes,
                    output_bytes=ma.output_size_in_bytes,
                    temp_bytes=ma.temp_size_in_bytes,
                    alias_bytes=ma.alias_size_in_bytes),
        hlo={"flops_per_chip": summary.flops,
             "bytes_per_chip": summary.bytes_accessed,
             "collective_bytes_per_chip": summary.total_collective_bytes,
             "collectives": summary.collective_bytes,
             "collective_counts": summary.collective_counts,
             "warnings": summary.warnings[:10]},
        model_flops=fr.model_flops,
        roofline={**terms, "dominant": max(terms, key=terms.get),
                  "step_s_lower_bound": max(terms.values()),
                  "note": "one round = p decode iterations (p microbatches)",
                  "mfu_bound": (fr.model_flops / CHIP_PEAK_FLOPS / chips)
                  / max(max(terms.values()), 1e-12)},
    )
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             options: ModelOptions = ModelOptions(), tag: str = "",
             strategy_override: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
           "ok": False}
    runnable, why = cell_is_runnable(cfg, shape)
    if not runnable:
        rec.update(skipped=True, reason=why, ok=True)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    strategy = strategy_override or ("train" if shape.kind == "train" else "serve")
    shard = ShardCtx.from_mesh(mesh, strategy)
    model = build_model(cfg, shard, options, enc_len=shape.seq_len)
    fn, args = build_step(model, shape, mesh, strategy)

    t1 = time.time()
    lowered = fn.lower(*args)
    t2 = time.time()
    compiled = lowered.compile()
    t3 = time.time()

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    summary = hlo_analysis.analyze(txt)

    fr = model_flops(cfg, shape, tp=shard.tp, triangular=options.triangular)
    flops_chip = summary.flops
    bytes_chip = summary.bytes_accessed
    coll_chip = summary.total_collective_bytes

    compute_s = flops_chip / CHIP_PEAK_FLOPS
    memory_s = bytes_chip / CHIP_HBM_BW
    collective_s = coll_chip / ICI_LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    rec.update(
        ok=True,
        chips=chips,
        strategy=strategy,
        build_s=round(t1 - t0, 2),
        lower_s=round(t2 - t1, 2),
        compile_s=round(t3 - t2, 2),
        memory=dict(
            argument_bytes=ma.argument_size_in_bytes,
            output_bytes=ma.output_size_in_bytes,
            temp_bytes=ma.temp_size_in_bytes,
            alias_bytes=ma.alias_size_in_bytes,
            peak_bytes=ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes,
        ),
        cost_raw={"flops": ca.get("flops", 0.0),
                  "bytes": ca.get("bytes accessed", 0.0)},
        hlo={"flops_per_chip": flops_chip, "bytes_per_chip": bytes_chip,
             "collective_bytes_per_chip": coll_chip,
             "collectives": summary.collective_bytes,
             "collective_counts": summary.collective_counts,
             "warnings": summary.warnings[:10]},
        model_flops=fr.model_flops,
        detailed_flops=fr.detailed_flops,
        roofline={**terms, "dominant": dominant,
                  "step_s_lower_bound": max(terms.values()),
                  "useful_ratio": fr.model_flops / max(flops_chip * chips, 1.0),
                  "mfu_bound": (fr.model_flops / CHIP_PEAK_FLOPS / chips)
                  / max(max(terms.values()), 1e-12)},
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--strategy", default="")
    ap.add_argument("--pp", type=int, default=0,
                    help="run the PP-regime dry-run with this pipeline degree"
                         " (0 with --strategy pp = per-arch default)")
    ap.add_argument("--triangular", action="store_true")
    ap.add_argument("--fuse-shared", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--kv-block", type=int, default=512)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    options = ModelOptions(kv_block=args.kv_block, triangular=args.triangular,
                           fuse_shared_expert=args.fuse_shared,
                           seq_shard=args.seq_shard, kv_quant=args.kv_quant)

    cells = []
    if args.all:
        for arch in list_archs():
            for shape in SHAPES_BY_NAME:
                cells.append((arch, shape, args.multi_pod))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape, args.multi_pod))

    n_fail = 0
    for arch, shape, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        fname = out_dir / f"{args.tag}__{arch}__{shape}__{mesh_name}.json"
        if args.skip_existing and fname.exists():
            print(f"[skip existing] {fname.name}")
            continue
        try:
            if args.strategy == "pp" or args.pp:
                rec = run_pp_cell(arch, shape, mp, options, args.tag, args.pp)
            else:
                rec = run_cell(arch, shape, mp, out_dir, options, args.tag,
                               args.strategy)
        except Exception as e:  # a failed cell is a bug; record it
            rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "tag": args.tag, "ok": False, "error": str(e)[-2000:],
                   "traceback": traceback.format_exc()[-4000:]}
            n_fail += 1
        fname.write_text(json.dumps(rec, indent=2, default=float))
        status = "SKIP" if rec.get("skipped") else ("OK" if rec["ok"] else "FAIL")
        extra = ""
        if rec.get("ok") and not rec.get("skipped"):
            r = rec["roofline"]
            extra = (f" compile={rec['compile_s']}s dominant={r['dominant']}"
                     f" mfu_bound={r['mfu_bound']:.3f}")
        print(f"[{status}] {arch} x {shape} x {mesh_name}{extra}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
