"""Corrected cost analysis from compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once*,
which under-reports FLOPs/bytes for scan-over-layers models by ~L x.  This
module parses the post-SPMD HLO text, recovers loop trip counts from loop
conditions, walks the call graph, and accumulates per-chip:

  * dot FLOPs (x loop multipliers)
  * HBM bytes (operand+result bytes of materializing top-level ops)
  * collective link bytes per op kind (ring-model per-chip traffic)

All numbers are PER CHIP because the module is the per-partition SPMD
program.  Dynamic-bound loops (no constant trip) fall back to a supplied
default and are reported in ``warnings``.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_ITEMSIZE = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*?)\)\s*->\s*(.+?)\s*{\s*$")
_CALL_SINGLE_RE = re.compile(
    r"(?:calls|condition|body|to_apply|comparator)=%?([\w.\-]+)"
)
_CALL_LIST_RE = re.compile(
    r"(?:calls|branch_computations|called_computations)=\{([^}]*)\}"
)
_KNOWN_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_REPL_GROUP_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_REPL_GROUP_V2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """'(bf16[2,3]{1,0}, s32[])' or 'f32[4,5]' -> [(dtype, dims), ...]."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt in ("token", "opaque"):
            continue
        shape = tuple(int(x) for x in dims.split(",") if x) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(type_str: str, normalize_f32: bool = False) -> int:
    """normalize_f32: charge f32 arrays at 2 bytes/elem.  The XLA *CPU*
    backend upcasts bf16 compute to f32 (no native bf16); on the TPU
    target these buffers stay bf16, so byte accounting for the roofline
    uses the normalized size (documented in DESIGN.md)."""
    total = 0
    for dt, shape in _parse_shapes(type_str):
        n = 1
        for d in shape:
            n *= d
        size = _ITEMSIZE.get(dt, 4)
        if normalize_f32 and dt == "f32":
            size = 2
        total += n * size
    return total


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_str: str
    rest: str            # raw text after the opening paren
    operands: List[str]
    is_root: bool


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    param_types: Dict[str, str]
    ops: Dict[str, Op]
    order: List[str]


def _split_operands(rest: str) -> List[str]:
    """Operand names from 'args...), attr=...' (names only, best-effort)."""
    depth = 0
    args = []
    cur = []
    for ch in rest:
        if ch in "([{":
            depth += 1
            cur.append(ch)
        elif ch in ")]}":
            if ch == ")" and depth == 0:
                break
            depth -= 1
            cur.append(ch)
        elif ch == "," and depth == 0:
            args.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        args.append("".join(cur).strip())
    names = []
    for a in args:
        # newer XLA prints operands with inline types: 'f32[32,64]{1,0} %x';
        # prefer the %-prefixed name, else strip the type prefix first
        m = re.search(r"%([\w.\-]+)", a)
        if m is None:
            a = re.sub(r"^\w+\[[\d,]*\](\{[^}]*\})?\s*", "", a).strip() or a
            m = re.match(r"([\w.\-]+)", a)
        if m:
            names.append(m.group(1))
    return names


def _split_top_level(s: str) -> List[str]:
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return [p for p in parts if p]


def _parse_header(line: str) -> Optional[Tuple[str, bool, Dict[str, str]]]:
    """Computation headers sit at column 0 and end with '{'."""
    if line.startswith((" ", "\t")) or not line.endswith("{") or " -> " not in line:
        return None
    is_entry = line.startswith("ENTRY")
    body = line[len("ENTRY"):].strip() if is_entry else line
    lp = body.find("(")
    arrow = body.rfind(") -> ")
    if lp < 0 or arrow < 0:
        return None
    name = body[:lp].strip().lstrip("%").strip()
    params: Dict[str, str] = {}
    for item in _split_top_level(body[lp + 1 : arrow]):
        if ":" in item:
            pname, ptype = item.split(":", 1)
            params[pname.strip().lstrip("%")] = ptype.strip()
    return name, is_entry, params


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _parse_header(line)
        if hdr is not None:
            name, is_entry, params = hdr
            cur = Computation(name, is_entry, params, {}, [])
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            is_root, name, type_str, kind, rest = (
                bool(m.group(1)), m.group(2), m.group(3), m.group(4), m.group(5))
            op = Op(name, kind, type_str, rest, _split_operands(rest), is_root)
            cur.ops[name] = op
            cur.order.append(name)
    return comps


def _shape_of(name: str, comp: Computation, comps: Dict[str, Computation]) -> Optional[str]:
    if name in comp.ops:
        return comp.ops[name].type_str
    if name in comp.param_types:
        return comp.param_types[name]
    return None


def _resolve_constant(name: str, comp: Computation) -> Optional[int]:
    op = comp.ops.get(name)
    if op is None:
        return None
    if op.kind == "constant":
        m = _CONST_RE.search(op.type_str + " constant(" + op.rest)
        m2 = re.search(r"constant\((\d+)\)", "constant(" + op.rest)
        if m2:
            return int(m2.group(1))
    return None


def _trip_count(while_op: Op, comps: Dict[str, Computation]) -> Optional[int]:
    km = _KNOWN_TRIP_RE.search(while_op.rest)
    if km:  # XLA annotates counted loops in backend_config
        return int(km.group(1))
    m = re.search(r"condition=%?([\w.\-]+)", while_op.rest)
    if not m or m.group(1) not in comps:
        return None
    cond = comps[m.group(1)]
    # constants defined in the condition computation
    consts = []
    for op in cond.ops.values():
        if op.kind == "constant":
            mm = re.search(r"constant\((\d+)\)", "constant(" + op.rest)
            if mm:
                consts.append(int(mm.group(1)))
    # find ROOT; if compare against a constant, use it; else if fusion, look
    # for a single integer constant among its operands / the computation
    root = next((o for o in cond.ops.values() if o.is_root), None)
    if root is not None and root.kind == "compare":
        for nm in root.operands:
            c = _resolve_constant(nm, cond)
            if c is not None:
                return c
    if len(consts) == 1:
        return consts[0]
    if consts:
        return max(consts)  # loop bound is usually the largest constant
    return None


def _callees(op: Op) -> List[str]:
    names: List[str] = []
    for m in _CALL_SINGLE_RE.finditer(op.rest):
        names.append(m.group(1))
    for m in _CALL_LIST_RE.finditer(op.rest):
        names.extend(x.strip().lstrip("%") for x in m.group(1).split(",") if x.strip())
    return list(dict.fromkeys(names))


def _dot_flops(op: Op, comp: Computation) -> int:
    out_elems = 0
    for _, shape in _parse_shapes(op.type_str):
        n = 1
        for d in shape:
            n *= d
        out_elems += n
    lhs_name = op.operands[0] if op.operands else None
    lhs_type = _shape_of(lhs_name, comp, {}) if lhs_name else None
    contract = 1
    mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if lhs_type and mm:
        shapes = _parse_shapes(lhs_type)
        if shapes:
            dims = shapes[0][1]
            for idx in (int(x) for x in mm.group(1).split(",") if x):
                if idx < len(dims):
                    contract *= dims[idx]
    return 2 * out_elems * contract


_SKIP_BYTES = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # callers: their bodies' ops are charged directly
    "while", "conditional", "call",
}


@dataclasses.dataclass
class CostSummary:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    warnings: List[str] = dataclasses.field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str, default_trip: int = 1) -> CostSummary:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    summary = CostSummary()
    if entry is None:
        summary.warnings.append("no ENTRY computation found")
        return summary

    # computations reachable as fusion/reduce/sort bodies are "internal":
    # their ops do not individually touch HBM
    internal: set = set()
    materializing_callers = {"while", "conditional", "call", "async-start"}
    for comp in comps.values():
        for op in comp.ops.values():
            for callee in _callees(op):
                if op.kind not in materializing_callers and callee in comps:
                    internal.add(callee)

    # multipliers via DFS from entry
    mult: Dict[str, float] = defaultdict(float)
    mult[entry.name] = 1.0
    stack = [entry.name]
    visited_edges = set()
    order: List[str] = []
    # propagate: process in topological-ish order via repeated passes
    changed = True
    passes = 0
    while changed and passes < 64:
        changed = False
        passes += 1
        for comp in comps.values():
            base = mult.get(comp.name, 0.0)
            if base <= 0:
                continue
            for op in comp.ops.values():
                factor = 1.0
                if op.kind == "while":
                    trip = _trip_count(op, comps)
                    if trip is None:
                        trip = default_trip
                        summary.warnings.append(
                            f"dynamic trip count for {op.name}; default={default_trip}")
                    factor = float(trip)
                for callee in _callees(op):
                    if callee not in comps:
                        continue
                    if op.kind == "while" and callee != _body_name(op):
                        f = 1.0  # condition evaluated trip+1 times; negligible
                    else:
                        f = factor
                    new = base * f
                    if new > mult.get(callee, 0.0):
                        mult[callee] = new
                        changed = True

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        is_internal = comp.name in internal
        for op in comp.ops.values():
            if op.kind in ("dot", "dot-general"):
                summary.flops += m * _dot_flops(op, comp)
            kind = op.kind.replace("-start", "")
            if kind in COLLECTIVE_KINDS:
                payload = sum(
                    _nbytes(_shape_of(nm, comp, comps) or "", normalize_f32=True)
                    for nm in op.operands
                    if _shape_of(nm, comp, comps)
                )
                result = _nbytes(op.type_str, normalize_f32=True)
                g = _group_size(op)
                link = _link_bytes(kind, payload, result, g)
                summary.collective_bytes[kind] = summary.collective_bytes.get(kind, 0.0) + m * link
                summary.collective_counts[kind] = summary.collective_counts.get(kind, 0) + 1
            if not is_internal and op.kind not in _SKIP_BYTES and not op.kind.endswith("-done"):
                summary.bytes_accessed += m * _op_bytes(op, comp, comps)
    return summary


_SLICING_KINDS = {"dynamic-slice", "slice", "gather"}
_PLUMBING_KINDS = {"convert", "bitcast", "copy", "reshape", "transpose",
                   "parameter", "tuple", "get-tuple-element", "constant"}
_NB = dict(normalize_f32=True)


def _op_bytes(op: Op, comp: Computation, comps: Dict[str, Computation]) -> int:
    """HBM bytes touched by one materializing op.

    Slicing ops read only the slice, not the whole operand; in-place
    dynamic-update-slice touches only the update region; fusions whose
    parameters are consumed exclusively by slicing ops are charged the
    slice bytes (XLA fuses cache reads this way).  Pure dtype/layout
    plumbing fusions (bf16<->f32 converts the CPU backend inserts) are
    charged zero — they do not exist on the TPU target.
    """
    result = _nbytes(op.type_str, **_NB)
    if op.kind in _SLICING_KINDS:
        return 2 * result  # read slice + write result
    if op.kind == "dynamic-update-slice":
        upd = _nbytes(_shape_of(op.operands[1], comp, comps) or "", **_NB) if len(op.operands) > 1 else 0
        return 2 * upd  # read update + write region (rest aliases in place)
    if op.kind == "scatter":
        upd = _nbytes(_shape_of(op.operands[-1], comp, comps) or "", **_NB) if op.operands else 0
        return result + 2 * upd

    if op.kind == "fusion":
        callee = next((c for c in _callees(op) if c in comps), None)
        body = comps.get(callee) if callee else None
        if body is not None and all(o.kind in _PLUMBING_KINDS for o in body.ops.values()):
            return 0  # CPU-backend dtype/layout artifact
        total = _fusion_output_bytes(op, body, comp, comps)
        params_order = list(body.param_types) if body else []
        for idx, nm in enumerate(op.operands):
            ts = _shape_of(nm, comp, comps)
            if not ts:
                continue
            full = _nbytes(ts, **_NB)
            if body is not None and idx < len(params_order):
                sliced = _sliced_param_bytes(body, params_order[idx])
                if sliced is not None:
                    total += min(sliced, full)
                    continue
            total += full
        return total

    total = result
    for nm in op.operands:
        ts = _shape_of(nm, comp, comps)
        if ts:
            total += _nbytes(ts, **_NB)
    return total


def _fusion_root(body: Computation) -> Optional[Op]:
    root = next((o for o in body.ops.values() if o.is_root), None)
    # look through trailing converts/copies to the real producer
    seen = 0
    while root is not None and root.kind in ("convert", "bitcast", "copy") and seen < 8:
        nxt = body.ops.get(root.operands[0]) if root.operands else None
        if nxt is None:
            break
        root, seen = nxt, seen + 1
    return root


def _fusion_output_bytes(op: Op, body: Optional[Computation],
                         comp: Computation, comps: Dict[str, Computation]) -> int:
    """If the fusion root is a dynamic-update-slice, the output aliases the
    input buffer and only the update region is written."""
    if body is not None:
        root = _fusion_root(body)
        if root is not None and root.kind == "dynamic-update-slice" and len(root.operands) > 1:
            upd = _shape_of(root.operands[1], body, comps)
            if upd:
                return _nbytes(upd, **_NB)
    return _nbytes(op.type_str, **_NB)


def _sliced_param_bytes(body: Computation, pname: str) -> Optional[int]:
    """Bytes actually read from a fusion parameter.

    Follows dtype/layout aliases (convert/bitcast/copy/reshape — CPU-backend
    artifacts, free on the TPU target).  Returns None when the buffer is
    consumed whole by real compute; 0 when its only sink is operand 0 of a
    dynamic-update-slice (in-place update target); slice bytes when all
    sinks are slicing ops."""
    aliases = {pname}
    frontier = [pname]
    total = 0
    steps = 0
    while frontier and steps < 64:
        steps += 1
        nm = frontier.pop()
        for o in body.ops.values():
            if nm not in o.operands:
                continue
            if o.kind in ("convert", "bitcast", "copy", "reshape"):
                if o.name not in aliases:
                    aliases.add(o.name)
                    frontier.append(o.name)
            elif o.kind in _SLICING_KINDS:
                if o.operands and o.operands[0] == nm:
                    total += _nbytes(o.type_str, **_NB)
                # index operands are free
            elif o.kind == "dynamic-update-slice":
                if o.operands and o.operands[0] == nm:
                    continue  # in-place target: no read
                return None  # param is the update: read it whole
            else:
                return None  # real compute consumes the buffer
    return total


def _body_name(op: Op) -> Optional[str]:
    m = re.search(r"body=%?([\w.\-]+)", op.rest)
    return m.group(1) if m else None


def _group_size(op: Op) -> int:
    m = _REPL_GROUP_RE.search(op.rest)
    if m:
        return len(m.group(1).split(","))
    m = _REPL_GROUP_V2.search(op.rest)
    if m:  # iota tile format [groups,size]
        return int(m.group(2))
    return 2


def _link_bytes(kind: str, payload: int, result: int, g: int) -> float:
    """Per-chip bytes crossing ICI links under ring algorithms."""
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * payload * frac
    if kind == "all-gather":
        return result * frac
    if kind == "reduce-scatter":
        return payload * frac
    if kind in ("all-to-all", "ragged-all-to-all"):
        return payload * frac
    if kind == "collective-permute":
        return float(payload)
    return float(payload)
