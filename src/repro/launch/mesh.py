"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The dry-run requires:
  single-pod:  (16, 16)    axes ("data", "model")      = 256 chips
  multi-pod:   (2, 16, 16) axes ("pod", "data", "model") = 512 chips

``make_pipeline_mesh`` builds the derived pipeline view over the same
devices for the paper's PP regime: ("pipe", "data", "model").
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_pipeline_mesh(pp: int, *, multi_pod: bool = False, tp: int = 16) -> Mesh:
    """Reshape the production device set into ("pipe", "data", "model").

    pp * data * tp must equal the chip count (256 or 512); the "pod" axis
    folds into "data" (each pod contributes pipeline-replica batch shards).
    """
    n = 512 if multi_pod else 256
    assert n % (pp * tp) == 0, (pp, tp, n)
    dp = n // (pp * tp)
    devices = np.asarray(jax.devices()[:n]).reshape(pp, dp, tp)
    return Mesh(devices, ("pipe", "data", "model"))


def make_host_mesh(shape: Tuple[int, ...] = (), axes: Tuple[str, ...] = ()) -> Optional[Mesh]:
    """Small local mesh for tests/examples (None on a single device)."""
    n = len(jax.devices())
    if not shape:
        return None
    assert math.prod(shape) <= n
    return jax.make_mesh(shape, axes)


# Hardware model: TPU v5e (target platform for this reproduction).
CHIP_PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
CHIP_HBM_BW = 819e9               # bytes/s per chip
ICI_LINK_BW = 50e9                # bytes/s per link (~ per direction)


def mesh_chips(mesh: Mesh) -> int:
    return int(np.prod(mesh.devices.shape))
