"""Training driver: real steps on the local device(s), with the full
production substrate — sharded AdamW, LR schedules, deterministic
restartable data, periodic checkpoints, crash restart, optional int8
gradient compression with error feedback.

Example (CPU, reduced config — examples/train_small.py wraps this):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import get_config
from repro.models import ModelOptions, ShardCtx, build_model
from repro.models.common import abstract_params, logical_axes
from repro.runtime import checkpoint as ckpt_lib
from repro.runtime.data import SyntheticLM
from repro.runtime.fault_tolerance import RetryPolicy


def make_train_step(model, ocfg: optim.AdamWConfig, schedule,
                    grad_compression: bool = False):
    def train_step(params, opt_state, comp_err, batch):
        def loss_fn(p):
            logits = model.forward_train(p, batch)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            ll = jnp.take_along_axis(lp, batch["labels"][..., None], -1)
            return -jnp.mean(ll)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        if grad_compression:
            grads, comp_err = optim.compress_grads_with_feedback(grads, comp_err)
        lr_scale = schedule(opt_state["step"])
        params, opt_state = optim.adamw_update(params, grads, opt_state, ocfg,
                                               lr_scale)
        return loss, params, opt_state, comp_err

    return jax.jit(train_step, donate_argnums=(0, 1, 2))


def run(arch: str, *, smoke: bool = True, steps: int = 50, batch: int = 8,
        seq: int = 128, ckpt_dir: str = "", ckpt_every: int = 20,
        grad_compression: bool = False, lr: float = 3e-4,
        schedule: str = "cosine", log_every: int = 10,
        simulate_crash_at: int = -1) -> dict:
    cfg = get_config(arch + ("-smoke" if smoke else ""))
    model = build_model(cfg, ShardCtx.single(), ModelOptions(), enc_len=seq)
    ocfg = optim.AdamWConfig(lr=lr)
    sched = (optim.wsd_schedule(steps // 10, steps * 7 // 10, steps * 2 // 10)
             if schedule == "wsd" else optim.cosine_schedule(steps // 10, steps))
    step_fn = make_train_step(model, ocfg, sched, grad_compression)
    data = SyntheticLM(cfg.vocab_size, seq, batch, seed=1)

    params = model.init(jax.random.key(0))
    opt_state = optim.init_opt_state(params, ocfg)
    comp_err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
        if grad_compression else {"_": jnp.zeros(())}
    start_step = 0

    mgr = ckpt_lib.CheckpointManager(ckpt_dir, ckpt_every) if ckpt_dir else None
    if mgr is not None:
        got = mgr.restore_or_none({"params": params, "opt": opt_state})
        if got is not None:
            start_step, tree = got
            params, opt_state = tree["params"], tree["opt"]
            print(f"[train] restored checkpoint at step {start_step}")

    losses = []
    retry = RetryPolicy(max_attempts=2)
    t0 = time.time()
    for step in range(start_step, steps):
        if step == simulate_crash_at:
            raise RuntimeError("simulated crash (restart me)")
        toks, labels = data.batch_at(step)
        b = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        if cfg.family == "vlm":
            from repro.models.transformer import cfg_n_patches

            b["patches"] = jnp.zeros((batch, cfg_n_patches(cfg), cfg.d_model),
                                     jnp.bfloat16)
        if cfg.family == "audio":
            b["frames"] = jnp.zeros((batch, seq, cfg.d_model), jnp.bfloat16)

        loss, params, opt_state, comp_err = retry.run(
            step_fn, params, opt_state, comp_err, b)
        losses.append(float(loss))
        if mgr is not None:
            mgr.maybe_save(step + 1, {"params": params, "opt": opt_state})
        if step % log_every == 0:
            print(f"[train] step {step} loss {float(loss):.4f} "
                  f"lr x{float(sched(step)):.3f} ({time.time()-t0:.1f}s)")

    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "steps": steps, "wall_s": time.time() - t0}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    args = ap.parse_args()
    out = run(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
              seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
              grad_compression=args.grad_compression, schedule=args.schedule)
    print(f"[train] done: final_loss={out['final_loss']:.4f} "
          f"wall={out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
