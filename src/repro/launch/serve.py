"""Serving driver: the SiPipe engine end-to-end on a real (reduced) model
with a ShareGPT-shaped batched workload.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --engine sipipe --pp 2 --requests 8
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import EngineConfig, NaivePPEngine, SiPipeEngine
from repro.core.sampling_params import SamplingParams
from repro.models import ModelOptions, ShardCtx, build_model
from repro.runtime.data import ShareGPTLike


def run(arch: str, *, engine: str = "sipipe", pp: int = 2, requests: int = 8,
        max_batch: int = 4, max_new_tokens: int = 16, max_seq_len: int = 256,
        n_samplers: int = 2, chunk_tokens: int = 0, policy: str = "auto",
        hysteresis_tokens: int = 0, seed: int = 0,
        verbose: bool = True) -> dict:
    cfg = get_config(arch + "-smoke" if not arch.endswith("-smoke") else arch)
    model = build_model(cfg, ShardCtx.single(), ModelOptions())
    params = model.init(jax.random.key(0))
    ecfg = EngineConfig(pp_degree=pp, max_batch=max_batch,
                        max_seq_len=max_seq_len, n_samplers=n_samplers,
                        prefill_chunk_tokens=chunk_tokens or None,
                        scheduling_policy=policy,
                        phase_hysteresis_tokens=hysteresis_tokens or None,
                        seed=seed)
    eng = (SiPipeEngine if engine == "sipipe" else NaivePPEngine)(
        model, params, ecfg)
    wl = ShareGPTLike(cfg.vocab_size, n_requests=requests, seed=seed,
                      prompt_len_median=12, max_prompt=max_seq_len // 4,
                      output_len_median=max_new_tokens,
                      max_output=max_new_tokens)
    sp_base = SamplingParams(temperature=0.8, top_k=40, top_p=0.95,
                             frequency_penalty=0.2, presence_penalty=0.1)
    for prompt, budget in wl.requests():
        eng.add_request(prompt, SamplingParams(
            **{**sp_base.__dict__, "max_new_tokens": min(budget, max_new_tokens)}))
    done = eng.run()
    m = eng.metrics()
    m["engine"] = engine
    m["finished"] = len(done)
    if verbose:
        print(json.dumps({k: v for k, v in m.items() if k != "stages"},
                         indent=1, default=float))
        for i, st in enumerate(m["stages"]):
            print(f"  stage{i}: busy={st['busy_s']:.2f}s "
                  f"prep={st['prep_s']:.2f}s bubble={st['bubble_frac']:.2f}")
    return m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--engine", default="sipipe", choices=["sipipe", "naive"])
    ap.add_argument("--pp", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--samplers", type=int, default=2)
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="per-iteration token budget for span scheduling "
                         "policies (0 = monolithic whole-prompt prefill)")
    ap.add_argument("--policy", default="auto",
                    choices=["auto", "monolithic", "chunked", "disaggregated"],
                    help="scheduling policy; 'auto' maps a token budget to "
                         "chunked and no budget to monolithic "
                         "(docs/scheduling.md §Scheduling policies)")
    ap.add_argument("--hysteresis-tokens", type=int, default=0,
                    help="disaggregated decode->prefill switch threshold in "
                         "pending prefill tokens per paused decode slot "
                         "(0 = the token budget)")
    args = ap.parse_args()
    run(args.arch, engine=args.engine, pp=args.pp, requests=args.requests,
        max_batch=args.max_batch, max_new_tokens=args.max_new_tokens,
        n_samplers=args.samplers, chunk_tokens=args.chunk_tokens,
        policy=args.policy, hysteresis_tokens=args.hysteresis_tokens)


if __name__ == "__main__":
    main()
