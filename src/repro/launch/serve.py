"""Serving driver: the SiPipe engine end-to-end on a real (reduced) model
with a ShareGPT-shaped workload.

Offline batch (enqueue everything, blocking run):

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --engine sipipe --pp 2 --requests 8

Online continuous serving (Poisson arrivals replayed through the
step-driven request API, docs/serving.md):

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
      --online --arrival-rate 8 --policy chunked --chunk-tokens 16
"""
from __future__ import annotations

import argparse
import json
import time
from collections import deque

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import EngineConfig, NaivePPEngine, SiPipeEngine
from repro.core.sampling_params import SamplingParams
from repro.models import ModelOptions, ShardCtx, build_model
from repro.runtime.data import ShareGPTLike

POLICY_CHOICES = ["auto", "monolithic", "chunked", "disaggregated", "adaptive"]


def _build_engine(arch: str, *, engine: str, pp: int, max_batch: int,
                  max_seq_len: int, n_samplers: int, chunk_tokens: int,
                  policy: str, hysteresis_tokens: int, tpot_slo_ms: float,
                  kv_layout: str = "auto", block_size: int = 16,
                  kv_blocks: int = 0, overlap_sampling: bool = True,
                  prefix_caching: bool = True, decode_enlarge_factor: int = 1,
                  keep_recent: int = 2048, seed: int = 0, prebuilt=None):
    """``prebuilt`` = (cfg, model, params) skips the model build — callers
    comparing several engine configs on one model (benchmarks) reuse it."""
    if prebuilt is not None:
        cfg, model, params = prebuilt
    else:
        cfg = get_config(arch + "-smoke" if not arch.endswith("-smoke")
                         else arch)
        model = build_model(cfg, ShardCtx.single(), ModelOptions())
        params = model.init(jax.random.key(0))
    ecfg = EngineConfig(pp_degree=pp, max_batch=max_batch,
                        max_seq_len=max_seq_len, n_samplers=n_samplers,
                        prefill_chunk_tokens=chunk_tokens or None,
                        scheduling_policy=policy,
                        phase_hysteresis_tokens=hysteresis_tokens or None,
                        tpot_slo_s=(tpot_slo_ms / 1e3) or None,
                        kv_layout=kv_layout, kv_block_size=block_size,
                        kv_blocks=kv_blocks or None,
                        overlap_sampling=overlap_sampling,
                        enable_prefix_caching=prefix_caching,
                        decode_enlarge_factor=decode_enlarge_factor,
                        keep_recent_requests=keep_recent, seed=seed)
    eng = (SiPipeEngine if engine == "sipipe" else NaivePPEngine)(
        model, params, ecfg)
    return cfg, eng


def run(arch: str, *, engine: str = "sipipe", pp: int = 2, requests: int = 8,
        max_batch: int = 4, max_new_tokens: int = 16, max_seq_len: int = 256,
        n_samplers: int = 2, chunk_tokens: int = 0, policy: str = "auto",
        hysteresis_tokens: int = 0, tpot_slo_ms: float = 0.0,
        kv_layout: str = "auto", block_size: int = 16,
        kv_blocks: int = 0, n_samples: int = 1,
        prefix_caching: bool = True, seed: int = 0,
        verbose: bool = True) -> dict:
    """Offline batch mode: enqueue every prompt, blocking run()."""
    cfg, eng = _build_engine(arch, engine=engine, pp=pp, max_batch=max_batch,
                             max_seq_len=max_seq_len, n_samplers=n_samplers,
                             chunk_tokens=chunk_tokens, policy=policy,
                             hysteresis_tokens=hysteresis_tokens,
                             tpot_slo_ms=tpot_slo_ms, kv_layout=kv_layout,
                             block_size=block_size, kv_blocks=kv_blocks,
                             prefix_caching=prefix_caching, seed=seed)
    wl = ShareGPTLike(cfg.vocab_size, n_requests=requests, seed=seed,
                      prompt_len_median=12, max_prompt=max_seq_len // 4,
                      output_len_median=max_new_tokens,
                      max_output=max_new_tokens)
    sp_base = SamplingParams(temperature=0.8, top_k=40, top_p=0.95,
                             frequency_penalty=0.2, presence_penalty=0.1)
    for prompt, budget in wl.requests():
        eng.add_request(prompt, SamplingParams(
            **{**sp_base.__dict__, "n": n_samples,
               "max_new_tokens": min(budget, max_new_tokens)}))
    done = eng.run()
    m = eng.metrics()
    m["engine"] = engine
    m["finished"] = len(done)
    if verbose:
        _print_metrics(m)
    return m


def run_online(arch: str, *, engine: str = "sipipe", pp: int = 2,
               requests: int = 8, max_batch: int = 4, max_new_tokens: int = 16,
               max_seq_len: int = 256, n_samplers: int = 2,
               chunk_tokens: int = 16, policy: str = "chunked",
               hysteresis_tokens: int = 0, tpot_slo_ms: float = 0.0,
               kv_layout: str = "auto", block_size: int = 16,
               kv_blocks: int = 0, overlap_sampling: bool = True,
               prefix_caching: bool = True, decode_enlarge_factor: int = 1,
               arrival_rate: float = 4.0, abort_every: int = 0,
               offline_requests: int = 0,
               seed: int = 0, verbose: bool = True, prebuilt=None) -> dict:
    """Online continuous serving: replay a Poisson arrival trace through
    the step-driven request API (``add_request``/``step``/``abort``),
    streaming tokens as they land and recording per-request
    TTFT/TPOT/queue-delay (docs/serving.md).

    ``abort_every`` > 0 cancels every Nth request after its first
    streamed token — the online smoke's abort-path coverage.

    ``offline_requests`` > 0 enqueues that many tier="offline" batch
    requests up front (docs/hybrid.md); they run only in scheduler
    slack and are accounted separately from the online trace.
    """
    cfg, eng = _build_engine(arch, engine=engine, pp=pp, max_batch=max_batch,
                             max_seq_len=max_seq_len, n_samplers=n_samplers,
                             chunk_tokens=chunk_tokens, policy=policy,
                             hysteresis_tokens=hysteresis_tokens,
                             tpot_slo_ms=tpot_slo_ms, kv_layout=kv_layout,
                             block_size=block_size, kv_blocks=kv_blocks,
                             overlap_sampling=overlap_sampling,
                             prefix_caching=prefix_caching,
                             decode_enlarge_factor=decode_enlarge_factor,
                             seed=seed, prebuilt=prebuilt)
    wl = ShareGPTLike(cfg.vocab_size, n_requests=requests, seed=seed,
                      prompt_len_median=12, max_prompt=max_seq_len // 4,
                      output_len_median=max_new_tokens,
                      max_output=max_new_tokens)
    sp_base = SamplingParams(temperature=0.8, top_k=40, top_p=0.95,
                             frequency_penalty=0.2, presence_penalty=0.1)
    offline_rids: set = set()
    if offline_requests:
        owl = ShareGPTLike(cfg.vocab_size, n_requests=offline_requests,
                           seed=seed + 7919, prompt_len_median=12,
                           max_prompt=max_seq_len // 4,
                           output_len_median=max_new_tokens,
                           max_output=max_new_tokens)
        for prompt, budget in owl.requests():
            offline_rids.add(eng.add_request(prompt, SamplingParams(
                **{**sp_base.__dict__, "tier": "offline",
                   "max_new_tokens": min(budget, max_new_tokens)})))
    trace = deque(wl.arrivals(arrival_rate))
    t0 = time.monotonic()
    n_submitted = n_finished = n_aborted = 0
    offline_finished = offline_tokens = 0
    abort_armed: set = set()
    streamed_tokens = 0
    while trace or eng.has_work:
        now = time.monotonic() - t0
        while trace and trace[0][0] <= now:
            t_arr, prompt, budget = trace.popleft()
            # backdate to the NOMINAL arrival: time spent queued outside
            # the engine (behind a blocking step) counts toward TTFT
            rid = eng.add_request(prompt, SamplingParams(
                **{**sp_base.__dict__,
                   "max_new_tokens": min(budget, max_new_tokens)}),
                arrival_t=t0 + t_arr)
            n_submitted += 1
            if abort_every and n_submitted % abort_every == 0:
                abort_armed.add(rid)
        outs = eng.step()
        for out in outs:
            if out.request_id in offline_rids:
                offline_tokens += len(out.new_token_ids)
                if out.finished:
                    offline_finished += 1
                continue
            streamed_tokens += len(out.new_token_ids)
            if out.finished:
                n_finished += out.state.name == "FINISHED"
                n_aborted += out.state.name == "ABORTED"
            elif out.request_id in abort_armed and out.token_ids:
                # mid-decode cancellation: the request already streamed
                # at least one token
                abort_armed.discard(out.request_id)
                eng.abort(out.request_id)
        if not outs and not eng.has_work and trace:
            # idle until the next arrival (bounded nap, wall-clock replay)
            time.sleep(min(0.002, max(0.0, trace[0][0] - now)))
    eng.shutdown()
    m = eng.metrics()
    m["engine"] = engine
    m["online"] = True
    m["arrival_rate_rps"] = arrival_rate
    m["finished"] = n_finished
    m["aborted"] = n_aborted
    m["streamed_tokens"] = streamed_tokens
    m["offline_submitted"] = len(offline_rids)
    m["offline_finished"] = offline_finished
    m["offline_streamed_tokens"] = offline_tokens
    # the accounting invariant covers the ONLINE trace only; offline
    # completions are asserted separately (the loop runs to empty, so
    # every offline request must have finished too)
    assert n_finished + n_aborted == n_submitted == requests, \
        (n_finished, n_aborted, n_submitted)
    assert offline_finished == len(offline_rids), \
        (offline_finished, len(offline_rids))
    if verbose:
        _print_metrics(m)
    return m


def build_http_server(arch: str, *, engine: str = "sipipe", replicas: int = 1,
                      pp: int = 2, max_batch: int = 4, max_seq_len: int = 128,
                      n_samplers: int = 2, chunk_tokens: int = 16,
                      policy: str = "auto", kv_layout: str = "auto",
                      block_size: int = 16, kv_blocks: int = 0,
                      max_queue: int = 64, max_active: int = 0,
                      decode_enlarge_factor: int = 1,
                      host: str = "127.0.0.1", port: int = 0,
                      seed: int = 0, prebuilt=None):
    """Build (but don't start) the HTTP front-end: one model, N engine
    replicas behind a least-loaded-KV router, admission control, and the
    OpenAI-style completions server (docs/http.md)."""
    from repro.serving import CompletionServer, EngineReplica, Router

    if prebuilt is None:
        cfg = get_config(arch + "-smoke" if not arch.endswith("-smoke")
                         else arch)
        model = build_model(cfg, ShardCtx.single(), ModelOptions())
        params = model.init(jax.random.key(0))
        prebuilt_full = (cfg, model, params)
    else:
        prebuilt_full = prebuilt
        cfg = prebuilt_full[0]
    reps = []
    for i in range(replicas):
        _, eng = _build_engine(arch, engine=engine, pp=pp,
                               max_batch=max_batch, max_seq_len=max_seq_len,
                               n_samplers=n_samplers,
                               chunk_tokens=chunk_tokens, policy=policy,
                               hysteresis_tokens=0, tpot_slo_ms=0.0,
                               kv_layout=kv_layout, block_size=block_size,
                               kv_blocks=kv_blocks,
                               decode_enlarge_factor=decode_enlarge_factor,
                               seed=seed, prebuilt=prebuilt_full)
        reps.append(EngineReplica(f"r{i}", eng))
    server = CompletionServer(Router(reps), vocab_size=cfg.vocab_size,
                              model_name=arch, max_queue=max_queue,
                              max_active=max_active or None,
                              host=host, port=port)
    return cfg, server


def run_http(arch: str, *, port: int = 8000, replicas: int = 1,
             smoke: bool = False, **kw) -> int:
    """Serve over HTTP until interrupted; ``smoke=True`` instead runs the
    in-process stdlib-client checks (streaming + 429 + /metrics) against
    a tiny-cap server and returns an exit code (the CI gate)."""
    if smoke:
        # the 429 case needs deterministically tiny caps: one active
        # stream holds the dispatch window, one ticket fills the queue
        kw["max_queue"], kw["max_active"] = 1, 1
        port = 0                       # ephemeral: parallel CI jobs
    _, server = build_http_server(arch, replicas=replicas, port=port, **kw)
    server.start()
    host, bound = server.address
    print(f"serving on http://{host}:{bound} "
          f"(replicas={replicas}, smoke={smoke})", flush=True)
    if smoke:
        try:
            _http_smoke(host, bound)
            print("HTTP smoke OK", flush=True)
            return 0
        except Exception as e:     # noqa: BLE001 — exit-code gate
            import traceback
            traceback.print_exc()
            print(f"HTTP smoke FAILED: {e}", flush=True)
            return 1
        finally:
            server.close()
    try:
        while True:
            time.sleep(1.0)
    except KeyboardInterrupt:
        pass
    finally:
        server.close()
    return 0


def _http_smoke(host: str, port: int):
    """Stdlib-client smoke against a live server with max_active=1,
    max_queue=1: (1) a streamed greedy completion produces SSE chunks and
    [DONE]; (2) with the single active slot held by a live stream, an
    offline /v1/batches submission still completes (it bypasses the
    online window — docs/hybrid.md), and with the queue full a further
    online request gets 429 + Retry-After while the held stream keeps
    producing; (3) /metrics scrapes as Prometheus text."""
    import http.client

    def post(body, extra_headers=None):
        c = http.client.HTTPConnection(host, port, timeout=120)
        c.request("POST", "/v1/completions", json.dumps(body),
                  {"Content-Type": "application/json",
                   **(extra_headers or {})})
        return c, c.getresponse()

    # 1) plain streamed completion end-to-end
    c, r = post({"prompt": [5, 9, 13], "max_tokens": 4,
                 "temperature": 0.0, "stream": True})
    assert r.status == 200, r.status
    events = _read_sse(r)
    assert events and events[-1] == "[DONE]", events[-2:]
    toks = []
    for ev in events[:-1]:
        toks += json.loads(ev)["choices"][0]["token_ids"]
    assert len(toks) == 4, toks
    c.close()

    # 2) hold the active slot with a long stream, fill the queue, expect
    #    429 on the next arrival — while the held stream stays live
    hold_c, hold_r = post({"prompt": [2, 3], "max_tokens": 48,
                           "temperature": 0.0, "stream": True})
    assert hold_r.status == 200
    first = _read_sse(hold_r, max_events=1)    # it is actively decoding
    assert first and first[0] != "[DONE]"

    # 2a) hybrid tier (docs/hybrid.md): with max_active=1 HELD by the
    #     live stream, an offline batch must still go through — offline
    #     bypasses the online dispatch window and runs in engine slack
    cb = http.client.HTTPConnection(host, port, timeout=120)
    cb.request("POST", "/v1/batches", json.dumps({
        "requests": [{"prompt": [7, 8, 9], "max_tokens": 3,
                      "temperature": 0.0}]}),
               {"Content-Type": "application/json"})
    rb = cb.getresponse()
    assert rb.status == 200, rb.status
    batch = json.loads(rb.read())
    cb.close()
    assert batch["object"] == "batch", batch
    assert len(batch["results"]) == 1
    assert len(batch["results"][0]["choices"][0]["token_ids"]) == 3, batch

    import threading as _t
    queued_done = _t.Event()

    def queued():
        c2, r2 = post({"prompt": [4, 5], "max_tokens": 2,
                       "temperature": 0.0, "stream": True})
        _read_sse(r2)
        c2.close()
        queued_done.set()

    qt = _t.Thread(target=queued, daemon=True)
    qt.start()
    deadline = time.monotonic() + 30
    while time.monotonic() < deadline:   # wait until it occupies the queue
        c3 = http.client.HTTPConnection(host, port, timeout=30)
        c3.request("GET", "/metrics")
        pending = [ln for ln in c3.getresponse().read().decode().splitlines()
                   if ln.startswith("repro_admission_pending")]
        c3.close()
        if pending and pending[0].endswith(" 1"):
            break
        time.sleep(0.05)
    c4, r4 = post({"prompt": [6], "max_tokens": 2, "stream": False})
    assert r4.status == 429, r4.status
    assert r4.getheader("Retry-After"), "429 must carry Retry-After"
    c4.close()
    rest = _read_sse(hold_r)                  # held stream was not perturbed
    assert rest and rest[-1] == "[DONE]"
    hold_c.close()
    assert queued_done.wait(60), "queued request never completed"
    qt.join(5)

    # 3) Prometheus scrape
    c5 = http.client.HTTPConnection(host, port, timeout=30)
    c5.request("GET", "/metrics")
    r5 = c5.getresponse()
    assert r5.status == 200
    text = r5.read().decode()
    c5.close()
    assert 'repro_requests_finished{replica="r0"}' in text, text[:400]
    assert "repro_admission_rejected_total 1" in text, text[:400]
    assert "repro_admission_offline_admitted_total 1" in text, text[:400]
    assert 'repro_slack_tokens_sold{replica="r0"}' in text, text[:400]


def _read_sse(resp, max_events: int = 0):
    """Read SSE ``data:`` payloads off an http.client response (until
    [DONE]/EOF, or the first ``max_events`` if set)."""
    events = []
    while True:
        line = resp.fp.readline()
        if not line:
            return events
        line = line.decode().strip()
        if not line.startswith("data: "):
            continue
        events.append(line[len("data: "):])
        if events[-1] == "[DONE]" or (max_events and
                                      len(events) >= max_events):
            return events


def _print_metrics(m: dict):
    print(json.dumps({k: v for k, v in m.items()
                      if k not in ("stages", "requests")},
                     indent=1, default=float))
    for i, st in enumerate(m["stages"]):
        print(f"  stage{i}: busy={st['busy_s']:.2f}s "
              f"prep={st['prep_s']:.2f}s bubble={st['bubble_frac']:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--engine", default="sipipe", choices=["sipipe", "naive"])
    ap.add_argument("--pp", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--samplers", type=int, default=2)
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="per-iteration token budget for span scheduling "
                         "policies (0 = monolithic whole-prompt prefill)")
    ap.add_argument("--policy", default="auto", choices=POLICY_CHOICES,
                    help="scheduling policy; 'auto' maps a token budget to "
                         "chunked and no budget to monolithic "
                         "(docs/scheduling.md §Scheduling policies)")
    ap.add_argument("--hysteresis-tokens", type=int, default=0,
                    help="disaggregated decode->prefill switch threshold in "
                         "pending prefill tokens per paused decode slot "
                         "(0 = the token budget)")
    ap.add_argument("--tpot-slo-ms", type=float, default=0.0,
                    help="adaptive policy: target mean inter-token latency "
                         "in ms (0 = self-calibrate from the first window); "
                         "disaggregated policy: prefill-phase length cap")
    ap.add_argument("--kv-layout", default="auto",
                    choices=["auto", "contiguous", "paged"],
                    help="KV memory substrate: 'paged' = block tables with "
                         "budget admission + preemption, attention through "
                         "the table (docs/memory.md); 'contiguous' = dense "
                         "per-sequence rows (the escape hatch); 'auto' "
                         "(default) = paged where the family supports it")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged layout: KV slots per physical block")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="paged layout: total physical blocks (0 = the "
                         "slot budget contiguous rows would reserve)")
    ap.add_argument("--no-prefix-caching", action="store_true",
                    help="disable hash-based prompt-prefix block sharing "
                         "(paged layout; docs/memory.md)")
    ap.add_argument("-n", "--n-samples", type=int, default=1,
                    help="parallel sampling: completions per request "
                         "(n > 1 CoW-forks the prompt KV; paged layout, "
                         "offline mode)")
    ap.add_argument("--online", action="store_true",
                    help="continuous serving: Poisson arrivals replayed "
                         "through the step-driven request API "
                         "(docs/serving.md)")
    ap.add_argument("--http", action="store_true",
                    help="serve the OpenAI-style HTTP completions API "
                         "over N engine replicas (docs/http.md)")
    ap.add_argument("--port", type=int, default=8000,
                    help="HTTP mode: listen port (0 = ephemeral)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="HTTP mode: in-process engine replicas behind "
                         "the least-loaded-KV router")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="HTTP mode: admission queue cap (full = 429)")
    ap.add_argument("--max-active", type=int, default=0,
                    help="HTTP mode: dispatched-request window "
                         "(0 = unbounded)")
    ap.add_argument("--smoke", action="store_true",
                    help="HTTP mode: run the stdlib-client smoke checks "
                         "(streaming + 429 + /metrics) and exit with a "
                         "status code — the CI gate")
    ap.add_argument("--arrival-rate", type=float, default=4.0,
                    help="online mode: Poisson arrival rate (requests/s)")
    ap.add_argument("--abort-every", type=int, default=0,
                    help="online mode: abort every Nth request after its "
                         "first streamed token (0 = never)")
    ap.add_argument("--offline-requests", type=int, default=0,
                    help="online mode: tier='offline' batch requests "
                         "enqueued up front, served only in scheduler "
                         "slack (docs/hybrid.md; paged layout)")
    ap.add_argument("--decode-enlarge-factor", type=int, default=1,
                    help="disaggregated policy: decode-phase batch "
                         "enlargement cap for offline work, pow2 rungs "
                         "up to max_batch * factor (docs/hybrid.md)")
    args = ap.parse_args()
    common = dict(engine=args.engine, pp=args.pp, requests=args.requests,
                  max_batch=args.max_batch, max_new_tokens=args.max_new_tokens,
                  n_samplers=args.samplers, chunk_tokens=args.chunk_tokens,
                  policy=args.policy, hysteresis_tokens=args.hysteresis_tokens,
                  tpot_slo_ms=args.tpot_slo_ms, kv_layout=args.kv_layout,
                  block_size=args.block_size, kv_blocks=args.kv_blocks,
                  prefix_caching=not args.no_prefix_caching,
                  decode_enlarge_factor=args.decode_enlarge_factor)
    if args.http:
        raise SystemExit(run_http(
            args.arch, port=args.port, replicas=args.replicas,
            smoke=args.smoke, engine=args.engine, pp=args.pp,
            max_batch=args.max_batch, n_samplers=args.samplers,
            chunk_tokens=args.chunk_tokens, policy=args.policy,
            kv_layout=args.kv_layout, block_size=args.block_size,
            kv_blocks=args.kv_blocks, max_queue=args.max_queue,
            max_active=args.max_active))
    if args.online:
        run_online(args.arch, arrival_rate=args.arrival_rate,
                   abort_every=args.abort_every,
                   offline_requests=args.offline_requests, **common)
    else:
        common.pop("decode_enlarge_factor", None)
        run(args.arch, n_samples=args.n_samples, **common)


if __name__ == "__main__":
    main()
