"""xLSTM family: mLSTM (matrix memory) + sLSTM (scalar memory) blocks.

mLSTM uses a *chunkwise-parallel* form (log-space exp-gating with running
stabilizer, GLA-style): intra-chunk work is attention-like [T, T] matmuls,
inter-chunk state flows through a lax.scan over chunks — this is what makes
4k-token training feasible (a naive per-token scan would checkpoint a
[B, H, dk, dv] state per step).  Decode is a single fused recurrence step.

sLSTM is inherently sequential (its gates read h_{t-1} through recurrent
block-diagonal weights), so it scans per token; only 1 in 8 blocks is
sLSTM, matching the paper's mostly-mLSTM [7:1] configuration.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamSpec, rmsnorm
from repro.models.stacked import Ctx, Stack

CHUNK = 128


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    h = cfg.num_heads
    return {
        "ln": ParamSpec((d,), ("embed",), "ones"),
        "wu": ParamSpec((d, 2 * d), ("embed", "rnn")),       # (cell input, z gate)
        "wq": ParamSpec((d, d), ("embed", "rnn")),
        "wk": ParamSpec((d, d), ("embed", "rnn")),
        "wv": ParamSpec((d, d), ("embed", "rnn")),
        "wif": ParamSpec((d, 2 * h), ("embed", None), "small"),  # per-head i,f
        "wog": ParamSpec((d, d), ("embed", "rnn"), "small"),     # output gate
        "wd": ParamSpec((d, d), ("rnn", "embed")),               # down proj
        "gn": ParamSpec((d,), ("rnn",), "ones"),                 # per-head norm
    }


def _mlstm_qkvif(p, xm, cfg: ArchConfig):
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    lead = xm.shape[:-1]
    q = (xm @ p["wq"]).reshape(*lead, h, hd)
    k = ((xm @ p["wk"]) * (hd ** -0.5)).reshape(*lead, h, hd)
    v = (xm @ p["wv"]).reshape(*lead, h, hd)
    gif = (xm @ p["wif"]).astype(jnp.float32).reshape(*lead, 2, h)
    i_raw, f_raw = gif[..., 0, :], gif[..., 1, :]
    return q, k, v, i_raw, jax.nn.log_sigmoid(f_raw)


def _mlstm_chunk(q, k, v, i_raw, f_log, carry):
    """One chunk, batched over [B, H].  q/k/v [B,T,H,hd]; gates [B,T,H].

    carry = (C [B,H,dk,dv], n [B,H,dk], m [B,H]) — stabilized state."""
    C, n, m = carry
    b, t, h, hd = q.shape
    F = jnp.cumsum(f_log, axis=1)                        # [B,T,H]
    g = i_raw - F                                        # log i_j - F_j
    M = jax.lax.cummax(g, axis=1)                        # running max
    m_i = F + jnp.maximum(m[:, None], M)                 # [B,T,H]

    # intra-chunk: D_ij = exp(F_i - F_j + i_j - m_i), j <= i
    logD = F[:, :, None] - F[:, None, :] + i_raw[:, None, :] - m_i[:, :, None]
    causal = jnp.tril(jnp.ones((t, t), bool))
    D = jnp.where(causal[None, :, :, None], jnp.exp(logD), 0.0)  # [B,Ti,Tj,H]
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    s_att = jnp.einsum("bihd,bjhd->bijh", qf, kf) * D
    h_intra = jnp.einsum("bijh,bjhd->bihd", s_att, vf)
    n_intra = jnp.einsum("bijh,bjhd->bihd", D, kf)

    # inter-chunk
    scale_i = jnp.exp(F + m[:, None] - m_i)              # [B,T,H]
    h_inter = jnp.einsum("bihd,bhde->bihe", qf, C) * scale_i[..., None]
    n_inter = n[:, None] * scale_i[..., None]
    n_i = n_intra + n_inter

    denom = jnp.maximum(jnp.abs(jnp.einsum("bihd,bihd->bih", qf, n_i)),
                        jnp.exp(-m_i))
    h_out = (h_intra + h_inter) / denom[..., None]

    # carry update at chunk end
    F_T = F[:, -1]                                       # [B,H]
    m_new = F_T + jnp.maximum(m, M[:, -1])
    w_j = jnp.exp(F_T[:, None] - F + i_raw - m_new[:, None])  # [B,T,H]
    C_new = C * jnp.exp(F_T + m - m_new)[..., None, None] + jnp.einsum(
        "bthd,bthe,bth->bhde", kf, vf, w_j
    )
    n_new = n * jnp.exp(F_T + m - m_new)[..., None] + jnp.einsum(
        "bthd,bth->bhd", kf, w_j
    )
    return (C_new, n_new, m_new), h_out


def mlstm_block(p, x, ctx: Ctx, cache, cfg: ArchConfig):
    h_heads, hd = cfg.num_heads, cfg.resolved_head_dim
    d = cfg.d_model
    shard = ctx.shard

    if ctx.mode == "decode":
        hx = rmsnorm(x, p["ln"], cfg.norm_eps)           # [B, d]
        u = hx @ p["wu"]
        xm, zg = u[:, :d], u[:, d:]
        q, k, v, i_raw, f_log = _mlstm_qkvif(p, xm, cfg)
        C, n, m = cache["C"], cache["n"], cache["m"]
        m_new = jnp.maximum(f_log + m, i_raw)
        fs = jnp.exp(f_log + m - m_new)
        is_ = jnp.exp(i_raw - m_new)
        kf, vf, qf = (a.astype(jnp.float32) for a in (k, v, q))
        C = C * fs[..., None, None] + is_[..., None, None] * jnp.einsum(
            "bhd,bhe->bhde", kf, vf
        )
        n = n * fs[..., None] + is_[..., None] * kf
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                            jnp.exp(-m_new))
        hv = jnp.einsum("bhd,bhde->bhe", qf, C) / denom[..., None]
        y = _mlstm_out(p, hv.reshape(-1, d), zg, xm, cfg)
        return x + y, {"C": C, "n": n, "m": m_new}

    b, s, _ = x.shape
    hx = rmsnorm(x, p["ln"], cfg.norm_eps)
    u = hx @ p["wu"]
    xm, zg = u[..., :d], u[..., d:]
    q, k, v, i_raw, f_log = _mlstm_qkvif(p, xm, cfg)

    t = min(CHUNK, s)
    while s % t:
        t //= 2
    nc = s // t
    split = lambda a: a.reshape(b, nc, t, *a.shape[2:]).swapaxes(0, 1)
    c0 = (
        jnp.zeros((b, h_heads, hd, hd), jnp.float32),
        jnp.zeros((b, h_heads, hd), jnp.float32),
        jnp.full((b, h_heads), -1e30, jnp.float32),
    )

    def body(carry, inp):
        qc, kc, vc, ic, fc = inp
        return _mlstm_chunk(qc, kc, vc, ic, fc, carry)

    carry, h_chunks = jax.lax.scan(
        body, c0, (split(q), split(k), split(v), split(i_raw), split(f_log))
    )
    hv = h_chunks.swapaxes(0, 1).reshape(b, s, h_heads, hd).reshape(b, s, d)
    y = _mlstm_out(p, hv, zg, xm, cfg)
    x = x + y
    new_cache = None
    if ctx.mode == "prefill":
        new_cache = {"C": carry[0], "n": carry[1], "m": carry[2]}
    return x, new_cache


def _mlstm_out(p, hv, zg, xm, cfg: ArchConfig):
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    shape = hv.shape
    hn = rmsnorm(hv.reshape(*shape[:-1], h, hd),
                 p["gn"].reshape(h, hd), cfg.norm_eps).reshape(shape)
    og = jax.nn.sigmoid((xm @ p["wog"]).astype(jnp.float32)).astype(zg.dtype)
    out = (hn.astype(zg.dtype) * og * jax.nn.silu(zg)) @ p["wd"]
    return out


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    ffs = int(round(4 * d / 3 / 8)) * 8
    sp = {"ln": ParamSpec((d,), ("embed",), "ones")}
    for gname in ("z", "i", "f", "o"):
        sp[f"w{gname}"] = ParamSpec((d, d), ("embed", "rnn"))
        sp[f"r{gname}"] = ParamSpec((h, hd, hd), (None, "rnn", None), "small")
        sp[f"b{gname}"] = ParamSpec((d,), ("rnn",), "zeros", jnp.float32)
    sp.update(
        gn=ParamSpec((d,), ("rnn",), "ones"),
        w1=ParamSpec((d, ffs), ("embed", "ff")),
        w3=ParamSpec((d, ffs), ("embed", "ff")),
        w2=ParamSpec((ffs, d), ("ff", "embed"), fan_in=ffs),
    )
    return sp


def _slstm_step(p, xz, xi, xf, xo, carry, cfg: ArchConfig):
    """One token.  x* [B, H, hd] fp32 pre-activations; carry h,c,n,m fp32."""
    hprev, c, n, m = carry
    rec = lambda g: jnp.einsum("bhd,hde->bhe", hprev, p[f"r{g}"].astype(jnp.float32))
    z = jnp.tanh(xz + rec("z"))
    i_raw = xi + rec("i")
    f_log = jax.nn.log_sigmoid(xf + rec("f"))
    o = jax.nn.sigmoid(xo + rec("o"))
    m_new = jnp.maximum(f_log + m, i_raw)
    fs, is_ = jnp.exp(f_log + m - m_new), jnp.exp(i_raw - m_new)
    c = fs * c + is_ * z
    n = fs * n + is_
    h_new = o * (c / jnp.maximum(n, jnp.exp(-m_new)))
    return (h_new, c, n, m_new), h_new


def slstm_block(p, x, ctx: Ctx, cache, cfg: ArchConfig):
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    heads = lambda a: a.astype(jnp.float32).reshape(*a.shape[:-1], h, hd)

    if ctx.mode == "decode":
        hx = rmsnorm(x, p["ln"], cfg.norm_eps)
        pre = {g: heads(hx @ p[f"w{g}"] + p[f"b{g}"].astype(x.dtype)) for g in "zifo"}
        carry = (cache["h"], cache["c"], cache["n"], cache["m"])
        carry, hnew = _slstm_step(p, pre["z"], pre["i"], pre["f"], pre["o"], carry, cfg)
        y = _slstm_out(p, hnew[:, None], x[:, None, :], cfg)[:, 0]
        return x + y, {"h": carry[0], "c": carry[1], "n": carry[2], "m": carry[3]}

    b, s, _ = x.shape
    hx = rmsnorm(x, p["ln"], cfg.norm_eps)
    pre = {g: heads(hx @ p[f"w{g}"] + p[f"b{g}"].astype(x.dtype)) for g in "zifo"}
    c0 = tuple(jnp.zeros((b, h, hd), jnp.float32) for _ in range(3)) + (
        jnp.full((b, h, hd), -1e30, jnp.float32),
    )
    c0 = (c0[0], c0[1], c0[2], c0[3])

    def body(carry, inp):
        xz, xi, xf, xo = inp
        return _slstm_step(p, xz, xi, xf, xo, carry, cfg)

    xs = tuple(pre[g].swapaxes(0, 1) for g in "zifo")
    carry, hseq = jax.lax.scan(body, c0, xs)
    hseq = hseq.swapaxes(0, 1)                        # [B,S,H,hd]
    y = _slstm_out(p, hseq, x, cfg)
    x = x + y
    new_cache = None
    if ctx.mode == "prefill":
        new_cache = {"h": carry[0], "c": carry[1], "n": carry[2], "m": carry[3]}
    return x, new_cache


def _slstm_out(p, hseq, x, cfg: ArchConfig):
    h, hd, d = cfg.num_heads, cfg.resolved_head_dim, cfg.d_model
    hn = rmsnorm(hseq, p["gn"].reshape(h, hd), cfg.norm_eps)
    hn = hn.reshape(*hseq.shape[:-2], d).astype(x.dtype)
    a = jax.nn.gelu(hn @ p["w1"]) * (hn @ p["w3"])
    return a @ p["w2"]


# ---------------------------------------------------------------------------
# Stack
# ---------------------------------------------------------------------------

def xlstm_stack(cfg: ArchConfig, tp: int) -> Stack:
    """Groups of (1 sLSTM + (group-1) mLSTM), scanned L/group times."""
    group = cfg.xlstm_group or 4
    n_s = cfg.xlstm_slstm_per_group
    n_m = group - n_s
    n = cfg.num_layers // group
    group_specs = {"slstm": slstm_specs(cfg) if n_s else None,
                   "mlstm": mlstm_specs(cfg)}
    # stack the m-lstm sub-layers for an inner mini-scan
    from repro.models.stacked import Stack as _S, stack_specs as _ss

    inner = _S("m", n_m, group_specs["mlstm"], None)
    group_specs = {"mlstm": _ss(inner)}
    if n_s:
        group_specs["slstm"] = slstm_specs(cfg)

    def apply(gp, x, ctx: Ctx, cache_g):
        new_caches = {}
        if n_s:
            c = cache_g["slstm"] if cache_g is not None else None
            x, nc = slstm_block(gp["slstm"], x, ctx, c, cfg)
            if nc is not None:
                new_caches["slstm"] = nc

        if ctx.mode == "decode":
            def mbody(xc, inp):
                mp, mc = inp
                return mlstm_block(mp, xc, ctx, mc, cfg)

            x, mcache = jax.lax.scan(mbody, x, (gp["mlstm"], cache_g["mlstm"]))
            new_caches["mlstm"] = mcache
        else:
            def mbody(xc, mp):
                return mlstm_block(mp, xc, ctx, None, cfg)

            x, mcache = jax.lax.scan(mbody, x, gp["mlstm"])
            if ctx.mode == "prefill":
                new_caches["mlstm"] = mcache
        return x, (new_caches or None)

    h, hd = cfg.num_heads, cfg.resolved_head_dim

    def cache_spec(batch, cache_len):
        d = {
            "mlstm": {
                "C": jax.ShapeDtypeStruct((n_m, batch, h, hd, hd), jnp.float32),
                "n": jax.ShapeDtypeStruct((n_m, batch, h, hd), jnp.float32),
                "m": jax.ShapeDtypeStruct((n_m, batch, h), jnp.float32),
            }
        }
        if n_s:
            sd = jax.ShapeDtypeStruct((batch, h, hd), jnp.float32)
            d["slstm"] = {"h": sd, "c": sd, "n": sd, "m": sd}
        return d

    def cache_axes():
        d = {
            "mlstm": {
                "C": (None, "batch", None, "rnn", None),
                "n": (None, "batch", None, "rnn"),
                "m": (None, "batch", None),
            }
        }
        if n_s:
            a = ("batch", None, "rnn")
            d["slstm"] = {"h": a, "c": a, "n": a, "m": a}
        return d

    return Stack("xlstm", n, group_specs, apply, cache_spec, cache_axes)
