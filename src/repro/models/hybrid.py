"""RecurrentGemma / Griffin hybrid family: RG-LRU temporal blocks + local
attention, in repeating (rglru, rglru, attn) superblocks, each mixing block
followed by a gated-GeLU MLP residual.

RG-LRU recurrence (fp32):  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)
  a_t = exp(-c * softplus(Lambda) * r_t),  r/i = sigmoid(diag-gates(u_t))
Prefill uses an associative scan (O(log S) depth); decode is a single step.
Gates are diagonal (per-channel), keeping the parameter budget at ~9B.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamSpec, rmsnorm
from repro.models.stacked import Ctx, Stack
from repro.models.transformer import (
    attn_specs,
    mlp_specs,
    self_attn_block,
    _self_cache_spec,
    _self_cache_axes,
)

RG_C = 8.0
CONV_W = 4


def rglru_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    dr = cfg.d_model  # lru width == d_model (recurrentgemma-9b)
    return {
        "ln": ParamSpec((d,), ("embed",), "ones"),
        "wx": ParamSpec((d, dr), ("embed", "rnn")),
        "wg": ParamSpec((d, dr), ("embed", "rnn")),
        "conv_w": ParamSpec((CONV_W, dr), (None, "rnn"), "small"),
        "conv_b": ParamSpec((dr,), ("rnn",), "zeros"),
        "lam": ParamSpec((dr,), ("rnn",), "ones", jnp.float32),
        "wa": ParamSpec((dr,), ("rnn",), "small", jnp.float32),
        "ba": ParamSpec((dr,), ("rnn",), "zeros", jnp.float32),
        "wi": ParamSpec((dr,), ("rnn",), "small", jnp.float32),
        "bi": ParamSpec((dr,), ("rnn",), "zeros", jnp.float32),
        "wout": ParamSpec((dr, d), ("rnn", "embed"), fan_in=dr),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array, tail=None):
    """Depthwise causal conv width 4 over [B, S, dr] via shifted adds.

    ``tail`` [B, CONV_W-1, dr] supplies state for decode/continuation."""
    if u.ndim == 2:  # decode: u [B, dr], tail [B,3,dr]
        hist = jnp.concatenate([tail, u[:, None, :]], 1)  # [B, 4, dr]
        y = jnp.einsum("btd,td->bd", hist, w) + b
        return y, hist[:, 1:]
    pad = jnp.zeros((u.shape[0], CONV_W - 1, u.shape[2]), u.dtype) if tail is None else tail
    up = jnp.concatenate([pad, u], 1)
    y = sum(up[:, i : i + u.shape[1]] * w[i] for i in range(CONV_W)) + b
    return y, up[:, -(CONV_W - 1) :]


def _rglru_gates(p, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(p["wa"] * uf + p["ba"])
    i = jax.nn.sigmoid(p["wi"] * uf + p["bi"])
    log_a = -RG_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12))
    return a, beta * (i * uf)


def rglru_block(p, x, ctx: Ctx, cache, cfg: ArchConfig):
    """cache = {"h": [B, dr] fp32, "conv": [B, 3, dr]} or None (train)."""
    shard = ctx.shard
    if ctx.mode == "decode":
        h = rmsnorm(x, p["ln"], cfg.norm_eps)
        u = h @ p["wx"]
        gate = jax.nn.gelu(h @ p["wg"])
        u, conv_tail = _causal_conv(u, p["conv_w"], p["conv_b"], cache["conv"])
        a, w_in = _rglru_gates(p, u)
        state = a * cache["h"] + w_in
        y = (state.astype(x.dtype) * gate) @ p["wout"]
        return x + y, {"h": state, "conv": conv_tail}

    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    u = h @ p["wx"]                       # [B, S, dr]
    gate = jax.nn.gelu(h @ p["wg"])
    u, conv_tail = _causal_conv(u, p["conv_w"], p["conv_b"])
    a, w_in = _rglru_gates(p, u)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, states = jax.lax.associative_scan(combine, (a, w_in), axis=1)
    y = (states.astype(x.dtype) * gate) @ p["wout"]
    x = x + y
    new_cache = None
    if ctx.mode == "prefill":
        new_cache = {"h": states[:, -1], "conv": conv_tail}
    return x, new_cache


def hybrid_stack(cfg: ArchConfig, tp: int) -> Stack:
    """(rglru, rglru, attn) superblocks; each mixing block + its MLP."""
    pattern = cfg.block_pattern
    n = (cfg.num_layers - len(cfg.tail_pattern)) // len(pattern)
    group_specs: Dict[str, Any] = {}
    for i, kind in enumerate(pattern):
        mix = rglru_specs(cfg) if kind == "rglru" else attn_specs(cfg, tp)
        group_specs[f"l{i}"] = {"mix": mix, "ffn": mlp_specs(cfg, tp)}

    def gelu_mlp(p, x, shard):
        h = rmsnorm(x, p["ln"], cfg.norm_eps)
        a = jax.nn.gelu(h @ p["w1"]) * (h @ p["w3"])
        return x + a @ p["w2"]

    def apply(gp, x, ctx: Ctx, cache_g):
        new_caches = {}
        for i, kind in enumerate(pattern):
            p = gp[f"l{i}"]
            c = cache_g[f"l{i}"] if cache_g is not None else None
            if kind == "rglru":
                x, nc = rglru_block(p["mix"], x, ctx, c, cfg)
            else:
                x, nc = self_attn_block(p["mix"], x, ctx, c, cfg)
            if nc is not None:
                new_caches[f"l{i}"] = nc
            x = gelu_mlp(p["ffn"], x, ctx.shard)
        return x, (new_caches or None)

    attn_cspec = _self_cache_spec(cfg, tp)
    dr = cfg.d_model

    def cache_spec(batch, cache_len):
        d = {}
        for i, kind in enumerate(pattern):
            if kind == "rglru":
                d[f"l{i}"] = {
                    "h": jax.ShapeDtypeStruct((batch, dr), jnp.float32),
                    "conv": jax.ShapeDtypeStruct((batch, CONV_W - 1, dr), jnp.bfloat16),
                }
            else:
                d[f"l{i}"] = attn_cspec(batch, cache_len)
        return d

    attn_caxes = _self_cache_axes(cfg, tp)

    def cache_axes():
        d = {}
        for i, kind in enumerate(pattern):
            if kind == "rglru":
                d[f"l{i}"] = {"h": ("batch", "rnn"), "conv": ("batch", None, "rnn")}
            else:
                d[f"l{i}"] = attn_caxes()
        return d

    return Stack("hybrid", n, group_specs, apply, cache_spec, cache_axes)


def hybrid_tail_stack(cfg: ArchConfig, tp: int) -> Stack:
    """Trailing rglru layers (38 = 12*3 + 2)."""
    sub = ArchConfig(**{**cfg.__dict__, "block_pattern": cfg.tail_pattern,
                        "tail_pattern": (), "num_layers": len(cfg.tail_pattern)})
    st = hybrid_stack(sub, tp)
    st.name = "hybrid_tail"
    st.n = 1
    return st
