"""Sparse Mixture-of-Experts FFN with sort-based dispatch.

Design notes (TPU adaptation, see DESIGN.md):
  * Dispatch uses argsort + gather/scatter-add — NOT the one-hot einsum
    formulation — so compiled FLOPs stay proportional to *active* experts
    (roofline ratio MODEL_FLOPS/HLO_FLOPs stays ~1) and no [T, E, C]
    dispatch tensor is ever materialized.
  * Expert parallelism runs under shard_map: activations are replicated
    along the "model" mesh axis (they are batch-sharded along data axes),
    so every model-rank routes identically, computes its *local* experts,
    and a single psum combines — collective volume equals one TP
    all-reduce, with no all-to-all required.
  * When num_experts %% tp != 0 (mixtral: 8 experts, tp=16) expert weights
    are replicated and their FFN dim is tensor-sharded instead; the same
    psum then combines partial ff products.  Both variants share this code.
  * Under the pipeline ("pp") strategy the surrounding stage is already a
    shard_map region, so the plain-jnp path runs and GSPMD auto-partitions
    it (decode activations are tiny there).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # canonical location moved across jax versions
    from jax import shard_map as _shard_map_mod  # type: ignore

    shard_map = _shard_map_mod  # jax>=0.7 exposes jax.shard_map directly
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # type: ignore

from repro.configs.base import MoEConfig
from repro.models.common import ParamSpec, ShardCtx


def moe_specs(d_model: int, moe: MoEConfig, tp: int) -> dict:
    e, ff = moe.num_experts, (moe.expert_d_ff or 0)
    assert ff > 0
    ep = e % tp == 0  # expert-parallel vs. ff-tensor-parallel
    ax_e = "experts" if ep else None
    ax_ff = None if ep else "expert_ff"
    return {
        "router": ParamSpec((d_model, e), ("embed", None), "small"),
        "w1": ParamSpec((e, d_model, ff), (ax_e, "embed", ax_ff)),
        "w3": ParamSpec((e, d_model, ff), (ax_e, "embed", ax_ff)),
        "w2": ParamSpec((e, ff, d_model), (ax_e, ax_ff, "embed"), fan_in=ff),
    }


def _capacity(tokens: int, moe: MoEConfig) -> int:
    c = int(math.ceil(tokens * moe.top_k * moe.capacity_factor / moe.num_experts))
    return max(8, int(math.ceil(c / 8)) * 8) if tokens >= 64 else max(c, 4)


def _moe_local(x2d, params, moe: MoEConfig, *, axis_name: Optional[str],
               n_local: int, shared: Optional[dict] = None):
    """Per-device MoE over local tokens x2d [T, d].

    ``n_local`` = experts computed on this device (== num_experts unless
    expert-parallel under shard_map).  ``shared`` (optional, §Perf B1):
    llama4-style shared-expert weights with the ff dim model-sharded; its
    partial product folds into the SAME psum as the routed experts,
    saving one activation all-reduce per MoE layer (fwd and bwd).
    """
    t, d = x2d.shape
    e, k = moe.num_experts, moe.top_k
    cap = _capacity(t, moe)
    ep_sharded = axis_name is not None and n_local < e

    logits = (x2d @ params["router"]).astype(jnp.float32)  # [T, E]
    gate_vals, ids = jax.lax.top_k(logits, k)              # [T, k]
    gates = jax.nn.softmax(gate_vals, axis=-1)             # renormalized over selected

    expert_flat = ids.reshape(-1)                          # [T*k], token-major
    gate_flat = gates.reshape(-1)
    token_flat = jnp.arange(t * k) // k

    order = jnp.argsort(expert_flat)                       # stable
    se = expert_flat[order]
    st = token_flat[order]
    sg = gate_flat[order]
    starts = jnp.searchsorted(se, jnp.arange(e))
    pos = jnp.arange(t * k) - starts[se]                   # slot within expert

    e_lo = jax.lax.axis_index(axis_name) * n_local if ep_sharded else 0
    local = (se >= e_lo) & (se < e_lo + n_local) & (pos < cap)
    dest = jnp.where(local, (se - e_lo) * cap + pos, n_local * cap)  # dump row

    xb = jnp.zeros((n_local * cap + 1, d), x2d.dtype).at[dest].add(x2d[st])
    h = xb[: n_local * cap].reshape(n_local, cap, d)

    a = jnp.einsum("ecd,edf->ecf", h, params["w1"])
    b = jnp.einsum("ecd,edf->ecf", h, params["w3"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(a) * b, params["w2"])  # [E_loc,C,d]

    y_flat = jnp.concatenate([y.reshape(n_local * cap, d), jnp.zeros((1, d), y.dtype)], 0)
    contrib = y_flat[dest] * sg[:, None].astype(y.dtype)
    out = jnp.zeros((t, d), y.dtype).at[st].add(jnp.where(local[:, None], contrib, 0))
    if shared is not None:  # partial over the local ff shard
        a = jax.nn.silu(x2d @ shared["w1"]) * (x2d @ shared["w3"])
        out = out + (a @ shared["w2"]).astype(out.dtype)
    if axis_name is not None:
        out = jax.lax.psum(out, axis_name)
    return out.astype(x2d.dtype)


def moe_ffn(x: jax.Array, params: dict, moe: MoEConfig, shard: ShardCtx,
            shared: Optional[dict] = None) -> jax.Array:
    """x [B, S, d] -> [B, S, d].  Runs under shard_map when a mesh is present."""
    b, s, d = x.shape
    mesh = shard.mesh

    def plain(xl, pl, sh):
        return _moe_local(xl.reshape(-1, d), pl, moe, axis_name=None,
                          n_local=moe.num_experts, shared=sh).reshape(xl.shape)

    if (
        mesh is None
        or math.prod(mesh.devices.shape) == 1
        or shard.strategy == "pp"
        or shard.tp == 1
    ):
        return plain(x, params, shared)

    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep = moe.num_experts % shard.tp == 0
    n_local = moe.num_experts // shard.tp if ep else moe.num_experts

    data_axes = tuple(a for a in shard.data_axes if a in mesh_shape)
    dp = math.prod(mesh_shape[a] for a in data_axes)
    if data_axes and b % dp == 0:
        x_spec = P(data_axes if len(data_axes) > 1 else data_axes[0], None, None)
    else:
        x_spec = P(None, None, None)  # tiny batches stay replicated

    w_e = P("model", None, None) if ep else P(None, None, "model")
    w2_e = P("model", None, None) if ep else P(None, "model", None)
    pspecs = {"router": P(None, None), "w1": w_e, "w3": w_e, "w2": w2_e}
    shared_specs = {"w1": P(None, "model"), "w3": P(None, "model"),
                    "w2": P("model", None)} if shared is not None else None

    def inner(xl, pl, sh):
        y = _moe_local(xl.reshape(-1, d), pl, moe, axis_name="model",
                       n_local=n_local, shared=sh)
        return y.reshape(xl.shape)

    fn = shard_map(
        inner,
        mesh=mesh,
        in_specs=(x_spec, pspecs, shared_specs),
        out_specs=x_spec,
        check_vma=False,
    )
    return fn(x, params, shared)
