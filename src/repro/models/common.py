"""Shared model machinery: ParamSpec trees, norms, RoPE, shard context."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative parameter: shape + logical axes + init scheme."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | small
    dtype: Any = jnp.bfloat16
    # fan_in override for scaled-normal init (0 -> shape[-2] or shape[-1])
    fan_in: int = 0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract_params(spec_tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=is_spec
    )


def logical_axes(spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def init_params(spec_tree: PyTree, key: jax.Array) -> PyTree:
    """Materialize real parameters (smoke tests / examples only)."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def init_one(s: ParamSpec, k):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        fan = s.fan_in or (s.shape[-2] if len(s.shape) >= 2 else s.shape[-1])
        scale = 0.02 if s.init == "small" else 1.0 / math.sqrt(max(fan, 1))
        return (jax.random.normal(k, s.shape, jnp.float32) * scale).astype(s.dtype)

    return jax.tree.unflatten(treedef, [init_one(s, k) for s, k in zip(leaves, keys)])


def param_bytes(spec_tree: PyTree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in leaves)


def param_count_tree(spec_tree: PyTree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Distribution context threaded through model builders.

    ``mesh`` may be None (single-device smoke tests).  ``tp`` is the size of
    the tensor-model axis; head padding depends on it.  ``strategy`` selects
    the sharding rule table.
    """

    mesh: Optional[Mesh] = None
    strategy: str = "serve"
    tp: int = 1
    data_axes: Tuple[str, ...] = ("pod", "data")
    model_axis: str = "model"

    @staticmethod
    def single() -> "ShardCtx":
        return ShardCtx(mesh=None, strategy="serve", tp=1)

    @staticmethod
    def from_mesh(mesh: Mesh, strategy: str = "serve") -> "ShardCtx":
        shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        return ShardCtx(
            mesh=mesh,
            strategy=strategy,
            tp=shape.get("model", 1),
            data_axes=tuple(a for a in ("pod", "data") if a in shape),
        )

    def constrain(self, x, axes):
        from repro import sharding

        return sharding.constrain(x, axes, self.strategy, self.mesh)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * inv).astype(x.dtype) * w


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


def rope_tables(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions [...,] -> (cos, sin) of shape [..., head_dim/2], fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., n_heads, head_dim]; cos/sin broadcastable [..., 1, head_dim/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1).astype(x.dtype)


def sinusoid_positions(length: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal position embedding [length, d_model]."""
    half = d_model // 2
    scale = np.log(10000.0) / max(half - 1, 1)
    inv = np.exp(-scale * np.arange(half))
    pos = np.arange(length)[:, None] * inv[None, :]
    emb = np.concatenate([np.sin(pos), np.cos(pos)], axis=1)
    return jnp.asarray(emb, jnp.bfloat16)


def pad_heads(n_heads: int, tp: int) -> int:
    return int(math.ceil(n_heads / tp) * tp)
