"""Decoder-only transformer families: dense, moe, vlm.

One shared attention block; FFN varies (SwiGLU dense / sparse MoE); the
vlm family interleaves gated cross-attention layers attending to stubbed
patch embeddings (one per ``cross_attn_every`` self-attn layers).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.common import (
    ParamSpec,
    ShardCtx,
    apply_rope,
    pad_heads,
    rmsnorm,
    rope_tables,
)
from repro.models.moe import moe_ffn, moe_specs
from repro.models.stacked import Ctx, Stack

PyTree = Any


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def eff_kv_heads(cfg: ArchConfig, tp: int) -> int:
    """MHA (kv == q heads) pads kv together with q so GQA grouping holds;
    true GQA keeps kv unpadded (replicated when not tp-divisible)."""
    if cfg.num_kv_heads == cfg.num_heads:
        return pad_heads(cfg.num_heads, tp)
    return cfg.num_kv_heads


def attn_specs(cfg: ArchConfig, tp: int) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hp = pad_heads(cfg.num_heads, tp)
    kvh = eff_kv_heads(cfg, tp)
    kv_ax = "kv_heads" if kvh % tp == 0 else None
    return {
        "ln": ParamSpec((d,), ("embed",), "ones"),
        "wq": ParamSpec((d, hp * hd), ("embed", "heads")),
        "wk": ParamSpec((d, kvh * hd), ("embed", kv_ax)),
        "wv": ParamSpec((d, kvh * hd), ("embed", kv_ax)),
        "wo": ParamSpec((hp * hd, d), ("heads", "embed"), fan_in=cfg.num_heads * hd),
    }


def mlp_specs(cfg: ArchConfig, tp: int) -> Dict[str, ParamSpec]:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "ln": ParamSpec((d,), ("embed",), "ones"),
        "w1": ParamSpec((d, ff), ("embed", "ff")),
        "w3": ParamSpec((d, ff), ("embed", "ff")),
        "w2": ParamSpec((ff, d), ("ff", "embed"), fan_in=ff),
    }


def cross_attn_specs(cfg: ArchConfig, tp: int) -> Dict[str, ParamSpec]:
    s = attn_specs(cfg, tp)
    d = cfg.d_model
    s["gate"] = ParamSpec((1,), (None,), "zeros", jnp.float32)
    s["ln_kv"] = ParamSpec((d,), ("embed",), "ones")
    return s


# ---------------------------------------------------------------------------
# Block applications
# ---------------------------------------------------------------------------

def _qkv(p, h, cfg: ArchConfig, tp: int):
    hd = cfg.resolved_head_dim
    hp = pad_heads(cfg.num_heads, tp)
    kvh = eff_kv_heads(cfg, tp)
    lead = h.shape[:-1]
    q = (h @ p["wq"]).reshape(*lead, hp, hd)
    k = (h @ p["wk"]).reshape(*lead, kvh, hd)
    v = (h @ p["wv"]).reshape(*lead, kvh, hd)
    return q, k, v


def _repeat_kv_for_pad(k: jax.Array, cfg: ArchConfig, tp: int) -> int:
    """Padded GQA group count (query heads per kv head, incl. padding)."""
    return pad_heads(cfg.num_heads, tp) // cfg.num_kv_heads


def self_attn_block(p, x, ctx: Ctx, cache, cfg: ArchConfig, *, causal=True,
                    use_rope=True):
    """Returns (x, new_cache).  cache = {"k","v"} or None (train/encoder)."""
    shard = ctx.shard
    tp = shard.tp
    w = cfg.window

    if ctx.mode == "decode":
        h = rmsnorm(x, p["ln"], cfg.norm_eps)            # x [B, d]
        q, k, v = _qkv(p, h, cfg, tp)                    # [B, H, hd]
        if use_rope:
            cos, sin = ctx.rope_cos[:, None, :], ctx.rope_sin[:, None, :]
            q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        b = x.shape[0]
        slot = ctx.positions % w if w else ctx.positions
        if ctx.block_tables is not None:
            # paged KV: cache leaves are block-major [n_blocks, bs, ...].
            # Scatter ONLY the new token's physical (block, offset) slot —
            # the dirty-slot write-back — then attend the per-row gathered
            # view (decode's full softmax reads every slot anyway; XLA
            # fuses the gather, and masked trash contributes exactly 0.0).
            tables = ctx.block_tables                    # [B, nb]
            bs = cache["k"].shape[1]
            blk = jnp.minimum(slot // bs, tables.shape[1] - 1)
            phys = tables[jnp.arange(b), blk]
            off = slot % bs
            if "ks" in cache:
                k8, ks1 = attn.quantize_kv(k)
                v8, vs1 = attn.quantize_kv(v)
                new_cache = {
                    "k": cache["k"].at[phys, off].set(k8),
                    "v": cache["v"].at[phys, off].set(v8),
                    "ks": cache["ks"].at[phys, off].set(ks1),
                    "vs": cache["vs"].at[phys, off].set(vs1),
                }
                o = attn.decode_attention_quant(
                    q,
                    attn.gather_paged_cache(new_cache["k"], tables),
                    attn.gather_paged_cache(new_cache["ks"], tables),
                    attn.gather_paged_cache(new_cache["v"], tables),
                    attn.gather_paged_cache(new_cache["vs"], tables),
                    ctx.positions, rolling_window=w)
                return x + o @ p["wo"], new_cache
            kc = cache["k"].at[phys, off].set(k)
            vc = cache["v"].at[phys, off].set(v)
            o = attn.decode_attention(
                q, attn.gather_paged_cache(kc, tables),
                attn.gather_paged_cache(vc, tables),
                ctx.positions, rolling_window=w)
            return x + o @ p["wo"], {"k": kc, "v": vc}
        rows = jnp.arange(b)
        if "ks" in cache:  # §Perf C1: int8 cache, s8xs8 attention dots
            k8, ks1 = attn.quantize_kv(k)
            v8, vs1 = attn.quantize_kv(v)
            new_cache = {
                "k": cache["k"].at[rows, slot].set(k8),
                "v": cache["v"].at[rows, slot].set(v8),
                "ks": cache["ks"].at[rows, slot].set(ks1),
                "vs": cache["vs"].at[rows, slot].set(vs1),
            }
            ca = _cache_axes(cfg, tp)
            new_cache = {kk: shard.constrain(vv, ca if vv.ndim == 4 else ca[:3])
                         for kk, vv in new_cache.items()}
            o = attn.decode_attention_quant(
                q, new_cache["k"], new_cache["ks"], new_cache["v"],
                new_cache["vs"], ctx.positions, rolling_window=w)
            return x + o @ p["wo"], new_cache
        kc = cache["k"].at[rows, slot].set(k)
        vc = cache["v"].at[rows, slot].set(v)
        kc = shard.constrain(kc, _cache_axes(cfg, tp))
        vc = shard.constrain(vc, _cache_axes(cfg, tp))
        o = attn.decode_attention(q, kc, vc, ctx.positions, rolling_window=w)
        x = x + o @ p["wo"]
        return x, {"k": kc, "v": vc}

    if ctx.mode == "chunk":
        # chunked prefill, packed ragged layout: x [T, d] is the batch's
        # valid span tokens concatenated (T = bucket width), with per-token
        # absolute positions [T] and batch rows ctx.seq_idx [T]; the cache
        # already holds all earlier chunks.  Bucket padding duplicates the
        # last valid token (same token, position AND row), so duplicate
        # cache scatters write identical values and stay deterministic.
        h = rmsnorm(x, p["ln"], cfg.norm_eps)            # x [T, d]
        q, k, v = _qkv(p, h, cfg, tp)                    # [T, H, hd]
        if use_rope:
            cos = ctx.rope_cos[:, None, :]               # [T, 1, hd/2]
            sin = ctx.rope_sin[:, None, :]
            q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        si = ctx.seq_idx
        ca = _cache_axes(cfg, tp)
        if w:
            # rolling cache: attend (old cache + the span's own fresh K/V),
            # THEN scatter — scatter-first would overwrite window entries
            # earlier span tokens still need (see attention.py docstrings).
            offs = ctx.span_starts[si]                   # [T] row span start
            n_valid = ctx.n_valid if ctx.n_valid is not None else x.shape[0]
            if ctx.block_tables is not None:
                # paged rolling: attend the old physical cache through the
                # block table (plus the span's fresh K/V), THEN scatter
                # only the touched (block, offset) slots — scatter-first
                # would overwrite window entries earlier span tokens need.
                tables = ctx.block_tables
                bs = cache["k"].shape[1]
                slot = ctx.positions % w
                blk = jnp.minimum(slot // bs, tables.shape[1] - 1)
                phys = tables[si, blk]
                off = slot % bs
                if "ks" in (cache or {}):
                    o = attn.paged_span_attention_rolling_quant_exec(
                        q, cache["k"], cache["ks"], cache["v"], cache["vs"],
                        k, v, tables, ctx.positions, si, offs, n_valid,
                        window=w)
                    k8, ks1 = attn.quantize_kv(k)
                    v8, vs1 = attn.quantize_kv(v)
                    new_cache = {
                        "k": cache["k"].at[phys, off].set(k8),
                        "v": cache["v"].at[phys, off].set(v8),
                        "ks": cache["ks"].at[phys, off].set(ks1),
                        "vs": cache["vs"].at[phys, off].set(vs1),
                    }
                    return x + o @ p["wo"], new_cache
                o = attn.paged_span_attention_rolling_exec(
                    q, cache["k"], cache["v"], k, v, tables, ctx.positions,
                    si, offs, n_valid, window=w)
                kc = cache["k"].at[phys, off].set(k)
                vc = cache["v"].at[phys, off].set(v)
                return x + o @ p["wo"], {"k": kc, "v": vc}
            if "ks" in (cache or {}):
                o = attn.packed_span_attention_rolling_quant(
                    q, cache["k"], cache["ks"], cache["v"], cache["vs"],
                    k, v, ctx.positions, si, offs, n_valid, window=w)
                k8, ks1 = attn.quantize_kv(k)
                v8, vs1 = attn.quantize_kv(v)
                slot = ctx.positions % w
                new_cache = {
                    "k": cache["k"].at[si, slot].set(k8),
                    "v": cache["v"].at[si, slot].set(v8),
                    "ks": cache["ks"].at[si, slot].set(ks1),
                    "vs": cache["vs"].at[si, slot].set(vs1),
                }
                new_cache = {kk: shard.constrain(vv, ca if vv.ndim == 4
                                                 else ca[:3])
                             for kk, vv in new_cache.items()}
                return x + o @ p["wo"], new_cache
            o = attn.packed_span_attention_rolling(
                q, cache["k"], cache["v"], k, v, ctx.positions, si, offs,
                n_valid, window=w)
            slot = ctx.positions % w
            kc = shard.constrain(cache["k"].at[si, slot].set(k), ca)
            vc = shard.constrain(cache["v"].at[si, slot].set(v), ca)
            return x + o @ p["wo"], {"k": kc, "v": vc}
        if ctx.block_tables is not None:
            # paged full-cache chunk: dirty-slot scatter into the physical
            # blocks the span touches, then attend straight through the
            # table (per-tile gather, no [B, nb*bs] view).  Bucket-padding
            # duplicates write identical (block, offset, value) triples.
            tables = ctx.block_tables
            bs = cache["k"].shape[1]
            blk = jnp.minimum(ctx.positions // bs, tables.shape[1] - 1)
            phys = tables[si, blk]
            off = ctx.positions % bs
            if "ks" in (cache or {}):
                k8, ks1 = attn.quantize_kv(k)
                v8, vs1 = attn.quantize_kv(v)
                new_cache = {
                    "k": cache["k"].at[phys, off].set(k8),
                    "v": cache["v"].at[phys, off].set(v8),
                    "ks": cache["ks"].at[phys, off].set(ks1),
                    "vs": cache["vs"].at[phys, off].set(vs1),
                }
                o = attn.paged_span_attention_quant_exec(
                    q, new_cache["k"], new_cache["ks"], new_cache["v"],
                    new_cache["vs"], tables, ctx.positions, si)
                return x + o @ p["wo"], new_cache
            kc = cache["k"].at[phys, off].set(k)
            vc = cache["v"].at[phys, off].set(v)
            o = attn.paged_span_attention_exec(q, kc, vc, tables,
                                               ctx.positions, si)
            return x + o @ p["wo"], {"k": kc, "v": vc}
        if "ks" in (cache or {}):
            k8, ks1 = attn.quantize_kv(k)
            v8, vs1 = attn.quantize_kv(v)
            new_cache = {
                "k": cache["k"].at[si, ctx.positions].set(k8),
                "v": cache["v"].at[si, ctx.positions].set(v8),
                "ks": cache["ks"].at[si, ctx.positions].set(ks1),
                "vs": cache["vs"].at[si, ctx.positions].set(vs1),
            }
            new_cache = {kk: shard.constrain(vv, ca if vv.ndim == 4 else ca[:3])
                         for kk, vv in new_cache.items()}
            o = attn.packed_span_attention_quant(
                q, new_cache["k"], new_cache["ks"], new_cache["v"],
                new_cache["vs"], ctx.positions, si)
            return x + o @ p["wo"], new_cache
        kc = shard.constrain(cache["k"].at[si, ctx.positions].set(k), ca)
        vc = shard.constrain(cache["v"].at[si, ctx.positions].set(v), ca)
        o = attn.packed_span_attention(q, kc, vc, ctx.positions, si)
        return x + o @ p["wo"], {"k": kc, "v": vc}

    h = rmsnorm(x, p["ln"], cfg.norm_eps)                # x [B, S, d]
    q, k, v = _qkv(p, h, cfg, tp)                        # [B, S, H, hd]
    if use_rope:
        cos, sin = ctx.rope_cos[None, :, None, :], ctx.rope_sin[None, :, None, :]
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    q = shard.constrain(q, ("batch", None, "heads", None))
    if not causal:
        o = attn.chunked_attention(q, k, v, causal=False, kv_block=ctx.kv_block)
    elif w:
        o = attn.local_attention(q, k, v, window=w, q_block=min(ctx.kv_block, w))
    else:
        o = attn.chunked_attention(
            q, k, v, causal=True, kv_block=ctx.kv_block,
            q_positions=ctx.positions, triangular=ctx.triangular,
        )
    x = x + o @ p["wo"]
    new_cache = None
    if ctx.mode == "prefill" and cache is not False:
        if w:
            if ctx.seq_lens is not None:
                # ragged (right-padded) batch: gather by per-row position
                # so pad-tail K/V never reaches a rolling slot
                kc = attn.fill_rolling_cache_ragged(k, w, ctx.seq_lens)
                vc = attn.fill_rolling_cache_ragged(v, w, ctx.seq_lens)
            else:
                kc = attn.fill_rolling_cache(k, w)
                vc = attn.fill_rolling_cache(v, w)
        else:
            kc, vc = k, v
        ca = _cache_axes(cfg, tp)
        if ctx.kv_quant:
            k8, ks = attn.quantize_kv(kc)
            v8, vs = attn.quantize_kv(vc)
            new_cache = {
                "k": shard.constrain(k8, ca), "v": shard.constrain(v8, ca),
                "ks": shard.constrain(ks, ca[:3]),
                "vs": shard.constrain(vs, ca[:3]),
            }
        else:
            new_cache = {
                "k": shard.constrain(kc, ca),
                "v": shard.constrain(vc, ca),
            }
    return x, new_cache


def _cache_axes(cfg: ArchConfig, tp: int) -> Tuple:
    kvh = eff_kv_heads(cfg, tp)
    if kvh % tp == 0 and kvh >= tp:
        return ("batch", None, "kv_heads", None)
    return ("batch", "kv_seq", None, None)


def mlp_block(p, x, cfg: ArchConfig, shard: ShardCtx):
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    a = jax.nn.silu(h @ p["w1"]) * (h @ p["w3"])
    a = shard.constrain(a, ("batch", None, "ff") if a.ndim == 3 else ("batch", "ff"))
    return x + a @ p["w2"]


def moe_block(p, x, cfg: ArchConfig, shard: ShardCtx, *, fuse_shared=False):
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    squeeze = h.ndim == 2
    h3 = h[:, None, :] if squeeze else h
    has_shared = "shared_w1" in p
    if has_shared and fuse_shared:
        # §Perf B1: shared-expert partials join the routed-expert psum
        shared = {"w1": p["shared_w1"], "w3": p["shared_w3"],
                  "w2": p["shared_w2"]}
        y = moe_ffn(h3, p["moe"], cfg.moe, shard, shared=shared)
    else:
        y = moe_ffn(h3, p["moe"], cfg.moe, shard)
        if has_shared:  # baseline: separate dense shared-expert branch
            a = jax.nn.silu(h3 @ p["shared_w1"]) * (h3 @ p["shared_w3"])
            y = y + a @ p["shared_w2"]
    y = y[:, 0, :] if squeeze else y
    return x + y


def cross_attn_block(p, x, ctx: Ctx, cache, cfg: ArchConfig):
    """Gated cross-attention to ctx.patches / ctx.enc_out.

    prefill: computes the memory's K/V and returns them as cache.
    decode:  reuses cached K/V.
    """
    shard = ctx.shard
    tp = shard.tp
    hd = cfg.resolved_head_dim
    kvh = eff_kv_heads(cfg, tp)
    gate = jnp.tanh(p["gate"].astype(jnp.float32))[0]

    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    lead = h.shape[:-1]
    q = (h @ p["wq"]).reshape(*lead, pad_heads(cfg.num_heads, tp), hd)

    if ctx.mode == "decode":
        kc, vc = cache["k"], cache["v"]
        o = attn.decode_attention(q, kc, vc, positions=None)
        return x + (gate * (o @ p["wo"]).astype(jnp.float32)).astype(x.dtype), cache

    mem = ctx.patches if ctx.patches is not None else ctx.enc_out
    m = rmsnorm(mem, p["ln_kv"], cfg.norm_eps)
    k = (m @ p["wk"]).reshape(*mem.shape[:-1], kvh, hd)
    v = (m @ p["wv"]).reshape(*mem.shape[:-1], kvh, hd)
    o = attn.cross_attention(q, k, v, kv_block=ctx.kv_block)
    x = x + (gate * (o @ p["wo"]).astype(jnp.float32)).astype(x.dtype)
    new_cache = None
    if ctx.mode == "prefill":
        new_cache = {
            "k": shard.constrain(k, ("batch", None, None, None)),
            "v": shard.constrain(v, ("batch", None, None, None)),
        }
    return x, new_cache


# ---------------------------------------------------------------------------
# Stacks per family
# ---------------------------------------------------------------------------

def _self_cache_spec(cfg: ArchConfig, tp: int = 1, dtype=jnp.bfloat16,
                     quant: bool = False):
    hd = cfg.resolved_head_dim

    def spec(batch: int, cache_len: int):
        # rolling caches are always exactly window-sized: the decode path
        # indexes slots by position %% window, so the buffer cannot shrink
        # even when the requested cache_len is shorter
        s = cfg.window if cfg.window else cache_len
        kvh = eff_kv_heads(cfg, tp)
        if quant:
            sd = jax.ShapeDtypeStruct((batch, s, kvh, hd), jnp.int8)
            sc = jax.ShapeDtypeStruct((batch, s, kvh), jnp.bfloat16)
            return {"k": sd, "v": sd, "ks": sc, "vs": sc}
        sd = jax.ShapeDtypeStruct((batch, s, kvh, hd), dtype)
        return {"k": sd, "v": sd}

    return spec


def _self_cache_axes(cfg: ArchConfig, tp: int, quant: bool = False):
    def axes():
        a = _cache_axes(cfg, tp)
        if quant:
            return {"k": a, "v": a, "ks": a[:3], "vs": a[:3]}
        return {"k": a, "v": a}

    return axes


def dense_layer_stack(cfg: ArchConfig, tp: int, n: int, *, moe_every: int = 0,
                      shared_expert: bool = False,
                      kv_quant: bool = False) -> Stack:
    """n groups; each group = ``moe_every`` layers with the last one MoE
    (moe_every=0 -> single dense layer per group)."""
    per = max(moe_every, 1)
    layer_specs = []
    for i in range(per):
        is_moe = cfg.moe is not None and (moe_every == 0 or i == per - 1) and (
            moe_every > 0 or cfg.moe is not None
        )
        if cfg.moe is None:
            is_moe = False
        spec = {"attn": attn_specs(cfg, tp)}
        if is_moe:
            ffn = {"ln": ParamSpec((cfg.d_model,), ("embed",), "ones"),
                   "moe": moe_specs(cfg.d_model, cfg.moe, tp)}
            if shared_expert:
                ff = cfg.moe.expert_d_ff or cfg.d_ff
                ffn.update(
                    shared_w1=ParamSpec((cfg.d_model, ff), ("embed", "ff")),
                    shared_w3=ParamSpec((cfg.d_model, ff), ("embed", "ff")),
                    shared_w2=ParamSpec((ff, cfg.d_model), ("ff", "embed"), fan_in=ff),
                )
            spec["ffn"] = ffn
            spec["ffn_kind"] = "moe"
        else:
            spec["ffn"] = mlp_specs(cfg, tp)
            spec["ffn_kind"] = "mlp"
        layer_specs.append(spec)

    kinds = tuple(s.pop("ffn_kind") for s in layer_specs)
    group_specs = {f"l{i}": s for i, s in enumerate(layer_specs)}

    def apply(gp, x, ctx: Ctx, cache_g):
        new_caches = {}
        for i in range(per):
            p = gp[f"l{i}"]
            c = cache_g[f"l{i}"] if cache_g is not None else None
            if ctx.seq_shard and x.ndim == 3:
                # §Perf B2: residual stream sequence-sharded between blocks
                x = ctx.shard.constrain(x, ("batch", "seq_sp", None))
            x, nc = self_attn_block(p["attn"], x, ctx, c, cfg)
            if nc is not None:
                new_caches[f"l{i}"] = nc
            if ctx.seq_shard and x.ndim == 3:
                x = ctx.shard.constrain(x, ("batch", "seq_sp", None))
            if kinds[i] == "moe":
                x = moe_block(p["ffn"], x, cfg, ctx.shard,
                              fuse_shared=ctx.fuse_shared_expert)
            else:
                x = mlp_block(p["ffn"], x, cfg, ctx.shard)
        return x, (new_caches or None)

    cspec = _self_cache_spec(cfg, tp, quant=kv_quant)

    def cache_spec(batch, cache_len):
        return {f"l{i}": cspec(batch, cache_len) for i in range(per)}

    caxes = _self_cache_axes(cfg, tp, quant=kv_quant)

    def cache_axes():
        return {f"l{i}": caxes() for i in range(per)}

    return Stack("blocks", n, group_specs, apply, cache_spec, cache_axes)


def vlm_stack(cfg: ArchConfig, tp: int) -> Stack:
    """Groups of (cross_attn_every self layers + 1 cross layer)."""
    per = cfg.cross_attn_every
    n = cfg.num_layers // (per + 1)
    assert n * (per + 1) == cfg.num_layers, "vlm layer count must factor"
    group_specs = {f"self{i}": {"attn": attn_specs(cfg, tp), "ffn": mlp_specs(cfg, tp)}
                   for i in range(per)}
    group_specs["cross"] = {"attn": cross_attn_specs(cfg, tp),
                            "ffn": mlp_specs(cfg, tp)}

    def apply(gp, x, ctx: Ctx, cache_g):
        new_caches = {}
        for i in range(per):
            p = gp[f"self{i}"]
            c = cache_g[f"self{i}"] if cache_g is not None else None
            x, nc = self_attn_block(p["attn"], x, ctx, c, cfg)
            if nc is not None:
                new_caches[f"self{i}"] = nc
            x = mlp_block(p["ffn"], x, cfg, ctx.shard)
        c = cache_g["cross"] if cache_g is not None else None
        x, nc = cross_attn_block(gp["cross"]["attn"], x, ctx, c, cfg)
        if nc is not None:
            new_caches["cross"] = nc
        x = mlp_block(gp["cross"]["ffn"], x, cfg, ctx.shard)
        return x, (new_caches or None)

    cspec = _self_cache_spec(cfg, tp)
    hd = cfg.resolved_head_dim

    def cache_spec(batch, cache_len):
        d = {f"self{i}": cspec(batch, cache_len) for i in range(per)}
        sd = jax.ShapeDtypeStruct((batch, cfg_n_patches(cfg), eff_kv_heads(cfg, tp), hd),
                                  jnp.bfloat16)
        d["cross"] = {"k": sd, "v": sd}
        return d

    caxes = _self_cache_axes(cfg, tp)

    def cache_axes():
        d = {f"self{i}": caxes() for i in range(per)}
        a = ("batch", None, None, None)
        d["cross"] = {"k": a, "v": a}
        return d

    return Stack("blocks", n, group_specs, apply, cache_spec, cache_axes)


def cfg_n_patches(cfg: ArchConfig) -> int:
    """Stubbed vision frontend: 4 tiles x 40x40 patches = 6400."""
    return 6400
