"""Whisper-style audio encoder-decoder backbone.

The conv frontend is a STUB per assignment: ``input_specs()`` supplies
precomputed frame embeddings [B, S_enc, d].  Encoder: bidirectional
self-attention blocks.  Decoder: causal self-attention (cached) +
cross-attention to the encoder output (cached at prefill) + GELU MLP.
Fixed sinusoidal positions on both stacks.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import ParamSpec, rmsnorm
from repro.models.stacked import Ctx, Stack
from repro.models.transformer import (
    eff_kv_heads,
    attn_specs,
    cross_attn_specs,
    self_attn_block,
    cross_attn_block,
    _self_cache_spec,
    _self_cache_axes,
)


def gelu_mlp_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "ln": ParamSpec((d,), ("embed",), "ones"),
        "w1": ParamSpec((d, ff), ("embed", "ff")),
        "w2": ParamSpec((ff, d), ("ff", "embed"), fan_in=ff),
    }


def gelu_mlp(p, x, cfg: ArchConfig):
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    return x + jax.nn.gelu(h @ p["w1"]) @ p["w2"]


def encoder_stack(cfg: ArchConfig, tp: int) -> Stack:
    specs = {"attn": attn_specs(cfg, tp), "ffn": gelu_mlp_specs(cfg)}

    def apply(gp, x, ctx: Ctx, cache_g):
        x, _ = self_attn_block(gp["attn"], x, ctx, False, cfg,
                               causal=False, use_rope=False)
        x = gelu_mlp(gp["ffn"], x, cfg)
        return x, None

    return Stack("encoder", cfg.encoder_layers, specs, apply)


def decoder_stack(cfg: ArchConfig, tp: int, enc_len: int) -> Stack:
    specs = {
        "self": attn_specs(cfg, tp),
        "cross": cross_attn_specs(cfg, tp),
        "ffn": gelu_mlp_specs(cfg),
    }

    def apply(gp, x, ctx: Ctx, cache_g):
        new_caches = {}
        c = cache_g["self"] if cache_g is not None else None
        x, nc = self_attn_block(gp["self"], x, ctx, c, cfg, use_rope=False)
        if nc is not None:
            new_caches["self"] = nc
        c = cache_g["cross"] if cache_g is not None else None
        x, nc = cross_attn_block(gp["cross"], x, ctx, c, cfg)
        if nc is not None:
            new_caches["cross"] = nc
        x = gelu_mlp(gp["ffn"], x, cfg)
        return x, (new_caches or None)

    cspec = _self_cache_spec(cfg, tp)
    hd = cfg.resolved_head_dim

    def cache_spec(batch, cache_len):
        sd = jax.ShapeDtypeStruct((batch, enc_len, eff_kv_heads(cfg, tp), hd), jnp.bfloat16)
        return {"self": cspec(batch, cache_len), "cross": {"k": sd, "v": sd}}

    caxes = _self_cache_axes(cfg, tp)

    def cache_axes():
        a = ("batch", "kv_seq", None, None)
        return {"self": caxes(), "cross": {"k": a, "v": a}}

    return Stack("decoder", cfg.num_layers, specs, apply, cache_spec, cache_axes)
