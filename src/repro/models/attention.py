"""Attention variants as memory-safe pure-jnp (lax.scan) implementations.

These are the *reference/distribution* paths: the compiled HLO never
materializes an [S, S] score matrix, so 32k prefill fits device memory and
the dry-run ``memory_analysis`` is realistic.  The Pallas kernels in
:mod:`repro.kernels` are the TPU-optimized equivalents of the same math
(validated against these in interpret mode).

Layout conventions:
  q            [B, Sq, Hq, hd]     (Hq may be tp-padded)
  k, v         [B, Skv, Hkv, hd]   (GQA: Hq % Hkv == 0)
  decode q     [B, Hq, hd]         (single new token)
  caches       [B, S_max, Hkv, hd] (full) or [B, W, Hkv, hd] (rolling)
Outputs are [B, Sq, Hq*hd] / [B, Hq*hd].
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _group(q: jax.Array, n_kv: int) -> jax.Array:
    """[B,S,Hq,hd] -> [B,S,Kv,G,hd] grouping query heads over kv heads."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    kv_block: int = 512,
    q_positions: Optional[jax.Array] = None,
    triangular: bool = False,
) -> jax.Array:
    """Flash-style attention: lax.scan over kv blocks with running softmax.

    ``triangular=True`` skips fully-masked kv blocks for causal attention via
    a dynamic-bound fori_loop per q block (~2x compute saving at long S);
    kept off for the paper-faithful baseline and enabled during the perf
    hillclimb (see EXPERIMENTS.md §Perf).
    """
    b, sq, hq, hd = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    g = hq // n_kv
    kv_block = min(kv_block, skv)
    while skv % kv_block:
        kv_block //= 2
    nb = skv // kv_block
    qg = _group(q, n_kv)  # [B,Sq,Kv,G,hd]
    scale = hd ** -0.5
    qpos = q_positions if q_positions is not None else jnp.arange(sq)

    if triangular and causal and nb > 1:
        return _triangular_attention(qg, k, v, window=window, kv_block=kv_block,
                                     q_positions=qpos, scale=scale)

    kb = k.reshape(b, nb, kv_block, n_kv, hd).swapaxes(0, 1)
    vb = v.reshape(b, nb, kv_block, n_kv, hd).swapaxes(0, 1)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, i = inp
        kpos = i * kv_block + jnp.arange(kv_block)
        # scores [B, Kv, G, Sq, blk]
        s = jnp.einsum("bsgqd,btgd->bgqst", qg, kblk).astype(jnp.float32) * scale
        mask = jnp.ones((sq, kv_block), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        mn = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - mn[..., None])
        corr = jnp.exp(m - mn)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bgqst,btgd->bgqsd", p.astype(q.dtype), vblk
        ).astype(jnp.float32)
        return (mn, l, acc), None

    m0 = jnp.full((b, n_kv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, n_kv, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype).transpose(0, 3, 1, 2, 4).reshape(b, sq, hq * hd)


def _triangular_attention(qg, k, v, *, window, kv_block, q_positions, scale):
    """Causal attention skipping future kv blocks (dynamic-bound inner loop)."""
    b, sq, n_kv, g, hd = qg.shape
    skv = k.shape[1]
    q_block = kv_block
    while sq % q_block:
        q_block //= 2
    nq = sq // q_block
    dtype = qg.dtype

    def q_block_fn(qi, qblk, qpos_blk):
        # attend kv blocks [lo, hi): lo from the sliding window, hi from causality
        hi = jnp.minimum((qpos_blk.max() // kv_block) + 1, skv // kv_block)
        lo = jnp.maximum((qpos_blk.min() - (window - 1)) // kv_block, 0) if window else 0

        def body(j, carry):
            m, l, acc = carry
            kblk = jax.lax.dynamic_slice_in_dim(k, j * kv_block, kv_block, 1)
            vblk = jax.lax.dynamic_slice_in_dim(v, j * kv_block, kv_block, 1)
            kpos = j * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bsgqd,btgd->bgqst", qblk, kblk).astype(jnp.float32) * scale
            mask = qpos_blk[:, None] >= kpos[None, :]
            if window:
                mask &= kpos[None, :] > qpos_blk[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            mn = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - mn[..., None])
            corr = jnp.exp(m - mn)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bgqst,btgd->bgqsd", p.astype(dtype), vblk
            ).astype(jnp.float32)
            return (mn, l, acc)

        m0 = jnp.full((b, n_kv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, n_kv, g, q_block, hd), jnp.float32)
        m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
        return (acc / jnp.maximum(l[..., None], 1e-30)).astype(dtype)

    qb = qg.reshape(b, nq, q_block, n_kv, g, hd).swapaxes(0, 1)
    qpos_b = q_positions.reshape(nq, q_block)

    def scan_body(_, inp):
        qi, qblk, qpos_blk = inp
        return None, q_block_fn(qi, qblk, qpos_blk)

    _, outs = jax.lax.scan(scan_body, None, (jnp.arange(nq), qb, qpos_b))
    # outs [nq, B, Kv, G, q_block, hd] -> [B, Sq, H*hd]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, n_kv * g * hd)
    return out


def local_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    q_block: int = 512,
) -> jax.Array:
    """Banded causal attention: each q block attends a [window + q_block]
    kv slice -> compute O(S * window) instead of O(S^2)."""
    b, sq, hq, hd = q.shape
    n_kv = k.shape[2]
    g = hq // n_kv
    q_block = min(q_block, sq)
    while sq % q_block:
        q_block //= 2
    nq = sq // q_block
    span = window + q_block
    scale = hd ** -0.5
    qg = _group(q, n_kv).reshape(b, nq, q_block, n_kv, g, hd).swapaxes(0, 1)
    # pad kv on the left so every slice is in-bounds
    kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))

    def body(_, inp):
        i, qblk = inp
        start = i * q_block  # in padded coords: real kv [start-window, start+q_block)
        kblk = jax.lax.dynamic_slice_in_dim(kp, start, span, 1)
        vblk = jax.lax.dynamic_slice_in_dim(vp, start, span, 1)
        qpos = start + jnp.arange(q_block)
        kpos = start - window + jnp.arange(span)
        # §Perf A2: keep the [*, qb, span] score array in bf16 — it is the
        # dominant HBM temporary of windowed prefill; softmax stats in f32
        s = jnp.einsum("bsgqd,btgd->bgqst", qblk, kblk) * jnp.asarray(
            scale, q.dtype)
        mask = (qpos[:, None] >= kpos[None, :]) & (kpos[None, :] > qpos[:, None] - window) & (
            kpos[None, :] >= 0
        )
        s = jnp.where(mask[None, None, None], s, jnp.asarray(-3e38, q.dtype)
                      if q.dtype == jnp.bfloat16 else NEG_INF)
        # §Perf A1: unnormalized probabilities, stored bf16; the softmax
        # division moves to the [*, qb, hd] output (span/hd x less traffic)
        m = s.max(axis=-1, keepdims=True).astype(jnp.float32)
        p = jnp.exp(s.astype(jnp.float32) - m).astype(q.dtype)
        l = p.astype(jnp.float32).sum(axis=-1)            # [*, qb]
        o = jnp.einsum("bgqst,btgd->bgqsd", p, vblk)
        o = (o.astype(jnp.float32) / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return None, o

    _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qg))
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, hq * hd)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    positions: jax.Array,
    *,
    rolling_window: int = 0,
) -> jax.Array:
    """Single-token attention over a (possibly sequence-sharded) KV cache.

    q [B, Hq, hd]; caches [B, S, Kv, hd]; positions [B] = index of the new
    token (cache already contains it).  For rolling caches (S == window)
    validity is age-based.
    """
    b, hq, hd = q.shape
    s, n_kv = k_cache.shape[1], k_cache.shape[2]
    g = hq // n_kv
    qg = q.reshape(b, n_kv, g, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bgqd,bsgd->bgqs", qg, k_cache).astype(jnp.float32) * scale
    if positions is not None:
        idx = jnp.arange(s)
        if rolling_window:
            valid = idx[None, :] < jnp.minimum(positions + 1, rolling_window)[:, None]
        else:
            valid = idx[None, :] <= positions[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgqs,bsgd->bgqd", p.astype(q.dtype), v_cache)
    return out.reshape(b, hq * hd)


def span_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    positions: jax.Array,
) -> jax.Array:
    """Multi-token attention over a KV cache for chunked prefill.

    Generalizes :func:`decode_attention` to a span of C new tokens per
    sequence with per-sequence positions: q [B, C, Hq, hd]; caches
    [B, S, Kv, hd] (already containing the span's K/V); positions [B, C]
    absolute position of each span token.  Causal validity is positional:
    cache entry s is visible to span token (b, c) iff s <= positions[b, c]
    — entries beyond the filled region are masked out, so chunk i attends
    chunks 0..i plus itself and nothing else.  Output [B, C, Hq*hd].
    """
    b, c, hq, hd = q.shape
    s, n_kv = k_cache.shape[1], k_cache.shape[2]
    g = hq // n_kv
    qg = q.reshape(b, c, n_kv, g, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bcgqd,bsgd->bgqcs", qg, k_cache).astype(jnp.float32) * scale
    valid = jnp.arange(s)[None, None, :] <= positions[:, :, None]   # [B, C, S]
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgqcs,bsgd->bcgqd", p.astype(q.dtype), v_cache)
    return out.reshape(b, c, hq * hd)


def packed_span_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    positions: jax.Array,
    seq_idx: jax.Array,
    *,
    window: int = 0,
    kv_block: int = 512,
) -> jax.Array:
    """Ragged multi-token attention over a KV cache (packed chunk layout).

    The packed layout replaces the padded [B, C] span matrices: the batch's
    valid span tokens are concatenated into flat [T] vectors, so a mixed
    iteration does ``sum(len_i x T_i)`` attention work instead of
    ``B x C x S``.  q [T, Hq, hd]; caches [B, S, Kv, hd] (already containing
    the span's K/V); positions [T] absolute position of each packed token;
    seq_idx [T] batch row of each token.  Cache entry s of row seq_idx[t]
    is visible to token t iff ``s <= positions[t]`` (and, with ``window``,
    ``s > positions[t] - window`` — full-length cache semantics).  The scan
    streams the cache in kv blocks with a running softmax, so no [T, S]
    score tensor is ever materialized.  Output [T, Hq*hd].
    """
    t, hq, hd = q.shape
    s, n_kv = k_cache.shape[1], k_cache.shape[2]
    g = hq // n_kv
    kv_block = min(kv_block, s)
    while s % kv_block:
        kv_block //= 2
    nb = s // kv_block
    qg = q.reshape(t, n_kv, g, hd)
    scale = hd ** -0.5
    kb = k_cache.reshape(-1, nb, kv_block, n_kv, hd).swapaxes(0, 1)
    vb = v_cache.reshape(-1, nb, kv_block, n_kv, hd).swapaxes(0, 1)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, i = inp
        kt = kblk[seq_idx]                       # [T, kb, Kv, hd]
        vt = vblk[seq_idx]
        kpos = i * kv_block + jnp.arange(kv_block)
        sc = jnp.einsum("tngd,tknd->tngk", qg, kt).astype(jnp.float32) * scale
        mask = kpos[None, :] <= positions[:, None]
        if window:
            mask &= kpos[None, :] > positions[:, None] - window
        sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
        mn = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - mn[..., None])
        corr = jnp.exp(m - mn)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "tngk,tknd->tngd", p.astype(q.dtype), vt).astype(jnp.float32)
        return (mn, l, acc), None

    m0 = jnp.full((t, n_kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((t, n_kv, g), jnp.float32)
    a0 = jnp.zeros((t, n_kv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype).reshape(t, hq * hd)


def packed_span_attention_rolling(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    k_span: jax.Array,
    v_span: jax.Array,
    positions: jax.Array,
    seq_idx: jax.Array,
    offsets: jax.Array,
    n_valid: jax.Array,
    *,
    window: int,
    kv_block: int = 512,
) -> jax.Array:
    """Packed span attention for sliding-window models with *rolling* caches.

    A rolling cache (slot = pos %% W) cannot use scatter-then-attend: a
    chunk's writes would overwrite window entries its earlier tokens still
    need.  So the span attends two sources under one running softmax:

      1. the old cache, holding each row's tokens [off-W, off) at slots
         pos %% W — slot s stores position ``off-1-((off-1-s) mod W)``,
         which is reconstructed per query to mask age and window;
      2. the span's own fresh K/V [T, Kv, hd] with an intra-span causal +
         window + same-row mask (``u < n_valid`` drops bucket padding,
         whose duplicated entries would otherwise be double-counted).

    offsets [T] = each token's row span start (tokens already in cache).
    The caller scatters the span K/V into the cache *after* this returns.
    """
    t, hq, hd = q.shape
    w_slots, n_kv = k_cache.shape[1], k_cache.shape[2]
    g = hq // n_kv
    kv_block = min(kv_block, w_slots)
    while w_slots % kv_block:
        kv_block //= 2
    nb = w_slots // kv_block
    qg = q.reshape(t, n_kv, g, hd)
    scale = hd ** -0.5
    kb = k_cache.reshape(-1, nb, kv_block, n_kv, hd).swapaxes(0, 1)
    vb = v_cache.reshape(-1, nb, kv_block, n_kv, hd).swapaxes(0, 1)

    def cache_body(carry, inp):
        m, l, acc = carry
        kblk, vblk, i = inp
        kt = kblk[seq_idx]
        vt = vblk[seq_idx]
        slot = i * kv_block + jnp.arange(kv_block)
        # position stored in slot s of a row whose cache holds [0, off)
        stored = offsets[:, None] - 1 - (
            (offsets[:, None] - 1 - slot[None, :]) % w_slots)
        mask = (offsets[:, None] >= 1) & (stored >= 0) & (
            stored > positions[:, None] - window)
        sc = jnp.einsum("tngd,tknd->tngk", qg, kt).astype(jnp.float32) * scale
        sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
        mn = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - mn[..., None])
        corr = jnp.exp(m - mn)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "tngk,tknd->tngd", p.astype(q.dtype), vt).astype(jnp.float32)
        return (mn, l, acc), None

    m0 = jnp.full((t, n_kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((t, n_kv, g), jnp.float32)
    a0 = jnp.zeros((t, n_kv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(cache_body, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nb)))

    # intra-span source: fresh K/V of the packed chunk itself
    sc = jnp.einsum("tngd,und->tngu", qg, k_span).astype(jnp.float32) * scale
    upos, useq = positions, seq_idx
    mask = (useq[None, :] == seq_idx[:, None]) \
        & (upos[None, :] <= positions[:, None]) \
        & (upos[None, :] > positions[:, None] - window) \
        & (jnp.arange(t)[None, :] < n_valid)
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    mn = jnp.maximum(m, sc.max(-1))
    p = jnp.exp(sc - mn[..., None])
    corr = jnp.exp(m - mn)
    l = l * corr + p.sum(-1)
    acc = acc * corr[..., None] + jnp.einsum(
        "tngu,und->tngd", p.astype(q.dtype), v_span).astype(jnp.float32)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype).reshape(t, hq * hd)


def cross_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    kv_block: int = 512,
) -> jax.Array:
    """Non-causal attention to a fixed memory (vision patches / encoder out)."""
    return chunked_attention(q, k, v, causal=False, kv_block=kv_block)


# ---------------------------------------------------------------------------
# Paged KV cache (block tables) — oracles for the paged Pallas kernels
# ---------------------------------------------------------------------------

def gather_paged_cache(cache: jax.Array, block_tables: jax.Array) -> jax.Array:
    """[n_blocks, bs, ...] physical cache + [B, nb] block table ->
    [B, nb * bs, ...] per-sequence contiguous view: logical slot p of row
    i is ``cache[block_tables[i, p // bs], p %% bs]``.  Padded table
    entries gather arbitrary blocks — always position-masked downstream."""
    b, nb = block_tables.shape
    g = cache[block_tables]                       # [B, nb, bs, ...]
    return g.reshape(b, nb * cache.shape[1], *cache.shape[2:])


def paged_span_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    block_tables: jax.Array,
    positions: jax.Array,
    seq_idx: jax.Array,
    *,
    window: int = 0,
    kv_block: int = 512,
) -> jax.Array:
    """:func:`packed_span_attention` over a block-paged physical cache.

    q [T, Hq, hd]; k_cache/v_cache [n_blocks, bs, Kv, hd];
    block_tables [B, nb]; positions/seq_idx [T].  Reference semantics for
    the paged Pallas kernel (``repro.kernels.span_attention.
    paged_span_attention``): gather each row's table into the contiguous
    view, then attend — on TPU the kernel performs the same gather
    per-block in VMEM via scalar-prefetched BlockSpecs."""
    k = gather_paged_cache(k_cache, block_tables)
    v = gather_paged_cache(v_cache, block_tables)
    return packed_span_attention(q, k, v, positions, seq_idx,
                                 window=window, kv_block=kv_block)


def paged_span_attention_quant(
    q: jax.Array,
    k8: jax.Array, ks: jax.Array,
    v8: jax.Array, vs: jax.Array,
    block_tables: jax.Array,
    positions: jax.Array,
    seq_idx: jax.Array,
    *,
    kv_block: int = 512,
) -> jax.Array:
    """:func:`packed_span_attention_quant` over a block-paged int8 cache.
    k8/v8 [n_blocks, bs, Kv, hd] int8; ks/vs [n_blocks, bs, Kv]."""
    return packed_span_attention_quant(
        q,
        gather_paged_cache(k8, block_tables),
        gather_paged_cache(ks, block_tables),
        gather_paged_cache(v8, block_tables),
        gather_paged_cache(vs, block_tables),
        positions, seq_idx, kv_block=kv_block)


def paged_span_attention_rolling(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    k_span: jax.Array,
    v_span: jax.Array,
    block_tables: jax.Array,
    positions: jax.Array,
    seq_idx: jax.Array,
    offsets: jax.Array,
    n_valid: jax.Array,
    *,
    window: int,
    kv_block: int = 512,
) -> jax.Array:
    """:func:`packed_span_attention_rolling` over a block-paged rolling
    cache.  The gathered view has ``nb * bs`` slots; the rolling stored-
    position reconstruction runs against that view width, which matches
    the physical layout whenever either no row has wrapped (every offset
    fits the view) or the tables cover the full window (view == W)."""
    k = gather_paged_cache(k_cache, block_tables)
    v = gather_paged_cache(v_cache, block_tables)
    return packed_span_attention_rolling(
        q, k, v, k_span, v_span, positions, seq_idx, offsets, n_valid,
        window=window, kv_block=kv_block)


def paged_span_attention_rolling_quant(
    q: jax.Array,
    k8: jax.Array, ks: jax.Array,
    v8: jax.Array, vs: jax.Array,
    k_span: jax.Array,
    v_span: jax.Array,
    block_tables: jax.Array,
    positions: jax.Array,
    seq_idx: jax.Array,
    offsets: jax.Array,
    n_valid: jax.Array,
    *,
    window: int,
    kv_block: int = 512,
) -> jax.Array:
    """:func:`packed_span_attention_rolling_quant` over a block-paged int8
    rolling cache."""
    return packed_span_attention_rolling_quant(
        q,
        gather_paged_cache(k8, block_tables),
        gather_paged_cache(ks, block_tables),
        gather_paged_cache(v8, block_tables),
        gather_paged_cache(vs, block_tables),
        k_span, v_span, positions, seq_idx, offsets, n_valid,
        window=window, kv_block=kv_block)


# ---------------------------------------------------------------------------
# int8 KV cache (§Perf C1 — beyond-paper)
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array, axis: int = -1):
    """Symmetric per-vector int8 quantization.  Returns (int8, bf16 scale
    with ``axis`` reduced)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=axis) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(xf / jnp.expand_dims(scale, axis)), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def decode_attention_quant(
    q: jax.Array,
    k8: jax.Array, ks: jax.Array,
    v8: jax.Array, vs: jax.Array,
    positions: Optional[jax.Array],
    *,
    rolling_window: int = 0,
) -> jax.Array:
    """Single-token attention over an int8 KV cache.

    Both contractions run as native s8 x s8 -> s32 MXU dots: the cache is
    never dequantized to a materialized bf16 array (halving decode HBM
    traffic).  q and the probability rows are quantized on the fly; the
    per-position V scales fold into the probabilities before the AV dot.

    q [B,H,hd] bf16; k8/v8 [B,S,Kv,hd] int8; ks/vs [B,S,Kv] bf16.
    """
    b, hq, hd = q.shape
    s, n_kv = k8.shape[1], k8.shape[2]
    g = hq // n_kv
    qg = q.reshape(b, n_kv, g, hd)
    q8, qs = quantize_kv(qg)                          # [B,Kv,G,hd], [B,Kv,G]
    s32 = jnp.einsum("bgqd,bsgd->bgqs", q8, k8,
                     preferred_element_type=jnp.int32)
    ks_t = ks.transpose(0, 2, 1)[:, :, None, :].astype(jnp.float32)
    scores = s32.astype(jnp.float32) * qs[..., None].astype(jnp.float32) \
        * ks_t * (hd ** -0.5)
    if positions is not None:
        idx = jnp.arange(s)
        if rolling_window:
            valid = idx[None, :] < jnp.minimum(positions + 1, rolling_window)[:, None]
        else:
            valid = idx[None, :] <= positions[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)               # [B,Kv,G,S] fp32
    pv = p * vs.transpose(0, 2, 1)[:, :, None, :].astype(jnp.float32)
    p8, ps = quantize_kv(pv)                          # scale per [B,Kv,G]
    o32 = jnp.einsum("bgqs,bsgd->bgqd", p8, v8,
                     preferred_element_type=jnp.int32)
    out = o32.astype(jnp.float32) * ps[..., None].astype(jnp.float32)
    return out.astype(q.dtype).reshape(b, hq * hd)


def packed_span_attention_quant(
    q: jax.Array,
    k8: jax.Array, ks: jax.Array,
    v8: jax.Array, vs: jax.Array,
    positions: jax.Array,
    seq_idx: jax.Array,
    *,
    kv_block: int = 512,
) -> jax.Array:
    """Packed ragged span attention over an int8 KV cache.

    Generalizes :func:`decode_attention_quant` to the packed chunk layout:
    both contractions are s8 x s8 -> s32 dots with the per-position K/V
    scales folded outside them (q and the probability rows are quantized
    on the fly, per kv block).  q [T,Hq,hd]; k8/v8 [B,S,Kv,hd] int8;
    ks/vs [B,S,Kv] bf16; positions/seq_idx [T].  Output [T, Hq*hd].
    """
    t, hq, hd = q.shape
    s, n_kv = k8.shape[1], k8.shape[2]
    g = hq // n_kv
    kv_block = min(kv_block, s)
    while s % kv_block:
        kv_block //= 2
    nb = s // kv_block
    qg = q.reshape(t, n_kv, g, hd)
    q8, qs = quantize_kv(qg)                     # [T,Kv,G,hd], [T,Kv,G]
    scale = hd ** -0.5
    kb = k8.reshape(-1, nb, kv_block, n_kv, hd).swapaxes(0, 1)
    vb = v8.reshape(-1, nb, kv_block, n_kv, hd).swapaxes(0, 1)
    ksb = ks.reshape(-1, nb, kv_block, n_kv).swapaxes(0, 1)
    vsb = vs.reshape(-1, nb, kv_block, n_kv).swapaxes(0, 1)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, ksblk, vsblk, i = inp
        kt, vt = kblk[seq_idx], vblk[seq_idx]    # [T, kb, Kv, hd] int8
        kst = ksblk[seq_idx].transpose(0, 2, 1)[:, :, None, :]  # [T,Kv,1,kb]
        vst = vsblk[seq_idx].transpose(0, 2, 1)[:, :, None, :]
        s32 = jnp.einsum("tngd,tknd->tngk", q8, kt,
                         preferred_element_type=jnp.int32)
        sc = s32.astype(jnp.float32) * qs[..., None].astype(jnp.float32) \
            * kst.astype(jnp.float32) * scale
        kpos = i * kv_block + jnp.arange(kv_block)
        mask = kpos[None, :] <= positions[:, None]
        sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
        mn = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - mn[..., None])
        corr = jnp.exp(m - mn)
        l = l * corr + p.sum(-1)
        pv = p * vst.astype(jnp.float32)         # fold V scales, then requant
        p8, ps = quantize_kv(pv)
        o32 = jnp.einsum("tngk,tknd->tngd", p8, vt,
                         preferred_element_type=jnp.int32)
        acc = acc * corr[..., None] + \
            o32.astype(jnp.float32) * ps[..., None].astype(jnp.float32)
        return (mn, l, acc), None

    m0 = jnp.full((t, n_kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((t, n_kv, g), jnp.float32)
    a0 = jnp.zeros((t, n_kv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kb, vb, ksb, vsb, jnp.arange(nb)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype).reshape(t, hq * hd)


def packed_span_attention_rolling_quant(
    q: jax.Array,
    k8: jax.Array, ks: jax.Array,
    v8: jax.Array, vs: jax.Array,
    k_span: jax.Array,
    v_span: jax.Array,
    positions: jax.Array,
    seq_idx: jax.Array,
    offsets: jax.Array,
    n_valid: jax.Array,
    *,
    window: int,
    kv_block: int = 512,
) -> jax.Array:
    """Rolling-cache windowed span attention with an int8 cache.

    The old-cache source runs as s8 x s8 -> s32 dots with folded scales
    (as :func:`packed_span_attention_quant`); the span's own fresh K/V is
    still bf16, so the intra-span source uses full-precision dots — both
    feed one running softmax, mirroring the fp rolling variant.
    """
    t, hq, hd = q.shape
    w_slots, n_kv = k8.shape[1], k8.shape[2]
    g = hq // n_kv
    kv_block = min(kv_block, w_slots)
    while w_slots % kv_block:
        kv_block //= 2
    nb = w_slots // kv_block
    qg = q.reshape(t, n_kv, g, hd)
    q8, qs = quantize_kv(qg)
    scale = hd ** -0.5
    kb = k8.reshape(-1, nb, kv_block, n_kv, hd).swapaxes(0, 1)
    vb = v8.reshape(-1, nb, kv_block, n_kv, hd).swapaxes(0, 1)
    ksb = ks.reshape(-1, nb, kv_block, n_kv).swapaxes(0, 1)
    vsb = vs.reshape(-1, nb, kv_block, n_kv).swapaxes(0, 1)

    def cache_body(carry, inp):
        m, l, acc = carry
        kblk, vblk, ksblk, vsblk, i = inp
        kt, vt = kblk[seq_idx], vblk[seq_idx]
        kst = ksblk[seq_idx].transpose(0, 2, 1)[:, :, None, :]
        vst = vsblk[seq_idx].transpose(0, 2, 1)[:, :, None, :]
        slot = i * kv_block + jnp.arange(kv_block)
        stored = offsets[:, None] - 1 - (
            (offsets[:, None] - 1 - slot[None, :]) % w_slots)
        mask = (offsets[:, None] >= 1) & (stored >= 0) & (
            stored > positions[:, None] - window)
        s32 = jnp.einsum("tngd,tknd->tngk", q8, kt,
                         preferred_element_type=jnp.int32)
        sc = s32.astype(jnp.float32) * qs[..., None].astype(jnp.float32) \
            * kst.astype(jnp.float32) * scale
        sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
        mn = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - mn[..., None])
        corr = jnp.exp(m - mn)
        l = l * corr + p.sum(-1)
        pv = p * vst.astype(jnp.float32)
        p8, ps = quantize_kv(pv)
        o32 = jnp.einsum("tngk,tknd->tngd", p8, vt,
                         preferred_element_type=jnp.int32)
        acc = acc * corr[..., None] + \
            o32.astype(jnp.float32) * ps[..., None].astype(jnp.float32)
        return (mn, l, acc), None

    m0 = jnp.full((t, n_kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((t, n_kv, g), jnp.float32)
    a0 = jnp.zeros((t, n_kv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(cache_body, (m0, l0, a0),
                                  (kb, vb, ksb, vsb, jnp.arange(nb)))

    sc = jnp.einsum("tngd,und->tngu", qg, k_span).astype(jnp.float32) * scale
    mask = (seq_idx[None, :] == seq_idx[:, None]) \
        & (positions[None, :] <= positions[:, None]) \
        & (positions[None, :] > positions[:, None] - window) \
        & (jnp.arange(t)[None, :] < n_valid)
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    mn = jnp.maximum(m, sc.max(-1))
    p = jnp.exp(sc - mn[..., None])
    corr = jnp.exp(m - mn)
    l = l * corr + p.sum(-1)
    acc = acc * corr[..., None] + jnp.einsum(
        "tngu,und->tngd", p.astype(q.dtype), v_span).astype(jnp.float32)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype).reshape(t, hq * hd)


# ---------------------------------------------------------------------------
# Paged-native execution path: per-tile block-table gather, no [B, nb*bs]
# materialized view (docs/memory.md §Paged-native execution)
# ---------------------------------------------------------------------------
# The oracles above gather each row's whole table into a contiguous view
# before attending; these natives fetch exactly one kv tile per scan step
# straight through the table, mirroring what the paged Pallas kernels do
# per grid cell in VMEM.  The tile VALUES (and every downstream shape,
# mask, and reduction) are identical to the gather-then-attend path, so
# the natives are bit-exact to the oracles — and hence to the contiguous
# layout, since masked slots contribute exp(NEG_INF - m) == 0.0 exactly.


def _paged_tile(flat: jax.Array, tab_rows: jax.Array, offs: jax.Array,
                bs: int) -> jax.Array:
    """One kv tile through the block table: logical slot p of packed token
    t is ``flat[tab_rows[t, p // bs] * bs + p %% bs]``.  flat is the
    physical cache with its block axes flattened ([n_blocks*bs, ...]);
    offs [kb] are the tile's logical slots.  Returns [T, kb, ...]."""
    idx = tab_rows[:, offs // bs] * bs + (offs % bs)[None, :]
    return flat[idx]


def paged_span_attention_native(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    block_tables: jax.Array,
    positions: jax.Array,
    seq_idx: jax.Array,
    *,
    window: int = 0,
    kv_block: int = 512,
) -> jax.Array:
    """:func:`packed_span_attention` reading straight through the block
    table — no [B, nb*bs] gathered view is ever materialized; each scan
    step gathers one [T, kv_block] tile of K and V from the physical
    cache.  Bit-exact to :func:`paged_span_attention` (the gather-then-
    attend oracle).  q [T,Hq,hd]; caches [n_blocks,bs,Kv,hd];
    block_tables [B,nb]; positions/seq_idx [T]."""
    t, hq, hd = q.shape
    bs, n_kv = k_cache.shape[1], k_cache.shape[2]
    s = block_tables.shape[1] * bs
    g = hq // n_kv
    kv_block = min(kv_block, s)
    while s % kv_block:
        kv_block //= 2
    nb = s // kv_block
    qg = q.reshape(t, n_kv, g, hd)
    scale = hd ** -0.5
    kf = k_cache.reshape(-1, n_kv, hd)
    vf = v_cache.reshape(-1, n_kv, hd)
    tab = block_tables[seq_idx].astype(jnp.int32)       # [T, nb_t]
    span = jnp.arange(kv_block)

    def body(carry, i):
        m, l, acc = carry
        kpos = i * kv_block + span
        kt = _paged_tile(kf, tab, kpos, bs)             # [T, kb, Kv, hd]
        vt = _paged_tile(vf, tab, kpos, bs)
        sc = jnp.einsum("tngd,tknd->tngk", qg, kt).astype(jnp.float32) * scale
        mask = kpos[None, :] <= positions[:, None]
        if window:
            mask &= kpos[None, :] > positions[:, None] - window
        sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
        mn = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - mn[..., None])
        corr = jnp.exp(m - mn)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "tngk,tknd->tngd", p.astype(q.dtype), vt).astype(jnp.float32)
        return (mn, l, acc), None

    m0 = jnp.full((t, n_kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((t, n_kv, g), jnp.float32)
    a0 = jnp.zeros((t, n_kv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype).reshape(t, hq * hd)


def paged_span_attention_quant_native(
    q: jax.Array,
    k8: jax.Array, ks: jax.Array,
    v8: jax.Array, vs: jax.Array,
    block_tables: jax.Array,
    positions: jax.Array,
    seq_idx: jax.Array,
    *,
    kv_block: int = 512,
) -> jax.Array:
    """:func:`packed_span_attention_quant` through the block table (int8
    cache, per-tile gather, no materialized view).  Bit-exact to
    :func:`paged_span_attention_quant`."""
    t, hq, hd = q.shape
    bs, n_kv = k8.shape[1], k8.shape[2]
    s = block_tables.shape[1] * bs
    g = hq // n_kv
    kv_block = min(kv_block, s)
    while s % kv_block:
        kv_block //= 2
    nb = s // kv_block
    qg = q.reshape(t, n_kv, g, hd)
    q8, qs = quantize_kv(qg)
    scale = hd ** -0.5
    kf = k8.reshape(-1, n_kv, hd)
    vf = v8.reshape(-1, n_kv, hd)
    ksf = ks.reshape(-1, n_kv)
    vsf = vs.reshape(-1, n_kv)
    tab = block_tables[seq_idx].astype(jnp.int32)
    span = jnp.arange(kv_block)

    def body(carry, i):
        m, l, acc = carry
        kpos = i * kv_block + span
        kt = _paged_tile(kf, tab, kpos, bs)
        vt = _paged_tile(vf, tab, kpos, bs)
        kst = _paged_tile(ksf, tab, kpos, bs).transpose(0, 2, 1)[:, :, None, :]
        vst = _paged_tile(vsf, tab, kpos, bs).transpose(0, 2, 1)[:, :, None, :]
        s32 = jnp.einsum("tngd,tknd->tngk", q8, kt,
                         preferred_element_type=jnp.int32)
        sc = s32.astype(jnp.float32) * qs[..., None].astype(jnp.float32) \
            * kst.astype(jnp.float32) * scale
        mask = kpos[None, :] <= positions[:, None]
        sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
        mn = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - mn[..., None])
        corr = jnp.exp(m - mn)
        l = l * corr + p.sum(-1)
        pv = p * vst.astype(jnp.float32)
        p8, ps = quantize_kv(pv)
        o32 = jnp.einsum("tngk,tknd->tngd", p8, vt,
                         preferred_element_type=jnp.int32)
        acc = acc * corr[..., None] + \
            o32.astype(jnp.float32) * ps[..., None].astype(jnp.float32)
        return (mn, l, acc), None

    m0 = jnp.full((t, n_kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((t, n_kv, g), jnp.float32)
    a0 = jnp.zeros((t, n_kv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype).reshape(t, hq * hd)


def paged_span_attention_rolling_native(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    k_span: jax.Array,
    v_span: jax.Array,
    block_tables: jax.Array,
    positions: jax.Array,
    seq_idx: jax.Array,
    offsets: jax.Array,
    n_valid: jax.Array,
    *,
    window: int,
    kv_block: int = 512,
) -> jax.Array:
    """:func:`packed_span_attention_rolling` through the block table.
    The stored-position modulus is the table's logical width ``nb * bs``
    (== W once a row's table covers the full window); bit-exact to
    :func:`paged_span_attention_rolling`."""
    t, hq, hd = q.shape
    bs, n_kv = k_cache.shape[1], k_cache.shape[2]
    w_slots = block_tables.shape[1] * bs
    g = hq // n_kv
    kv_block = min(kv_block, w_slots)
    while w_slots % kv_block:
        kv_block //= 2
    nb = w_slots // kv_block
    qg = q.reshape(t, n_kv, g, hd)
    scale = hd ** -0.5
    kf = k_cache.reshape(-1, n_kv, hd)
    vf = v_cache.reshape(-1, n_kv, hd)
    tab = block_tables[seq_idx].astype(jnp.int32)
    span = jnp.arange(kv_block)

    def cache_body(carry, i):
        m, l, acc = carry
        slot = i * kv_block + span
        kt = _paged_tile(kf, tab, slot, bs)
        vt = _paged_tile(vf, tab, slot, bs)
        stored = offsets[:, None] - 1 - (
            (offsets[:, None] - 1 - slot[None, :]) % w_slots)
        mask = (offsets[:, None] >= 1) & (stored >= 0) & (
            stored > positions[:, None] - window)
        sc = jnp.einsum("tngd,tknd->tngk", qg, kt).astype(jnp.float32) * scale
        sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
        mn = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - mn[..., None])
        corr = jnp.exp(m - mn)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "tngk,tknd->tngd", p.astype(q.dtype), vt).astype(jnp.float32)
        return (mn, l, acc), None

    m0 = jnp.full((t, n_kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((t, n_kv, g), jnp.float32)
    a0 = jnp.zeros((t, n_kv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(cache_body, (m0, l0, a0), jnp.arange(nb))

    sc = jnp.einsum("tngd,und->tngu", qg, k_span).astype(jnp.float32) * scale
    upos, useq = positions, seq_idx
    mask = (useq[None, :] == seq_idx[:, None]) \
        & (upos[None, :] <= positions[:, None]) \
        & (upos[None, :] > positions[:, None] - window) \
        & (jnp.arange(t)[None, :] < n_valid)
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    mn = jnp.maximum(m, sc.max(-1))
    p = jnp.exp(sc - mn[..., None])
    corr = jnp.exp(m - mn)
    l = l * corr + p.sum(-1)
    acc = acc * corr[..., None] + jnp.einsum(
        "tngu,und->tngd", p.astype(q.dtype), v_span).astype(jnp.float32)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype).reshape(t, hq * hd)


def paged_span_attention_rolling_quant_native(
    q: jax.Array,
    k8: jax.Array, ks: jax.Array,
    v8: jax.Array, vs: jax.Array,
    k_span: jax.Array,
    v_span: jax.Array,
    block_tables: jax.Array,
    positions: jax.Array,
    seq_idx: jax.Array,
    offsets: jax.Array,
    n_valid: jax.Array,
    *,
    window: int,
    kv_block: int = 512,
) -> jax.Array:
    """:func:`packed_span_attention_rolling_quant` through the block table
    (int8 old-cache source + bf16 intra-span source, per-tile gather).
    Bit-exact to :func:`paged_span_attention_rolling_quant`."""
    t, hq, hd = q.shape
    bs, n_kv = k8.shape[1], k8.shape[2]
    w_slots = block_tables.shape[1] * bs
    g = hq // n_kv
    kv_block = min(kv_block, w_slots)
    while w_slots % kv_block:
        kv_block //= 2
    nb = w_slots // kv_block
    qg = q.reshape(t, n_kv, g, hd)
    q8, qs = quantize_kv(qg)
    scale = hd ** -0.5
    kf = k8.reshape(-1, n_kv, hd)
    vf = v8.reshape(-1, n_kv, hd)
    ksf = ks.reshape(-1, n_kv)
    vsf = vs.reshape(-1, n_kv)
    tab = block_tables[seq_idx].astype(jnp.int32)
    span = jnp.arange(kv_block)

    def cache_body(carry, i):
        m, l, acc = carry
        slot = i * kv_block + span
        kt = _paged_tile(kf, tab, slot, bs)
        vt = _paged_tile(vf, tab, slot, bs)
        kst = _paged_tile(ksf, tab, slot, bs).transpose(0, 2, 1)[:, :, None, :]
        vst = _paged_tile(vsf, tab, slot, bs).transpose(0, 2, 1)[:, :, None, :]
        stored = offsets[:, None] - 1 - (
            (offsets[:, None] - 1 - slot[None, :]) % w_slots)
        mask = (offsets[:, None] >= 1) & (stored >= 0) & (
            stored > positions[:, None] - window)
        s32 = jnp.einsum("tngd,tknd->tngk", q8, kt,
                         preferred_element_type=jnp.int32)
        sc = s32.astype(jnp.float32) * qs[..., None].astype(jnp.float32) \
            * kst.astype(jnp.float32) * scale
        sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
        mn = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - mn[..., None])
        corr = jnp.exp(m - mn)
        l = l * corr + p.sum(-1)
        pv = p * vst.astype(jnp.float32)
        p8, ps = quantize_kv(pv)
        o32 = jnp.einsum("tngk,tknd->tngd", p8, vt,
                         preferred_element_type=jnp.int32)
        acc = acc * corr[..., None] + \
            o32.astype(jnp.float32) * ps[..., None].astype(jnp.float32)
        return (mn, l, acc), None

    m0 = jnp.full((t, n_kv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((t, n_kv, g), jnp.float32)
    a0 = jnp.zeros((t, n_kv, g, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(cache_body, (m0, l0, a0), jnp.arange(nb))

    sc = jnp.einsum("tngd,und->tngu", qg, k_span).astype(jnp.float32) * scale
    mask = (seq_idx[None, :] == seq_idx[:, None]) \
        & (positions[None, :] <= positions[:, None]) \
        & (positions[None, :] > positions[:, None] - window) \
        & (jnp.arange(t)[None, :] < n_valid)
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    mn = jnp.maximum(m, sc.max(-1))
    p = jnp.exp(sc - mn[..., None])
    corr = jnp.exp(m - mn)
    l = l * corr + p.sum(-1)
    acc = acc * corr[..., None] + jnp.einsum(
        "tngu,und->tngd", p.astype(q.dtype), v_span).astype(jnp.float32)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype).reshape(t, hq * hd)


def use_pallas_paged() -> bool:
    """Backend choice for the paged execution path: the Pallas kernels
    (:mod:`repro.kernels.span_attention` paged twins) compile natively on
    TPU; everywhere else interpret-mode Pallas is orders of magnitude too
    slow for a hot path, so the bit-exact jnp natives above run instead.
    ``REPRO_PAGED_KERNELS=pallas|native`` overrides the autodetection."""
    mode = os.environ.get("REPRO_PAGED_KERNELS", "auto")
    if mode == "pallas":
        return True
    if mode in ("native", "jnp"):
        return False
    return jax.default_backend() == "tpu"


def paged_span_attention_exec(q, k_cache, v_cache, block_tables, positions,
                              seq_idx, *, window=0, kv_block=512):
    """Dispatch :func:`paged_span_attention` semantics to the execution
    backend (Pallas kernel on TPU, jnp native elsewhere)."""
    if use_pallas_paged():
        from repro.kernels import span_attention as ksa
        return ksa.paged_span_attention(
            q, k_cache, v_cache, positions, seq_idx, block_tables,
            window=window, interpret=False)
    return paged_span_attention_native(
        q, k_cache, v_cache, block_tables, positions, seq_idx,
        window=window, kv_block=kv_block)


def paged_span_attention_quant_exec(q, k8, ks, v8, vs, block_tables,
                                    positions, seq_idx, *, kv_block=512):
    if use_pallas_paged():
        from repro.kernels import span_attention as ksa
        return ksa.paged_span_attention_quant(
            q, k8, ks, v8, vs, positions, seq_idx, block_tables,
            interpret=False)
    return paged_span_attention_quant_native(
        q, k8, ks, v8, vs, block_tables, positions, seq_idx,
        kv_block=kv_block)


def paged_span_attention_rolling_exec(q, k_cache, v_cache, k_span, v_span,
                                      block_tables, positions, seq_idx,
                                      offsets, n_valid, *, window,
                                      kv_block=512):
    if use_pallas_paged():
        from repro.kernels import span_attention as ksa
        return ksa.paged_span_attention_rolling(
            q, k_cache, v_cache, k_span, v_span, positions, seq_idx,
            offsets, n_valid, block_tables, window=window, interpret=False)
    return paged_span_attention_rolling_native(
        q, k_cache, v_cache, k_span, v_span, block_tables, positions,
        seq_idx, offsets, n_valid, window=window, kv_block=kv_block)


def paged_span_attention_rolling_quant_exec(q, k8, ks, v8, vs, k_span,
                                            v_span, block_tables, positions,
                                            seq_idx, offsets, n_valid, *,
                                            window, kv_block=512):
    if use_pallas_paged():
        from repro.kernels import span_attention as ksa
        return ksa.paged_span_attention_rolling_quant(
            q, k8, ks, v8, vs, k_span, v_span, positions, seq_idx,
            offsets, n_valid, block_tables, window=window, interpret=False)
    return paged_span_attention_rolling_quant_native(
        q, k8, ks, v8, vs, k_span, v_span, block_tables, positions,
        seq_idx, offsets, n_valid, window=window, kv_block=kv_block)


def fill_rolling_cache(k: jax.Array, window: int) -> jax.Array:
    """Convert prefill K/V [B, S, kv, hd] into a rolling cache [B, W, kv, hd]
    under the slot = position %% W convention.

    Assumes an UNPADDED batch: every row's sequence fills all S positions.
    Ragged (right-padded) batches must use
    :func:`fill_rolling_cache_ragged`, else pad-tail K/V lands in slots
    that later decode steps treat as real window entries.
    """
    s = k.shape[1]
    if s < window:
        return jnp.pad(k, ((0, 0), (0, window - s), (0, 0), (0, 0)))
    tail = k[:, s - window:]
    shift = s % window
    return jnp.roll(tail, shift, axis=1) if shift else tail


def fill_rolling_cache_ragged(k: jax.Array, window: int,
                              lengths: jax.Array) -> jax.Array:
    """Ragged-batch variant of :func:`fill_rolling_cache`.

    ``k`` [B, S, kv, hd] is right-padded; ``lengths`` [B] gives each row's
    real token count.  Slot s of row i must hold the row's LAST position
    congruent to s mod W — ``L-1 - ((L-1 - s) mod W)`` (the same
    reconstruction the rolling span-attention kernels use) — and slots
    whose reconstructed position is negative (sequence shorter than the
    window) are zeroed, exactly matching what per-token decode/chunk
    scatters would have produced.  Gathering by position instead of
    rolling the tail keeps pad-tail K/V out of the cache.
    """
    b, s = k.shape[0], k.shape[1]
    slots = jnp.arange(window)
    last = lengths.astype(jnp.int32)[:, None] - 1            # [B, 1]
    stored = last - ((last - slots[None, :]) % window)       # [B, W]
    valid = stored >= 0
    idx = jnp.clip(stored, 0, s - 1)
    out = k[jnp.arange(b)[:, None], idx]                     # [B, W, kv, hd]
    return jnp.where(valid[..., None, None], out, 0).astype(k.dtype)
