"""Analytic FLOP / byte model per (arch x shape) — exact matmul accounting.

Used as MODEL_FLOPS in the roofline table (6*N*D train / 2*N_active*D
serve per the assignment) and as a cross-check of the corrected HLO
counts (repro.launch.hlo_analysis).  The detailed estimate enumerates the
actual matmuls the implementation performs, including attention scores,
MoE capacity slack, head padding and remat recompute — so the ratio
MODEL_FLOPS / HLO_FLOPs surfaces genuine waste, not accounting gaps.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, InputShape
from repro.models.registry import count_params


@dataclasses.dataclass
class FlopsReport:
    model_flops: float          # canonical 6ND / 2ND
    detailed_flops: float       # what the implementation actually computes
    attn_flops: float
    weight_bytes: float         # bytes of parameters read per step (global)
    cache_bytes: float          # KV/state cache traffic per step (global)


def _attn_score_flops(cfg: ArchConfig, b: int, sq: int, skv_avg: float,
                      heads: int) -> float:
    hd = cfg.resolved_head_dim
    return 2.0 * 2.0 * b * sq * skv_avg * heads * hd  # QK^T + AV


def model_flops(cfg: ArchConfig, shape: InputShape, *, tp: int = 16,
                remat: bool = True, triangular: bool = False) -> FlopsReport:
    b, s = shape.global_batch, shape.seq_len
    n_total = count_params(cfg)
    n_active = count_params(cfg, active_only=True)
    hd = cfg.resolved_head_dim
    import math

    hp = int(math.ceil(cfg.num_heads / tp) * tp)

    if shape.kind == "train":
        tokens = b * s
        canonical = 6.0 * n_active * tokens
        fwd_mult, total_mult = 1.0, (4.0 if remat else 3.0)
    elif shape.kind == "prefill":
        tokens = b * s
        canonical = 2.0 * n_active * tokens
        fwd_mult, total_mult = 1.0, 1.0
    else:  # decode: one token per sequence
        tokens = b
        canonical = 2.0 * n_active * tokens
        fwd_mult, total_mult = 1.0, 1.0

    # ---- attention context sizes -----------------------------------------
    if shape.kind == "decode":
        ctx_len = float(min(cfg.window, s) if cfg.window else s)
        sq = 1.0
    else:
        # dense-scan baseline computes all S kv positions then masks;
        # the triangular schedule only computes the causal half
        full_avg = (s + 1) / 2.0 if triangular else float(s)
        ctx_len = float(min(cfg.window + 512, s)) if cfg.window else full_avg
        sq = float(s)

    n_attn_layers = _attention_layer_count(cfg)
    attn = _attn_score_flops(cfg, b, sq, ctx_len, hp) * n_attn_layers
    if cfg.family == "vlm":
        n_cross = cfg.num_layers // (cfg.cross_attn_every + 1)
        from repro.models.transformer import cfg_n_patches

        attn += _attn_score_flops(cfg, b, sq, cfg_n_patches(cfg), hp) * n_cross
    if cfg.family == "audio":
        enc_s = s if shape.kind != "decode" else 0
        attn += _attn_score_flops(cfg, b, enc_s, enc_s, hp) * cfg.encoder_layers
        attn += _attn_score_flops(cfg, b, sq, s, hp) * cfg.num_layers  # cross
    if cfg.family == "ssm":
        # mLSTM chunkwise: intra-chunk [T,T] work ~ attention with ctx=chunk
        attn = _attn_score_flops(cfg, b, sq, 128.0 if sq > 1 else 1.0,
                                 cfg.num_heads) * cfg.num_layers

    # weight matmuls: 2 * tokens * active_params (embed gather excluded ~2%)
    weight_fwd = 2.0 * tokens * n_active
    detailed = (weight_fwd + attn) * total_mult
    if shape.kind == "train":
        canonical = canonical  # 6ND convention already includes bwd

    # ---- memory traffic ---------------------------------------------------
    pbytes = 2.0 * n_active if shape.kind == "decode" else 2.0 * n_total
    if shape.kind == "decode" and cfg.moe is not None:
        e, k = cfg.moe.num_experts, cfg.moe.top_k
        expert_total = (n_total - n_active) / max(1.0 - k / e, 1e-9)
        dense_part = n_total - expert_total
        touched = min(b * k, e)
        pbytes = 2.0 * (dense_part + expert_total * touched / e)
    cache_bytes = _cache_bytes(cfg, shape)
    return FlopsReport(canonical, detailed, attn, pbytes, cache_bytes)


def _attention_layer_count(cfg: ArchConfig) -> int:
    if cfg.family == "hybrid":
        per = len(cfg.block_pattern)
        n = (cfg.num_layers - len(cfg.tail_pattern)) // per
        return n * sum(1 for k in cfg.block_pattern if k == "attn") + sum(
            1 for k in cfg.tail_pattern if k == "attn")
    if cfg.family == "ssm":
        return 0
    if cfg.family == "vlm":
        return cfg.num_layers - cfg.num_layers // (cfg.cross_attn_every + 1)
    if cfg.family == "audio":
        return cfg.num_layers  # decoder self-attn; enc/cross added separately
    return cfg.num_layers


def _cache_bytes(cfg: ArchConfig, shape: InputShape) -> float:
    """Per-step global KV/state traffic (decode reads whole cache once)."""
    if shape.kind != "decode":
        return 0.0
    b, s = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    eff = min(cfg.window, s) if cfg.window else s
    kv = 2.0 * b * eff * cfg.num_kv_heads * hd * 2.0
    if cfg.family == "hybrid":
        n_attn = _attention_layer_count(cfg)
        n_rec = cfg.num_layers - n_attn
        state = b * cfg.d_model * 4.0 * n_rec
        return kv * n_attn + state
    if cfg.family == "ssm":
        h = cfg.num_heads
        return (b * h * hd * hd * 4.0) * cfg.num_layers * 2.0  # read+write C
    if cfg.family == "audio":
        cross = 2.0 * b * s * cfg.num_kv_heads * hd * 2.0
        return (kv + cross) * cfg.num_layers
    if cfg.family == "vlm":
        n_cross = cfg.num_layers // (cfg.cross_attn_every + 1)
        from repro.models.transformer import cfg_n_patches

        cross = 2.0 * b * cfg_n_patches(cfg) * cfg.num_kv_heads * hd * 2.0
        return kv * (cfg.num_layers - n_cross) + cross * n_cross
    return kv * cfg.num_layers
