"""Model assembly: build any assigned architecture into a uniform Model API.

Model = embed -> [Stack...] -> final norm -> lm head, with three entry
points (forward_train / prefill / decode) plus abstract input & cache
specs so the multi-pod dry-run can lower every (arch x shape) cell with
ShapeDtypeStructs only (no allocation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import stacked
from repro.models.common import (
    ParamSpec,
    ShardCtx,
    abstract_params,
    init_params,
    is_spec,
    logical_axes,
    param_count_tree,
    rmsnorm,
    rope_tables,
    sinusoid_positions,
)
from repro.models.stacked import Ctx, Stack, run_stack, stack_specs
from repro.models.transformer import cfg_n_patches, dense_layer_stack, vlm_stack
from repro.models.hybrid import hybrid_stack, hybrid_tail_stack
from repro.models.xlstm import xlstm_stack
from repro.models.whisper import decoder_stack, encoder_stack

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelOptions:
    kv_block: int = 512
    # ---- perf-hillclimb toggles (EXPERIMENTS.md §Perf; default = the
    # paper-faithful baseline the roofline table records) ----
    triangular: bool = False          # causal block-skipping attention
    fuse_shared_expert: bool = False  # B1: shared expert inside MoE psum
    seq_shard: bool = False           # B2: sequence-sharded residual stream
    kv_quant: bool = False            # C1: int8 KV cache with inline dequant
    remat: bool = True
    logits_fp32: bool = True


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    shard: ShardCtx
    options: ModelOptions
    specs: PyTree                      # ParamSpec tree (stacked)
    stacks: Dict[str, Stack]
    forward_train: Callable            # (params, batch) -> logits [B,S,V]
    prefill: Callable                  # (params, batch) -> (logits [B,V], cache)
    decode: Callable                   # (params, cache, batch) -> (logits [B,V], cache)
    # internal hooks (used by the PP stage splitter in core/engine.py)
    make_ctx: Callable = None
    embed_tokens: Callable = None
    lm_head: Callable = None

    # ---- abstract views -------------------------------------------------
    def abstract_params(self) -> PyTree:
        return abstract_params(self.specs)

    def param_axes(self) -> PyTree:
        return logical_axes(self.specs)

    def init(self, key) -> PyTree:
        return init_params(self.specs, key)

    def abstract_cache(self, batch: int, cache_len: int) -> PyTree:
        return {
            name: stacked.abstract_cache_tree(st, batch, cache_len)
            for name, st in self.stacks.items()
            if st.cache_spec is not None
        }

    def cache_axes(self) -> PyTree:
        return {
            name: stacked.cache_axes_tree(st)
            for name, st in self.stacks.items()
            if st.cache_spec is not None
        }

    def init_cache(self, batch: int, cache_len: int) -> PyTree:
        return stacked.zeros_cache(self.abstract_cache(batch, cache_len))

    def input_specs(self, shape: InputShape) -> Tuple[Dict, Dict]:
        return input_specs(self.cfg, shape)


# ---------------------------------------------------------------------------
# Family -> stacks
# ---------------------------------------------------------------------------

def _build_stacks(cfg: ArchConfig, tp: int, enc_len: int,
                  kv_quant: bool = False) -> Dict[str, Stack]:
    if cfg.family in ("dense",):
        return {"blocks": dense_layer_stack(cfg, tp, cfg.num_layers,
                                            kv_quant=kv_quant)}
    if cfg.family == "moe":
        per = cfg.moe.every
        return {"blocks": dense_layer_stack(cfg, tp, cfg.num_layers // per,
                                            moe_every=per,
                                            shared_expert=cfg.moe.shared,
                                            kv_quant=kv_quant)}
    if cfg.family == "vlm":
        return {"blocks": vlm_stack(cfg, tp)}
    if cfg.family == "hybrid":
        st = {"blocks": hybrid_stack(cfg, tp)}
        if cfg.tail_pattern:
            st["tail"] = hybrid_tail_stack(cfg, tp)
        return st
    if cfg.family == "ssm":
        return {"blocks": xlstm_stack(cfg, tp)}
    if cfg.family == "audio":
        return {"encoder": encoder_stack(cfg, tp),
                "decoder": decoder_stack(cfg, tp, enc_len)}
    raise ValueError(f"unknown family {cfg.family}")


def _lm_specs(cfg: ArchConfig, stacks: Dict[str, Stack]) -> PyTree:
    d, v = cfg.d_model, cfg.vocab_size
    specs: Dict[str, Any] = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), "small"),
        "lnf": ParamSpec((d,), ("embed",), "ones"),
        "head": ParamSpec((d, v), ("embed", "vocab")),
        "stacks": {name: stack_specs(st) for name, st in stacks.items()},
    }
    if cfg.family == "audio":
        specs["enc_lnf"] = ParamSpec((d,), ("embed",), "ones")
    return specs


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def build_model(cfg: ArchConfig, shard: Optional[ShardCtx] = None,
                options: ModelOptions = ModelOptions(),
                enc_len: int = 0) -> Model:
    shard = shard or ShardCtx.single()
    tp = shard.tp
    stacks = _build_stacks(cfg, tp, enc_len or 1500,
                           kv_quant=options.kv_quant and cfg.family in ("dense", "moe"))
    specs = _lm_specs(cfg, stacks)
    hd = cfg.resolved_head_dim
    uses_rope = cfg.family not in ("ssm", "audio")

    def make_ctx(mode, positions, patches=None, enc_out=None,
                 seq_idx=None, span_starts=None, n_valid=None, seq_lens=None,
                 block_tables=None):
        cos = sin = None
        if uses_rope:
            cos, sin = rope_tables(positions, hd, cfg.rope_theta)
        return Ctx(mode=mode, shard=shard, positions=positions,
                   rope_cos=cos, rope_sin=sin, patches=patches, enc_out=enc_out,
                   seq_idx=seq_idx, span_starts=span_starts, n_valid=n_valid,
                   seq_lens=seq_lens, block_tables=block_tables,
                   kv_block=options.kv_block, triangular=options.triangular,
                   fuse_shared_expert=options.fuse_shared_expert,
                   seq_shard=options.seq_shard, kv_quant=options.kv_quant)

    def embed_tokens(params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        return shard.constrain(x, ("batch",) + (None,) * (x.ndim - 1))

    def lm_head(params, x):
        x = rmsnorm(x, params["lnf"], cfg.norm_eps)
        logits = x @ params["head"]
        if options.logits_fp32:
            logits = logits.astype(jnp.float32)
        ax = ("batch", None, "vocab") if logits.ndim == 3 else ("batch", "vocab")
        return shard.constrain(logits, ax)

    def run_encoder(params, frames, mode):
        s = frames.shape[1]
        x = frames + sinusoid_positions(s, cfg.d_model)[None]
        ctx = Ctx(mode="train", shard=shard, positions=jnp.arange(s),
                  kv_block=options.kv_block)
        x, _ = run_stack(stacks["encoder"], params["stacks"]["encoder"], x, ctx,
                         remat=options.remat and mode == "train")
        return rmsnorm(x, params["enc_lnf"], cfg.norm_eps)

    # ---- train ----------------------------------------------------------
    def forward_train(params, batch):
        if cfg.family == "audio":
            enc_out = run_encoder(params, batch["frames"], "train")
            tokens = batch["tokens"]
            s = tokens.shape[1]
            x = embed_tokens(params, tokens) + sinusoid_positions(s, cfg.d_model)[None]
            ctx = make_ctx("train", jnp.arange(s), enc_out=enc_out)
            x, _ = run_stack(stacks["decoder"], params["stacks"]["decoder"], x, ctx,
                             remat=options.remat)
            return lm_head(params, x)

        tokens = batch["tokens"]
        s = tokens.shape[1]
        x = embed_tokens(params, tokens)
        ctx = make_ctx("train", jnp.arange(s), patches=batch.get("patches"))
        for name in _stack_order(stacks):
            x, _ = run_stack(stacks[name], params["stacks"][name], x, ctx,
                             remat=options.remat)
        return lm_head(params, x)

    # ---- prefill ----------------------------------------------------------
    def prefill(params, batch):
        if cfg.family == "audio":
            enc_out = run_encoder(params, batch["frames"], "prefill")
            tokens = batch["tokens"]
            s = tokens.shape[1]
            x = embed_tokens(params, tokens) + sinusoid_positions(s, cfg.d_model)[None]
            ctx = make_ctx("prefill", jnp.arange(s), enc_out=enc_out)
            x, cache = run_stack(stacks["decoder"], params["stacks"]["decoder"],
                                 x, ctx, remat=False)
            return lm_head(params, x[:, -1]), {"decoder": cache}

        tokens = batch["tokens"]
        s = tokens.shape[1]
        x = embed_tokens(params, tokens)
        ctx = make_ctx("prefill", jnp.arange(s), patches=batch.get("patches"))
        caches = {}
        for name in _stack_order(stacks):
            x, c = run_stack(stacks[name], params["stacks"][name], x, ctx, remat=False)
            if c is not None:
                caches[name] = c
        return lm_head(params, x[:, -1]), caches

    # ---- decode -----------------------------------------------------------
    def decode(params, cache, batch):
        token, positions = batch["token"], batch["positions"]
        x = embed_tokens(params, token)
        if cfg.family == "audio":
            x = x + _sinusoid_at(positions, cfg.d_model)
        ctx = make_ctx("decode", positions)
        new_cache = {}
        for name in _stack_order(stacks):
            if name == "encoder":
                continue
            x, c = run_stack(stacks[name], params["stacks"][name], x, ctx,
                             cache_stacked=cache[name], remat=False)
            new_cache[name] = c
        return lm_head(params, x), new_cache

    return Model(cfg=cfg, shard=shard, options=options, specs=specs,
                 stacks=stacks, forward_train=forward_train,
                 prefill=prefill, decode=decode,
                 make_ctx=make_ctx, embed_tokens=embed_tokens, lm_head=lm_head)


def _stack_order(stacks):
    order = [n for n in ("encoder", "blocks", "tail", "decoder") if n in stacks]
    assert len(order) == len(stacks)
    return order


def _sinusoid_at(positions: jax.Array, d_model: int) -> jax.Array:
    half = d_model // 2
    inv = jnp.exp(-jnp.log(10000.0) / max(half - 1, 1) * jnp.arange(half))
    ang = positions.astype(jnp.float32)[:, None] * inv[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# Abstract inputs per (arch x shape)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: InputShape) -> Tuple[Dict, Dict]:
    """Returns (ShapeDtypeStruct dict, logical-axes dict) for one cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    bf16 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.bfloat16)
    d = cfg.d_model

    if shape.kind == "train":
        sds = {"tokens": i32((b, s)), "labels": i32((b, s))}
        ax = {"tokens": ("batch", None), "labels": ("batch", None)}
        if cfg.family == "vlm":
            sds["patches"] = bf16((b, cfg_n_patches(cfg), d))
            ax["patches"] = ("batch", None, None)
        if cfg.family == "audio":
            sds["frames"] = bf16((b, s, d))
            ax["frames"] = ("batch", None, None)
        return sds, ax

    if shape.kind == "prefill":
        if cfg.family == "audio":
            sds = {"frames": bf16((b, s, d)), "tokens": i32((b, 8))}
            ax = {"frames": ("batch", None, None), "tokens": ("batch", None)}
            return sds, ax
        sds = {"tokens": i32((b, s))}
        ax = {"tokens": ("batch", None)}
        if cfg.family == "vlm":
            sds["patches"] = bf16((b, cfg_n_patches(cfg), d))
            ax["patches"] = ("batch", None, None)
        return sds, ax

    # decode: one new token against a cache of length s
    sds = {"token": i32((b,)), "positions": i32((b,))}
    ax = {"token": ("batch",), "positions": ("batch",)}
    return sds, ax


# ---------------------------------------------------------------------------
# Parameter counting (exact, from the spec tree)
# ---------------------------------------------------------------------------

def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    model = build_model(cfg, ShardCtx.single())
    leaves = jax.tree_util.tree_flatten_with_path(
        model.specs, is_leaf=is_spec
    )[0]
    total = 0
    for path, spec in leaves:
        n = 1
        for dim in spec.shape:
            n *= dim
        keys = [getattr(k, "key", str(k)) for k in path]
        if active_only and cfg.moe is not None and "moe" in keys and any(
            k in ("w1", "w2", "w3") for k in keys
        ):
            n = n * cfg.moe.top_k // cfg.moe.num_experts
        total += n
    return total
