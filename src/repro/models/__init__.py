from repro.models.registry import (  # noqa: F401
    Model,
    ModelOptions,
    build_model,
    count_params,
    input_specs,
)
from repro.models.common import ShardCtx  # noqa: F401
