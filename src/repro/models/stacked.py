"""Generic scan-over-layer-groups machinery shared by all families.

A model is: embed -> [Stack...] -> final norm -> lm head.  Each Stack is a
group of layers scanned ``n`` times (weights stacked on a leading "layers"
axis) so the compiled HLO stays small regardless of depth.  Heterogeneous
patterns (e.g. 4 self-attn + 1 cross-attn) live *inside* one group and are
unrolled; the homogeneous repetition is the scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, ShardCtx, is_spec

PyTree = Any


@dataclasses.dataclass
class Ctx:
    """Per-call context threaded through block apply functions."""

    mode: str                      # train | prefill | decode | chunk
    shard: ShardCtx
    positions: jax.Array           # prefill: [S]; decode: [B]; chunk: [T]
    rope_cos: Optional[jax.Array] = None
    rope_sin: Optional[jax.Array] = None
    # chunk mode (packed ragged layout): batch row of each packed token [T]
    # and each row's span-start offset [B] (rolling-cache window attention)
    seq_idx: Optional[jax.Array] = None
    span_starts: Optional[jax.Array] = None
    n_valid: Optional[jax.Array] = None    # scalar: valid packed tokens
    # prefill mode: per-row real token counts [B] for RAGGED (right-padded)
    # batches — windowed models need them to keep pad-tail K/V out of the
    # rolling cache (None = batch is unpadded)
    seq_lens: Optional[jax.Array] = None
    patches: Optional[jax.Array] = None    # vlm cross-attn memory [B, P, d]
    enc_out: Optional[jax.Array] = None    # whisper encoder output [B, Se, d]
    # paged KV layout (decode/chunk): per-row physical block ids [B, nb];
    # when set, cache leaves are block-major [n_blocks, block_size, ...] and
    # attention reads/writes through the table (docs/memory.md)
    block_tables: Optional[jax.Array] = None
    kv_block: int = 512
    triangular: bool = False
    fuse_shared_expert: bool = False
    seq_shard: bool = False
    kv_quant: bool = False


@dataclasses.dataclass
class Stack:
    """``apply(group_params, x, ctx, cache_group) -> (x, new_cache_group)``.

    In train mode ``apply`` must return cache ``None``; in prefill it
    returns the filled per-group cache; in decode it consumes and returns
    the updated per-group cache.
    """

    name: str
    n: int
    specs: PyTree
    apply: Callable
    cache_spec: Optional[Callable] = None  # (B, cache_len) -> per-group SDS tree
    cache_axes: Optional[Callable] = None  # () -> matching logical-axes tree


def stack_specs(stack: Stack, axis_name: str = "layers") -> PyTree:
    return jax.tree.map(
        lambda s: ParamSpec((stack.n,) + s.shape, (axis_name,) + s.axes,
                            s.init, s.dtype, s.fan_in),
        stack.specs,
        is_leaf=is_spec,
    )


def run_stack(
    stack: Stack,
    params_stacked: PyTree,
    x: jax.Array,
    ctx: Ctx,
    cache_stacked: Optional[PyTree] = None,
    *,
    remat: bool = True,
) -> tuple:
    """Scan a stack; returns (x, stacked caches or None)."""
    if stack.n == 1:
        gp = jax.tree.map(lambda p: p[0], params_stacked)
        cg = jax.tree.map(lambda c: c[0], cache_stacked) if cache_stacked is not None else None
        fn = lambda g, xc, c: stack.apply(g, xc, ctx, c)
        if remat and ctx.mode == "train":
            fn = jax.checkpoint(fn)
        x, new_c = fn(gp, x, cg)
        pack = (lambda t: jax.tree.map(lambda l: l[None], t)) if new_c is not None else (lambda t: None)
        return x, pack(new_c)

    if ctx.mode in ("decode", "chunk"):
        def body(xc, inp):
            gp, cg = inp
            xo, ncg = stack.apply(gp, xc, ctx, cg)
            return xo, ncg

        x, new_cache = jax.lax.scan(body, x, (params_stacked, cache_stacked))
        return x, new_cache

    def body(xc, gp):
        xo, cg = stack.apply(gp, xc, ctx, None)
        return xo, cg

    if remat and ctx.mode == "train":
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, params_stacked)
    return x, caches


def abstract_cache_tree(stack: Stack, batch: int, cache_len: int) -> Optional[PyTree]:
    if stack.cache_spec is None:
        return None
    per_group = stack.cache_spec(batch, cache_len)
    return jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct((stack.n,) + sd.shape, sd.dtype), per_group
    )


def cache_axes_tree(stack: Stack) -> Optional[PyTree]:
    if stack.cache_axes is None:
        return None
    per_group = stack.cache_axes()
    return jax.tree.map(
        lambda ax: ("layers",) + ax,
        per_group,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )


def zeros_cache(abstract: PyTree) -> PyTree:
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), abstract)
