"""Server-side admission control (docs/http.md §Admission).

Sits between the HTTP handler threads and the router: every completion
request takes a :class:`Ticket` here BEFORE touching any engine.  The
controller enforces

  * a queue cap — more than ``max_queue`` undispatched tickets rejects
    with :class:`QueueFull` (the server maps it to HTTP 429 +
    ``Retry-After``) without perturbing anything already running;
  * a dispatch window — at most ``max_active`` tickets are dispatched
    (= submitted to an engine) at once, so the engines' own waiting
    queues stay shallow and priority reordering happens HERE, where the
    full picture (tenant, priority, arrival) is visible;
  * dispatch order: priority desc, then per-tenant fair share (fewest
    in-flight requests first — a tenant flooding the queue cannot starve
    others at equal priority), then FIFO arrival.

The scheduler below repeats the priority-then-FIFO ordering for
whatever does reach an engine queue, and its preemption victim choice
is lowest-priority-then-latest-arrival — so priorities hold end to end:
admission, engine queueing, and block-pressure eviction.

Hybrid tier (docs/hybrid.md): ``tier="offline"`` tickets live OUTSIDE
the online accounting entirely.  They never occupy the online queue or
the ``max_active`` dispatch window (the engines' slack admission is the
real throttle for offline work — holding it behind the online window
would let batch traffic starve, or worse, let a deep batch backlog eat
the window and delay SLO traffic).  They are capped separately: at most
``max_queue_offline`` offline tickets may be live (submitted, not yet
released) at once; beyond that ``submit`` raises :class:`QueueFull`
with ``tier="offline"``, which the server maps to HTTP 503 + a
tier-carrying body (a batch client should back off much longer than an
interactive one — 429/Retry-After semantics are wrong for it).

The ``Retry-After`` hint on online 429s is estimated from the observed
drain rate: the controller timestamps recent ticket releases and
projects how long the current backlog needs to flush.  With no drain
history yet it falls back to the constructor's ``retry_after_s``.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional


class QueueFull(Exception):
    """Admission queue at capacity; carries the Retry-After hint (s) and
    the tier whose queue overflowed (the server's status code and body
    depend on it: online -> 429 + Retry-After, offline -> 503 + tier)."""

    def __init__(self, retry_after: int = 1, tier: str = "online"):
        super().__init__(
            f"{tier} admission queue full; retry after {retry_after}s")
        self.retry_after = retry_after
        self.tier = tier


class Closed(Exception):
    """Controller draining/shut down; server maps it to HTTP 503."""


@dataclasses.dataclass
class Ticket:
    """One request's admission handle (created by ``submit``)."""

    seq: int                      # arrival order (monotonic)
    priority: int
    tenant: str
    tier: str = "online"
    dispatched: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    cancelled: bool = False
    released: bool = False


# releases sampled for the drain-rate Retry-After estimate; small and
# recent beats large and stale (load shifts minute to minute)
_DRAIN_WINDOW = 32


class AdmissionController:
    def __init__(self, max_queue: int = 64,
                 max_active: Optional[int] = None,
                 retry_after_s: int = 1,
                 max_queue_offline: int = 256,
                 clock: Optional[Callable[[], float]] = None):
        self.max_queue = max_queue
        self.max_active = max_active           # None = unbounded dispatch
        self.retry_after_s = retry_after_s     # hint before drain history
        self.max_queue_offline = max_queue_offline
        self._clock = clock or time.monotonic  # injectable for tests
        self._lock = threading.Lock()
        self._pending: List[Ticket] = []       # undispatched, arrival order
        self._inflight: Dict[str, int] = {}    # tenant -> dispatched count
        self._active = 0
        self._offline_live = 0                 # offline submitted-not-released
        self._seq = 0
        self._closed = False
        self._releases: Deque[float] = deque(maxlen=_DRAIN_WINDOW)
        self._releases_offline: Deque[float] = deque(maxlen=_DRAIN_WINDOW)
        self.n_admitted = 0
        self.n_rejected = 0
        self.n_dispatched = 0
        self.n_admitted_offline = 0
        self.n_rejected_offline = 0

    # -- client side --------------------------------------------------------
    def submit(self, *, priority: int = 0, tenant: str = "anonymous",
               tier: str = "online") -> Ticket:
        """Take a ticket; raises :class:`QueueFull` when the tier's queue
        is at capacity, :class:`Closed` while draining.  Offline tickets
        dispatch immediately (their throttle is the engine's slack
        admission, not the online window) but are capped in total."""
        with self._lock:
            if self._closed:
                raise Closed()
            if tier == "offline":
                if self._offline_live >= self.max_queue_offline:
                    self.n_rejected_offline += 1
                    raise QueueFull(
                        self._drain_hint(self._releases_offline,
                                         self._offline_live),
                        tier="offline")
                t = Ticket(seq=self._seq, priority=priority,
                           tenant=tenant, tier="offline")
                self._seq += 1
                self._offline_live += 1
                self.n_admitted_offline += 1
                t.dispatched.set()
                return t
            if len(self._pending) >= self.max_queue:
                self.n_rejected += 1
                raise QueueFull(
                    self._drain_hint(self._releases, len(self._pending)))
            t = Ticket(seq=self._seq, priority=priority, tenant=tenant)
            self._seq += 1
            self._pending.append(t)
            self.n_admitted += 1
            self._pump()
        return t

    def wait(self, ticket: Ticket, timeout: Optional[float] = None) -> bool:
        """Block until the ticket is dispatched (True) or timeout."""
        return ticket.dispatched.wait(timeout)

    def release(self, ticket: Ticket):
        """Return the ticket's dispatch slot (request finished, aborted,
        or client gone); idempotent.  Cancels instead if undispatched."""
        with self._lock:
            if ticket.released:
                return
            ticket.released = True
            if ticket.tier == "offline":
                self._offline_live -= 1
                self._releases_offline.append(self._clock())
                return
            if not ticket.dispatched.is_set():
                ticket.cancelled = True
                try:
                    self._pending.remove(ticket)
                except ValueError:
                    pass
                return
            self._active -= 1
            self._releases.append(self._clock())
            n = self._inflight.get(ticket.tenant, 1) - 1
            if n:
                self._inflight[ticket.tenant] = n
            else:
                self._inflight.pop(ticket.tenant, None)
            self._pump()

    # -- dispatch ------------------------------------------------------------
    def _pump(self):
        """Dispatch pending tickets while the window has room (caller
        holds the lock).  Order: priority desc, least tenant in-flight,
        FIFO arrival — see the module docstring."""
        while self._pending and (self.max_active is None
                                 or self._active < self.max_active):
            best = min(self._pending,
                       key=lambda t: (-t.priority,
                                      self._inflight.get(t.tenant, 0),
                                      t.seq))
            self._pending.remove(best)
            self._active += 1
            self._inflight[best.tenant] = \
                self._inflight.get(best.tenant, 0) + 1
            self.n_dispatched += 1
            best.dispatched.set()

    def _drain_hint(self, releases: Deque[float], depth: int) -> int:
        """Retry-After (seconds) from the observed release rate: project
        how long ``depth + 1`` queued requests take to drain.  Falls back
        to ``retry_after_s`` before two releases exist (no rate yet) and
        clamps to [1, 60] — a hint, not a promise (caller holds the
        lock; reads only controller state)."""
        rel = list(releases)
        if len(rel) < 2:
            return max(1, int(self.retry_after_s))
        span = rel[-1] - rel[0]
        if span <= 0.0:
            return 1
        rate = (len(rel) - 1) / span           # releases / second
        return max(1, min(60, math.ceil((depth + 1) / rate)))

    # -- lifecycle / introspection -------------------------------------------
    def close(self):
        """Stop admitting; pending undispatched tickets are cancelled
        (their waiters see ``cancelled`` after a spurious dispatch)."""
        with self._lock:
            self._closed = True
            for t in self._pending:
                t.cancelled = True
                t.dispatched.set()     # wake waiters; they check cancelled
            self._pending.clear()

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {
                "admission_pending": len(self._pending),
                "admission_active": self._active,
                "admission_admitted_total": self.n_admitted,
                "admission_rejected_total": self.n_rejected,
                "admission_dispatched_total": self.n_dispatched,
                "admission_offline_live": self._offline_live,
                "admission_offline_admitted_total": self.n_admitted_offline,
                "admission_offline_rejected_total": self.n_rejected_offline,
            }
